"""Layer-2 model: pre-LN transformer with pluggable attention variant.

One model family serves every experiment:

* ``task = "lm"``  — causal language model (MQAR, WikiText-style corpus):
  logits at every position over ``vocab``.
* ``task = "cls"`` — sequence classifier (LRA-style tasks): masked mean-pool
  over positions then a linear head over ``n_classes``.

The config is a plain dict so it can be serialized verbatim into the AOT
manifest. Mandatory keys: vocab, seq_len, d_model, n_layers, n_heads, attn,
task. Variant-specific keys are documented in attention.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import attention_apply, attention_init

__all__ = ["model_init", "model_apply", "param_count"]


def _layernorm(p, x, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


def _ln_init(d):
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def _mlp_init(key, d, mult=4):
    k1, k2 = jax.random.split(key)
    h = mult * d
    s1 = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s2 = 1.0 / jnp.sqrt(jnp.asarray(h, jnp.float32))
    return {
        "w1": jax.random.normal(k1, (d, h), jnp.float32) * s1,
        "b1": jnp.zeros((h,), jnp.float32),
        "w2": jax.random.normal(k2, (h, d), jnp.float32) * s2,
        "b2": jnp.zeros((d,), jnp.float32),
    }


def _mlp_apply(p, x):
    return jax.nn.gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def model_init(key, cfg):
    """Initialize the full parameter pytree for ``cfg``."""
    d = cfg["d_model"]
    vocab = cfg["vocab"]
    n = cfg["seq_len"]
    keys = jax.random.split(key, 4 + cfg["n_layers"])

    params = {
        "embed": jax.random.normal(keys[0], (vocab, d), jnp.float32) * 0.02,
        "pos": jax.random.normal(keys[1], (n, d), jnp.float32) * 0.02,
        "blocks": [],
        "ln_f": _ln_init(d),
    }
    for i in range(cfg["n_layers"]):
        bk = jax.random.split(keys[4 + i], 2)
        params["blocks"].append(
            {
                "ln1": _ln_init(d),
                "attn": attention_init(bk[0], cfg),
                "ln2": _ln_init(d),
                "mlp": _mlp_init(bk[1], d, cfg.get("mlp_mult", 4)),
            }
        )
    if cfg["task"] == "lm":
        params["head"] = jax.random.normal(keys[2], (d, vocab), jnp.float32) * 0.02
    else:
        params["head"] = jax.random.normal(keys[2], (d, cfg["n_classes"]), jnp.float32) * 0.02
        params["head_b"] = jnp.zeros((cfg["n_classes"],), jnp.float32)
    return params


def model_apply(params, tokens, cfg):
    """tokens (B, N) int32 -> logits.

    lm:  (B, N, vocab) — next-token logits at every position.
    cls: (B, n_classes) — masked-mean-pooled classifier logits (token 0 is
         treated as padding and excluded from the pool).
    """
    b, n = tokens.shape
    x = params["embed"][tokens] + params["pos"][None, :n, :]
    for blk in params["blocks"]:
        x = x + attention_apply(blk["attn"], _layernorm(blk["ln1"], x), cfg)
        x = x + _mlp_apply(blk["mlp"], _layernorm(blk["ln2"], x))
    x = _layernorm(params["ln_f"], x)
    if cfg["task"] == "lm":
        return x @ params["head"]
    pad_mask = (tokens != 0).astype(jnp.float32)[..., None]  # (B, N, 1)
    denom = jnp.maximum(jnp.sum(pad_mask, axis=1), 1.0)
    pooled = jnp.sum(x * pad_mask, axis=1) / denom
    return pooled @ params["head"] + params["head_b"]


def param_count(params) -> int:
    leaves = jax.tree_util.tree_leaves(params)
    return int(sum(int(p.size) for p in leaves))
