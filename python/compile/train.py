"""Layer-2 training graphs: loss, Adam, train/eval steps.

Everything here is a pure function of arrays so that ``train_step`` lowers to
a single HLO module the Rust trainer can drive (Python never runs at
training time). The optimizer state is an (m, v) pytree mirroring params
plus a scalar step counter carried by the Rust side.

Loss conventions
----------------
* lm:  per-position weighted softmax cross-entropy. ``w`` (B, N) float32
  selects which positions count (all 1s for language modeling; answer
  positions only for MQAR, matching the Zoology evaluation protocol).
* cls: per-sequence cross-entropy; ``w`` is (B,) (usually all 1s).

``train_step`` returns (loss, new_params, new_m, new_v); ``eval_step``
returns (loss_sum, correct, weight_sum) so accuracy aggregates exactly
across batches of any size.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .model import model_apply

__all__ = ["loss_fn", "make_train_step", "make_eval_step", "adam_update"]


def _xent(logits, targets, w):
    """Weighted mean cross-entropy. logits (..., C), targets (...,) int32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    denom = jnp.maximum(jnp.sum(w), 1.0)
    return jnp.sum(nll * w) / denom


def loss_fn(params, x, y, w, cfg):
    logits = model_apply(params, x, cfg)
    return _xent(logits, y, w)


def adam_update(params, grads, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8,
                warmup=50):
    """Adam with linear warmup. step is int32 (1-based at first update)."""
    stepf = step.astype(jnp.float32)
    lr_t = lr * jnp.minimum(1.0, stepf / float(max(warmup, 1)))
    bc1 = 1.0 - b1 ** stepf
    bc2 = 1.0 - b2 ** stepf

    def upd(p, g, m_, v_):
        m2 = b1 * m_ + (1.0 - b1) * g
        v2 = b2 * v_ + (1.0 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        return p - lr_t * mhat / (jnp.sqrt(vhat) + eps), m2, v2

    flat_p, tree = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(m)
    flat_v = jax.tree_util.tree_leaves(v)
    out = [upd(p, g, m_, v_) for p, g, m_, v_ in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tree, [o[2] for o in out])
    return new_p, new_m, new_v


def make_train_step(cfg, lr, grad_clip=1.0, warmup=50):
    """Returns train_step(params, m, v, step, x, y, w) -> (loss, p', m', v')."""

    def train_step(params, m, v, step, x, y, w):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y, w, cfg)
        # Global-norm gradient clipping.
        leaves = jax.tree_util.tree_leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves) + 1e-12)
        scale = jnp.minimum(1.0, grad_clip / gnorm)
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        new_p, new_m, new_v = adam_update(params, grads, m, v, step, lr,
                                          warmup=warmup)
        return loss, new_p, new_m, new_v

    return train_step


def make_eval_step(cfg):
    """Returns eval_step(params, x, y, w) -> (loss_sum, correct, weight_sum)."""

    def eval_step(params, x, y, w):
        logits = model_apply(params, x, cfg)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * w
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        correct = jnp.sum((pred == y).astype(jnp.float32) * w)
        return jnp.sum(nll), correct, jnp.sum(w)

    return eval_step
