"""AOT pipeline: lower every preset entry point to HLO text + manifest.

The interchange format is HLO *text*, not a serialized HloModuleProto —
jax >= 0.5 emits protos with 64-bit instruction ids that the runtime's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

For each preset (presets.py) we lower up to four entry points over *flat*
argument lists (PJRT executables take positional buffers, not pytrees):

  init(seed:int32[])                      -> (param_0, ..., param_P)
  train(step:int32[], x, y, w, params..., m..., v...)
                                          -> (loss, params'..., m'..., v'...)
  eval(x, y, w, params...)                -> (loss_sum, correct, weight_sum)
  forward(x, params...)                   -> (logits,)

``artifacts/manifest.json`` records, per entry: the HLO file, the exact
input/output names+shapes+dtypes in positional order, the parameter-tree
flattening (jax tree paths), and the preset config — the Rust runtime never
guesses a shape.

Usage:  python -m compile.aot --out-dir ../artifacts [--groups core,fig2a]
                              [--filter REGEX] [--list]
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import presets as presets_mod
from .model import model_init, param_count
from .train import make_eval_step, make_train_step


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _path_name(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return ".".join(out)


def _spec_of(x) -> dict:
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


def _flatten_spec(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = [_path_name(p) for p, _ in flat]
    leaves = [l for _, l in flat]
    return names, leaves, jax.tree_util.tree_structure(tree)


def build_preset(name: str, spec: dict, out_dir: str) -> dict:
    cfg = spec["cfg"]
    batch = spec["batch"]
    n = cfg["seq_len"]
    lr = spec["lr"]
    entries = spec["entries"]

    params0 = jax.eval_shape(lambda: model_init(jax.random.PRNGKey(0), cfg))
    pnames, pleaves, ptree = _flatten_spec(params0)
    nparams = len(pleaves)

    x_spec = jax.ShapeDtypeStruct((batch, n), jnp.int32)
    if cfg["task"] == "lm":
        y_spec = jax.ShapeDtypeStruct((batch, n), jnp.int32)
        w_spec = jax.ShapeDtypeStruct((batch, n), jnp.float32)
    else:
        y_spec = jax.ShapeDtypeStruct((batch,), jnp.int32)
        w_spec = jax.ShapeDtypeStruct((batch,), jnp.float32)
    i32 = jax.ShapeDtypeStruct((), jnp.int32)

    def unflatten(flat):
        return jax.tree_util.tree_unflatten(ptree, list(flat))

    manifest_entry = {
        "config": cfg,
        "batch": batch,
        "lr": lr,
        "param_count": int(sum(
            int(jnp.prod(jnp.asarray(l.shape))) if l.shape else 1 for l in pleaves
        )),
        "params": [
            {"name": nm, **_spec_of(l)} for nm, l in zip(pnames, pleaves)
        ],
        "entries": {},
    }

    def emit(entry_name, fn, arg_specs, arg_names):
        t0 = time.time()
        # keep_unused=True: jax otherwise prunes arguments that do not reach
        # the outputs (e.g. the Cauchy theta in the neg_euclid operator,
        # whose gradient is identically zero) and the lowered HLO would then
        # expect fewer buffers than the manifest promises the Rust side.
        lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.{entry_name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *arg_specs)
        outs = jax.tree_util.tree_leaves(out_shapes)
        manifest_entry["entries"][entry_name] = {
            "file": fname,
            "inputs": [
                {"name": nm, **_spec_of(s)} for nm, s in zip(arg_names, arg_specs)
            ],
            "outputs": [_spec_of(o) for o in outs],
        }
        print(f"  {name}.{entry_name}: {len(text) / 1e6:.2f} MB "
              f"({time.time() - t0:.1f}s)", flush=True)

    if "init" in entries:
        def flat_init(seed):
            p = model_init(jax.random.PRNGKey(seed), cfg)
            return tuple(jax.tree_util.tree_leaves(p))

        emit("init", flat_init, [i32], ["seed"])

    if "train" in entries:
        train_step = make_train_step(cfg, lr)

        def flat_train(step, x, y, w, *flat):
            p = unflatten(flat[:nparams])
            m = unflatten(flat[nparams:2 * nparams])
            v = unflatten(flat[2 * nparams:3 * nparams])
            loss, p2, m2, v2 = train_step(p, m, v, step, x, y, w)
            return (
                loss,
                *jax.tree_util.tree_leaves(p2),
                *jax.tree_util.tree_leaves(m2),
                *jax.tree_util.tree_leaves(v2),
            )

        arg_specs = [i32, x_spec, y_spec, w_spec] + pleaves * 3
        arg_names = (
            ["step", "x", "y", "w"]
            + [f"p.{n_}" for n_ in pnames]
            + [f"m.{n_}" for n_ in pnames]
            + [f"v.{n_}" for n_ in pnames]
        )
        emit("train", flat_train, arg_specs, arg_names)

    if "eval" in entries:
        eval_step = make_eval_step(cfg)

        def flat_eval(x, y, w, *flat):
            return eval_step(unflatten(flat), x, y, w)

        emit("eval", flat_eval, [x_spec, y_spec, w_spec] + pleaves,
             ["x", "y", "w"] + [f"p.{n_}" for n_ in pnames])

    if "forward" in entries:
        from .model import model_apply

        def flat_forward(x, *flat):
            return (model_apply(unflatten(flat), x, cfg),)

        emit("forward", flat_forward, [x_spec] + pleaves,
             ["x"] + [f"p.{n_}" for n_ in pnames])

    return manifest_entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--groups", default="core",
                    help="comma-separated preset groups, or 'all'")
    ap.add_argument("--filter", default=None, help="regex over preset names")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    groups = None if args.groups == "all" else args.groups.split(",")
    names = presets_mod.preset_names(groups)
    if args.filter:
        rx = re.compile(args.filter)
        names = [n for n in names if rx.search(n)]
    if args.list:
        for n in names:
            print(n)
        return

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)

    t0 = time.time()
    for i, name in enumerate(names):
        print(f"[{i + 1}/{len(names)}] {name}", flush=True)
        manifest[name] = build_preset(name, presets_mod.PRESETS[name], args.out_dir)
        # Write incrementally so a crash keeps earlier work usable.
        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"done: {len(names)} presets in {time.time() - t0:.0f}s "
          f"-> {manifest_path}", flush=True)


if __name__ == "__main__":
    main()
