"""Parallel causal top-k candidate search in Z-order space (paper §3.2.2).

Algorithm 1 of the paper, fully vectorized so it lowers to one HLO module:

1. Morton-encode keys and queries on a shared grid (zorder.py).
2. ``argsort`` the key codes once per (batch, head) row — the parallel sort
   that replaces per-query kNN structures.
3. For every query, ``searchsorted`` gives its insertion position among the
   sorted key codes; a window of ``window`` candidates around that position
   is gathered.
4. Chunked causal masking: a query at position i in chunk m = i // chunk may
   only use keys with original position < m*chunk (the paper's rule), so
   whole chunks are either visible or not and the search stays parallel.
5. Of the valid window candidates, the k with smallest |z_key - z_query| are
   kept (the paper's "window centered on the insertion position", made
   robust to masked-out entries by over-fetching ``window >= k``).

Outputs are gather indices + validity mask; the exact Cauchy scores are then
computed by the Layer-1 kernel on the gathered (q, k) pairs, so quantization
error in the Morton codes only ever affects *which* tokens are candidates,
never the attention weights themselves.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import zorder

__all__ = ["topk_candidates", "history_mean"]


def _row_topk(qz_row, kz_row, k, chunk, window):
    """Candidate search for one (batch*head) row.

    qz_row, kz_row: (N,) uint32 Morton codes. Returns (idx (N,k), valid (N,k)).
    """
    n = qz_row.shape[0]
    order = jnp.argsort(kz_row)  # (N,) original position of each sorted slot
    kz_sorted = kz_row[order]

    ins = jnp.searchsorted(kz_sorted, qz_row)  # (N,)
    offs = jnp.arange(window) - window // 2
    cand_slot = jnp.clip(ins[:, None] + offs[None, :], 0, n - 1)  # (N, W)
    cand_pos = order[cand_slot]  # (N, W) original key positions
    cand_code = kz_sorted[cand_slot]  # (N, W)

    # Chunked causal mask: query i sees keys with position < (i//chunk)*chunk.
    limit = (jnp.arange(n) // chunk) * chunk  # (N,)
    valid = cand_pos < limit[:, None]  # (N, W)

    # Window clipping at the array ends duplicates candidates; keep only the
    # first occurrence of each slot so duplicates never double-count.
    first = jnp.concatenate(
        [jnp.ones((n, 1), bool), cand_slot[:, 1:] != cand_slot[:, :-1]], axis=1
    )
    valid = valid & first

    # Rank candidates by |z - q| (proxy distance along the curve). Codes use
    # at most 31 bits so the int32 subtraction cannot overflow; float32
    # ranking precision (24-bit mantissa) is ample for candidate selection.
    zdiff = cand_code.astype(jnp.int32) - qz_row[:, None].astype(jnp.int32)
    zdist = jnp.abs(zdiff).astype(jnp.float32)
    ranked = jnp.where(valid, zdist, jnp.inf)
    # k smallest distances via argsort (NOT jax.lax.top_k: that lowers to a
    # `topk(..., largest=true)` HLO op the runtime's XLA 0.5.1 text parser
    # cannot read; `sort` round-trips fine).
    sel = jnp.argsort(ranked, axis=1)[:, :k]
    idx = jnp.take_along_axis(cand_pos, sel, axis=1)  # (N, k)
    keep = jnp.take_along_axis(valid, sel, axis=1)
    # Invalid slots point at position 0 (harmless: they are masked).
    return jnp.where(keep, idx, 0), keep


def topk_candidates(q, k_, k: int, chunk: int, window: int | None = None,
                    bits: int | None = None, fixed_range: float | None = 4.0):
    """Top-k causal candidates for every query, batched over leading axes.

    q, k_: (..., N, d) low-dimensional projections. Returns
    idx (..., N, k) int32 and valid (..., N, k) float32.
    """
    if window is None:
        window = 2 * k
    qz, kz = zorder.encode(q, k_, bits=bits, fixed_range=fixed_range)  # (..., N)
    lead = qz.shape[:-1]
    n = qz.shape[-1]
    qz2 = qz.reshape((-1, n))
    kz2 = kz.reshape((-1, n))
    idx, valid = jax.vmap(lambda a, b: _row_topk(a, b, k, chunk, window))(qz2, kz2)
    return (
        idx.reshape(lead + (n, k)).astype(jnp.int32),
        valid.reshape(lead + (n, k)).astype(jnp.float32),
    )


def history_mean(x):
    """Causal inclusive running mean over the token axis (paper §3.4).

    x: (..., N, d). Position i gets mean(x[..., :i+1, :]) — the smoothing
    token appended to the top-k set so every query attends to something and
    gradients flow through low-probability tokens.
    """
    n = x.shape[-2]
    csum = jnp.cumsum(x, axis=-2)
    denom = jnp.arange(1, n + 1, dtype=x.dtype).reshape((n, 1))
    return csum / denom
