"""Z-order (Morton) curve projection for low-dimensional keys/queries.

Layer-2 (build-time JAX). The paper maps d_K-dimensional keys and queries to
*one* dimension by quantizing each coordinate to ``bits`` bits and
interleaving the bits (Eq. 4). Nearby points in R^{d_K} receive nearby Morton
codes, so a single parallel sort + binary search replaces a kNN structure.

Everything here is pure ``jnp`` and lowers to plain HLO (shifts, ors,
comparisons), so it fuses into the same AOT artifact as the Pallas kernel.

Key design points
-----------------
* Keys and queries MUST share one quantization grid — the insertion position
  of a query among sorted keys is only meaningful if both were digitized with
  the same (lo, scale). ``shared_grid`` computes that grid from the union.
* ``bits * d <= 31`` so the code fits a (signed-safe) uint32 lane; for the
  paper's d_K = 3 we use 10 bits/coordinate (30-bit codes).
* Quantization bounds come from data min/max per (batch, head) — the grid is
  causal-safe because it only affects *which* tokens are candidates, never
  the attention values themselves; exact scores are recomputed in the kernel.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["bits_for_dim", "shared_grid", "quantize", "interleave", "encode"]


def bits_for_dim(d: int, max_bits: int = 10) -> int:
    """Bits per coordinate so the interleaved code fits in 31 bits."""
    if d < 1:
        raise ValueError(f"dimension must be >= 1, got {d}")
    return max(1, min(max_bits, 31 // d))


def shared_grid(q: jnp.ndarray, k: jnp.ndarray, eps: float = 1e-6):
    """Common (lo, inv_step) over the union of queries and keys.

    q, k: (..., N, d). Reduction is over the token axis only, so each
    batch/head gets its own grid (matches the paper's per-head projection).
    Returns lo, inv_step with shape (..., 1, d).
    """
    both_lo = jnp.minimum(q.min(axis=-2), k.min(axis=-2))
    both_hi = jnp.maximum(q.max(axis=-2), k.max(axis=-2))
    lo = both_lo[..., None, :]
    span = jnp.maximum(both_hi[..., None, :] - lo, eps)
    return lo, 1.0 / span


def quantize(x: jnp.ndarray, lo: jnp.ndarray, inv_step: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Digitize float coordinates to ``bits``-bit unsigned integers."""
    levels = (1 << bits) - 1
    u = (x - lo) * inv_step  # in [0, 1] for in-grid points
    q = jnp.clip(jnp.floor(u * levels + 0.5), 0, levels)
    return q.astype(jnp.uint32)


def interleave(q: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Interleave bits of the last axis: (..., d) uint32 -> (...,) uint32.

    Bit b of coordinate j lands at output position b*d + j, i.e. the paper's
    Eq. 4 with coordinate 0 providing the least-significant of each group.
    The double loop is static (bits*d <= 31 iterations) and lowers to a flat
    chain of shift/and/or HLO ops.
    """
    d = q.shape[-1]
    if bits * d > 31:
        raise ValueError(f"bits*d = {bits * d} exceeds 31-bit code budget")
    z = jnp.zeros(q.shape[:-1], jnp.uint32)
    for b in range(bits):
        for j in range(d):
            bit = (q[..., j] >> jnp.uint32(b)) & jnp.uint32(1)
            z = z | (bit << jnp.uint32(b * d + j))
    return z


def encode(q: jnp.ndarray, k: jnp.ndarray, bits: int | None = None,
           fixed_range: float | None = None):
    """Morton-encode queries and keys on a shared grid.

    q, k: (..., N, d) float arrays. Returns (qz, kz) uint32 of shape (..., N).

    With ``fixed_range = B`` the grid is the static box [-B, B]^d (points
    outside clip to the boundary bins). This keeps the digitization
    independent of the data — in causal attention a data-derived grid would
    let future tokens shift *candidate selection* for past queries. (The
    window search still shares one sorted array across the sequence, the
    same selection-level approximation as the paper's Algorithm 1; exact
    attention scores are always computed from past tokens only.)
    """
    d = q.shape[-1]
    if bits is None:
        bits = bits_for_dim(d)
    if fixed_range is not None:
        lo = jnp.full((d,), -fixed_range, q.dtype)
        inv_step = jnp.full((d,), 1.0 / (2.0 * fixed_range), q.dtype)
    else:
        lo, inv_step = shared_grid(q, k)
    qq = quantize(q, lo, inv_step, bits)
    qk = quantize(k, lo, inv_step, bits)
    return interleave(qq, bits), interleave(qk, bits)
