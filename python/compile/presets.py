"""Preset registry: every AOT artifact the Rust side can ask for.

A preset bundles a model config, batch geometry and learning rate, and
declares which entry points get lowered (init / train_step / eval_step /
forward). Presets are grouped so ``make artifacts`` builds only the core set
(examples, tests, serving) while ``make artifacts-full`` additionally builds
the full experiment sweeps behind Figures 2–3 and Tables 1–6.

Naming convention: ``<experiment>_<variant>_<axis...>`` — the Rust experiment
harness reconstructs sweep axes from these names via the manifest.
"""

from __future__ import annotations

__all__ = ["PRESETS", "GROUPS", "preset_names"]


def _mqar_cfg(attn, d_model, **kw):
    cfg = {
        "vocab": 64,
        "seq_len": 64,
        "d_model": d_model,
        "n_layers": 2,
        "n_heads": max(1, d_model // 32),
        "attn": attn,
        "task": "lm",
    }
    cfg.update(kw)
    return cfg


def _lra_cfg(attn, task_name, seq_len, n_classes, d_model=64, **kw):
    cfg = {
        "vocab": 256,
        "seq_len": seq_len,
        "d_model": d_model,
        "n_layers": 2,
        "n_heads": 2,
        "attn": attn,
        "task": "cls",
        "n_classes": n_classes,
        "lra_task": task_name,
    }
    cfg.update(kw)
    return cfg


def _lm_cfg(attn, d_model=128, n_layers=4, seq_len=256, **kw):
    cfg = {
        "vocab": 256,
        "seq_len": seq_len,
        "d_model": d_model,
        "n_layers": n_layers,
        "n_heads": 4,
        "attn": attn,
        "task": "lm",
    }
    cfg.update(kw)
    return cfg


_ZETA = {"d_k": 3, "k": 16, "chunk": 8, "two_layer_qk": True}

PRESETS: dict[str, dict] = {}
GROUPS: dict[str, list[str]] = {}


def _add(group, name, cfg, batch, lr=3e-3, entries=("init", "train", "eval")):
    PRESETS[name] = {"cfg": cfg, "batch": batch, "lr": lr, "entries": list(entries)}
    GROUPS.setdefault(group, []).append(name)


# --------------------------------------------------------------------------
# core — examples, tests, serving (built by `make artifacts`)
# --------------------------------------------------------------------------
_add("core", "quickstart_zeta", _mqar_cfg("zeta", 64, **_ZETA), batch=4,
     entries=("init", "forward"))
_add("core", "mqar_zeta_d64", _mqar_cfg("zeta", 64, **_ZETA), batch=32,
     entries=("init", "train", "eval", "forward"))
_add("core", "mqar_vanilla_d64", _mqar_cfg("vanilla", 64), batch=32)
_add("core", "serve_cls", _lra_cfg("zeta", "text", 256, 2, **_ZETA), batch=8,
     entries=("init", "train", "eval", "forward"))
_add("core", "lm_zeta", _lm_cfg("zeta", d_model=128, n_layers=4, **_ZETA),
     batch=8, lr=1e-3, entries=("init", "train", "eval", "forward"))

# --------------------------------------------------------------------------
# fig2a — MQAR accuracy vs model dim for 4 architectures
# --------------------------------------------------------------------------
for arch in ("vanilla", "performer", "based", "zeta"):
    for dm in (32, 64, 128, 256):
        kw = dict(_ZETA) if arch == "zeta" else {}
        _add("fig2a", f"fig2a_{arch}_d{dm}", _mqar_cfg(arch, dm, **kw), batch=16)

# --------------------------------------------------------------------------
# fig2b — vanilla transformer with low-dimensional QK, d_K sweep
# --------------------------------------------------------------------------
for dm in (32, 64, 128):
    for dk in (1, 2, 3, 8):
        _add("fig2b", f"fig2b_d{dm}_dk{dk}",
             _mqar_cfg("vanilla", dm, d_k=dk, low_dim_qk=True, two_layer_qk=True),
             batch=16)

# --------------------------------------------------------------------------
# fig2c + table6 — Euclidean-based softmax operators vs d_K (dense)
# --------------------------------------------------------------------------
for op in ("cauchy", "neg_euclid", "inv_euclid", "norm_dot"):
    for dk in (1, 2, 3, 4):
        _add("fig2c", f"fig2c_{op}_dk{dk}",
             _mqar_cfg("dense_op", 64, d_k=dk, operator=op, two_layer_qk=True),
             batch=16)

# --------------------------------------------------------------------------
# fig2d — ZETA ablation over k (k=32 cells come from fig2a presets)
# --------------------------------------------------------------------------
for dm in (64, 256):
    for k in (16, 48):
        z = dict(_ZETA)
        z["k"] = k
        _add("fig2d", f"fig2d_d{dm}_k{k}", _mqar_cfg("zeta", dm, **z), batch=16)

# --------------------------------------------------------------------------
# table2 — LRA-style synthetic tasks x 4 architectures
# --------------------------------------------------------------------------
_LRA_TASKS = {
    "listops": (256, 10),
    "text": (512, 2),
    "retrieval": (512, 2),
    "image": (256, 10),
    "pathfinder": (256, 2),
}
for task_name, (n, nc) in _LRA_TASKS.items():
    for arch in ("vanilla", "zeta", "performer", "based"):
        kw = dict(_ZETA, chunk=max(8, n // 16)) if arch == "zeta" else {}
        _add("table2", f"table2_{task_name}_{arch}",
             _lra_cfg(arch, task_name, n, nc, **kw), batch=16, lr=1e-3)

# --------------------------------------------------------------------------
# table5 — d_K ablation on ListOps / Image (dense attention, low-dim QK)
# --------------------------------------------------------------------------
for task_name in ("listops", "image"):
    n, nc = _LRA_TASKS[task_name]
    for dk in (1, 2, 3, 32):
        _add("table5", f"table5_{task_name}_dk{dk}",
             _lra_cfg("vanilla", task_name, n, nc, d_k=dk, low_dim_qk=True,
                      two_layer_qk=True), batch=16, lr=1e-3)

# --------------------------------------------------------------------------
# table1 — language modeling perplexity comparison
# --------------------------------------------------------------------------
for arch in ("vanilla", "performer", "based", "zeta"):
    kw = dict(_ZETA) if arch == "zeta" else {}
    _add("table1", f"table1_{arch}", _lm_cfg(arch, d_model=128, n_layers=2, **kw),
         batch=8, lr=1e-3)


def preset_names(groups=None):
    if not groups:
        return list(PRESETS)
    out = []
    for g in groups:
        out.extend(GROUPS[g])
    return out
