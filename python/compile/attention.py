"""Layer-2 attention variants: ZETA and every baseline the paper compares.

All functions take params (pytree of jnp arrays) + activations and are pure,
so the whole model lowers to a single HLO module. Variants:

  zeta       — the paper's contribution: shared low-dim QK projection,
               Z-order top-k candidate search (topk.py), history-mean
               smoothing token, Adaptive Cauchy-Softmax Pallas kernel (L1).
  vanilla    — softmax(QK^T/sqrt(d))V, causal. ``d_k`` configurable so the
               Fig-2b d_K sweep runs on this variant.
  dense_op   — dense attention under the Euclidean operators of §4.3 /
               Table 6 (cauchy / neg_euclid / inv_euclid / norm_dot).
  performer  — FAVOR+ positive random features, causal prefix sums.
  based      — BASED-style linear attention (order-2 Taylor feature map),
               causal prefix sums.

Shapes: x (B, N, D); heads split D into H * dv. Low-dim QK projections for
zeta/dense_op map D -> d_k per head (two-layer MLP per paper §4.2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import topk
from .kernels.cauchy import cauchy_topk_attention
from .kernels.ref import dense_attention_ref, dense_distance_attention_ref

__all__ = ["attention_apply", "attention_init", "ATTENTION_KINDS"]

ATTENTION_KINDS = ("zeta", "vanilla", "dense_op", "performer", "based")


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def _dense_init(key, d_in, d_out, scale=None):
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d_in, jnp.float32))
    return jax.random.normal(key, (d_in, d_out), jnp.float32) * scale


def _qk_proj_init(key, d_model, d_k, two_layer, hidden=None):
    """Projection f_q = f_k: either a linear map or a 2-layer MLP (§4.2)."""
    if not two_layer:
        return {"w": _dense_init(key, d_model, d_k)}
    hidden = hidden or max(4 * d_k, 16)
    k1, k2 = jax.random.split(key)
    return {
        "w1": _dense_init(k1, d_model, hidden),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": _dense_init(k2, hidden, d_k),
    }


def _qk_proj_apply(p, x):
    if "w" in p:
        return x @ p["w"]
    h = jax.nn.gelu(x @ p["w1"] + p["b1"])
    return h @ p["w2"]


def attention_init(key, cfg):
    """Init attention params for one layer. cfg is the model config dict."""
    kind = cfg["attn"]
    d = cfg["d_model"]
    h = cfg["n_heads"]
    dv = d // h
    d_k = cfg.get("d_k", dv)
    keys = jax.random.split(key, 8)

    if kind == "zeta":
        # Shared QK projection per head (Reformer-style, paper App. A).
        return {
            "qk": [
                _qk_proj_init(jax.random.fold_in(keys[0], i), d, d_k, cfg.get("two_layer_qk", True))
                for i in range(h)
            ],
            "wv": _dense_init(keys[1], d, d),
            "wo": _dense_init(keys[2], d, d),
            # gamma^2 = sigmoid(theta) in [0, 1]; theta = 0 -> gamma^2 = 0.5.
            "theta": jnp.zeros((), jnp.float32),
        }
    if kind in ("vanilla", "dense_op"):
        if cfg.get("low_dim_qk", kind == "dense_op"):
            qk = {
                "wq": [
                    _qk_proj_init(jax.random.fold_in(keys[0], i), d, d_k, cfg.get("two_layer_qk", True))
                    for i in range(h)
                ],
                "wk": [
                    _qk_proj_init(jax.random.fold_in(keys[1], i), d, d_k, cfg.get("two_layer_qk", True))
                    for i in range(h)
                ],
            }
        else:
            qk = {"wq": _dense_init(keys[0], d, h * d_k), "wk": _dense_init(keys[1], d, h * d_k)}
        out = dict(qk)
        out["wv"] = _dense_init(keys[2], d, d)
        out["wo"] = _dense_init(keys[3], d, d)
        if kind == "dense_op":
            out["theta"] = jnp.zeros((), jnp.float32)
        return out
    if kind == "performer":
        m = cfg.get("n_features", max(dv, 32))
        return {
            "wq": _dense_init(keys[0], d, d),
            "wk": _dense_init(keys[1], d, d),
            "wv": _dense_init(keys[2], d, d),
            "wo": _dense_init(keys[3], d, d),
            # FAVOR+ projection; trained like any other param (harmless).
            "feat": jax.random.normal(keys[4], (h, dv, m), jnp.float32),
        }
    if kind == "based":
        df = cfg.get("d_feature", min(16, dv))
        return {
            "wq": _dense_init(keys[0], d, h * df),
            "wk": _dense_init(keys[1], d, h * df),
            "wv": _dense_init(keys[2], d, d),
            "wo": _dense_init(keys[3], d, d),
        }
    raise ValueError(f"unknown attention kind {kind!r}")


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _split_heads(x, h):
    b, n, d = x.shape
    return x.reshape(b, n, h, d // h).transpose(0, 2, 1, 3)  # (B, H, N, dv)


def _merge_heads(x):
    b, h, n, dv = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, n, h * dv)


def _gather_rows(arr, idx):
    """arr (..., N, d), idx (..., N, k) -> (..., N, k, d) without O(N^2)."""
    lead = arr.shape[:-2]
    n, d = arr.shape[-2:]
    k = idx.shape[-1]
    arr2 = arr.reshape((-1, n, d))
    idx2 = idx.reshape((-1, n, k))
    out = jax.vmap(lambda a, i: a[i])(arr2, idx2)  # (R, N, k, d)
    return out.reshape(lead + (n, k, d))


def _zeta_forward(p, x, cfg):
    b, n, d = x.shape
    h = cfg["n_heads"]
    dv = d // h
    k = cfg.get("k", 32)
    chunk = cfg.get("chunk", max(8, n // cfg.get("n_chunks", 8)))
    window = cfg.get("window", 2 * k)

    # Shared QK projection per head: (B, H, N, d_k).
    qk = jnp.stack([_qk_proj_apply(pi, x) for pi in p["qk"]], axis=1)
    v = _split_heads(x @ p["wv"], h)  # (B, H, N, dv)

    idx, valid = topk.topk_candidates(qk, qk, k=k, chunk=chunk, window=window,
                                      bits=cfg.get("bits"),
                                      fixed_range=cfg.get("fixed_range", 4.0))

    kg = _gather_rows(qk, idx)  # (B, H, N, k, d_k)
    vg = _gather_rows(v, idx)  # (B, H, N, k, dv)

    # History-mean smoothing token (paper §3.4): causal running mean of the
    # keys/values, always valid, appended as candidate k+1.
    km = topk.history_mean(qk)[..., :, None, :]  # (B, H, N, 1, d_k)
    vm = topk.history_mean(v)[..., :, None, :]  # (B, H, N, 1, dv)
    kg = jnp.concatenate([kg, km], axis=-2)
    vg = jnp.concatenate([vg, vm], axis=-2)
    valid = jnp.concatenate([valid, jnp.ones(valid.shape[:-1] + (1,), valid.dtype)], axis=-1)

    eps = jax.nn.sigmoid(p["theta"])  # gamma^2 in (0, 1)

    rows = b * h * n
    o = cauchy_topk_attention(
        qk.reshape(rows, -1),
        kg.reshape(rows, k + 1, -1),
        vg.reshape(rows, k + 1, -1),
        valid.reshape(rows, k + 1),
        eps,
    )
    o = o.reshape(b, h, n, dv)
    return _merge_heads(o) @ p["wo"]


def _vanilla_forward(p, x, cfg):
    h = cfg["n_heads"]
    if isinstance(p["wq"], list):
        q = jnp.stack([_qk_proj_apply(pi, x) for pi in p["wq"]], axis=1)
        k = jnp.stack([_qk_proj_apply(pi, x) for pi in p["wk"]], axis=1)
    else:
        q = _split_heads(x @ p["wq"], h)
        k = _split_heads(x @ p["wk"], h)
    v = _split_heads(x @ p["wv"], h)
    o = dense_attention_ref(q, k, v, causal=True)
    return _merge_heads(o) @ p["wo"]


def _dense_op_forward(p, x, cfg):
    h = cfg["n_heads"]
    if isinstance(p["wq"], list):
        q = jnp.stack([_qk_proj_apply(pi, x) for pi in p["wq"]], axis=1)
        k = jnp.stack([_qk_proj_apply(pi, x) for pi in p["wk"]], axis=1)
    else:
        q = _split_heads(x @ p["wq"], h)
        k = _split_heads(x @ p["wk"], h)
    v = _split_heads(x @ p["wv"], h)
    eps = jax.nn.sigmoid(p["theta"])
    o = dense_distance_attention_ref(q, k, v, cfg["operator"], eps, causal=True)
    return _merge_heads(o) @ p["wo"]


def _performer_forward(p, x, cfg):
    h = cfg["n_heads"]
    q = _split_heads(x @ p["wq"], h)  # (B, H, N, dv)
    k = _split_heads(x @ p["wk"], h)
    v = _split_heads(x @ p["wv"], h)
    w = p["feat"]  # (H, dv, m)
    scale = 1.0 / jnp.sqrt(jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32)))

    def phi(u):
        # FAVOR+ positive features: exp(w.u - |u|^2/2) / sqrt(m).
        proj = jnp.einsum("bhnd,hdm->bhnm", u * scale, w)
        norm = 0.5 * jnp.sum((u * scale) ** 2, axis=-1, keepdims=True)
        return jnp.exp(proj - norm) / jnp.sqrt(jnp.asarray(w.shape[-1], jnp.float32))

    qf, kf = phi(q), phi(k)  # (B, H, N, m)
    # Causal linear attention via prefix sums.
    skv = jnp.cumsum(jnp.einsum("bhnm,bhnd->bhnmd", kf, v), axis=2)
    sk = jnp.cumsum(kf, axis=2)
    num = jnp.einsum("bhnm,bhnmd->bhnd", qf, skv)
    den = jnp.einsum("bhnm,bhnm->bhn", qf, sk)
    o = num / (den[..., None] + 1e-6)
    return _merge_heads(o) @ p["wo"]


def _based_forward(p, x, cfg):
    h = cfg["n_heads"]
    df = cfg.get("d_feature", min(16, cfg["d_model"] // h))
    b, n, d = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    q = q.reshape(b, n, h, df).transpose(0, 2, 1, 3)
    k = k.reshape(b, n, h, df).transpose(0, 2, 1, 3)
    v = _split_heads(x @ p["wv"], h)

    def phi(u):
        # Order-2 Taylor approximation of exp(q.k): [1, u, vec(uu^T)/sqrt(2)].
        u = u / jnp.sqrt(jnp.sqrt(jnp.asarray(df, jnp.float32)))
        ones = jnp.ones(u.shape[:-1] + (1,), u.dtype)
        quad = jnp.einsum("...i,...j->...ij", u, u) / jnp.sqrt(2.0)
        quad = quad.reshape(u.shape[:-1] + (df * df,))
        return jnp.concatenate([ones, u, quad], axis=-1)

    qf, kf = phi(q), phi(k)  # (B, H, N, f)
    skv = jnp.cumsum(jnp.einsum("bhnf,bhnd->bhnfd", kf, v), axis=2)
    sk = jnp.cumsum(kf, axis=2)
    num = jnp.einsum("bhnf,bhnfd->bhnd", qf, skv)
    den = jnp.einsum("bhnf,bhnf->bhn", qf, sk)
    o = num / (den[..., None] + 1e-6)
    return _merge_heads(o) @ p["wo"]


_FORWARDS = {
    "zeta": _zeta_forward,
    "vanilla": _vanilla_forward,
    "dense_op": _dense_op_forward,
    "performer": _performer_forward,
    "based": _based_forward,
}


def attention_apply(p, x, cfg):
    """Dispatch one attention layer. x (B, N, D) -> (B, N, D)."""
    return _FORWARDS[cfg["attn"]](p, x, cfg)
