"""Layer-1 Pallas kernel: sparse top-k Adaptive Cauchy-Softmax attention.

This is the paper's Appendix-D Triton kernel rethought for Pallas/TPU (see
DESIGN.md §Hardware-Adaptation):

* Each grid step owns a ``(block_rows, k+1, ·)`` slab of *pre-gathered* keys
  and values in VMEM — the gather itself stays at the XLA level where the
  compiler lowers it to dynamic slices; random-access loads inside the kernel
  would defeat the TPU vector unit.
* The Cauchy score matrix for a block is ``(block_rows, k+1)`` — tiny
  (k = 32 in the paper) — so the full normalization lives in VMEM with no
  streaming-softmax machinery.
* The backward pass is a second Pallas kernel implementing the closed-form
  gradients of Appendix E (Eqs. 44–47); the scatter-add the Triton version
  performs with ``tl.atomic_add`` is instead produced by XLA when the
  surrounding gather is transposed.

Rows are independent queries: the caller flattens (batch, heads, seq) into a
single row axis. Inputs per row:

  q     (d,)        low-dimensional query (d = d_K, typically 3)
  kg    (k+1, d)    gathered candidate keys (+1 = history-mean smoothing key)
  vg    (k+1, dv)   gathered candidate values (+1 = history-mean value)
  mask  (k+1,)      1.0 where the candidate is valid (causal / in-range)
  eps   scalar      gamma^2 of the Adaptive Cauchy-Softmax

Forward (paper Eq. 6):  s_j = mask_j / (||q - k_j||^2 + eps)
                        o   = sum_j (s_j / Z) v_j,   Z = sum_j s_j
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["cauchy_topk_attention", "DEFAULT_BLOCK_ROWS"]

# 128 rows x (k+1=33) candidates x (d_v<=256) f32 ≈ 4.3 MB VMEM worst case;
# the shipped configs (d_v <= 128) stay under 2.2 MB. See DESIGN.md §Perf.
DEFAULT_BLOCK_ROWS = 128


def _fwd_kernel(q_ref, k_ref, v_ref, m_ref, eps_ref, o_ref, z_ref):
    """One block of rows: scores, normalizer and weighted values in VMEM."""
    q = q_ref[...]  # (bq, d)
    kk = k_ref[...]  # (bq, kc, d)
    vv = v_ref[...]  # (bq, kc, dv)
    m = m_ref[...]  # (bq, kc)
    eps = eps_ref[0]

    diff = q[:, None, :] - kk  # (bq, kc, d)
    dist = jnp.sum(diff * diff, axis=-1)  # (bq, kc)
    s = m / (dist + eps)  # masked Cauchy scores
    z = jnp.sum(s, axis=-1)  # (bq,)
    # Every row has at least the smoothing token valid, but guard anyway so a
    # fully-masked row yields zeros instead of NaN.
    zsafe = jnp.where(z > 0.0, z, 1.0)
    a = s / zsafe[:, None]  # (bq, kc)
    o_ref[...] = jnp.sum(a[:, :, None] * vv, axis=1)  # (bq, dv)
    z_ref[...] = zsafe


def _bwd_kernel(q_ref, k_ref, v_ref, m_ref, eps_ref, o_ref, z_ref, g_ref,
                dq_ref, dk_ref, dv_ref, de_ref):
    """Appendix-E gradients for one block of rows.

    With s_j = m_j/(D_j + eps), A_j = s_j/Z, o = sum_j A_j v_j and upstream
    gradient g = dL/do:
      dL/dv_j  = A_j g                                   (Eq. 44)
      dL/dS_j  = g . (v_j - o) / Z
      dL/ddel_j = -dL/dS_j * s_j^2 / m_j  (= -dS * 1/del^2 on valid entries)
      dL/dq    = sum_j dL/ddel_j * 2 (q - k_j)           (Eq. 45)
      dL/dk_j  = -dL/ddel_j * 2 (q - k_j)                (Eq. 46)
      dL/deps  = sum_j dL/ddel_j                         (Eq. 47)
    """
    q = q_ref[...]
    kk = k_ref[...]
    vv = v_ref[...]
    m = m_ref[...]
    eps = eps_ref[0]
    o = o_ref[...]  # (bq, dv) saved forward output
    z = z_ref[...]  # (bq,) saved normalizer
    g = g_ref[...]  # (bq, dv)

    diff = q[:, None, :] - kk  # (bq, kc, d)
    dist = jnp.sum(diff * diff, axis=-1)
    s = m / (dist + eps)  # (bq, kc)
    a = s / z[:, None]

    dv_ref[...] = a[:, :, None] * g[:, None, :]  # (bq, kc, dv)

    # dL/dS_j = g.(v_j - o)/Z  -> (bq, kc)
    gdotv = jnp.sum(g[:, None, :] * (vv - o[:, None, :]), axis=-1)
    ds = gdotv / z[:, None]
    # On valid entries s = 1/delta so s^2 = 1/delta^2; masked entries have
    # s = 0 and contribute nothing.
    ddelta = -ds * s * s / jnp.where(m > 0.0, m, 1.0)  # (bq, kc)

    dq_ref[...] = jnp.sum(ddelta[:, :, None] * 2.0 * diff, axis=1)  # (bq, d)
    dk_ref[...] = -ddelta[:, :, None] * 2.0 * diff  # (bq, kc, d)
    de_ref[...] = jnp.sum(ddelta, axis=-1)  # (bq,)


def _pad_rows(x: jnp.ndarray, rows: int) -> jnp.ndarray:
    pad = rows - x.shape[0]
    if pad == 0:
        return x
    cfg = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, cfg)


def _block_rows(rows: int, requested: int) -> int:
    return min(requested, rows)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def cauchy_topk_attention(q, kg, vg, mask, eps, block_rows=DEFAULT_BLOCK_ROWS):
    """Sparse Cauchy-softmax attention over pre-gathered candidates.

    q (R, d), kg (R, kc, d), vg (R, kc, dv), mask (R, kc), eps scalar array.
    Returns o (R, dv). Differentiable w.r.t. q, kg, vg and eps.
    """
    o, _ = _fwd_impl(q, kg, vg, mask, eps, block_rows)
    return o


def _fwd_impl(q, kg, vg, mask, eps, block_rows):
    rows, d = q.shape
    kc = kg.shape[1]
    dv = vg.shape[2]
    bq = _block_rows(rows, block_rows)
    padded = ((rows + bq - 1) // bq) * bq
    qp, kp, vp, mp = (_pad_rows(x, padded) for x in (q, kg, vg, mask))
    grid = (padded // bq,)
    eps_arr = jnp.reshape(eps.astype(jnp.float32), (1,))

    o, z = pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((bq, kc, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bq, kc, dv), lambda i: (i, 0, 0)),
            pl.BlockSpec((bq, kc), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bq, dv), lambda i: (i, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded, dv), jnp.float32),
            jax.ShapeDtypeStruct((padded,), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(qp, kp, vp, mp, eps_arr)
    return o[:rows], z[:rows]


def _vjp_fwd(q, kg, vg, mask, eps, block_rows):
    o, z = _fwd_impl(q, kg, vg, mask, eps, block_rows)
    return o, (q, kg, vg, mask, eps, o, z)


def _vjp_bwd(block_rows, res, g):
    q, kg, vg, mask, eps, o, z = res
    rows, d = q.shape
    kc = kg.shape[1]
    dv = vg.shape[2]
    bq = _block_rows(rows, block_rows)
    padded = ((rows + bq - 1) // bq) * bq
    qp, kp, vp, mp, op, zp, gp = (
        _pad_rows(x, padded) for x in (q, kg, vg, mask, o, z, g)
    )
    # Padded rows have z == 0; make the normalizer safe there.
    zp = jnp.where(zp > 0.0, zp, 1.0)
    grid = (padded // bq,)
    eps_arr = jnp.reshape(eps.astype(jnp.float32), (1,))

    dq, dk, dv_, de = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((bq, kc, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bq, kc, dv), lambda i: (i, 0, 0)),
            pl.BlockSpec((bq, kc), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((bq, dv), lambda i: (i, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
            pl.BlockSpec((bq, dv), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((bq, kc, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bq, kc, dv), lambda i: (i, 0, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded, d), jnp.float32),
            jax.ShapeDtypeStruct((padded, kc, d), jnp.float32),
            jax.ShapeDtypeStruct((padded, kc, dv), jnp.float32),
            jax.ShapeDtypeStruct((padded,), jnp.float32),
        ],
        interpret=True,
    )(qp, kp, vp, mp, eps_arr, op, zp, gp)

    deps = jnp.sum(de[:rows]).astype(eps.dtype).reshape(eps.shape)
    return dq[:rows], dk[:rows], dv_[:rows], jnp.zeros_like(mask), deps


cauchy_topk_attention.defvjp(_vjp_fwd, _vjp_bwd)
