"""Pure-jnp correctness oracles for every Layer-1 kernel.

These are the ground truth the Pallas kernels are tested against (pytest +
hypothesis in python/tests). They are deliberately written in the most
obvious way possible — no blocking, no padding, no custom VJP — so that a
mismatch always implicates the kernel, never the oracle.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "cauchy_topk_attention_ref",
    "dense_attention_ref",
    "dense_distance_attention_ref",
]


def cauchy_topk_attention_ref(q, kg, vg, mask, eps):
    """Reference for kernels.cauchy.cauchy_topk_attention.

    q (R, d), kg (R, kc, d), vg (R, kc, dv), mask (R, kc), eps scalar.
    s_j = mask_j / (||q - k_j||^2 + eps); o = sum_j s_j v_j / sum_j s_j.
    """
    diff = q[:, None, :] - kg
    dist = jnp.sum(diff * diff, axis=-1)
    s = mask / (dist + eps)
    z = jnp.sum(s, axis=-1, keepdims=True)
    z = jnp.where(z > 0.0, z, 1.0)
    return jnp.einsum("rk,rkd->rd", s / z, vg)


def dense_attention_ref(q, k, v, causal=True, scale=None):
    """Vanilla softmax(QK^T/sqrt(d))V with optional causal mask.

    q, k: (..., N, d); v: (..., N, dv).
    """
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum("...qd,...kd->...qk", q, k) * scale
    if causal:
        n = q.shape[-2]
        causal_mask = jnp.tril(jnp.ones((n, n), bool))
        logits = jnp.where(causal_mask, logits, -jnp.inf)
    a = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    a = a / jnp.sum(a, axis=-1, keepdims=True)
    return jnp.einsum("...qk,...kv->...qv", a, v)


def dense_distance_attention_ref(q, k, v, operator, eps, causal=True):
    """Dense attention under the paper's Euclidean-based operators (§4.3).

    operator: 'cauchy'     -> weights 1/(D + eps), normalized
              'neg_euclid' -> softmax(-D)
              'inv_euclid' -> weights 1/(sqrt(D) + eps), normalized
              'norm_dot'   -> softmax(q_hat . k_hat / sqrt(d)) (Table 6)
    """
    n = q.shape[-2]
    if operator == "norm_dot":
        qh = q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-6)
        kh = k / (jnp.linalg.norm(k, axis=-1, keepdims=True) + 1e-6)
        return dense_attention_ref(qh, kh, v, causal=causal)

    d2 = (
        jnp.sum(q * q, axis=-1)[..., :, None]
        + jnp.sum(k * k, axis=-1)[..., None, :]
        - 2.0 * jnp.einsum("...qd,...kd->...qk", q, k)
    )
    d2 = jnp.maximum(d2, 0.0)
    causal_mask = jnp.tril(jnp.ones((n, n), bool)) if causal else jnp.ones((n, n), bool)
    if operator == "cauchy":
        s = jnp.where(causal_mask, 1.0 / (d2 + eps), 0.0)
    elif operator == "inv_euclid":
        s = jnp.where(causal_mask, 1.0 / (jnp.sqrt(d2) + eps + 1e-6), 0.0)
    elif operator == "neg_euclid":
        logits = jnp.where(causal_mask, -d2, -jnp.inf)
        s = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    else:
        raise ValueError(f"unknown operator {operator!r}")
    z = jnp.sum(s, axis=-1, keepdims=True)
    z = jnp.where(z > 0.0, z, 1.0)
    return jnp.einsum("...qk,...kv->...qv", s / z, v)
