"""AOT pipeline tests: HLO-text emission and the preset registry contract."""

import jax
import jax.numpy as jnp

from compile import presets
from compile.aot import to_hlo_text


def test_to_hlo_text_emits_parseable_module():
    def fn(x, y):
        return (jnp.matmul(x, y) + 1.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True: the root must be a tuple
    assert "tuple(" in text or "(f32[2,2]" in text


def test_no_topk_op_in_lowered_search():
    """The runtime's XLA 0.5.1 HLO parser rejects `topk(..., largest=)` —
    the candidate search must lower to `sort` instead (see topk.py)."""
    from compile import topk

    def fn(q):
        idx, valid = topk.topk_candidates(q, q, k=4, chunk=8)
        return (idx, valid)

    spec = jax.ShapeDtypeStruct((1, 32, 3), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec))
    assert " topk(" not in text, "lowered graph contains a topk op"
    assert " sort(" in text


def test_preset_registry_consistency():
    assert len(presets.PRESETS) >= 80
    # groups partition the registry
    grouped = [n for g in presets.GROUPS.values() for n in g]
    assert sorted(grouped) == sorted(presets.PRESETS)
    for name, spec in presets.PRESETS.items():
        cfg = spec["cfg"]
        assert cfg["d_model"] % cfg["n_heads"] == 0, name
        assert spec["batch"] >= 1
        assert set(spec["entries"]) <= {"init", "train", "eval", "forward"}
        if cfg["task"] == "cls":
            assert "n_classes" in cfg, name
        if cfg["attn"] == "dense_op":
            assert "operator" in cfg, name


def test_group_selection():
    core = presets.preset_names(["core"])
    assert "quickstart_zeta" in core
    assert all(not n.startswith("fig2a") for n in core)
    everything = presets.preset_names(None)
    assert len(everything) == len(presets.PRESETS)
