"""L2 tests: chunked causal top-k search invariants (topk.py)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import topk


def _random_qk(seed, b, n, d):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, n, d)), jnp.float32)
    return q


def test_causal_never_selects_future():
    """The core causal invariant: a valid candidate for query i always has
    original position < (i // chunk) * chunk (paper §3.2.2)."""
    for seed in range(3):
        q = _random_qk(seed, 2, 64, 3)
        idx, valid = topk.topk_candidates(q, q, k=8, chunk=8)
        idx = np.asarray(idx)
        valid = np.asarray(valid)
        limit = (np.arange(64) // 8) * 8
        for bi in range(2):
            for i in range(64):
                sel = idx[bi, i][valid[bi, i] > 0]
                assert np.all(sel < limit[i]), f"i={i}: {sel} !< {limit[i]}"


def test_first_chunk_has_no_candidates():
    q = _random_qk(0, 1, 32, 2)
    _, valid = topk.topk_candidates(q, q, k=4, chunk=8)
    assert float(np.asarray(valid)[0, :8].sum()) == 0.0


def test_no_duplicate_candidates():
    q = _random_qk(1, 1, 64, 3)
    idx, valid = topk.topk_candidates(q, q, k=8, chunk=8)
    idx, valid = np.asarray(idx)[0], np.asarray(valid)[0]
    for i in range(64):
        sel = idx[i][valid[i] > 0]
        assert len(np.unique(sel)) == len(sel), f"dups at query {i}"


def test_selected_are_near_in_z():
    """Valid candidates must be the nearest *visible* keys in z-space among
    the window — check against brute force on the Morton codes."""
    from compile import zorder

    q = _random_qk(2, 1, 64, 3)
    k = 6
    chunk = 8
    idx, valid = topk.topk_candidates(q, q, k=k, chunk=chunk, window=128)
    # Same fixed grid as topk_candidates' default.
    qz, kz = zorder.encode(q, q, fixed_range=4.0)
    qz = np.asarray(qz)[0].astype(np.int64)
    kz = np.asarray(kz)[0].astype(np.int64)
    idx, valid = np.asarray(idx)[0], np.asarray(valid)[0]
    for i in range(8, 64, 7):
        lim = (i // chunk) * chunk
        ranked = sorted(range(lim), key=lambda j: abs(kz[j] - qz[i]))
        got = set(idx[i][valid[i] > 0])
        # All selections lie in the true top-(k+8) by |dz| (float32 ranking
        # inside the graph can reorder near-ties), and most of the true
        # top-k is recovered.
        assert got <= set(ranked[: k + 8]), f"q{i}: {sorted(got)}"
        if len(got) == k:
            assert len(got & set(ranked[:k])) >= k - 3, f"q{i}"


def test_recall_beats_random_baseline():
    """Candidates should overlap the true Euclidean kNN far more than chance
    (the locality claim of Fig. 3 at d_K = 3)."""
    n, k = 256, 16
    q = _random_qk(3, 1, n, 3)
    idx, valid = topk.topk_candidates(q, q, k=k, chunk=16)
    x = np.asarray(q)[0]
    idx, valid = np.asarray(idx)[0], np.asarray(valid)[0]
    hits, total, rand_hits = 0, 0, 0
    rng = np.random.default_rng(0)
    for i in range(64, n):
        lim = (i // 16) * 16
        d2 = ((x[:lim] - x[i]) ** 2).sum(1)
        true = set(np.argsort(d2)[:k])
        got = set(idx[i][valid[i] > 0])
        hits += len(true & got)
        rand_hits += len(true & set(rng.choice(lim, size=min(k, lim), replace=False)))
        total += min(k, lim)
    assert hits > 2 * rand_hits, (hits, rand_hits)


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([32, 64, 96]),
    k=st.integers(2, 12),
    chunk=st.sampled_from([4, 8, 16]),
    d=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_causal_sweep(n, k, chunk, d, seed):
    q = _random_qk(seed, 1, n, d)
    idx, valid = topk.topk_candidates(q, q, k=k, chunk=chunk)
    idx, valid = np.asarray(idx)[0], np.asarray(valid)[0]
    limit = (np.arange(n) // chunk) * chunk
    mask = valid > 0
    assert np.all(idx[mask] < np.broadcast_to(limit[:, None], idx.shape)[mask])
    assert idx.shape == (n, k)


def test_history_mean_matches_naive():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(2, 3, 16, 5)), jnp.float32)
    hm = np.asarray(topk.history_mean(x))
    xn = np.asarray(x)
    for i in range(16):
        np.testing.assert_allclose(hm[..., i, :], xn[..., : i + 1, :].mean(-2),
                                   atol=1e-5)
