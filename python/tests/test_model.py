"""L2 tests: model shapes, all attention variants, training behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import dense_attention_ref, dense_distance_attention_ref
from compile.model import model_apply, model_init, param_count
from compile.train import make_eval_step, make_train_step


def _cfg(attn, **kw):
    cfg = {
        "vocab": 32,
        "seq_len": 32,
        "d_model": 32,
        "n_layers": 2,
        "n_heads": 2,
        "attn": attn,
        "task": "lm",
    }
    cfg.update(kw)
    return cfg


ZETA_KW = {"d_k": 3, "k": 4, "chunk": 8}


@pytest.mark.parametrize(
    "attn,kw",
    [
        ("vanilla", {}),
        ("vanilla", {"d_k": 2, "low_dim_qk": True}),
        ("dense_op", {"d_k": 3, "operator": "cauchy"}),
        ("dense_op", {"d_k": 3, "operator": "neg_euclid"}),
        ("dense_op", {"d_k": 3, "operator": "inv_euclid"}),
        ("dense_op", {"d_k": 3, "operator": "norm_dot"}),
        ("performer", {}),
        ("based", {}),
        ("zeta", ZETA_KW),
    ],
)
def test_lm_forward_shapes(attn, kw):
    cfg = _cfg(attn, **kw)
    p = model_init(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((2, cfg["seq_len"]), jnp.int32)
    logits = model_apply(p, x, cfg)
    assert logits.shape == (2, cfg["seq_len"], cfg["vocab"])
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("attn,kw", [("vanilla", {}), ("zeta", ZETA_KW)])
def test_cls_forward_shapes(attn, kw):
    cfg = _cfg(attn, task="cls", n_classes=5, **kw)
    p = model_init(jax.random.PRNGKey(0), cfg)
    x = jnp.ones((3, cfg["seq_len"]), jnp.int32)
    logits = model_apply(p, x, cfg)
    assert logits.shape == (3, 5)


def test_causality_dense_variants():
    """Changing a future token must not change past logits.

    ZETA is excluded here by design: its candidate *selection* shares one
    sorted Z-code array across the sequence (the paper's Algorithm 1), so a
    future token can displace which past keys fall into a query's window —
    attention values and scores themselves only ever use past tokens, which
    is what test_topk.py::test_causal_never_selects_future pins down.
    """
    for attn, kw in (("vanilla", {}), ("performer", {}), ("based", {})):
        cfg = _cfg(attn, **kw)
        p = model_init(jax.random.PRNGKey(1), cfg)
        rng = np.random.default_rng(0)
        x1 = rng.integers(1, 32, size=(1, 32)).astype(np.int32)
        x2 = x1.copy()
        x2[0, -1] = (x2[0, -1] + 5) % 31 + 1
        l1 = model_apply(p, jnp.asarray(x1), cfg)
        l2 = model_apply(p, jnp.asarray(x2), cfg)
        np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], atol=1e-4,
                                   err_msg=attn)


def test_dense_attention_rows_sum_to_one_effect():
    """Constant values -> output constant, any operator."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 1, 8, 3)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 8, 3)), jnp.float32)
    v = jnp.ones((1, 1, 8, 4), jnp.float32)
    for op in ("cauchy", "neg_euclid", "inv_euclid", "norm_dot"):
        o = dense_distance_attention_ref(q, k, v, op, 0.5)
        np.testing.assert_allclose(o, np.ones_like(o), atol=1e-5, err_msg=op)
    o = dense_attention_ref(q, k, v)
    np.testing.assert_allclose(o, np.ones_like(o), atol=1e-5)


def test_param_count_positive_and_consistent():
    cfg = _cfg("zeta", **ZETA_KW)
    p = model_init(jax.random.PRNGKey(0), cfg)
    n = param_count(p)
    assert n > 10_000
    p2 = model_init(jax.random.PRNGKey(7), cfg)
    assert param_count(p2) == n


@pytest.mark.parametrize("attn,kw", [("vanilla", {}), ("zeta", ZETA_KW)])
def test_train_step_overfits_single_batch(attn, kw):
    """Loss must drop substantially when repeating one batch — exercises the
    full fwd+bwd+Adam graph that gets lowered to HLO."""
    cfg = _cfg(attn, **kw)
    spec_lr = 3e-3
    step_fn = jax.jit(make_train_step(cfg, spec_lr, warmup=5))
    p = model_init(jax.random.PRNGKey(0), cfg)
    m = jax.tree_util.tree_map(jnp.zeros_like, p)
    v = jax.tree_util.tree_map(jnp.zeros_like, p)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(1, 32, size=(4, 32)), jnp.int32)
    y = jnp.roll(x, -1, axis=1)
    w = jnp.ones((4, 32), jnp.float32)
    first = None
    loss = None
    for step in range(80):
        loss, p, m, v = step_fn(p, m, v, jnp.int32(step + 1), x, y, w)
        if first is None:
            first = float(loss)
    assert float(loss) < 0.5 * first, (first, float(loss))


def test_eval_step_counts():
    cfg = _cfg("vanilla")
    p = model_init(jax.random.PRNGKey(0), cfg)
    ev = jax.jit(make_eval_step(cfg))
    x = jnp.zeros((2, 32), jnp.int32)
    y = jnp.zeros((2, 32), jnp.int32)
    w = jnp.zeros((2, 32), jnp.float32).at[:, :5].set(1.0)
    loss_sum, correct, wsum = ev(p, x, y, w)
    assert float(wsum) == 10.0
    assert 0.0 <= float(correct) <= 10.0
    assert float(loss_sum) > 0.0


def test_cls_train_learns_parity_task():
    """Tiny sanity task: class = whether token 1 appears in first half."""
    cfg = _cfg("vanilla", task="cls", n_classes=2, seq_len=16)
    step_fn = jax.jit(make_train_step(cfg, 3e-3, warmup=5))
    ev = jax.jit(make_eval_step(cfg))
    p = model_init(jax.random.PRNGKey(0), cfg)
    m = jax.tree_util.tree_map(jnp.zeros_like, p)
    v = jax.tree_util.tree_map(jnp.zeros_like, p)
    rng = np.random.default_rng(0)
    x = rng.integers(2, 32, size=(64, 16)).astype(np.int32)
    y = rng.integers(0, 2, size=(64,)).astype(np.int32)
    x[y == 1, 3] = 1
    x, y = jnp.asarray(x), jnp.asarray(y)
    w = jnp.ones((64,), jnp.float32)
    for step in range(60):
        loss, p, m, v = step_fn(p, m, v, jnp.int32(step + 1), x, y, w)
    _, correct, wsum = ev(p, x, y, w)
    assert float(correct) / float(wsum) > 0.9
