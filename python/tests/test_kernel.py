"""L1 correctness: Pallas Cauchy top-k kernel vs the pure-jnp oracle.

This is the core correctness signal of the whole stack — the same
`cauchy_topk_attention` that is exercised here gets lowered into every ZETA
HLO artifact the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.cauchy import cauchy_topk_attention
from compile.kernels.ref import cauchy_topk_attention_ref

ATOL = 2e-5


def _inputs(rng, rows, kc, d, dv, mask_p=0.5):
    q = jnp.asarray(rng.normal(size=(rows, d)), jnp.float32)
    kg = jnp.asarray(rng.normal(size=(rows, kc, d)), jnp.float32)
    vg = jnp.asarray(rng.normal(size=(rows, kc, dv)), jnp.float32)
    mask = jnp.asarray(rng.random(size=(rows, kc)) < mask_p, jnp.float32)
    # Smoothing token convention: last candidate always valid.
    mask = mask.at[:, -1].set(1.0)
    return q, kg, vg, mask


def test_forward_matches_ref():
    rng = np.random.default_rng(0)
    q, kg, vg, mask = _inputs(rng, 64, 17, 3, 32)
    eps = jnp.asarray(0.25, jnp.float32)
    out = cauchy_topk_attention(q, kg, vg, mask, eps)
    ref = cauchy_topk_attention_ref(q, kg, vg, mask, eps)
    np.testing.assert_allclose(out, ref, atol=ATOL)


def test_forward_row_padding_boundary():
    """Row counts that are not multiples of the block size must still match."""
    rng = np.random.default_rng(1)
    for rows in (1, 3, 127, 128, 129, 200):
        q, kg, vg, mask = _inputs(rng, rows, 9, 2, 8)
        eps = jnp.asarray(0.5, jnp.float32)
        out = cauchy_topk_attention(q, kg, vg, mask, eps)
        ref = cauchy_topk_attention_ref(q, kg, vg, mask, eps)
        np.testing.assert_allclose(out, ref, atol=ATOL, err_msg=f"rows={rows}")


def test_fully_masked_row_is_zero_not_nan():
    rng = np.random.default_rng(2)
    q, kg, vg, mask = _inputs(rng, 8, 5, 3, 4)
    mask = mask.at[3, :].set(0.0)
    out = cauchy_topk_attention(q, kg, vg, mask, jnp.asarray(0.1, jnp.float32))
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(out[3], np.zeros(4), atol=ATOL)


def test_weights_form_simplex():
    """Output is a convex combination of valid values (Assumption 3.2)."""
    rng = np.random.default_rng(3)
    rows, kc = 32, 9
    q, kg, _, mask = _inputs(rng, rows, kc, 3, 1)
    vg = jnp.ones((rows, kc, 1), jnp.float32)
    out = cauchy_topk_attention(q, kg, vg, mask, jnp.asarray(0.7, jnp.float32))
    np.testing.assert_allclose(out, np.ones((rows, 1)), atol=ATOL)


def test_gamma_limit_behaviour():
    """Large gamma^2 flattens attention toward the mean of valid values."""
    rng = np.random.default_rng(4)
    q, kg, vg, mask = _inputs(rng, 16, 7, 3, 5, mask_p=1.0)
    out = cauchy_topk_attention(q, kg, vg, mask, jnp.asarray(1e6, jnp.float32))
    np.testing.assert_allclose(out, jnp.mean(vg, axis=1), atol=1e-3)


def test_grads_match_ref():
    rng = np.random.default_rng(5)
    q, kg, vg, mask = _inputs(rng, 40, 9, 3, 16)
    eps = jnp.asarray(0.3, jnp.float32)

    def f(fn):
        def loss(q, kg, vg, eps):
            return jnp.sum(jnp.tanh(fn(q, kg, vg, mask, eps)))
        return jax.grad(loss, argnums=(0, 1, 2, 3))(q, kg, vg, eps)

    g = f(cauchy_topk_attention)
    gr = f(cauchy_topk_attention_ref)
    for a, b, nm in zip(g, gr, ("q", "k", "v", "eps")):
        np.testing.assert_allclose(a, b, atol=5e-5, err_msg=f"grad {nm}")


def test_grad_eps_numerical():
    """dL/d(gamma^2) against central finite differences."""
    rng = np.random.default_rng(6)
    q, kg, vg, mask = _inputs(rng, 12, 5, 2, 3)

    def loss(e):
        return jnp.sum(cauchy_topk_attention(q, kg, vg, mask, e))

    e0 = jnp.asarray(0.4, jnp.float32)
    g = jax.grad(loss)(e0)
    h = 1e-3
    fd = (loss(e0 + h) - loss(e0 - h)) / (2 * h)
    np.testing.assert_allclose(g, fd, rtol=2e-2)


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 70),
    kc=st.integers(1, 40),
    d=st.integers(1, 8),
    dv=st.integers(1, 48),
    eps=st.floats(1e-3, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_forward_sweep(rows, kc, d, dv, eps, seed):
    rng = np.random.default_rng(seed)
    q, kg, vg, mask = _inputs(rng, rows, kc, d, dv)
    e = jnp.asarray(eps, jnp.float32)
    out = cauchy_topk_attention(q, kg, vg, mask, e)
    ref = cauchy_topk_attention_ref(q, kg, vg, mask, e)
    np.testing.assert_allclose(out, ref, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    rows=st.integers(2, 40),
    kc=st.integers(2, 17),
    dv=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_grad_sweep(rows, kc, dv, seed):
    rng = np.random.default_rng(seed)
    q, kg, vg, mask = _inputs(rng, rows, kc, 3, dv)
    eps = jnp.asarray(0.2, jnp.float32)

    def f(fn):
        def loss(q, kg, vg):
            return jnp.sum(fn(q, kg, vg, mask, eps) ** 2)
        return jax.grad(loss, argnums=(0, 1, 2))(q, kg, vg)

    for a, b in zip(f(cauchy_topk_attention), f(cauchy_topk_attention_ref)):
        np.testing.assert_allclose(a, b, atol=1e-4)


def test_dtype_bf16_values_close():
    """bfloat16 values flow through the kernel (scores stay f32)."""
    rng = np.random.default_rng(7)
    q, kg, vg, mask = _inputs(rng, 16, 9, 3, 8)
    out32 = cauchy_topk_attention(q, kg, vg, mask, jnp.asarray(0.5, jnp.float32))
    outbf = cauchy_topk_attention(
        q, kg, vg.astype(jnp.bfloat16).astype(jnp.float32), mask,
        jnp.asarray(0.5, jnp.float32))
    assert float(jnp.max(jnp.abs(out32 - outbf))) < 0.1
