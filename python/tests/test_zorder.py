"""L2 tests: Morton encoding properties (zorder.py)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import zorder


def _interleave_naive(coords, bits):
    """Bit-by-bit python reference of paper Eq. 4."""
    d = len(coords)
    z = 0
    for b in range(bits):
        for j in range(d):
            z |= ((coords[j] >> b) & 1) << (b * d + j)
    return z


def test_bits_for_dim():
    assert zorder.bits_for_dim(1) == 10
    assert zorder.bits_for_dim(3) == 10
    assert zorder.bits_for_dim(4) == 7
    assert zorder.bits_for_dim(8) == 3
    assert zorder.bits_for_dim(31) == 1


@settings(max_examples=50, deadline=None)
@given(
    d=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_interleave_matches_naive(d, seed):
    bits = zorder.bits_for_dim(d)
    rng = np.random.default_rng(seed)
    coords = rng.integers(0, 1 << bits, size=(20, d), dtype=np.uint32)
    z = zorder.interleave(jnp.asarray(coords), bits)
    want = [_interleave_naive(list(row), bits) for row in coords]
    np.testing.assert_array_equal(np.asarray(z), np.asarray(want, np.uint32))


def test_interleave_is_injective():
    """Distinct quantized points must get distinct codes."""
    bits, d = 4, 3
    grid = np.stack(np.meshgrid(*[np.arange(1 << bits)] * d, indexing="ij"), -1)
    pts = jnp.asarray(grid.reshape(-1, d).astype(np.uint32))
    z = np.asarray(zorder.interleave(pts, bits))
    assert len(np.unique(z)) == z.size


def test_interleave_monotone_per_axis():
    """Increasing one coordinate (others fixed) increases the code."""
    bits, d = 5, 3
    base = jnp.asarray(np.full((1 << bits, d), 7, np.uint32))
    for axis in range(d):
        pts = base.at[:, axis].set(jnp.arange(1 << bits, dtype=jnp.uint32))
        z = np.asarray(zorder.interleave(pts, bits)).astype(np.int64)
        assert np.all(np.diff(z) > 0), f"axis {axis}"


def test_quantize_clips_and_centers():
    lo = jnp.zeros((1, 2))
    inv = jnp.ones((1, 2))
    x = jnp.asarray([[-5.0, 0.0], [0.5, 1.0], [2.0, 0.25]], jnp.float32)
    q = np.asarray(zorder.quantize(x, lo, inv, 4))
    assert q[0, 0] == 0  # clipped below
    assert q[2, 0] == 15  # clipped above
    assert q[1, 1] == 15
    assert q[1, 0] in (7, 8)  # midpoint


def test_shared_grid_covers_union():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 16, 3)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 16, 3)) * 3, jnp.float32)
    lo, inv = zorder.shared_grid(q, k)
    both = jnp.concatenate([q, k], axis=-2)
    u = (both - lo) * inv
    assert float(u.min()) >= -1e-5 and float(u.max()) <= 1 + 1e-5


def test_encode_locality_beats_random():
    """Nearby points in R^3 should get nearer codes than random pairs —
    the property §3.1 relies on (checked statistically)."""
    rng = np.random.default_rng(1)
    x = rng.normal(size=(512, 3)).astype(np.float32)
    xq = jnp.asarray(x)[None]
    qz, _ = zorder.encode(xq, xq)
    z = np.asarray(qz)[0].astype(np.int64)

    # mean |z_i - z_j| over 1k near pairs (j = nearest neighbour) vs random.
    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    nn = d2.argmin(1)
    near = np.abs(z - z[nn]).mean()
    rand = np.abs(z - np.roll(z, 257)).mean()
    assert near < 0.5 * rand


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(1, 4))
def test_encode_shapes_and_range(seed, d):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(2, 33, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 33, d)), jnp.float32)
    qz, kz = zorder.encode(q, k)
    assert qz.shape == (2, 33) and kz.shape == (2, 33)
    bits = zorder.bits_for_dim(d)
    top = np.uint64(1) << np.uint64(bits * d)
    assert np.asarray(qz).astype(np.uint64).max() < top
    assert np.asarray(kz).astype(np.uint64).max() < top
