//! Small row-major f32 host tensor.
//!
//! Used by the data generators, the Rust-native attention kernels (Table 3/4
//! benchmarks) and metric computation. This is intentionally *not* a general
//! ndarray: exactly the operations the crate needs, with explicit shapes.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {shape:?} != data len {}", data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn randn(shape: &[usize], rng: &mut crate::util::rng::Rng, sigma: f32) -> Self {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, sigma);
        t
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Size in bytes of the backing buffer (for the Table-4 memory model).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// Row view for a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let (n, d) = (self.shape[0], self.shape[1]);
        assert_eq!(self.ndim(), 2);
        assert!(i < n);
        &self.data[i * d..(i + 1) * d]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let d = self.shape[1];
        assert_eq!(self.ndim(), 2);
        &mut self.data[i * d..(i + 1) * d]
    }

    /// C = A @ B for 2-D tensors (used by tests and tiny projections).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.ndim(), 2);
        assert_eq!(other.ndim(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2);
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[p * n..(p + 1) * n];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[", self.shape)?;
        for (i, v) in self.data.iter().take(8).enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{v:.4}")?;
        }
        if self.data.len() > 8 {
            write!(f, ", …")?;
        }
        write!(f, "]")
    }
}

/// Euclidean squared distance between two equal-length slices, dispatched
/// through [`crate::util::simd`]: the scalar backend is the seed loop
/// bit-for-bit; vector backends block by index with a fixed reduction tree
/// (alignment-independent, ≤ 1e-4 of scalar).
#[inline]
pub fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    crate::util::simd::sqdist(a, b)
}

/// Dot product, dispatched through [`crate::util::simd`] (same contract as
/// [`sqdist`]). Every matvec in the crate — readout logits included — rides
/// this one routine.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    crate::util::simd::dot(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = Tensor::randn(&[4, 4], &mut rng, 1.0);
        let mut eye = Tensor::zeros(&[4, 4]);
        for i in 0..4 {
            eye.data[i * 4 + i] = 1.0;
        }
        let b = a.matmul(&eye);
        assert!(a.max_abs_diff(&b) < 1e-6);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn rows_and_bytes() {
        let t = Tensor::from_vec(&[2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.row(1), &[3., 4., 5.]);
        assert_eq!(t.bytes(), 24);
    }

    #[test]
    fn sqdist_dot() {
        assert_eq!(sqdist(&[0.0, 3.0], &[4.0, 0.0]), 25.0);
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }
}
