//! Speculative-decode draft sources.
//!
//! Speculative decoding splits each emitted token's cost in two: a cheap
//! **drafter** proposes a short continuation, and the full target kernel
//! **verifies** all proposed positions in one fused wave, committing the
//! longest prefix whose argmax matches plus the bonus token the verify
//! wave computed at the first divergence. Because the verify side runs the
//! exact per-token `step` arithmetic of non-speculative decoding, accepted
//! streams are bit-identical to plain decode no matter how bad the
//! drafter is — a weak drafter only costs speed, never correctness (the
//! tier-1 gate `rust/tests/spec_decode.rs` pins this).
//!
//! Two draft sources, selected by `--speculate`:
//!
//! * **mamba** — a constant-state selective-SSM stream
//!   ([`super::mamba::MambaLite`]) fed the same embedded rows as the
//!   target. Its recurrence is O(dv·n_state) per token with O(1) state in
//!   the context length — the *Transformers are RNNs* framing: a
//!   recurrent model drafts, the full attention kernel verifies.
//! * **self** — self-speculation via [`DecodeState::fork_draft`]: a
//!   copy-on-write fork of the target's own state (shared `ZIndex` runs
//!   and KV pages) whose selection is narrowed — for ZETA, `k` and the
//!   candidate window shrink by [`super::zeta::DRAFT_NARROWING`] — so a
//!   draft step prices a fraction of a full step while reading the exact
//!   same history.
//!
//! The drafter owns *no* model weights: embedding and readout live in the
//! model layer ([`crate::coordinator::session::NativeDecodeModel`]), which
//! drives both context catch-up and proposal stepping through the
//! [`DecodeState`] interface below.

use std::sync::Arc;

use super::mamba::MambaLite;
use super::{AttentionImpl, DecodeState};
use crate::util::arena::PageArena;

/// Which draft source serving sessions speculate with (`--speculate`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DraftSource {
    /// Speculation disabled — every token is one plain full-kernel step.
    Off,
    /// Constant-state mamba RNN drafter, verified by the target kernel.
    Mamba,
    /// Low-`k` self-speculation over the target's own forked state.
    SelfSpec,
}

impl DraftSource {
    /// The accepted `--speculate` values, for startup error messages.
    pub const ACCEPTED: &'static str = "off, mamba, self";

    pub fn parse(s: &str) -> Option<DraftSource> {
        Some(match s {
            "off" => DraftSource::Off,
            "mamba" => DraftSource::Mamba,
            "self" => DraftSource::SelfSpec,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            DraftSource::Off => "off",
            DraftSource::Mamba => "mamba",
            DraftSource::SelfSpec => "self",
        }
    }
}

/// A cheap token-proposal source attached to one serving session.
///
/// The model layer drives it in three phases per decode wave: catch the
/// persistent [`Drafter::context`] state up to the committed stream (a
/// drafter is *never* rolled back — rejected proposals simply aren't fed
/// to it), [`Drafter::begin`] a scratch fork to step proposals on, and
/// drop the fork after the verify wave. All persistent state lives on the
/// session arena, so drafts count against `--kv-mem-budget` like any
/// other per-session bytes and [`Drafter::shed`] frees them first under
/// pressure.
pub trait Drafter: Send {
    /// Draft-source name (matches [`DraftSource::name`]).
    fn name(&self) -> &'static str;

    /// The persistent context state that must track the committed token
    /// stream, creating it (empty) on first call. The model layer feeds
    /// it every committed token before drafting. `None`: this drafter
    /// re-forks the target each wave and needs no feeding.
    fn context(&mut self) -> Option<&mut dyn DecodeState>;

    /// Fork the scratch state this wave's proposals are stepped on,
    /// positioned at the drafter's current context. `None`: the drafter
    /// cannot propose this wave (no context yet, or the target kernel
    /// offers no draft configuration) — the session falls back to a
    /// plain single-token step.
    fn begin(&mut self, target: &dyn DecodeState) -> Option<Box<dyn DecodeState>>;

    /// Arena bytes the drafter's *persistent* state pins (scratch forks
    /// are transient within one sweep and not counted here).
    fn state_bytes(&self) -> usize;

    /// Return all persistent drafter pages to the arena (budget
    /// shedding). The context re-grows lazily from the committed stream
    /// on a later wave; shedding never perturbs the target state.
    fn shed(&mut self);
}

/// The mamba constant-state RNN drafter: one private
/// [`super::mamba::MambaDecode`] stream per session, fed the same
/// embedded q/k/v rows as the target so its proposals share the model's
/// embedding/readout geometry while its state stays O(1) in the context.
pub struct MambaDrafter {
    imp: MambaLite,
    d: usize,
    dv: usize,
    arena: Arc<PageArena>,
    state: Option<Box<dyn DecodeState>>,
}

impl MambaDrafter {
    pub fn new(d: usize, dv: usize, arena: &Arc<PageArena>) -> MambaDrafter {
        MambaDrafter { imp: MambaLite::default(), d, dv, arena: arena.clone(), state: None }
    }
}

impl Drafter for MambaDrafter {
    fn name(&self) -> &'static str {
        "mamba"
    }

    fn context(&mut self) -> Option<&mut dyn DecodeState> {
        if self.state.is_none() {
            self.state = Some(self.imp.begin_decode_in(self.d, self.dv, &self.arena));
        }
        Some(self.state.as_mut().unwrap().as_mut())
    }

    fn begin(&mut self, _target: &dyn DecodeState) -> Option<Box<dyn DecodeState>> {
        self.state.as_ref().map(|s| s.fork())
    }

    fn state_bytes(&self) -> usize {
        self.state.as_ref().map(|s| s.state_bytes()).unwrap_or(0)
    }

    fn shed(&mut self) {
        if let Some(mut s) = self.state.take() {
            s.release();
        }
    }
}

/// Self-speculation: no state of its own — every wave forks the target
/// through [`DecodeState::fork_draft`] (copy-on-write, shared pages and
/// `ZIndex` runs), so the draft context is the committed stream by
/// construction and there is nothing to catch up or shed.
pub struct SelfDrafter;

impl Drafter for SelfDrafter {
    fn name(&self) -> &'static str {
        "self"
    }

    fn context(&mut self) -> Option<&mut dyn DecodeState> {
        None
    }

    fn begin(&mut self, target: &dyn DecodeState) -> Option<Box<dyn DecodeState>> {
        target.fork_draft()
    }

    fn state_bytes(&self) -> usize {
        0
    }

    fn shed(&mut self) {}
}

/// The one `DraftSource → Drafter` factory (`Off` yields `None`). `d` /
/// `dv` and the arena size the mamba drafter's private stream; the self
/// drafter ignores them.
pub fn drafter_for(
    source: DraftSource,
    d: usize,
    dv: usize,
    arena: &Arc<PageArena>,
) -> Option<Box<dyn Drafter>> {
    match source {
        DraftSource::Off => None,
        DraftSource::Mamba => Some(Box::new(MambaDrafter::new(d, dv, arena))),
        DraftSource::SelfSpec => Some(Box::new(SelfDrafter)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::kernel_by_name;
    use crate::util::rng::Rng;

    #[test]
    fn draft_source_parses_exactly_the_cli_names() {
        assert_eq!(DraftSource::parse("off"), Some(DraftSource::Off));
        assert_eq!(DraftSource::parse("mamba"), Some(DraftSource::Mamba));
        assert_eq!(DraftSource::parse("self"), Some(DraftSource::SelfSpec));
        assert_eq!(DraftSource::parse("selfspec"), None);
        assert_eq!(DraftSource::parse(""), None);
        for s in [DraftSource::Off, DraftSource::Mamba, DraftSource::SelfSpec] {
            assert_eq!(DraftSource::parse(s.name()), Some(s));
        }
    }

    fn rows(rng: &mut Rng, n: usize, w: usize) -> Vec<f32> {
        (0..n * w).map(|_| rng.f32() * 2.0 - 1.0).collect()
    }

    #[test]
    fn mamba_drafter_context_grows_forks_and_sheds() {
        let arena = PageArena::new(16);
        let (d, dv) = (8, 8);
        let mut drafter = MambaDrafter::new(d, dv, &arena);
        assert_eq!(drafter.state_bytes(), 0);
        let target = kernel_by_name("naive").unwrap().begin_decode_in(d, dv, &arena);
        // No context yet: nothing to fork proposals from.
        assert!(drafter.begin(target.as_ref()).is_none());

        let mut rng = Rng::new(0x5bec_0001);
        let (q, k, v) = (rows(&mut rng, 6, d), rows(&mut rng, 6, d), rows(&mut rng, 6, dv));
        let mut out = vec![0f32; dv];
        let ctx = drafter.context().expect("mamba drafter keeps persistent context");
        for t in 0..6 {
            let (qr, kr) = (&q[t * d..(t + 1) * d], &k[t * d..(t + 1) * d]);
            ctx.step(qr, kr, &v[t * dv..(t + 1) * dv], &mut out);
        }
        assert!(drafter.state_bytes() > 0, "fed context must pin arena bytes");

        // A scratch fork steps independently without perturbing the context.
        let mut fork = drafter.begin(target.as_ref()).expect("fed context forks");
        assert_eq!(fork.pos(), 6);
        fork.step(&q[..d], &k[..d], &v[..dv], &mut out);
        assert_eq!(fork.pos(), 7);
        assert_eq!(drafter.context().unwrap().pos(), 6);
        drop(fork);

        drafter.shed();
        assert_eq!(drafter.state_bytes(), 0, "shed must drop every persistent byte");
        assert!(drafter.begin(target.as_ref()).is_none(), "shed drafter re-grows lazily");
        assert_eq!(drafter.context().unwrap().pos(), 0, "context restarts empty after shed");
    }

    #[test]
    fn self_drafter_forks_zeta_without_perturbing_the_target() {
        let arena = PageArena::new(16);
        let (d, dv) = (8, 8);
        let imp = kernel_by_name("zeta").unwrap();
        let mut target = imp.begin_decode_in(d, dv, &arena);
        let mut rng = Rng::new(0x5bec_0002);
        let n = 48;
        let (q, k, v) = (rows(&mut rng, n, d), rows(&mut rng, n, d), rows(&mut rng, n, dv));
        let mut out = vec![0f32; dv];
        for t in 0..n {
            let (qr, kr) = (&q[t * d..(t + 1) * d], &k[t * d..(t + 1) * d]);
            target.step(qr, kr, &v[t * dv..(t + 1) * dv], &mut out);
        }
        let control = target.fork();

        let mut drafter = SelfDrafter;
        assert!(drafter.context().is_none(), "self drafter carries no context");
        assert_eq!(drafter.state_bytes(), 0);
        let mut draft = drafter.begin(target.as_ref()).expect("zeta offers a draft fork");
        assert_eq!(draft.pos(), target.pos(), "draft fork sits at the target's position");
        // Stepping the narrowed draft must not perturb the target: the
        // target's next step stays bit-identical to an untouched fork's.
        let mut draft_out = vec![0f32; dv];
        draft.step(&q[..d], &k[..d], &v[..dv], &mut draft_out);
        drop(draft);
        let mut a = vec![0f32; dv];
        let mut b = vec![0f32; dv];
        target.step(&q[..d], &k[..d], &v[..dv], &mut a);
        let mut control = control;
        control.step(&q[..d], &k[..d], &v[..dv], &mut b);
        assert_eq!(a, b, "draft stepping leaked into the target state");
    }

    #[test]
    fn exact_softmax_kernels_offer_no_self_draft() {
        let arena = PageArena::new(16);
        for name in ["naive", "flash", "mamba"] {
            let st = kernel_by_name(name).unwrap().begin_decode_in(4, 4, &arena);
            assert!(
                st.fork_draft().is_none(),
                "{name} has no narrowed configuration; SelfDrafter must fall back"
            );
        }
    }
}
