//! Flash-style blocked attention — exact softmax attention with streaming
//! normalization (forward) and recompute (backward); O(N) extra memory.
//!
//! This is the CPU analogue of FlashAttention-2's algorithm: the score
//! matrix is never materialized. Per query block we stream over key blocks,
//! maintaining the running max `m_i`, normalizer `l_i` and the
//! un-normalized output accumulator. The backward pass stores only the
//! per-row logsumexp `L_i` and `D_i = dout_i . o_i`, recomputing score
//! blocks on the fly.

use super::{AttentionImpl, Grads, MemReport, Workload};
use crate::tensor::{dot, Tensor};

pub struct Flash {
    pub block: usize,
}

impl Flash {
    /// Forward that also returns per-row logsumexp (for the backward pass).
    fn fwd_with_lse(&self, w: &Workload) -> (Tensor, Vec<f32>, MemReport) {
        let n = w.n();
        let d = w.q.shape[1];
        let dv = w.v.shape[1];
        let scale = 1.0 / (d as f32).sqrt();
        let bs = self.block.max(1);

        let mut o = Tensor::zeros(&[n, dv]);
        let mut lse = vec![0f32; n];
        // Per-block workspace: scores (bs x bs), running stats (bs).
        let mut scores = vec![0f32; bs * bs];
        let mut mstat = vec![f32::NEG_INFINITY; bs];
        let mut lstat = vec![0f32; bs];

        let mut mem = MemReport::default();
        mem.workspace_bytes += (scores.len() + mstat.len() + lstat.len()) * 4 + n * 4;

        for qb in (0..n).step_by(bs) {
            let qe = (qb + bs).min(n);
            let rows = qe - qb;
            for s in mstat[..rows].iter_mut() {
                *s = f32::NEG_INFINITY;
            }
            for s in lstat[..rows].iter_mut() {
                *s = 0.0;
            }
            for r in qb..qe {
                for c in o.row_mut(r) {
                    *c = 0.0;
                }
            }
            for kb in (0..qe).step_by(bs) {
                let ke = (kb + bs).min(qe);
                // scores for this tile (causal-masked)
                for (ri, i) in (qb..qe).enumerate() {
                    let qi = w.q.row(i);
                    for (ci, j) in (kb..ke).enumerate() {
                        scores[ri * bs + ci] = if j <= i {
                            dot(qi, w.k.row(j)) * scale
                        } else {
                            f32::NEG_INFINITY
                        };
                    }
                }
                // online softmax update per row
                for (ri, i) in (qb..qe).enumerate() {
                    let mut mb = f32::NEG_INFINITY;
                    for ci in 0..(ke - kb) {
                        mb = mb.max(scores[ri * bs + ci]);
                    }
                    if mb == f32::NEG_INFINITY {
                        continue;
                    }
                    let mnew = mstat[ri].max(mb);
                    let corr = (mstat[ri] - mnew).exp();
                    let orow = o.row_mut(i);
                    if corr != 1.0 {
                        for c in orow.iter_mut() {
                            *c *= corr;
                        }
                    }
                    lstat[ri] *= corr;
                    for (ci, j) in (kb..ke).enumerate() {
                        let s = scores[ri * bs + ci];
                        if s == f32::NEG_INFINITY {
                            continue;
                        }
                        let p = (s - mnew).exp();
                        lstat[ri] += p;
                        let vrow = w.v.row(j);
                        for c in 0..dv {
                            orow[c] += p * vrow[c];
                        }
                    }
                    mstat[ri] = mnew;
                }
            }
            // normalize + record logsumexp
            for (ri, i) in (qb..qe).enumerate() {
                let inv = 1.0 / lstat[ri];
                for c in o.row_mut(i) {
                    *c *= inv;
                }
                lse[i] = mstat[ri] + lstat[ri].ln();
            }
        }
        mem.output_bytes = o.bytes();
        (o, lse, mem)
    }
}

impl AttentionImpl for Flash {
    fn name(&self) -> &'static str {
        "flash"
    }

    fn analytic_mem(&self, n: usize, d: usize, dv: usize, fb: bool) -> Option<MemReport> {
        // Mirrors fwd_with_lse / forward_backward allocations exactly.
        let bs = self.block.max(1);
        let fwd_ws = (bs * bs + 2 * bs + n) * 4;
        Some(if fb {
            MemReport {
                workspace_bytes: fwd_ws + n * 4 + n * dv * 4,
                output_bytes: (2 * n * d + n * dv) * 4,
            }
        } else {
            MemReport { workspace_bytes: fwd_ws, output_bytes: n * dv * 4 }
        })
    }

    fn forward(&self, w: &Workload) -> (Tensor, MemReport) {
        let (o, _, mem) = self.fwd_with_lse(w);
        (o, mem)
    }

    fn forward_backward(&self, w: &Workload) -> (Grads, MemReport) {
        let n = w.n();
        let d = w.q.shape[1];
        let dv = w.v.shape[1];
        let scale = 1.0 / (d as f32).sqrt();
        let bs = self.block.max(1);
        let (o, lse, mut mem) = self.fwd_with_lse(w);

        // D_i = dout_i . o_i  (the FA2 "delta")
        let mut delta = vec![0f32; n];
        for i in 0..n {
            delta[i] = dot(w.dout.row(i), o.row(i));
        }
        mem.workspace_bytes += n * 4 + o.bytes(); // delta + retained o/lse

        let mut dq = Tensor::zeros(&[n, d]);
        let mut dk = Tensor::zeros(&[n, d]);
        let mut dvt = Tensor::zeros(&[n, dv]);

        // Stream over key blocks; recompute P tile-by-tile.
        for kb in (0..n).step_by(bs) {
            let ke = (kb + bs).min(n);
            for i in kb..n {
                let qi = w.q.row(i);
                let gi = w.dout.row(i);
                let je = ke.min(i + 1);
                for j in kb..je {
                    let p = (dot(qi, w.k.row(j)) * scale - lse[i]).exp();
                    // dv_j += p * dout_i
                    let dvj = &mut dvt.data[j * dv..(j + 1) * dv];
                    let vj = w.v.row(j);
                    let da = dot(gi, vj);
                    let dsij = p * (da - delta[i]) * scale;
                    for c in 0..dv {
                        dvj[c] += p * gi[c];
                    }
                    // dq_i += dS_ij k_j ; dk_j += dS_ij q_i
                    let kj = w.k.row(j);
                    let dqi = &mut dq.data[i * d..(i + 1) * d];
                    for c in 0..d {
                        dqi[c] += dsij * kj[c];
                    }
                    let dkj = &mut dk.data[j * d..(j + 1) * d];
                    for c in 0..d {
                        dkj[c] += dsij * qi[c];
                    }
                }
            }
        }
        mem.output_bytes = dq.bytes() + dk.bytes() + dvt.bytes();
        (Grads { dq, dk, dv: dvt }, mem)
    }
}

#[cfg(test)]
mod tests {
    use super::super::naive::Naive;
    use super::*;

    #[test]
    fn forward_matches_naive() {
        for &n in &[7usize, 64, 130] {
            let w = Workload::random(n, 16, 8, 5);
            let (of, _) = Flash { block: 32 }.forward(&w);
            let (on, _) = Naive.forward(&w);
            assert!(of.max_abs_diff(&on) < 1e-4, "n={n}");
        }
    }

    #[test]
    fn backward_matches_naive() {
        let w = Workload::random(50, 8, 6, 6);
        let (gf, _) = Flash { block: 16 }.forward_backward(&w);
        let (gn, _) = Naive.forward_backward(&w);
        assert!(gf.dq.max_abs_diff(&gn.dq) < 1e-4);
        assert!(gf.dk.max_abs_diff(&gn.dk) < 1e-4);
        assert!(gf.dv.max_abs_diff(&gn.dv) < 1e-4);
    }

    #[test]
    fn memory_is_linear_not_quadratic() {
        let w1 = Workload::random(256, 8, 8, 7);
        let w2 = Workload::random(512, 8, 8, 7);
        let f = Flash { block: 64 };
        let (_, m1) = f.forward(&w1);
        let (_, m2) = f.forward(&w2);
        let ratio = m2.workspace_bytes as f64 / m1.workspace_bytes as f64;
        assert!(ratio < 2.5, "ratio {ratio}"); // ~2x for 2x N
    }

    #[test]
    fn block_size_does_not_change_result() {
        let w = Workload::random(33, 4, 4, 8);
        let (o1, _) = Flash { block: 4 }.forward(&w);
        let (o2, _) = Flash { block: 64 }.forward(&w);
        assert!(o1.max_abs_diff(&o2) < 1e-5);
    }
}
