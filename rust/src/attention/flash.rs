//! Flash-style blocked attention — exact softmax attention with streaming
//! normalization (forward) and recompute (backward); O(N) extra memory.
//!
//! This is the CPU analogue of FlashAttention-2's algorithm: the score
//! matrix is never materialized. Per query block we stream over key blocks,
//! maintaining the running max `m_i`, normalizer `l_i` and the
//! un-normalized output accumulator. The backward pass stores only the
//! per-row logsumexp `L_i` and `D_i = dout_i . o_i`, recomputing score
//! blocks on the fly.
//!
//! Parallel decomposition: query blocks are independent in the forward pass
//! (each worker owns a private score/stat scratch and writes disjoint rows
//! of O and lse). The backward is row-parallel over queries with dq rows
//! disjoint and per-thread dk/dv accumulators merged after the join.

use std::sync::Arc;

use super::naive::ExactKvDecode;
use super::{AttentionImpl, DecodeState, Grads, MemReport, Workload};
use crate::tensor::{dot, Tensor};
use crate::util::arena::PageArena;
use crate::util::pool::{merge_partials, Pool, SharedSlice};
use crate::util::simd;

pub struct Flash {
    pub block: usize,
}

impl Flash {
    /// Forward that also returns per-row logsumexp (for the backward pass).
    fn fwd_with_lse(&self, w: &Workload, pool: &Pool) -> (Tensor, Vec<f32>, MemReport) {
        let n = w.n();
        let d = w.q.shape[1];
        let dv = w.v.shape[1];
        let scale = 1.0 / (d as f32).sqrt();
        let bs = self.block.max(1);

        let mut o = Tensor::zeros(&[n, dv]);
        let mut lse = vec![0f32; n];
        let nblocks = (n + bs - 1) / bs;

        let mut mem = MemReport::default();
        mem.workspace_bytes += n * 4; // lse

        // Query blocks are claimed dynamically; each worker allocates its
        // scratch (scores tile + running stats) once and reports the bytes.
        {
            let osh = SharedSlice::new(&mut o.data);
            let lsh = SharedSlice::new(&mut lse);
            let scratch_bytes: Vec<usize> = pool.run_chunked(nblocks, 1, |queue| {
                // Per-worker scratch: score tile + running stats, allocated
                // once and reused across the blocks this worker claims.
                let mut scores = vec![0f32; bs * bs];
                let mut mstat = vec![f32::NEG_INFINITY; bs];
                let mut lstat = vec![0f32; bs];
                while let Some(blocks) = queue.next_chunk() {
                    for bi in blocks {
                        let qb = bi * bs;
                        let qe = (qb + bs).min(n);
                        let rows = qe - qb;
                        // Safety: rows [qb, qe) belong to this block only.
                        let oblk = unsafe { osh.range_mut(qb * dv..qe * dv) };
                        let lblk = unsafe { lsh.range_mut(qb..qe) };
                        for s in mstat[..rows].iter_mut() {
                            *s = f32::NEG_INFINITY;
                        }
                        for s in lstat[..rows].iter_mut() {
                            *s = 0.0;
                        }
                        for c in oblk.iter_mut() {
                            *c = 0.0;
                        }
                        for kb in (0..qe).step_by(bs) {
                            let ke = (kb + bs).min(qe);
                            // scores for this tile (causal-masked)
                            for (ri, i) in (qb..qe).enumerate() {
                                let qi = w.q.row(i);
                                for (ci, j) in (kb..ke).enumerate() {
                                    scores[ri * bs + ci] = if j <= i {
                                        dot(qi, w.k.row(j)) * scale
                                    } else {
                                        f32::NEG_INFINITY
                                    };
                                }
                            }
                            // online softmax update per row
                            for ri in 0..rows {
                                let mut mb = f32::NEG_INFINITY;
                                for ci in 0..(ke - kb) {
                                    mb = mb.max(scores[ri * bs + ci]);
                                }
                                if mb == f32::NEG_INFINITY {
                                    continue;
                                }
                                let mnew = mstat[ri].max(mb);
                                let corr = (mstat[ri] - mnew).exp();
                                let orow = &mut oblk[ri * dv..(ri + 1) * dv];
                                if corr != 1.0 {
                                    simd::scale(orow, corr);
                                }
                                lstat[ri] *= corr;
                                for (ci, j) in (kb..ke).enumerate() {
                                    let s = scores[ri * bs + ci];
                                    if s == f32::NEG_INFINITY {
                                        continue;
                                    }
                                    let p = (s - mnew).exp();
                                    lstat[ri] += p;
                                    simd::axpy(orow, p, w.v.row(j));
                                }
                                mstat[ri] = mnew;
                            }
                        }
                        // normalize + record logsumexp
                        for ri in 0..rows {
                            let inv = 1.0 / lstat[ri];
                            simd::scale(&mut oblk[ri * dv..(ri + 1) * dv], inv);
                            lblk[ri] = mstat[ri] + lstat[ri].ln();
                        }
                    }
                }
                (scores.len() + mstat.len() + lstat.len()) * 4
            });
            mem.workspace_bytes += scratch_bytes.iter().sum::<usize>();
        }
        mem.output_bytes = o.bytes();
        (o, lse, mem)
    }
}

impl AttentionImpl for Flash {
    fn name(&self) -> &'static str {
        "flash"
    }

    fn analytic_mem(
        &self,
        n: usize,
        d: usize,
        dv: usize,
        fb: bool,
        threads: usize,
    ) -> Option<MemReport> {
        // Mirrors fwd_with_lse / forward_backward allocations: one score
        // tile + stats per worker, lse, and for the backward the delta
        // vector, retained o and per-thread dk/dv accumulators.
        let bs = self.block.max(1);
        let fwd_ws = threads * (bs * bs + 2 * bs) * 4 + n * 4;
        Some(if fb {
            MemReport {
                workspace_bytes: fwd_ws + n * 4 + n * dv * 4 + threads * (n * d + n * dv) * 4,
                output_bytes: (2 * n * d + n * dv) * 4,
            }
        } else {
            MemReport { workspace_bytes: fwd_ws, output_bytes: n * dv * 4 }
        })
    }

    fn forward_with(&self, w: &Workload, pool: &Pool) -> (Tensor, MemReport) {
        let (o, _, mem) = self.fwd_with_lse(w, pool);
        (o, mem)
    }

    /// Single-row decode has no blocking to exploit — flash shares the
    /// exact-softmax KV-cache state with `naive` (the streaming-softmax
    /// forward agrees with the exact row softmax within fp tolerance, as
    /// the flash-vs-naive gates already pin).
    fn begin_decode_in(
        &self,
        d: usize,
        dv: usize,
        arena: &Arc<PageArena>,
    ) -> Box<dyn DecodeState> {
        Box::new(ExactKvDecode::new(d, dv, arena))
    }

    fn forward_backward_with(&self, w: &Workload, pool: &Pool) -> (Grads, MemReport) {
        let n = w.n();
        let d = w.q.shape[1];
        let dv = w.v.shape[1];
        let scale = 1.0 / (d as f32).sqrt();
        let (o, lse, mut mem) = self.fwd_with_lse(w, pool);

        // D_i = dout_i . o_i  (the FA2 "delta")
        let mut delta = vec![0f32; n];
        {
            let dsh = SharedSlice::new(&mut delta);
            pool.parallel_for(n, pool.grain(n, 64), |rows| {
                for i in rows {
                    // Safety: index i claimed by exactly one chunk.
                    unsafe { dsh.write(i, dot(w.dout.row(i), o.row(i))) };
                }
            });
        }
        mem.workspace_bytes += n * 4 + o.bytes(); // delta + retained o/lse

        let mut dq = Tensor::zeros(&[n, d]);
        let mut dk = Tensor::zeros(&[n, d]);
        let mut dvt = Tensor::zeros(&[n, dv]);

        // Row-parallel over queries, recomputing P tile-by-tile: dq rows
        // are disjoint; dk/dv scatter over keys, so workers accumulate into
        // private buffers merged after the join. The key-block tiling of
        // the serial kernel is kept inside each claimed row chunk so K/V
        // tiles stay cache-resident.
        let bs = self.block.max(1);
        let grain = pool.grain(n, 16);
        let parts: Vec<(Vec<f32>, Vec<f32>)> = {
            let dqsh = SharedSlice::new(&mut dq.data);
            pool.run_chunked(n, grain, |queue| {
                let mut dk_local = vec![0f32; n * d];
                let mut dv_local = vec![0f32; n * dv];
                while let Some(rows) = queue.next_chunk() {
                    for kb in (0..rows.end).step_by(bs) {
                        let ke = (kb + bs).min(rows.end);
                        for i in rows.start.max(kb)..rows.end {
                            let qi = w.q.row(i);
                            let gi = w.dout.row(i);
                            // Safety: row i claimed by exactly one chunk.
                            let dqi = unsafe { dqsh.range_mut(i * d..(i + 1) * d) };
                            let je = ke.min(i + 1);
                            for j in kb..je {
                                let p = (dot(qi, w.k.row(j)) * scale - lse[i]).exp();
                                let vj = w.v.row(j);
                                let da = dot(gi, vj);
                                let dsij = p * (da - delta[i]) * scale;
                                // dv_j += p * dout_i
                                simd::axpy(&mut dv_local[j * dv..(j + 1) * dv], p, gi);
                                // dq_i += dS_ij k_j ; dk_j += dS_ij q_i
                                simd::axpy(dqi, dsij, w.k.row(j));
                                simd::axpy(&mut dk_local[j * d..(j + 1) * d], dsij, qi);
                            }
                        }
                    }
                }
                (dk_local, dv_local)
            })
        };
        merge_partials(&mut dk.data, parts.iter().map(|(dk_p, _)| dk_p.as_slice()));
        merge_partials(&mut dvt.data, parts.iter().map(|(_, dv_p)| dv_p.as_slice()));
        mem.workspace_bytes += parts.len() * (n * d + n * dv) * 4;
        mem.output_bytes = dq.bytes() + dk.bytes() + dvt.bytes();
        (Grads { dq, dk, dv: dvt }, mem)
    }
}

#[cfg(test)]
mod tests {
    use super::super::naive::Naive;
    use super::*;

    #[test]
    fn forward_matches_naive() {
        for &n in &[7usize, 64, 130] {
            let w = Workload::random(n, 16, 8, 5);
            let (of, _) = Flash { block: 32 }.forward(&w);
            let (on, _) = Naive.forward(&w);
            assert!(of.max_abs_diff(&on) < 1e-4, "n={n}");
        }
    }

    #[test]
    fn backward_matches_naive() {
        let w = Workload::random(50, 8, 6, 6);
        let (gf, _) = Flash { block: 16 }.forward_backward(&w);
        let (gn, _) = Naive.forward_backward(&w);
        assert!(gf.dq.max_abs_diff(&gn.dq) < 1e-4);
        assert!(gf.dk.max_abs_diff(&gn.dk) < 1e-4);
        assert!(gf.dv.max_abs_diff(&gn.dv) < 1e-4);
    }

    #[test]
    fn memory_is_linear_not_quadratic() {
        let w1 = Workload::random(256, 8, 8, 7);
        let w2 = Workload::random(512, 8, 8, 7);
        let f = Flash { block: 64 };
        let (_, m1) = f.forward(&w1);
        let (_, m2) = f.forward(&w2);
        let ratio = m2.workspace_bytes as f64 / m1.workspace_bytes as f64;
        assert!(ratio < 2.5, "ratio {ratio}"); // ~2x for 2x N
    }

    #[test]
    fn block_size_does_not_change_result() {
        let w = Workload::random(33, 4, 4, 8);
        let (o1, _) = Flash { block: 4 }.forward(&w);
        let (o2, _) = Flash { block: 64 }.forward(&w);
        assert!(o1.max_abs_diff(&o2) < 1e-5);
    }

    #[test]
    fn fused_step_batch_matches_serial_stepping() {
        // The fused cross-stream sweep over flash's exact-KV decode states
        // must be bit-identical to stepping each stream alone, at any
        // thread count (each slot runs the same serial arithmetic on its
        // own state — only the schedule changes).
        use super::super::DecodeStep;
        let f = Flash { block: 16 };
        let (d, dv, n_streams, steps) = (8usize, 4usize, 6usize, 40usize);
        let ws: Vec<Workload> =
            (0..n_streams).map(|s| Workload::random(steps, d, dv, 100 + s as u64)).collect();
        for threads in [1usize, 4] {
            let pool = Pool::new(threads);
            let mut fused: Vec<_> = (0..n_streams).map(|_| f.begin_decode(d, dv)).collect();
            let mut serial: Vec<_> = (0..n_streams).map(|_| f.begin_decode(d, dv)).collect();
            let mut of = vec![0f32; n_streams * dv];
            let mut os = vec![0f32; n_streams * dv];
            for t in 0..steps {
                {
                    let mut batch: Vec<DecodeStep> = fused
                        .iter_mut()
                        .zip(of.chunks_mut(dv))
                        .enumerate()
                        .map(|(s, (st, out))| DecodeStep {
                            state: st.as_mut(),
                            q: ws[s].q.row(t),
                            k: ws[s].k.row(t),
                            v: ws[s].v.row(t),
                            out,
                        })
                        .collect();
                    f.step_batch(&mut batch, &pool);
                }
                for (s, st) in serial.iter_mut().enumerate() {
                    st.step(
                        ws[s].q.row(t),
                        ws[s].k.row(t),
                        ws[s].v.row(t),
                        &mut os[s * dv..(s + 1) * dv],
                    );
                }
                assert_eq!(of, os, "threads={threads} t={t}");
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let w = Workload::random(129, 8, 8, 12);
        let f = Flash { block: 16 };
        let (os, _) = f.forward_with(&w, &Pool::serial());
        let (op, _) = f.forward_with(&w, &Pool::new(4));
        assert!(os.max_abs_diff(&op) < 1e-5);
        let (gs, _) = f.forward_backward_with(&w, &Pool::serial());
        let (gp, _) = f.forward_backward_with(&w, &Pool::new(4));
        assert!(gs.dq.max_abs_diff(&gp.dq) < 1e-4);
        assert!(gs.dk.max_abs_diff(&gp.dk) < 1e-4);
        assert!(gs.dv.max_abs_diff(&gp.dv) < 1e-4);
    }
}
