//! Naive causal softmax attention — the "Torch Attention" baseline of
//! Tables 3–4: materializes the full (N, N) score matrix in both passes.

use super::{AttentionImpl, Grads, MemReport, Workload};
use crate::tensor::{dot, Tensor};

pub struct Naive;

impl Naive {
    /// Returns (output, attention matrix) — the bwd pass reuses A.
    fn fwd_full(&self, w: &Workload) -> (Tensor, Tensor) {
        let n = w.n();
        let d = w.q.shape[1];
        let dv = w.v.shape[1];
        let scale = 1.0 / (d as f32).sqrt();
        let mut a = Tensor::zeros(&[n, n]);
        let mut o = Tensor::zeros(&[n, dv]);
        for i in 0..n {
            let qi = w.q.row(i);
            let arow = &mut a.data[i * n..(i + 1) * n];
            let mut maxv = f32::NEG_INFINITY;
            for j in 0..=i {
                let s = dot(qi, w.k.row(j)) * scale;
                arow[j] = s;
                maxv = maxv.max(s);
            }
            let mut z = 0.0;
            for v in arow[..=i].iter_mut() {
                *v = (*v - maxv).exp();
                z += *v;
            }
            let inv = 1.0 / z;
            for v in arow[..=i].iter_mut() {
                *v *= inv;
            }
            let orow = &mut o.data[i * dv..(i + 1) * dv];
            for j in 0..=i {
                let aij = arow[j];
                let vrow = w.v.row(j);
                for c in 0..dv {
                    orow[c] += aij * vrow[c];
                }
            }
        }
        (o, a)
    }
}

impl AttentionImpl for Naive {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn analytic_mem(&self, n: usize, d: usize, dv: usize, fb: bool) -> Option<MemReport> {
        // fwd: A (N,N); fwd+bwd: A + dS (N,N each) + retained o.
        let quad = n * n * 4;
        Some(if fb {
            MemReport {
                workspace_bytes: 2 * quad + n * dv * 4,
                output_bytes: (2 * n * d + n * dv) * 4,
            }
        } else {
            MemReport { workspace_bytes: quad, output_bytes: n * dv * 4 }
        })
    }

    fn forward(&self, w: &Workload) -> (Tensor, MemReport) {
        let (o, a) = self.fwd_full(w);
        let mut mem = MemReport::default();
        mem.add(&a); // the O(N^2) matrix is workspace
        mem.output_bytes = o.bytes();
        (o, mem)
    }

    fn forward_backward(&self, w: &Workload) -> (Grads, MemReport) {
        let n = w.n();
        let d = w.q.shape[1];
        let dv = w.v.shape[1];
        let scale = 1.0 / (d as f32).sqrt();
        let (o, a) = self.fwd_full(w);

        let mut dq = Tensor::zeros(&[n, d]);
        let mut dk = Tensor::zeros(&[n, d]);
        let mut dvt = Tensor::zeros(&[n, dv]);
        let mut ds = Tensor::zeros(&[n, n]); // O(N^2) workspace again

        // dv_j = sum_i A_ij dout_i ; dA_ij = dout_i . v_j
        // dS_ij = A_ij (dA_ij - sum_l A_il dA_il)
        for i in 0..n {
            let gi = w.dout.row(i);
            let arow = &a.data[i * n..(i + 1) * n];
            // rowdot = sum_l A_il (dout_i . v_l) = dout_i . o_i
            let rowdot = dot(gi, o.row(i));
            let dsrow = &mut ds.data[i * n..(i + 1) * n];
            for j in 0..=i {
                let da = dot(gi, w.v.row(j));
                dsrow[j] = arow[j] * (da - rowdot);
                // accumulate dv
                let dvj = &mut dvt.data[j * dv..(j + 1) * dv];
                for c in 0..dv {
                    dvj[c] += arow[j] * gi[c];
                }
            }
        }
        // dq_i = scale * sum_j dS_ij k_j ; dk_j = scale * sum_i dS_ij q_i
        for i in 0..n {
            let dsrow = &ds.data[i * n..(i + 1) * n];
            let dqi = &mut dq.data[i * d..(i + 1) * d];
            for j in 0..=i {
                let s = dsrow[j] * scale;
                if s == 0.0 {
                    continue;
                }
                let kj = w.k.row(j);
                for c in 0..d {
                    dqi[c] += s * kj[c];
                }
            }
        }
        for j in 0..n {
            let dkj = &mut dk.data[j * d..(j + 1) * d];
            for i in j..n {
                let s = ds.data[i * n + j] * scale;
                if s == 0.0 {
                    continue;
                }
                let qi = w.q.row(i);
                for c in 0..d {
                    dkj[c] += s * qi[c];
                }
            }
        }

        let mut mem = MemReport::default();
        mem.add(&a);
        mem.add(&ds);
        mem.workspace_bytes += o.bytes(); // o is retained for the backward
        mem.output_bytes = dq.bytes() + dk.bytes() + dvt.bytes();
        (Grads { dq, dk, dv: dvt }, mem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loss(q: &[f32], w: &Workload, n: usize, d: usize) -> f32 {
        // scalar loss = sum(o * dout) with q replaced
        let mut w2 = Workload {
            q: Tensor::from_vec(&[n, d], q.to_vec()),
            k: w.k.clone(),
            v: w.v.clone(),
            dout: w.dout.clone(),
        };
        let (o, _) = Naive.forward(&w2);
        let s: f32 = o.data.iter().zip(&w2.dout.data).map(|(a, b)| a * b).sum();
        w2.q.data.clear();
        s
    }

    #[test]
    fn output_rows_are_convex_combos() {
        let w = Workload::random(16, 8, 4, 0);
        let mut wc = w;
        wc.v = Tensor::from_vec(&[16, 4], vec![1.0; 64]);
        let (o, _) = Naive.forward(&wc);
        for v in &o.data {
            assert!((v - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn first_row_attends_only_to_itself() {
        let w = Workload::random(8, 4, 4, 1);
        let (o, _) = Naive.forward(&w);
        for c in 0..4 {
            assert!((o.data[c] - w.v.data[c]).abs() < 1e-6);
        }
    }

    #[test]
    fn grad_q_matches_finite_difference() {
        let n = 6;
        let d = 3;
        let w = Workload::random(n, d, 2, 2);
        let (g, _) = Naive.forward_backward(&w);
        let mut q = w.q.data.clone();
        super::super::numeric_grad_check(|qq| loss(qq, &w, n, d), &mut q, &g.dq.data, 1e-3);
    }

    #[test]
    fn memory_is_quadratic() {
        let w1 = Workload::random(64, 8, 8, 3);
        let w2 = Workload::random(128, 8, 8, 3);
        let (_, m1) = Naive.forward(&w1);
        let (_, m2) = Naive.forward(&w2);
        let ratio = m2.workspace_bytes as f64 / m1.workspace_bytes as f64;
        assert!((ratio - 4.0).abs() < 0.2, "ratio {ratio}");
    }
}
