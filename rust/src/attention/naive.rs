//! Naive causal softmax attention — the "Torch Attention" baseline of
//! Tables 3–4: materializes the full (N, N) score matrix in both passes.
//!
//! Parallel decomposition: every query row is independent in the forward
//! pass (disjoint rows of A and O), and the backward splits into a
//! row-parallel dS/dV phase (per-thread dV accumulators merged at the end),
//! a row-parallel dQ phase and a column-parallel dK phase.

use std::sync::Arc;

use super::{AttentionImpl, DecodeState, Grads, MemReport, Workload};
use crate::tensor::{dot, Tensor};
use crate::util::arena::{PageArena, PagedKv, RowStore};
use crate::util::pool::{merge_partials, Pool, SharedSlice};
use crate::util::simd;

pub struct Naive;

/// Exact-softmax KV-cache decode state, shared by `naive` and `flash`: the
/// cache grows one row per token and each step computes a single causal
/// attention row — O(t·d) per token, versus O(t²·d) for recomputing the
/// full forward. The per-row arithmetic (max-subtracted exp, normalize,
/// then accumulate in key order) mirrors the naive kernel exactly, so
/// decode outputs are bit-compatible with prefill. The K/V rows live on
/// arena pages ([`PagedKv`]), so forks share the cached prefix
/// copy-on-write and preemption returns the pages to the arena.
pub struct ExactKvDecode {
    d: usize,
    dv: usize,
    k: PagedKv,
    v: PagedKv,
    scores: Vec<f32>,
    t: usize,
}

impl ExactKvDecode {
    pub fn new(d: usize, dv: usize, arena: &Arc<PageArena>) -> ExactKvDecode {
        ExactKvDecode {
            d,
            dv,
            k: PagedKv::new(arena, d),
            v: PagedKv::new(arena, dv),
            scores: Vec::new(),
            t: 0,
        }
    }
}

impl DecodeState for ExactKvDecode {
    fn step(&mut self, q_t: &[f32], k_t: &[f32], v_t: &[f32], out: &mut [f32]) {
        let (d, dv) = (self.d, self.dv);
        debug_assert_eq!(q_t.len(), d);
        debug_assert_eq!(k_t.len(), d);
        debug_assert_eq!(v_t.len(), dv);
        debug_assert_eq!(out.len(), dv);
        self.k.push_row(k_t);
        self.v.push_row(v_t);
        let t = self.t;
        self.t += 1;
        let scale = 1.0 / (d as f32).sqrt();
        self.scores.clear();
        let mut maxv = f32::NEG_INFINITY;
        for j in 0..=t {
            let s = self.k.dot_row(j, q_t) * scale;
            self.scores.push(s);
            maxv = maxv.max(s);
        }
        let mut z = 0.0;
        for s in self.scores.iter_mut() {
            *s = (*s - maxv).exp();
            z += *s;
        }
        let inv = 1.0 / z;
        simd::scale(&mut self.scores, inv);
        for o in out.iter_mut() {
            *o = 0.0;
        }
        for j in 0..=t {
            self.v.axpy_row(j, self.scores[j], out);
        }
    }

    fn pos(&self) -> usize {
        self.t
    }

    fn step_cost_hint(&self) -> usize {
        // One exact softmax row over the cache: O(t·(d + dv)).
        (self.t + 1) * (self.d + self.dv + 4)
    }

    fn state_bytes(&self) -> usize {
        self.k.bytes() + self.v.bytes() + self.scores.capacity() * 4
    }

    fn fork(&self) -> Box<dyn DecodeState> {
        Box::new(ExactKvDecode {
            d: self.d,
            dv: self.dv,
            k: self.k.fork(),
            v: self.v.fork(),
            scores: Vec::new(),
            t: self.t,
        })
    }

    fn release(&mut self) {
        self.k.release();
        self.v.release();
        self.scores = Vec::new();
        self.t = 0;
    }
}

impl Naive {
    /// Returns (output, attention matrix) — the bwd pass reuses A.
    fn fwd_full(&self, w: &Workload, pool: &Pool) -> (Tensor, Tensor) {
        let n = w.n();
        let d = w.q.shape[1];
        let dv = w.v.shape[1];
        let scale = 1.0 / (d as f32).sqrt();
        let mut a = Tensor::zeros(&[n, n]);
        let mut o = Tensor::zeros(&[n, dv]);
        {
            let ash = SharedSlice::new(&mut a.data);
            let osh = SharedSlice::new(&mut o.data);
            pool.parallel_for(n, pool.grain(n, 8), |rows| {
                for i in rows {
                    let qi = w.q.row(i);
                    // Safety: row i is claimed by exactly one chunk.
                    let arow = unsafe { ash.range_mut(i * n..(i + 1) * n) };
                    let orow = unsafe { osh.range_mut(i * dv..(i + 1) * dv) };
                    let mut maxv = f32::NEG_INFINITY;
                    for j in 0..=i {
                        let s = dot(qi, w.k.row(j)) * scale;
                        arow[j] = s;
                        maxv = maxv.max(s);
                    }
                    let mut z = 0.0;
                    for v in arow[..=i].iter_mut() {
                        *v = (*v - maxv).exp();
                        z += *v;
                    }
                    let inv = 1.0 / z;
                    simd::scale(&mut arow[..=i], inv);
                    for j in 0..=i {
                        simd::axpy(orow, arow[j], w.v.row(j));
                    }
                }
            });
        }
        (o, a)
    }
}

impl AttentionImpl for Naive {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn analytic_mem(
        &self,
        n: usize,
        d: usize,
        dv: usize,
        fb: bool,
        threads: usize,
    ) -> Option<MemReport> {
        // fwd: A (N,N); fwd+bwd: A + dS (N,N each) + retained o + the
        // per-thread dV accumulators of the parallel backward.
        let quad = n * n * 4;
        Some(if fb {
            MemReport {
                workspace_bytes: 2 * quad + n * dv * 4 + threads * n * dv * 4,
                output_bytes: (2 * n * d + n * dv) * 4,
            }
        } else {
            MemReport { workspace_bytes: quad, output_bytes: n * dv * 4 }
        })
    }

    fn forward_with(&self, w: &Workload, pool: &Pool) -> (Tensor, MemReport) {
        let (o, a) = self.fwd_full(w, pool);
        let mut mem = MemReport::default();
        mem.add(&a); // the O(N^2) matrix is workspace
        mem.output_bytes = o.bytes();
        (o, mem)
    }

    fn begin_decode_in(
        &self,
        d: usize,
        dv: usize,
        arena: &Arc<PageArena>,
    ) -> Box<dyn DecodeState> {
        Box::new(ExactKvDecode::new(d, dv, arena))
    }

    fn forward_backward_with(&self, w: &Workload, pool: &Pool) -> (Grads, MemReport) {
        let n = w.n();
        let d = w.q.shape[1];
        let dv = w.v.shape[1];
        let scale = 1.0 / (d as f32).sqrt();
        let (o, a) = self.fwd_full(w, pool);

        let mut dq = Tensor::zeros(&[n, d]);
        let mut dk = Tensor::zeros(&[n, d]);
        let mut dvt = Tensor::zeros(&[n, dv]);
        let mut ds = Tensor::zeros(&[n, n]); // O(N^2) workspace again
        let grain = pool.grain(n, 8);

        // Phase 1 (row-parallel over i): dS rows are disjoint; dv_j scatters
        // across j, so each worker accumulates into a private buffer.
        // dv_j = sum_i A_ij dout_i ; dA_ij = dout_i . v_j
        // dS_ij = A_ij (dA_ij - sum_l A_il dA_il)
        let dv_parts: Vec<Vec<f32>> = {
            let dssh = SharedSlice::new(&mut ds.data);
            pool.run_chunked(n, grain, |queue| {
                let mut dv_local = vec![0f32; n * dv];
                while let Some(rows) = queue.next_chunk() {
                    for i in rows {
                        let gi = w.dout.row(i);
                        let arow = &a.data[i * n..(i + 1) * n];
                        // rowdot = sum_l A_il (dout_i . v_l) = dout_i . o_i
                        let rowdot = dot(gi, o.row(i));
                        // Safety: row i claimed by exactly one chunk.
                        let dsrow = unsafe { dssh.range_mut(i * n..(i + 1) * n) };
                        for j in 0..=i {
                            let da = dot(gi, w.v.row(j));
                            dsrow[j] = arow[j] * (da - rowdot);
                            let dvj = &mut dv_local[j * dv..(j + 1) * dv];
                            simd::axpy(dvj, arow[j], gi);
                        }
                    }
                }
                dv_local
            })
        };
        merge_partials(&mut dvt.data, dv_parts.iter().map(|p| p.as_slice()));

        // Phase 2 (row-parallel): dq_i = scale * sum_j dS_ij k_j.
        {
            let dqsh = SharedSlice::new(&mut dq.data);
            pool.parallel_for(n, grain, |rows| {
                for i in rows {
                    let dsrow = &ds.data[i * n..(i + 1) * n];
                    // Safety: row i claimed by exactly one chunk.
                    let dqi = unsafe { dqsh.range_mut(i * d..(i + 1) * d) };
                    for j in 0..=i {
                        let s = dsrow[j] * scale;
                        if s == 0.0 {
                            continue;
                        }
                        simd::axpy(dqi, s, w.k.row(j));
                    }
                }
            });
        }

        // Phase 3 (column-parallel): dk_j = scale * sum_i dS_ij q_i.
        {
            let dksh = SharedSlice::new(&mut dk.data);
            pool.parallel_for(n, grain, |cols| {
                for j in cols {
                    // Safety: column j claimed by exactly one chunk.
                    let dkj = unsafe { dksh.range_mut(j * d..(j + 1) * d) };
                    for i in j..n {
                        let s = ds.data[i * n + j] * scale;
                        if s == 0.0 {
                            continue;
                        }
                        simd::axpy(dkj, s, w.q.row(i));
                    }
                }
            });
        }

        let mut mem = MemReport::default();
        mem.add(&a);
        mem.add(&ds);
        mem.workspace_bytes += o.bytes(); // o is retained for the backward
        mem.workspace_bytes += dv_parts.iter().map(|p| p.len() * 4).sum::<usize>();
        mem.output_bytes = dq.bytes() + dk.bytes() + dvt.bytes();
        (Grads { dq, dk, dv: dvt }, mem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loss(q: &[f32], w: &Workload, n: usize, d: usize) -> f32 {
        // scalar loss = sum(o * dout) with q replaced
        let mut w2 = Workload {
            q: Tensor::from_vec(&[n, d], q.to_vec()),
            k: w.k.clone(),
            v: w.v.clone(),
            dout: w.dout.clone(),
        };
        let (o, _) = Naive.forward(&w2);
        let s: f32 = o.data.iter().zip(&w2.dout.data).map(|(a, b)| a * b).sum();
        w2.q.data.clear();
        s
    }

    #[test]
    fn output_rows_are_convex_combos() {
        let w = Workload::random(16, 8, 4, 0);
        let mut wc = w;
        wc.v = Tensor::from_vec(&[16, 4], vec![1.0; 64]);
        let (o, _) = Naive.forward(&wc);
        for v in &o.data {
            assert!((v - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn first_row_attends_only_to_itself() {
        let w = Workload::random(8, 4, 4, 1);
        let (o, _) = Naive.forward(&w);
        for c in 0..4 {
            assert!((o.data[c] - w.v.data[c]).abs() < 1e-6);
        }
    }

    #[test]
    fn grad_q_matches_finite_difference() {
        let n = 6;
        let d = 3;
        let w = Workload::random(n, d, 2, 2);
        let (g, _) = Naive.forward_backward(&w);
        let mut q = w.q.data.clone();
        super::super::numeric_grad_check(|qq| loss(qq, &w, n, d), &mut q, &g.dq.data, 1e-3);
    }

    #[test]
    fn memory_is_quadratic() {
        let w1 = Workload::random(64, 8, 8, 3);
        let w2 = Workload::random(128, 8, 8, 3);
        let (_, m1) = Naive.forward(&w1);
        let (_, m2) = Naive.forward(&w2);
        let ratio = m2.workspace_bytes as f64 / m1.workspace_bytes as f64;
        assert!((ratio - 4.0).abs() < 0.2, "ratio {ratio}");
    }

    #[test]
    fn parallel_matches_serial() {
        let w = Workload::random(97, 8, 6, 11);
        let (os, _) = Naive.forward_with(&w, &Pool::serial());
        let (op, _) = Naive.forward_with(&w, &Pool::new(4));
        assert!(os.max_abs_diff(&op) < 1e-5);
        let (gs, _) = Naive.forward_backward_with(&w, &Pool::serial());
        let (gp, _) = Naive.forward_backward_with(&w, &Pool::new(4));
        assert!(gs.dq.max_abs_diff(&gp.dq) < 1e-4);
        assert!(gs.dk.max_abs_diff(&gp.dk) < 1e-4);
        assert!(gs.dv.max_abs_diff(&gp.dv) < 1e-4);
    }
}
