//! ZETA native kernel: Z-order top-k Cauchy attention on CPU.
//!
//! This is Algorithm 1 of the paper plus the Appendix-E backward, end to
//! end in Rust: project to d_K dims -> Morton-encode -> *incrementally
//! sorted* persistent index ([`crate::zorder::index::ZIndex`]) -> per-query
//! window candidate lookup under the chunked causal mask -> Adaptive
//! Cauchy-Softmax over the k candidates + the history-mean smoothing token.
//! O(N log N) time, O(N·k) memory.
//!
//! ## Chunk-sequential search (strictly causal selection)
//!
//! Keys enter the index chunk by chunk; every query in chunk `c` (causal
//! limit `c·chunk`) searches the index frozen at exactly `c·chunk` keys.
//! Future keys therefore can no longer perturb the candidate *window* (the
//! seed kernel sorted all N keys up front and filtered afterwards, which
//! let future keys crowd past keys out of the window even though their
//! values never leaked). More importantly this is precisely the state the
//! incremental decode path maintains, so batched prefill
//! ([`AttentionImpl::forward_with`]) and per-token decode ([`ZetaDecode`])
//! run the *same* selection routine over the *same* index states and agree
//! bit-for-bit.
//!
//! Parallel decomposition (the paper's claim that Z-order sorting makes
//! top-k selection parallel — "all queries searched simultaneously"):
//! Morton encoding is point-parallel, and within each chunk phase all
//! queries (across all heads sharing the key order) search the frozen
//! index concurrently; the Cauchy-softmax accumulation is query-parallel.
//! Only the O(log N)-amortized index appends and the O(N·d) history-mean
//! prefix scans stay serial. The backward is query-parallel with
//! per-thread dK/dV accumulators merged once after the join.

use std::sync::Arc;

use super::{AttentionImpl, DecodeState, Grads, MemReport, Workload};
use crate::tensor::{sqdist, Tensor};
use crate::util::arena::{FlatRows, PageArena, PagedKv, PagedU32, RowStore};
use crate::util::pool::{merge_partials, Pool, SharedSlice};
use crate::util::simd;
use crate::zorder;
use crate::zorder::index::{WindowScratch, ZIndex};

#[derive(Debug, Clone)]
pub struct ZetaNative {
    /// Low dimension used for the search/scores (paper: 3).
    pub d_k: usize,
    /// Number of attended candidates per query (paper: 32).
    pub k: usize,
    /// Chunk size of the causal mask (paper: N / #chunks).
    pub chunk: usize,
    /// Candidate window in the sorted order (>= k to survive masking).
    pub window: usize,
    /// gamma^2 of the Cauchy kernel.
    pub eps: f32,
    /// Fixed quantization range.
    pub range: f32,
    /// Serving mode for `forward_batch`: heads of one sequence share the
    /// key z-ordering built from head 0's projected keys — one encode +
    /// one incremental sort serves all `heads` candidate searches (the
    /// paper's per-layer shared search; per-head query codes still
    /// binary-search the shared order, and scoring always uses each head's
    /// own keys/values). Off by default: every head sorts its own keys and
    /// the batched path matches the per-head loop exactly.
    pub shared_sort: bool,
}

impl Default for ZetaNative {
    fn default() -> Self {
        ZetaNative {
            d_k: 3,
            k: 32,
            chunk: 64,
            window: 64,
            eps: 0.5,
            range: 4.0,
            shared_sort: false,
        }
    }
}

/// How much a [`DecodeState::fork_draft`] self-speculation fork narrows
/// the selection: draft forks attend `k / DRAFT_NARROWING` candidates out
/// of a `window / DRAFT_NARROWING` window (floored at 1 candidate). At the
/// serving defaults (k 32, window 64) a draft step scores 4 candidates
/// from an 8-entry window — cheap enough to propose several tokens per
/// full-kernel verify wave, close enough that concentrated attention
/// (repetitive/templated traffic) keeps the proposals' argmax aligned
/// with the full kernel's.
pub const DRAFT_NARROWING: usize = 8;

/// Candidate sets for all queries: indices + count per query.
struct Candidates {
    idx: Vec<u32>, // (N, k) padded with u32::MAX
    k: usize,
}

/// Score one query row: Cauchy weights over its candidate slots + the
/// history-mean smoothing token, accumulated into `out`; returns the
/// normalizer Z (kept for the backward). This is the single shared
/// implementation behind both the batch accumulation and the decode step —
/// the bit-for-bit decode == prefill contract lives here, so any change to
/// the scoring arithmetic automatically applies to both schedules.
///
/// `irow` is one query's `u32::MAX`-padded candidate slot row; `kl` / `v`
/// are the key-projection and value row stores the slots index into —
/// generic over [`RowStore`], so the batch path scores out of its flat
/// buffers and the decode path out of its paged arena caches through the
/// *same* monomorphized arithmetic (identical op sequence either way, so
/// the bit-for-bit decode == prefill contract survives the paging).
///
/// The distance kernel and the AV accumulation run through the stores'
/// codec-aware [`RowStore`] lane ops (backed by [`crate::util::simd`]):
/// flat f32 buffers lower to the plain vector routines, quantized paged
/// caches dequantize-and-score in the same pass. `pub(crate)` so
/// `exp kernels` can bench it.
#[allow(clippy::too_many_arguments)]
pub(crate) fn cauchy_row<KR: RowStore, VR: RowStore>(
    eps: f32,
    irow: &[u32],
    qi: &[f32],
    kl: &KR,
    km_i: &[f32],
    vm_i: &[f32],
    v: &VR,
    scores: &mut [f32],
    out: &mut [f32],
) -> f32 {
    let mut z = 0.0f32;
    let mut nc = 0usize;
    for (slot, &j) in irow.iter().enumerate() {
        if j == u32::MAX {
            break;
        }
        let jj = j as usize;
        let s = 1.0 / (kl.sqdist_row(jj, qi) + eps);
        scores[slot] = s;
        z += s;
        nc = slot + 1;
    }
    let sm = 1.0 / (sqdist(qi, km_i) + eps);
    z += sm;
    let inv = 1.0 / z;
    for o in out.iter_mut() {
        *o = 0.0;
    }
    for slot in 0..nc {
        let jj = irow[slot] as usize;
        let a = scores[slot] * inv;
        v.axpy_row(jj, a, out);
    }
    simd::axpy(out, sm * inv, vm_i);
    z
}

impl ZetaNative {
    /// Slice the first d_k dims of q/k as the low-dimensional projection.
    /// (In the full system the projection is learned at L2; for the kernel
    /// benchmark a fixed projection is the honest equivalent.)
    fn project(&self, x: &Tensor, pool: &Pool) -> Vec<f32> {
        let n = x.shape[0];
        let d = x.shape[1];
        let dk = self.d_k.min(d);
        let mut out = vec![0f32; n * self.d_k];
        let wdk = self.d_k;
        {
            let osh = SharedSlice::new(&mut out);
            pool.parallel_for(n, pool.grain(n, 256), |rows| {
                for i in rows {
                    // Safety: row i claimed by exactly one chunk.
                    let orow = unsafe { osh.range_mut(i * wdk..(i + 1) * wdk) };
                    orow[..dk].copy_from_slice(&x.row(i)[..dk]);
                }
            });
        }
        out
    }

    /// Gather the top-k candidates for one query code against the frozen
    /// index (keys strictly before the query's causal chunk limit), writing
    /// candidate key positions into `irow` (pre-filled with `u32::MAX`).
    /// Shared verbatim by the batch search and the incremental decode step
    /// so both paths select identically: window entries arrive in global
    /// sorted order, and ties in curve distance break on key position via
    /// the `(dz, pos)` tuple order — deterministic across schedules.
    fn select_into(
        &self,
        qc_i: u32,
        index: &ZIndex,
        scratch: &mut WindowScratch,
        win: &mut Vec<(u32, u32)>,
        cand: &mut Vec<(u32, u32)>,
        irow: &mut [u32],
    ) {
        index.window_with(qc_i, self.window, scratch, win);
        cand.clear();
        for &(c, pos) in win.iter() {
            let dz = (c as i64 - qc_i as i64).unsigned_abs() as u32;
            cand.push((dz, pos));
        }
        let kk = self.k.min(cand.len());
        if kk == 0 {
            return;
        }
        if cand.len() > kk {
            cand.select_nth_unstable(kk - 1);
        }
        for (slot, &(_, pos)) in cand[..kk].iter().enumerate() {
            irow[slot] = pos;
        }
    }

    /// Strictly-causal candidate search over one shared persistent index.
    /// `qcs` holds one query-code set per head sharing this key ordering
    /// ("one sort serves `heads` searches"); `kc` is the shared key codes.
    ///
    /// Two schedules of the same selection, chosen by the prefill
    /// break-even ([`crate::util::breakeven::PARALLEL_PREFILL_SCORE_MIN_LOOKUPS`]):
    ///
    /// * **Chunk-sequential** ([`ZetaNative::search_multi_sequential`]) —
    ///   one pool region per chunk phase, keys appended between phases.
    ///   Serial at threads = 1 and the inline path for short prompts.
    /// * **Pipelined** ([`ZetaNative::search_multi_pipelined`]) — all keys
    ///   appended up front with an O(log N) [`ZIndex::fork`] snapshot at
    ///   every chunk boundary, then *all* (chunk, head, query) lookups fan
    ///   out in a single region, each against its chunk's frozen snapshot.
    ///   Kills the phase-barrier serialization wall on long prompts.
    ///
    /// Snapshots are observationally identical to the live index at the
    /// same prefix length (runs are immutable and `Arc`-shared), and
    /// [`ZetaNative::select_into`] is shared verbatim, so the two schedules
    /// produce bit-identical candidate tables — pinned per boundary by
    /// `rust/tests/prefill_parallel.rs`.
    fn search_multi(&self, qcs: &[&[u32]], kc: &[u32], pool: &Pool) -> (Vec<Candidates>, usize) {
        use crate::util::breakeven::{fan_out, PARALLEL_PREFILL_SCORE_MIN_LOOKUPS};
        let n = kc.len();
        let h = qcs.len();
        let chunk = self.chunk.max(1);
        // Queries in chunk 0 have an empty causal prefix and never search.
        let total = n.saturating_sub(chunk) * h;
        if fan_out(total, total, pool.threads(), PARALLEL_PREFILL_SCORE_MIN_LOOKUPS) {
            self.search_multi_pipelined(qcs, kc, pool)
        } else {
            self.search_multi_sequential(qcs, kc, pool)
        }
    }

    /// Chunk-sequential schedule: within each chunk phase, all (head,
    /// query) pairs search the frozen index in parallel; between phases the
    /// chunk's keys are appended. Phases run sequentially and free their
    /// scratch at each join, so the reported workspace is the *peak*
    /// phase, not the sum.
    fn search_multi_sequential(
        &self,
        qcs: &[&[u32]],
        kc: &[u32],
        pool: &Pool,
    ) -> (Vec<Candidates>, usize) {
        let n = kc.len();
        let h = qcs.len();
        let chunk = self.chunk.max(1);
        let kk_cap = self.k;
        let mut tables: Vec<Vec<u32>> = (0..h).map(|_| vec![u32::MAX; n * kk_cap]).collect();
        let mut index = ZIndex::new();
        let mut cand_ws = 0usize;
        {
            let shares: Vec<SharedSlice<u32>> =
                tables.iter_mut().map(|t| SharedSlice::new(t.as_mut_slice())).collect();
            // Per-phase serial fallback: below the shared break-even a
            // phase runs inline — waking the resident team would cost more
            // than the window scans it splits. With the parked pool the
            // bound is low enough that even default-chunk (64) phases fan
            // out once a couple of heads search together.
            use crate::util::breakeven::{fan_out, PARALLEL_SEARCH_MIN_LOOKUPS};
            let mut serial_scratch = WindowScratch::default();
            let mut serial_win: Vec<(u32, u32)> = Vec::with_capacity(self.window);
            let mut serial_cand: Vec<(u32, u32)> = Vec::with_capacity(self.window);
            let mut cs = 0usize;
            while cs < n {
                let ce = (cs + chunk).min(n);
                if cs > 0 {
                    let span = ce - cs;
                    let total = span * h;
                    if !fan_out(total, total, pool.threads(), PARALLEL_SEARCH_MIN_LOOKUPS) {
                        for item in 0..total {
                            let head = item / span;
                            let i = cs + (item % span);
                            // Safety: single-threaded here; rows disjoint.
                            let irow = unsafe {
                                shares[head].range_mut(i * kk_cap..(i + 1) * kk_cap)
                            };
                            self.select_into(
                                qcs[head][i],
                                &index,
                                &mut serial_scratch,
                                &mut serial_win,
                                &mut serial_cand,
                                irow,
                            );
                        }
                        let phase_ws = (serial_win.capacity() + serial_cand.capacity()) * 8
                            + serial_scratch.bytes();
                        cand_ws = cand_ws.max(phase_ws);
                    } else {
                        let grain = pool.grain(total, 16);
                        let ws: Vec<usize> = pool.run_chunked(total, grain, |queue| {
                            let mut scratch = WindowScratch::default();
                            let mut win: Vec<(u32, u32)> = Vec::with_capacity(self.window);
                            let mut cand: Vec<(u32, u32)> = Vec::with_capacity(self.window);
                            while let Some(items) = queue.next_chunk() {
                                for item in items {
                                    let head = item / span;
                                    let i = cs + (item % span);
                                    // Safety: row (head, i) claimed by
                                    // exactly one chunk.
                                    let irow = unsafe {
                                        shares[head].range_mut(i * kk_cap..(i + 1) * kk_cap)
                                    };
                                    self.select_into(
                                        qcs[head][i],
                                        &index,
                                        &mut scratch,
                                        &mut win,
                                        &mut cand,
                                        irow,
                                    );
                                }
                            }
                            (win.capacity() + cand.capacity()) * 8 + scratch.bytes()
                        });
                        cand_ws = cand_ws.max(ws.iter().sum::<usize>());
                    }
                }
                for &code in &kc[cs..ce] {
                    index.append(code);
                }
                cs = ce;
            }
        }
        let ws = index.bytes() + cand_ws;
        let cands = tables.into_iter().map(|idx| Candidates { idx, k: kk_cap }).collect();
        (cands, ws)
    }

    /// Pipelined sequence-parallel schedule: the cheap serial parts run
    /// once up front — every key appended chunk by chunk (O(N log N)
    /// total) with an [`ZIndex::fork`] snapshot captured at each chunk
    /// boundary (O(log N) `Arc` pointer clones each, the PR 5 substrate) —
    /// then *every* (chunk, head, query) lookup fans out across the
    /// resident pool in one region, each query searching its own chunk's
    /// frozen snapshot. No phase barriers: a worker scoring chunk 1 never
    /// waits for chunk 40's lookups, so long-prompt wall-clock approaches
    /// (total lookups) / threads instead of Σ per-phase critical paths.
    fn search_multi_pipelined(
        &self,
        qcs: &[&[u32]],
        kc: &[u32],
        pool: &Pool,
    ) -> (Vec<Candidates>, usize) {
        let n = kc.len();
        let h = qcs.len();
        let chunk = self.chunk.max(1);
        let kk_cap = self.k;
        let mut tables: Vec<Vec<u32>> = (0..h).map(|_| vec![u32::MAX; n * kk_cap]).collect();

        // Serial front: append all keys, snapshotting at every boundary.
        // snaps[j] is the index frozen at exactly (j+1)*chunk keys — the
        // causal state queries of chunk j+1 must search. The final chunk's
        // keys still enter the live index (callers account its bytes) but
        // need no snapshot: no query in this call looks past them.
        let mut index = ZIndex::new();
        let mut snaps: Vec<ZIndex> = Vec::with_capacity(n / chunk + 1);
        let mut cs = 0usize;
        while cs < n {
            let ce = (cs + chunk).min(n);
            for &code in &kc[cs..ce] {
                index.append(code);
            }
            if ce < n {
                snaps.push(index.fork());
            }
            cs = ce;
        }

        // One region over all scoring items. Mapping interleaves heads so
        // consecutive items share a query position (and thus a snapshot) —
        // good locality for the per-worker window scratch.
        let qstart = chunk.min(n);
        let span = n - qstart;
        let total = span * h;
        let mut cand_ws = 0usize;
        {
            let shares: Vec<SharedSlice<u32>> =
                tables.iter_mut().map(|t| SharedSlice::new(t.as_mut_slice())).collect();
            let grain = pool.grain(total, 16);
            let ws: Vec<usize> = pool.run_chunked(total, grain, |queue| {
                let mut scratch = WindowScratch::default();
                let mut win: Vec<(u32, u32)> = Vec::with_capacity(self.window);
                let mut cand: Vec<(u32, u32)> = Vec::with_capacity(self.window);
                while let Some(items) = queue.next_chunk() {
                    for item in items {
                        let i = qstart + item / h;
                        let head = item % h;
                        let snap = &snaps[i / chunk - 1];
                        // Safety: row (head, i) claimed by exactly one chunk.
                        let irow = unsafe { shares[head].range_mut(i * kk_cap..(i + 1) * kk_cap) };
                        self.select_into(
                            qcs[head][i],
                            snap,
                            &mut scratch,
                            &mut win,
                            &mut cand,
                            irow,
                        );
                    }
                }
                (win.capacity() + cand.capacity()) * 8 + scratch.bytes()
            });
            cand_ws = cand_ws.max(ws.iter().sum::<usize>());
        }
        // Snapshots share every run allocation with the live index (fork
        // is Arc clones), so their resident cost is O(log N) handles per
        // boundary — not a second copy of the sorted prefix.
        let snap_ws = snaps
            .iter()
            .map(|s| s.run_count() * std::mem::size_of::<Arc<Vec<(u32, u32)>>>())
            .sum::<usize>();
        let ws = index.bytes() + snap_ws + cand_ws;
        let cands = tables.into_iter().map(|idx| Candidates { idx, k: kk_cap }).collect();
        (cands, ws)
    }

    fn search(&self, ql: &[f32], kl: &[f32], n: usize, pool: &Pool) -> (Candidates, usize) {
        let bits = zorder::bits_for_dim(self.d_k);
        let qc = zorder::encode_points_pool(ql, self.d_k, self.range, bits, pool);
        let kc = zorder::encode_points_pool(kl, self.d_k, self.range, bits, pool);
        debug_assert_eq!(kc.len(), n);
        let codes_ws = (qc.len() + kc.len()) * 4;
        let (mut cands, ws) = self.search_multi(&[qc.as_slice()], &kc, pool);
        (cands.pop().expect("one head"), ws + codes_ws)
    }

    /// Causal inclusive running means of the low-dim keys and values
    /// (the smoothing token of paper §3.4). Prefix scans stay serial —
    /// O(N·d), negligible next to the O(N·k·d) attention phases.
    fn history_means(&self, kl: &[f32], v: &Tensor, n: usize) -> (Vec<f32>, Vec<f32>) {
        let dk = self.d_k;
        let dv = v.shape[1];
        let mut km = vec![0f32; n * dk];
        let mut vm = vec![0f32; n * dv];
        let mut ksum = vec![0f32; dk];
        let mut vsum = vec![0f32; dv];
        for i in 0..n {
            for c in 0..dk {
                ksum[c] += kl[i * dk + c];
                km[i * dk + c] = ksum[c] / (i + 1) as f32;
            }
            let vr = v.row(i);
            for c in 0..dv {
                vsum[c] += vr[c];
                vm[i * dv + c] = vsum[c] / (i + 1) as f32;
            }
        }
        (km, vm)
    }

    /// Adaptive Cauchy-Softmax accumulation over candidate sets + the
    /// history-mean smoothing token (query-parallel): returns the outputs,
    /// the per-query normalizers (kept for the backward), and the scratch
    /// bytes. Shared by the single-head forward and the batched serving
    /// path.
    fn cauchy_accumulate(
        &self,
        cands: &Candidates,
        ql: &[f32],
        kl: &[f32],
        km: &[f32],
        vm: &[f32],
        v: &Tensor,
        pool: &Pool,
    ) -> (Tensor, Vec<f32>, usize) {
        let n = v.shape[0];
        let dv = v.shape[1];
        let dk = self.d_k;
        let mut o = Tensor::zeros(&[n, dv]);
        let mut zsum = vec![0f32; n]; // normalizers, kept for bwd
        // Query-parallel: o rows and zsum entries are disjoint per query.
        // Each worker caches its candidate scores so every Cauchy score is
        // computed exactly once.
        let kl_rows = FlatRows { data: kl, width: dk };
        let v_rows = FlatRows { data: &v.data, width: dv };
        let score_ws: usize = {
            let osh = SharedSlice::new(&mut o.data);
            let zsh = SharedSlice::new(&mut zsum);
            let ws: Vec<usize> = pool.run_chunked(n, pool.grain(n, 32), |queue| {
                let mut scores = vec![0f32; cands.k];
                while let Some(rows) = queue.next_chunk() {
                    for i in rows {
                        let base = i * cands.k;
                        // Safety: index/row i claimed by exactly one chunk.
                        let orow = unsafe { osh.range_mut(i * dv..(i + 1) * dv) };
                        let z = cauchy_row(
                            self.eps,
                            &cands.idx[base..base + cands.k],
                            &ql[i * dk..(i + 1) * dk],
                            &kl_rows,
                            &km[i * dk..(i + 1) * dk],
                            &vm[i * dv..(i + 1) * dv],
                            &v_rows,
                            &mut scores,
                            orow,
                        );
                        unsafe { zsh.write(i, z) };
                    }
                }
                scores.len() * 4
            });
            ws.iter().sum()
        };
        let ws = score_ws + zsum.len() * 4;
        (o, zsum, ws)
    }

    /// Forward returning everything the backward needs.
    #[allow(clippy::type_complexity)]
    fn fwd_full(
        &self,
        w: &Workload,
        pool: &Pool,
    ) -> (Tensor, Candidates, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, usize) {
        let n = w.n();
        let ql = self.project(&w.q, pool);
        let kl = self.project(&w.k, pool);
        let (cands, search_ws) = self.search(&ql, &kl, n, pool);
        let (km, vm) = self.history_means(&kl, &w.v, n);
        let (o, zsum, score_ws) = self.cauchy_accumulate(&cands, &ql, &kl, &km, &vm, &w.v, pool);
        let ws = search_ws
            + (ql.len() + kl.len() + km.len() + vm.len()) * 4
            + cands.idx.len() * 4
            + score_ws;
        (o, cands, ql, kl, km, vm, zsum, ws)
    }

    /// Shared-sort serving path of `forward_batch` (see the `shared_sort`
    /// field): per sequence, head 0's key codes feed one incremental sort
    /// that serves every head's candidate search; history means and Cauchy
    /// scoring still run on each head's own keys/values.
    fn forward_batch_shared(&self, mw: &super::MultiWorkload, pool: &Pool) -> (Tensor, MemReport) {
        let n = mw.seq_len();
        let dv = mw.v.shape[1];
        let heads = mw.heads;
        let p = mw.num_problems();
        let bits = zorder::bits_for_dim(self.d_k);
        let mut o = Tensor::zeros(&[p * n, dv]);
        let mut ws_total = 0usize;
        let mut out_total = 0usize;
        for b in 0..mw.batch {
            let wls: Vec<Workload> = (0..heads).map(|h| mw.problem(b * heads + h)).collect();
            let qls: Vec<Vec<f32>> = wls.iter().map(|wl| self.project(&wl.q, pool)).collect();
            let kls: Vec<Vec<f32>> = wls.iter().map(|wl| self.project(&wl.k, pool)).collect();
            let qcs: Vec<Vec<u32>> = qls
                .iter()
                .map(|ql| zorder::encode_points_pool(ql, self.d_k, self.range, bits, pool))
                .collect();
            // One key encode + one incremental sort per *sequence*.
            let kc0 = zorder::encode_points_pool(&kls[0], self.d_k, self.range, bits, pool);
            let qrefs: Vec<&[u32]> = qcs.iter().map(|q| q.as_slice()).collect();
            let (cands, search_ws) = self.search_multi(&qrefs, &kc0, pool);
            // Sequence peak: the per-head input copies, projections, codes
            // and candidate tables all coexist across the head loop; the
            // per-head history-mean/score scratch is transient, so only its
            // max contributes. Sequences run one after another (buffers
            // freed between them), hence the outer .max.
            let mut resident = search_ws
                + kc0.len() * 4
                + wls.iter().map(|wl| wl.input_bytes() + wl.dout.bytes()).sum::<usize>();
            let mut transient_peak = 0usize;
            for h in 0..heads {
                let (km, vm) = self.history_means(&kls[h], &wls[h].v, n);
                let (oh, _zsum, score_ws) =
                    self.cauchy_accumulate(&cands[h], &qls[h], &kls[h], &km, &vm, &wls[h].v, pool);
                let idx = b * heads + h;
                o.data[idx * n * dv..(idx + 1) * n * dv].copy_from_slice(&oh.data);
                resident += (qls[h].len() + kls[h].len() + qcs[h].len()) * 4
                    + cands[h].idx.len() * 4;
                transient_peak = transient_peak.max(score_ws + (km.len() + vm.len()) * 4);
                out_total += oh.bytes();
            }
            ws_total = ws_total.max(resident + transient_peak);
        }
        (o, MemReport { workspace_bytes: ws_total, output_bytes: out_total })
    }
}

/// Incremental ZETA decode state: a persistent sorted Z-order index over
/// the past keys' Morton codes, the low-dim key / value caches, and the
/// running history-mean sums. Per token: one O(log N)-amortized index
/// append per key (at chunk boundaries), one O(window·log N) window
/// lookup, and O(k·dv) scoring — versus O(N log N) for re-sorting from
/// scratch every token. Runs the *same* selection routine over the *same*
/// index states as the batch forward, so outputs agree bit-for-bit.
///
/// All O(N) storage lives on arena pages: the Morton-code history
/// ([`PagedU32`]) and the low-dim key / value caches ([`PagedKv`]), plus
/// the refcounted sorted runs inside [`ZIndex`]. [`DecodeState::fork`]
/// therefore shares the whole ingested prefix — full pages and sorted
/// runs by refcount bump, only the partial tail pages copied — instead of
/// re-projecting, re-encoding and re-sorting it.
pub struct ZetaDecode {
    cfg: ZetaNative,
    bits: u32,
    d: usize,
    dv: usize,
    index: ZIndex,
    /// Keys already appended to the index (== the causal chunk limit).
    indexed: usize,
    codes: PagedU32,
    kl: PagedKv,     // low-dim key cache (t, d_k)
    vcache: PagedKv, // value cache (t, dv)
    ksum: Vec<f32>,
    vsum: Vec<f32>,
    km_t: Vec<f32>,
    vm_t: Vec<f32>,
    qlow: Vec<f32>,
    klow: Vec<f32>,
    scratch: WindowScratch,
    win: Vec<(u32, u32)>,
    cand: Vec<(u32, u32)>,
    irow: Vec<u32>,
    scores: Vec<f32>,
    t: usize,
}

impl ZetaDecode {
    pub fn new(cfg: ZetaNative, d: usize, dv: usize, arena: &Arc<PageArena>) -> ZetaDecode {
        let dk = cfg.d_k;
        let k = cfg.k;
        ZetaDecode {
            bits: zorder::bits_for_dim(dk),
            d,
            dv,
            index: ZIndex::new(),
            indexed: 0,
            codes: PagedU32::new(arena),
            kl: PagedKv::new(arena, dk),
            vcache: PagedKv::new(arena, dv),
            ksum: vec![0f32; dk],
            vsum: vec![0f32; dv],
            km_t: vec![0f32; dk],
            vm_t: vec![0f32; dv],
            qlow: vec![0f32; dk],
            klow: vec![0f32; dk],
            scratch: WindowScratch::default(),
            win: Vec::new(),
            cand: Vec::new(),
            irow: vec![u32::MAX; k],
            scores: vec![0f32; k],
            t: 0,
            cfg,
        }
    }
}

impl DecodeState for ZetaDecode {
    fn step(&mut self, q_t: &[f32], k_t: &[f32], v_t: &[f32], out: &mut [f32]) {
        let dk = self.cfg.d_k;
        let dv = self.dv;
        debug_assert_eq!(v_t.len(), dv);
        debug_assert_eq!(out.len(), dv);
        let t = self.t;
        let dcopy = dk.min(self.d);

        // Project + encode + cache the new key (identical slice projection
        // and grid encoding as the batch path).
        for x in self.klow.iter_mut() {
            *x = 0.0;
        }
        self.klow[..dcopy].copy_from_slice(&k_t[..dcopy]);
        let code = zorder::encode_point(&self.klow, self.cfg.range, self.bits);
        self.codes.push(code);
        self.kl.push_row(&self.klow);
        self.vcache.push_row(v_t);

        // Running history means — same serial arithmetic as history_means.
        for c in 0..dk {
            self.ksum[c] += self.klow[c];
            self.km_t[c] = self.ksum[c] / (t + 1) as f32;
        }
        for c in 0..dv {
            self.vsum[c] += v_t[c];
            self.vm_t[c] = self.vsum[c] / (t + 1) as f32;
        }

        // Advance the index to this token's causal chunk limit.
        let chunk = self.cfg.chunk.max(1);
        let limit = (t / chunk) * chunk;
        while self.indexed < limit {
            self.index.append(self.codes.get(self.indexed));
            self.indexed += 1;
        }

        // Candidate selection — the same routine the batch search runs.
        for s in self.irow.iter_mut() {
            *s = u32::MAX;
        }
        for x in self.qlow.iter_mut() {
            *x = 0.0;
        }
        self.qlow[..dcopy].copy_from_slice(&q_t[..dcopy]);
        if limit > 0 {
            let qc = zorder::encode_point(&self.qlow, self.cfg.range, self.bits);
            self.cfg.select_into(
                qc,
                &self.index,
                &mut self.scratch,
                &mut self.win,
                &mut self.cand,
                &mut self.irow,
            );
        }

        // Cauchy-softmax over candidates + smoothing token — the exact
        // routine the batch kernel runs per row.
        cauchy_row(
            self.cfg.eps,
            &self.irow,
            &self.qlow,
            &self.kl,
            &self.km_t,
            &self.vm_t,
            &self.vcache,
            &mut self.scores,
            out,
        );
        self.t += 1;
    }

    /// Pipelined long-prompt prefill: the serial O(N·d) parts of `step` —
    /// project, encode, cache, history-mean prefix sums — run once up
    /// front; the index advances chunk by chunk with an O(log N)
    /// [`ZIndex::fork`] snapshot per boundary; then every position's
    /// candidate search + Cauchy scoring fans out across the pool, each
    /// position searching the snapshot frozen at its causal limit. The
    /// last position runs inline on the state's own scratch, so the
    /// post-run state (index, `indexed`, caches, running sums, scratch
    /// rows) is bit-identical to a serial `step` loop — the decode
    /// continuation after prefill can't tell the schedules apart.
    ///
    /// Strict causality is preserved exactly: the live index stops at the
    /// *last* position's chunk limit, never ahead of it, and each scored
    /// position only ever sees its own frozen prefix.
    fn prefill_run(
        &mut self,
        n: usize,
        qs: &[f32],
        ks: &[f32],
        vs: &[f32],
        out: &mut [f32],
        pool: &Pool,
    ) {
        use crate::util::breakeven::{fan_out, PARALLEL_PREFILL_SCORE_MIN_LOOKUPS};
        if n == 0 {
            return;
        }
        let d = qs.len() / n;
        let dv = self.dv;
        // Below the break-even (or on a serial pool) the inline step loop
        // is faster and trivially bit-identical; only the last position's
        // output survives either way.
        if !fan_out(n - 1, n, pool.threads(), PARALLEL_PREFILL_SCORE_MIN_LOOKUPS) {
            for i in 0..n {
                self.step(
                    &qs[i * d..(i + 1) * d],
                    &ks[i * d..(i + 1) * d],
                    &vs[i * dv..(i + 1) * dv],
                    out,
                );
            }
            return;
        }
        debug_assert_eq!(vs.len(), n * dv);
        debug_assert_eq!(out.len(), dv);
        let dk = self.cfg.d_k;
        let dcopy = dk.min(self.d);
        let t0 = self.t;
        let chunk = self.cfg.chunk.max(1);

        // ---- Serial front: project/encode/cache every key and prefix-scan
        // the history means — the same arithmetic in the same order as
        // `step`, hoisted out of the per-token loop. Per-position means and
        // query codes are kept for the scoring fan-out below.
        let mut qlow_all = vec![0f32; n * dk];
        let mut qc_all = vec![0u32; n];
        let mut km_all = vec![0f32; n * dk];
        let mut vm_all = vec![0f32; n * dv];
        for i in 0..n {
            let t = t0 + i;
            for x in self.klow.iter_mut() {
                *x = 0.0;
            }
            self.klow[..dcopy].copy_from_slice(&ks[i * d..i * d + dcopy]);
            let code = zorder::encode_point(&self.klow, self.cfg.range, self.bits);
            self.codes.push(code);
            self.kl.push_row(&self.klow);
            let v_t = &vs[i * dv..(i + 1) * dv];
            self.vcache.push_row(v_t);
            for c in 0..dk {
                self.ksum[c] += self.klow[c];
                km_all[i * dk + c] = self.ksum[c] / (t + 1) as f32;
            }
            for c in 0..dv {
                self.vsum[c] += v_t[c];
                vm_all[i * dv + c] = self.vsum[c] / (t + 1) as f32;
            }
            let ql = &mut qlow_all[i * dk..(i + 1) * dk];
            ql[..dcopy].copy_from_slice(&qs[i * d..i * d + dcopy]);
            qc_all[i] = zorder::encode_point(ql, self.cfg.range, self.bits);
        }

        // ---- Snapshot ladder: advance the index to each chunk boundary a
        // position in this run needs, forking at every rung. The live
        // index stops at the last position's limit — exactly where serial
        // stepping leaves `indexed` (appending further would leak future
        // keys into the next step's selection).
        let t_last = t0 + n - 1;
        let l_first = (t0 / chunk) * chunk;
        let l_last = (t_last / chunk) * chunk;
        let mut snaps: Vec<ZIndex> = Vec::with_capacity((l_last - l_first) / chunk + 1);
        let mut l = l_first;
        loop {
            while self.indexed < l {
                self.index.append(self.codes.get(self.indexed));
                self.indexed += 1;
            }
            snaps.push(self.index.fork());
            if l >= l_last {
                break;
            }
            l += chunk;
        }

        // ---- One region: score every position but the last against its
        // frozen snapshot. Non-final output rows are computed and dropped —
        // prefill surfaces only the last row, and doing the same
        // per-position work as the serial schedule keeps threads = 1
        // within noise of sequential while every thread count stays
        // bitwise identical (per-position math is untouched and
        // independent).
        if n > 1 {
            let m = n - 1;
            let cfg = &self.cfg;
            let kl = &self.kl;
            let vcache = &self.vcache;
            let snaps_ref = &snaps;
            let qlow_ref = &qlow_all;
            let qc_ref = &qc_all;
            let km_ref = &km_all;
            let vm_ref = &vm_all;
            pool.run_chunked(m, pool.grain(m, 16), |queue| {
                let mut scratch = WindowScratch::default();
                let mut win: Vec<(u32, u32)> = Vec::with_capacity(cfg.window);
                let mut cand: Vec<(u32, u32)> = Vec::with_capacity(cfg.window);
                let mut irow = vec![u32::MAX; cfg.k];
                let mut scores = vec![0f32; cfg.k];
                let mut orow = vec![0f32; dv];
                while let Some(items) = queue.next_chunk() {
                    for i in items {
                        let t = t0 + i;
                        let limit = (t / chunk) * chunk;
                        for s in irow.iter_mut() {
                            *s = u32::MAX;
                        }
                        if limit > 0 {
                            let snap = &snaps_ref[(limit - l_first) / chunk];
                            cfg.select_into(
                                qc_ref[i],
                                snap,
                                &mut scratch,
                                &mut win,
                                &mut cand,
                                &mut irow,
                            );
                        }
                        cauchy_row(
                            cfg.eps,
                            &irow,
                            &qlow_ref[i * dk..(i + 1) * dk],
                            kl,
                            &km_ref[i * dk..(i + 1) * dk],
                            &vm_ref[i * dv..(i + 1) * dv],
                            vcache,
                            &mut scores,
                            &mut orow,
                        );
                    }
                }
            });
        }

        // ---- Last position inline on the state's own persistent buffers,
        // leaving qlow/klow/km_t/vm_t/irow/scores and the window scratch
        // exactly as a serial step loop would.
        let i = n - 1;
        self.km_t.copy_from_slice(&km_all[i * dk..(i + 1) * dk]);
        self.vm_t.copy_from_slice(&vm_all[i * dv..(i + 1) * dv]);
        self.qlow.copy_from_slice(&qlow_all[i * dk..(i + 1) * dk]);
        for s in self.irow.iter_mut() {
            *s = u32::MAX;
        }
        if l_last > 0 {
            // The live index sits at exactly l_last keys — the last
            // position's frozen prefix.
            self.cfg.select_into(
                qc_all[i],
                &self.index,
                &mut self.scratch,
                &mut self.win,
                &mut self.cand,
                &mut self.irow,
            );
        }
        cauchy_row(
            self.cfg.eps,
            &self.irow,
            &self.qlow,
            &self.kl,
            &self.km_t,
            &self.vm_t,
            &self.vcache,
            &mut self.scores,
            out,
        );
        self.t += n;
    }

    fn pos(&self) -> usize {
        self.t
    }

    fn step_cost_hint(&self) -> usize {
        // Window scan over the sorted index + top-k Cauchy scoring —
        // O(window·log N + k·dv), constant-ish in context length.
        let logn = usize::BITS as usize - self.codes.len().max(1).leading_zeros() as usize;
        self.cfg.window * (logn + 8) + self.cfg.k * (self.dv + 8)
    }

    fn state_bytes(&self) -> usize {
        self.index.bytes()
            + self.codes.bytes()
            + self.kl.bytes()
            + self.vcache.bytes()
            + (self.ksum.len()
                + self.vsum.len()
                + self.km_t.len()
                + self.vm_t.len()
                + self.qlow.len()
                + self.klow.len()
                + self.scores.len())
                * 4
            + self.irow.len() * 4
            + (self.win.capacity() + self.cand.capacity()) * 8
            + self.scratch.bytes()
    }

    fn fork(&self) -> Box<dyn DecodeState> {
        Box::new(ZetaDecode {
            cfg: self.cfg.clone(),
            bits: self.bits,
            d: self.d,
            dv: self.dv,
            index: self.index.fork(),
            indexed: self.indexed,
            codes: self.codes.fork(),
            kl: self.kl.fork(),
            vcache: self.vcache.fork(),
            ksum: self.ksum.clone(),
            vsum: self.vsum.clone(),
            km_t: self.km_t.clone(),
            vm_t: self.vm_t.clone(),
            qlow: self.qlow.clone(),
            klow: self.klow.clone(),
            scratch: WindowScratch::default(),
            win: Vec::new(),
            cand: Vec::new(),
            irow: self.irow.clone(),
            scores: self.scores.clone(),
            t: self.t,
        })
    }

    /// Low-`k` self-speculation fork: the same ingested stream — sorted
    /// index runs, Morton codes and paged key/value caches all shared
    /// copy-on-write exactly as [`DecodeState::fork`] — but future
    /// selection runs a narrowed configuration: `k / DRAFT_NARROWING`
    /// candidates over a `window / DRAFT_NARROWING` window. Projection,
    /// encoding, chunk limits and the Cauchy arithmetic are untouched, so
    /// every cached code/row stays valid for both configurations; only
    /// the candidate set (and hence the proposals) narrows, which is what
    /// makes a draft step cost a fraction of a full step.
    fn fork_draft(&self) -> Option<Box<dyn DecodeState>> {
        let k = (self.cfg.k / DRAFT_NARROWING).max(1);
        let window = (self.cfg.window / DRAFT_NARROWING).max(k);
        let cfg = ZetaNative { k, window, ..self.cfg.clone() };
        Some(Box::new(ZetaDecode {
            cfg,
            bits: self.bits,
            d: self.d,
            dv: self.dv,
            index: self.index.fork(),
            indexed: self.indexed,
            codes: self.codes.fork(),
            kl: self.kl.fork(),
            vcache: self.vcache.fork(),
            ksum: self.ksum.clone(),
            vsum: self.vsum.clone(),
            km_t: self.km_t.clone(),
            vm_t: self.vm_t.clone(),
            qlow: self.qlow.clone(),
            klow: self.klow.clone(),
            scratch: WindowScratch::default(),
            win: Vec::new(),
            cand: Vec::new(),
            irow: vec![u32::MAX; k],
            scores: vec![0f32; k],
            t: self.t,
        }))
    }

    fn release(&mut self) {
        self.codes.release();
        self.kl.release();
        self.vcache.release();
        self.index = ZIndex::new();
        self.indexed = 0;
        self.t = 0;
        for x in self.ksum.iter_mut() {
            *x = 0.0;
        }
        for x in self.vsum.iter_mut() {
            *x = 0.0;
        }
    }
}

impl AttentionImpl for ZetaNative {
    fn name(&self) -> &'static str {
        "zeta"
    }

    fn forward_with(&self, w: &Workload, pool: &Pool) -> (Tensor, MemReport) {
        let (o, _, _, _, _, _, _, ws) = self.fwd_full(w, pool);
        let mem = MemReport { workspace_bytes: ws, output_bytes: o.bytes() };
        (o, mem)
    }

    fn begin_decode_in(
        &self,
        d: usize,
        dv: usize,
        arena: &Arc<PageArena>,
    ) -> Box<dyn DecodeState> {
        Box::new(ZetaDecode::new(self.clone(), d, dv, arena))
    }

    /// Specialized batched forward (ROADMAP open item): one pool region for
    /// the whole batch — workers claim whole head problems and run the
    /// serial pipeline, instead of the default loop's one pool region per
    /// phase per head. With `shared_sort` set, heads of a sequence
    /// additionally share one key encode + incremental sort
    /// ([`ZetaNative::forward_batch_shared`]).
    fn forward_batch(&self, mw: &super::MultiWorkload, pool: &Pool) -> (Tensor, MemReport) {
        if self.shared_sort && mw.heads > 1 {
            return self.forward_batch_shared(mw, pool);
        }
        let p = mw.num_problems();
        let n = mw.seq_len();
        let dv = mw.v.shape[1];
        let mut o = Tensor::zeros(&[p * n, dv]);
        if p < pool.threads() {
            // Fewer problems than workers: problem-level parallelism would
            // idle most of the pool, so keep each forward row-parallel on
            // the full pool instead (the default-impl schedule).
            let mut mem = MemReport::default();
            for idx in 0..p {
                let wl = mw.problem(idx);
                let head_copy = wl.input_bytes() + wl.dout.bytes();
                let (oh, mh) = self.forward_with(&wl, pool);
                o.data[idx * n * dv..(idx + 1) * n * dv].copy_from_slice(&oh.data);
                mem.workspace_bytes = mem.workspace_bytes.max(mh.workspace_bytes + head_copy);
                mem.output_bytes += mh.output_bytes;
            }
            return (o, mem);
        }
        let serial = Pool::serial();
        let stats: Vec<(usize, usize)> = {
            let osh = SharedSlice::new(&mut o.data);
            pool.run_chunked(p, 1, |queue| {
                let mut peak = 0usize;
                let mut outb = 0usize;
                while let Some(probs) = queue.next_chunk() {
                    for idx in probs {
                        let wl = mw.problem(idx);
                        let copy = wl.input_bytes() + wl.dout.bytes();
                        let (oh, mh) = self.forward_with(&wl, &serial);
                        // Safety: rows of problem idx claimed by one chunk.
                        let dst = unsafe { osh.range_mut(idx * n * dv..(idx + 1) * n * dv) };
                        dst.copy_from_slice(&oh.data);
                        peak = peak.max(mh.workspace_bytes + copy);
                        outb += mh.output_bytes;
                    }
                }
                (peak, outb)
            })
        };
        let mem = MemReport {
            workspace_bytes: stats.iter().map(|s| s.0).sum(),
            output_bytes: stats.iter().map(|s| s.1).sum(),
        };
        (o, mem)
    }

    fn forward_backward_with(&self, w: &Workload, pool: &Pool) -> (Grads, MemReport) {
        let n = w.n();
        let dv = w.v.shape[1];
        let dk = self.d_k;
        let d = w.q.shape[1];
        let (o, cands, ql, kl, km, vm, zsum, ws) = self.fwd_full(w, pool);

        // Gradients in the low-dim space; mapped back to the first d_k
        // coordinates of q/k (the projection is a fixed slice).
        let mut dql = vec![0f32; n * dk];
        let mut dkl = vec![0f32; n * dk];
        let mut dvt = Tensor::zeros(&[n, dv]);
        // Suffix accumulators for the history-mean tokens: the mean at row i
        // feeds every j <= i with weight 1/(i+1).
        let mut vm_suffix = vec![0f32; n * dv];
        let mut km_suffix = vec![0f32; n * dk];

        // Query-parallel main loop: dql / km_suffix / vm_suffix rows are
        // disjoint per query; dkl / dvt scatter across candidate keys, so
        // workers accumulate into private buffers merged after the join.
        let grain = pool.grain(n, 32);
        let parts: Vec<(Vec<f32>, Vec<f32>)> = {
            let dqlsh = SharedSlice::new(&mut dql);
            let kmsh = SharedSlice::new(&mut km_suffix);
            let vmsh = SharedSlice::new(&mut vm_suffix);
            pool.run_chunked(n, grain, |queue| {
                let mut dkl_local = vec![0f32; n * dk];
                let mut dvt_local = vec![0f32; n * dv];
                while let Some(rows) = queue.next_chunk() {
                    for i in rows {
                        let qi = &ql[i * dk..(i + 1) * dk];
                        let gi = w.dout.row(i);
                        let oi = o.row(i);
                        let z = zsum[i];
                        let base = i * cands.k;

                        let mut dq_acc = [0f32; 16];
                        debug_assert!(dk <= 16);
                        for slot in 0..=cands.k {
                            // slot == cands.k is the smoothing token
                            let (kj, vj, jj): (&[f32], &[f32], Option<usize>) =
                                if slot == cands.k {
                                    (
                                        &km[i * dk..(i + 1) * dk],
                                        &vm[i * dv..(i + 1) * dv],
                                        None,
                                    )
                                } else {
                                    let j = cands.idx[base + slot];
                                    if j == u32::MAX {
                                        continue;
                                    }
                                    let jj = j as usize;
                                    (
                                        &kl[jj * dk..(jj + 1) * dk],
                                        &w.v.data[jj * dv..(jj + 1) * dv],
                                        Some(jj),
                                    )
                                };
                            let delta = sqdist(qi, kj) + self.eps;
                            let s = 1.0 / delta;
                            let a = s / z;
                            // dL/dS = g . (v_j - o_i) / Z ; dL/ddelta = -dL/dS * s^2
                            let mut gdot = 0.0;
                            for c in 0..dv {
                                gdot += gi[c] * (vj[c] - oi[c]);
                            }
                            let ds = gdot / z;
                            let ddelta = -ds * s * s;
                            // dq += ddelta * 2 (q - k); dk_j -= ddelta * 2 (q - k)
                            match jj {
                                Some(j) => {
                                    let dkj = &mut dkl_local[j * dk..(j + 1) * dk];
                                    for c in 0..dk {
                                        let diff = 2.0 * (qi[c] - kj[c]) * ddelta;
                                        dq_acc[c] += diff;
                                        dkj[c] -= diff;
                                    }
                                    let dvj = &mut dvt_local[j * dv..(j + 1) * dv];
                                    for c in 0..dv {
                                        dvj[c] += a * gi[c];
                                    }
                                }
                                None => {
                                    // smoothing token: gradient flows into the
                                    // running means; defer via suffix
                                    // accumulators (rows disjoint per query).
                                    // Safety: row i claimed by one chunk.
                                    let kms = unsafe {
                                        kmsh.range_mut(i * dk..(i + 1) * dk)
                                    };
                                    for c in 0..dk {
                                        let diff = 2.0 * (qi[c] - kj[c]) * ddelta;
                                        dq_acc[c] += diff;
                                        kms[c] -= diff;
                                    }
                                    let vms = unsafe {
                                        vmsh.range_mut(i * dv..(i + 1) * dv)
                                    };
                                    for c in 0..dv {
                                        vms[c] += a * gi[c];
                                    }
                                }
                            }
                        }
                        // Safety: row i claimed by exactly one chunk.
                        let dqli = unsafe { dqlsh.range_mut(i * dk..(i + 1) * dk) };
                        for c in 0..dk {
                            dqli[c] += dq_acc[c];
                        }
                    }
                }
                (dkl_local, dvt_local)
            })
        };
        merge_partials(&mut dkl, parts.iter().map(|(dkl_p, _)| dkl_p.as_slice()));
        merge_partials(&mut dvt.data, parts.iter().map(|(_, dvt_p)| dvt_p.as_slice()));

        // Propagate history-mean gradients: contribution of row i spreads to
        // all positions j <= i with weight 1/(i+1). Reverse prefix sum of
        // (suffix_i / (i+1)) — inherently sequential, O(N·d), left serial.
        let mut acc_v = vec![0f32; dv];
        let mut acc_k = vec![0f32; dk];
        for i in (0..n).rev() {
            let wgt = 1.0 / (i + 1) as f32;
            for c in 0..dv {
                acc_v[c] += vm_suffix[i * dv + c] * wgt;
            }
            for c in 0..dk {
                acc_k[c] += km_suffix[i * dk + c] * wgt;
            }
            let dvj = &mut dvt.data[i * dv..(i + 1) * dv];
            for c in 0..dv {
                dvj[c] += acc_v[c];
            }
            let dkj = &mut dkl[i * dk..(i + 1) * dk];
            for c in 0..dk {
                dkj[c] += acc_k[c];
            }
        }

        // Map low-dim grads back into full-width dq/dk (slice projection).
        let mut dq = Tensor::zeros(&[n, d]);
        let mut dkt = Tensor::zeros(&[n, d]);
        let dcopy = dk.min(d);
        for i in 0..n {
            dq.row_mut(i)[..dcopy].copy_from_slice(&dql[i * dk..i * dk + dcopy]);
            dkt.row_mut(i)[..dcopy].copy_from_slice(&dkl[i * dk..i * dk + dcopy]);
        }

        let partial_bytes: usize =
            parts.iter().map(|(a, b)| (a.len() + b.len()) * 4).sum();
        let mem = MemReport {
            workspace_bytes: ws
                + (dql.len() + dkl.len() + vm_suffix.len() + km_suffix.len()) * 4
                + partial_bytes
                + o.bytes(),
            output_bytes: dq.bytes() + dkt.bytes() + dvt.bytes(),
        };
        (Grads { dq, dk: dkt, dv: dvt }, mem)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{decode_full, MultiWorkload};
    use super::*;

    fn tiny() -> ZetaNative {
        ZetaNative { d_k: 2, k: 4, chunk: 4, window: 16, ..ZetaNative::default() }
    }

    #[test]
    fn outputs_finite_and_convex() {
        let w = Workload::random(64, 8, 4, 0);
        let mut wc = w;
        wc.v = Tensor::from_vec(&[64, 4], vec![1.0; 256]);
        let (o, _) = tiny().forward(&wc);
        for (i, v) in o.data.iter().enumerate() {
            // row 0..chunk has only the smoothing token; still mean of ones
            assert!((v - 1.0).abs() < 1e-4, "elem {i}: {v}");
        }
    }

    #[test]
    fn causality_no_future_candidates() {
        // All values beyond position p are poisoned with a huge magnitude;
        // outputs for queries in chunks <= p/chunk must stay bounded.
        let n = 64;
        let mut w = Workload::random(n, 8, 4, 1);
        for i in 32..n {
            for c in 0..4 {
                w.v.row_mut(i)[c] = 1e6;
            }
        }
        let z = tiny();
        let (o, _) = z.forward(&w);
        for i in 0..32 {
            // history mean at i < 32 only includes v[..=i], all sane
            for &v in o.row(i) {
                assert!(v.abs() < 1e3, "row {i} leaked future value: {v}");
            }
        }
    }

    #[test]
    fn selection_is_strictly_causal() {
        // Chunk-sequential search: rewriting *keys and values* beyond
        // position 32 must leave rows 0..32 bit-identical (their candidate
        // windows are drawn from an index frozen before position 32). The
        // seed kernel failed this — future keys could crowd past keys out
        // of the full-sort window.
        let n = 64;
        let z = ZetaNative { chunk: 16, ..ZetaNative::default() };
        let w1 = Workload::random(n, 8, 4, 7);
        let mut w2 = Workload {
            q: w1.q.clone(),
            k: w1.k.clone(),
            v: w1.v.clone(),
            dout: w1.dout.clone(),
        };
        for i in 32..n {
            for c in 0..8 {
                w2.k.row_mut(i)[c] = -w2.k.row(i)[c] + 0.37;
            }
            for c in 0..4 {
                w2.v.row_mut(i)[c] = 1e4;
            }
        }
        let (o1, _) = z.forward(&w1);
        let (o2, _) = z.forward(&w2);
        for i in 0..32 {
            for c in 0..4 {
                assert_eq!(o1.row(i)[c], o2.row(i)[c], "row {i} col {c}");
            }
        }
    }

    #[test]
    fn decode_matches_forward_exactly() {
        // The incremental path shares selection + scoring with the batch
        // path over identical index states: agreement should be bitwise.
        let z = ZetaNative { chunk: 16, ..ZetaNative::default() };
        let w = Workload::random(160, 8, 4, 9);
        let (of, _) = z.forward_with(&w, &Pool::serial());
        let od = decode_full(&z, &w);
        assert!(of.max_abs_diff(&od) < 1e-6, "diff {}", of.max_abs_diff(&od));
    }

    #[test]
    fn decode_state_grows_sublinearly_vs_kv() {
        let z = ZetaNative::default();
        let mut st = z.begin_decode(8, 8);
        let w = Workload::random(512, 8, 8, 11);
        let mut out = vec![0f32; 8];
        for t in 0..w.n() {
            st.step(w.q.row(t), w.k.row(t), w.v.row(t), &mut out);
        }
        assert_eq!(st.pos(), 512);
        assert!(st.state_bytes() > 0);
        // state is O(N·(d_k + dv)), dominated by the value cache — just pin
        // that it stays well under the O(N²) regime.
        assert!(st.state_bytes() < 512 * 512, "{}", st.state_bytes());
    }

    #[test]
    fn batch_specialization_matches_per_head_loop() {
        let z = ZetaNative { chunk: 16, ..ZetaNative::default() };
        let mw = MultiWorkload::random(2, 3, 64, 16, 8, 5);
        let pool = Pool::new(4);
        let (o, mem) = z.forward_batch(&mw, &pool);
        assert!(mem.workspace_bytes > 0);
        let n = mw.seq_len();
        let dv = mw.v.shape[1];
        for idx in 0..mw.num_problems() {
            let (oh, _) = z.forward_with(&mw.problem(idx), &pool);
            let got = &o.data[idx * n * dv..(idx + 1) * n * dv];
            let maxdiff = got
                .iter()
                .zip(&oh.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(maxdiff < 1e-5, "head {idx}: {maxdiff}");
        }
    }

    #[test]
    fn shared_sort_matches_per_head_on_shared_keys() {
        // When every head of a sequence carries identical keys, the shared
        // sort is exactly each head's own sort — outputs must agree with
        // the per-head path.
        let z = ZetaNative { chunk: 16, shared_sort: true, ..ZetaNative::default() };
        let mut mw = MultiWorkload::random(2, 3, 64, 8, 4, 13);
        let n = mw.seq_len();
        let d = mw.k.shape[1];
        for b in 0..mw.batch {
            let src_start = (b * mw.heads) * n * d;
            let head0: Vec<f32> = mw.k.data[src_start..src_start + n * d].to_vec();
            for h in 1..mw.heads {
                let dst = (b * mw.heads + h) * n * d;
                mw.k.data[dst..dst + n * d].copy_from_slice(&head0);
            }
        }
        let pool = Pool::new(2);
        let (o, _) = z.forward_batch(&mw, &pool);
        let dv = mw.v.shape[1];
        for idx in 0..mw.num_problems() {
            let (oh, _) = z.forward_with(&mw.problem(idx), &pool);
            let got = &o.data[idx * n * dv..(idx + 1) * n * dv];
            let maxdiff = got
                .iter()
                .zip(&oh.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(maxdiff < 1e-5, "head {idx}: {maxdiff}");
        }
    }

    #[test]
    fn grads_match_finite_difference() {
        let n = 12;
        let d = 3;
        let dv = 2;
        let z =
            ZetaNative { d_k: 2, k: 3, chunk: 4, window: 16, eps: 0.4, ..ZetaNative::default() };
        let w = Workload::random(n, d, dv, 2);
        let (g, _) = z.forward_backward(&w);

        // loss = sum(o * dout); check dv (candidate selection is fixed w.r.t.
        // v, so the v-gradient is exact).
        let loss_v = |vdata: &[f32]| {
            let w2 = Workload {
                q: w.q.clone(),
                k: w.k.clone(),
                v: Tensor::from_vec(&[n, dv], vdata.to_vec()),
                dout: w.dout.clone(),
            };
            let (o, _) = z.forward(&w2);
            o.data.iter().zip(&w2.dout.data).map(|(a, b)| a * b).sum::<f32>()
        };
        let mut v0 = w.v.data.clone();
        super::super::numeric_grad_check(loss_v, &mut v0, &g.dv.data, 2e-3);
    }

    #[test]
    fn grad_q_matches_fd_where_selection_stable() {
        // q perturbations can flip candidate selection (non-differentiable
        // boundary); use a case with eps large enough to be smooth and
        // tolerate outliers by checking the median agreement.
        let n = 12;
        let d = 2;
        let dv = 2;
        let z = ZetaNative {
            d_k: 2,
            k: 3,
            chunk: 4,
            window: 16,
            eps: 0.8,
            range: 6.0,
            ..ZetaNative::default()
        };
        let w = Workload::random(n, d, dv, 3);
        let (g, _) = z.forward_backward(&w);
        let loss_q = |qdata: &[f32]| {
            let w2 = Workload {
                q: Tensor::from_vec(&[n, d], qdata.to_vec()),
                k: w.k.clone(),
                v: w.v.clone(),
                dout: w.dout.clone(),
            };
            let (o, _) = z.forward(&w2);
            o.data.iter().zip(&w2.dout.data).map(|(a, b)| a * b).sum::<f32>()
        };
        let mut q0 = w.q.data.clone();
        let h = 1e-3;
        let mut agree = 0;
        let total = q0.len();
        for i in 0..total {
            let orig = q0[i];
            q0[i] = orig + h;
            let fp = loss_q(&q0);
            q0[i] = orig - h;
            let fm = loss_q(&q0);
            q0[i] = orig;
            let fd = (fp - fm) / (2.0 * h);
            if (fd - g.dq.data[i]).abs() <= 2e-3 + 0.05 * fd.abs().max(g.dq.data[i].abs()) {
                agree += 1;
            }
        }
        assert!(agree * 10 >= total * 8, "only {agree}/{total} agree");
    }

    #[test]
    fn memory_scales_linearithmically() {
        let z = ZetaNative::default();
        let (_, m1) = z.forward(&Workload::random(1024, 8, 8, 4));
        let (_, m2) = z.forward(&Workload::random(4096, 8, 8, 4));
        let ratio = m2.workspace_bytes as f64 / m1.workspace_bytes as f64;
        assert!(ratio < 5.0, "ratio {ratio}"); // ~4x for 4x N
    }

    #[test]
    fn parallel_matches_serial() {
        let z = ZetaNative { chunk: 32, ..ZetaNative::default() };
        let w = Workload::random(512, 16, 8, 13);
        let (os, _) = z.forward_with(&w, &Pool::serial());
        let (op, _) = z.forward_with(&w, &Pool::new(4));
        assert!(os.max_abs_diff(&op) < 1e-5);
        let (gs, _) = z.forward_backward_with(&w, &Pool::serial());
        let (gp, _) = z.forward_backward_with(&w, &Pool::new(4));
        assert!(gs.dq.max_abs_diff(&gp.dq) < 1e-4);
        assert!(gs.dk.max_abs_diff(&gp.dk) < 1e-4);
        assert!(gs.dv.max_abs_diff(&gp.dv) < 1e-4);
    }
}
