//! ZETA native kernel: Z-order top-k Cauchy attention on CPU.
//!
//! This is Algorithm 1 of the paper plus the Appendix-E backward, end to
//! end in Rust: project to d_K dims -> Morton-encode -> radix sort ->
//! per-query binary search + window candidate scan under the chunked causal
//! mask -> Adaptive Cauchy-Softmax over the k candidates + the history-mean
//! smoothing token. O(N log N) time (the sort; everything else is O(N·k)),
//! O(N·k) memory.
//!
//! Parallel decomposition (the paper's claim that Z-order sorting makes
//! top-k selection parallel — "all queries searched simultaneously"):
//! Morton encoding, the per-query binary search + window scan, and the
//! Cauchy-softmax accumulation are all split by query chunks over the
//! shared pool; every worker writes disjoint candidate/output rows. Only
//! the O(N) radix sort and the O(N·d) history-mean prefix scans stay
//! serial. The backward is query-parallel with per-thread dK/dV
//! accumulators merged once after the join.

use super::{AttentionImpl, Grads, MemReport, Workload};
use crate::tensor::{sqdist, Tensor};
use crate::util::pool::{merge_partials, Pool, SharedSlice};
use crate::zorder;

pub struct ZetaNative {
    /// Low dimension used for the search/scores (paper: 3).
    pub d_k: usize,
    /// Number of attended candidates per query (paper: 32).
    pub k: usize,
    /// Chunk size of the causal mask (paper: N / #chunks).
    pub chunk: usize,
    /// Candidate window in the sorted order (>= k to survive masking).
    pub window: usize,
    /// gamma^2 of the Cauchy kernel.
    pub eps: f32,
    /// Fixed quantization range.
    pub range: f32,
}

impl Default for ZetaNative {
    fn default() -> Self {
        ZetaNative { d_k: 3, k: 32, chunk: 64, window: 64, eps: 0.5, range: 4.0 }
    }
}

/// Candidate sets for all queries: indices + count per query.
struct Candidates {
    idx: Vec<u32>, // (N, k) padded with u32::MAX
    k: usize,
}

impl ZetaNative {
    /// Slice the first d_k dims of q/k as the low-dimensional projection.
    /// (In the full system the projection is learned at L2; for the kernel
    /// benchmark a fixed projection is the honest equivalent.)
    fn project(&self, x: &Tensor, pool: &Pool) -> Vec<f32> {
        let n = x.shape[0];
        let d = x.shape[1];
        let dk = self.d_k.min(d);
        let mut out = vec![0f32; n * self.d_k];
        let wdk = self.d_k;
        {
            let osh = SharedSlice::new(&mut out);
            pool.parallel_for(n, pool.grain(n, 256), |rows| {
                for i in rows {
                    // Safety: row i claimed by exactly one chunk.
                    let orow = unsafe { osh.range_mut(i * wdk..(i + 1) * wdk) };
                    orow[..dk].copy_from_slice(&x.row(i)[..dk]);
                }
            });
        }
        out
    }

    fn search(&self, ql: &[f32], kl: &[f32], n: usize, pool: &Pool) -> (Candidates, usize) {
        let bits = zorder::bits_for_dim(self.d_k);
        let qc = zorder::encode_points_pool(ql, self.d_k, self.range, bits, pool);
        let kc = zorder::encode_points_pool(kl, self.d_k, self.range, bits, pool);
        let perm = zorder::argsort_codes(&kc); // O(N) radix sort (serial)
        let sorted: Vec<u32> = perm.iter().map(|&p| kc[p as usize]).collect();

        let mut idx = vec![u32::MAX; n * self.k];
        let half = self.window / 2;
        let kk_cap = self.k;
        // Query-parallel search: each worker owns a private candidate
        // scratch and writes disjoint rows of the index table.
        let grain = pool.grain(n, 32);
        let cand_ws: usize = {
            let ish = SharedSlice::new(&mut idx);
            let ws: Vec<usize> = pool.run_chunked(n, grain, |queue| {
                let mut cand: Vec<(u32, u32)> = Vec::with_capacity(self.window);
                while let Some(rows) = queue.next_chunk() {
                    for i in rows {
                        let limit = (i / self.chunk) * self.chunk; // causal bound
                        if limit == 0 {
                            continue;
                        }
                        // binary search for insertion position of q's code
                        let ins = sorted.partition_point(|&c| c < qc[i]);
                        let lo = ins.saturating_sub(half);
                        let hi = (ins + half).min(n);
                        cand.clear();
                        for s in lo..hi {
                            let pos = perm[s];
                            if (pos as usize) < limit {
                                let dz =
                                    (sorted[s] as i64 - qc[i] as i64).unsigned_abs() as u32;
                                cand.push((dz, pos));
                            }
                        }
                        // keep the k candidates nearest along the curve
                        let kk = kk_cap.min(cand.len());
                        if kk > 0 {
                            if cand.len() > kk {
                                cand.select_nth_unstable(kk - 1);
                            }
                            // Safety: row i claimed by exactly one chunk.
                            let irow =
                                unsafe { ish.range_mut(i * kk_cap..(i + 1) * kk_cap) };
                            for (slot, &(_, pos)) in cand[..kk].iter().enumerate() {
                                irow[slot] = pos;
                            }
                        }
                    }
                }
                cand.capacity() * 8
            });
            ws.iter().sum()
        };
        let ws =
            (qc.len() + kc.len() + perm.len() + sorted.len()) * 4 + cand_ws;
        (Candidates { idx, k: self.k }, ws)
    }

    /// Causal inclusive running means of the low-dim keys and values
    /// (the smoothing token of paper §3.4). Prefix scans stay serial —
    /// O(N·d), negligible next to the O(N·k·d) attention phases.
    fn history_means(&self, kl: &[f32], v: &Tensor, n: usize) -> (Vec<f32>, Vec<f32>) {
        let dk = self.d_k;
        let dv = v.shape[1];
        let mut km = vec![0f32; n * dk];
        let mut vm = vec![0f32; n * dv];
        let mut ksum = vec![0f32; dk];
        let mut vsum = vec![0f32; dv];
        for i in 0..n {
            for c in 0..dk {
                ksum[c] += kl[i * dk + c];
                km[i * dk + c] = ksum[c] / (i + 1) as f32;
            }
            let vr = v.row(i);
            for c in 0..dv {
                vsum[c] += vr[c];
                vm[i * dv + c] = vsum[c] / (i + 1) as f32;
            }
        }
        (km, vm)
    }

    /// Forward returning everything the backward needs.
    #[allow(clippy::type_complexity)]
    fn fwd_full(
        &self,
        w: &Workload,
        pool: &Pool,
    ) -> (Tensor, Candidates, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, usize) {
        let n = w.n();
        let dv = w.v.shape[1];
        let dk = self.d_k;
        let ql = self.project(&w.q, pool);
        let kl = self.project(&w.k, pool);
        let (cands, search_ws) = self.search(&ql, &kl, n, pool);
        let (km, vm) = self.history_means(&kl, &w.v, n);

        let mut o = Tensor::zeros(&[n, dv]);
        let mut zsum = vec![0f32; n]; // normalizers, kept for bwd
        // Query-parallel Cauchy-softmax accumulation: o rows and zsum
        // entries are disjoint per query. Each worker caches its candidate
        // scores so every Cauchy score is computed exactly once.
        let score_ws: usize = {
            let osh = SharedSlice::new(&mut o.data);
            let zsh = SharedSlice::new(&mut zsum);
            let ws: Vec<usize> = pool.run_chunked(n, pool.grain(n, 32), |queue| {
                let mut scores = vec![0f32; cands.k];
                while let Some(rows) = queue.next_chunk() {
                    for i in rows {
                        let qi = &ql[i * dk..(i + 1) * dk];
                        // scores over candidates + smoothing token
                        let mut z = 0.0f32;
                        let base = i * cands.k;
                        let mut nc = 0;
                        for slot in 0..cands.k {
                            let j = cands.idx[base + slot];
                            if j == u32::MAX {
                                break;
                            }
                            let jj = j as usize;
                            let s = 1.0
                                / (sqdist(qi, &kl[jj * dk..(jj + 1) * dk]) + self.eps);
                            scores[slot] = s;
                            z += s;
                            nc = slot + 1;
                        }
                        let sm =
                            1.0 / (sqdist(qi, &km[i * dk..(i + 1) * dk]) + self.eps);
                        z += sm;
                        // Safety: index/row i claimed by exactly one chunk.
                        unsafe { zsh.write(i, z) };
                        let inv = 1.0 / z;
                        let orow = unsafe { osh.range_mut(i * dv..(i + 1) * dv) };
                        for slot in 0..nc {
                            let jj = cands.idx[base + slot] as usize;
                            let a = scores[slot] * inv;
                            let vr = w.v.row(jj);
                            for c in 0..dv {
                                orow[c] += a * vr[c];
                            }
                        }
                        let am = sm * inv;
                        for c in 0..dv {
                            orow[c] += am * vm[i * dv + c];
                        }
                    }
                }
                scores.len() * 4
            });
            ws.iter().sum()
        };
        let ws = search_ws
            + (ql.len() + kl.len() + km.len() + vm.len() + zsum.len()) * 4
            + cands.idx.len() * 4
            + score_ws;
        (o, cands, ql, kl, km, vm, zsum, ws)
    }
}

impl AttentionImpl for ZetaNative {
    fn name(&self) -> &'static str {
        "zeta"
    }

    fn forward_with(&self, w: &Workload, pool: &Pool) -> (Tensor, MemReport) {
        let (o, _, _, _, _, _, _, ws) = self.fwd_full(w, pool);
        let mem = MemReport { workspace_bytes: ws, output_bytes: o.bytes() };
        (o, mem)
    }

    fn forward_backward_with(&self, w: &Workload, pool: &Pool) -> (Grads, MemReport) {
        let n = w.n();
        let dv = w.v.shape[1];
        let dk = self.d_k;
        let d = w.q.shape[1];
        let (o, cands, ql, kl, km, vm, zsum, ws) = self.fwd_full(w, pool);

        // Gradients in the low-dim space; mapped back to the first d_k
        // coordinates of q/k (the projection is a fixed slice).
        let mut dql = vec![0f32; n * dk];
        let mut dkl = vec![0f32; n * dk];
        let mut dvt = Tensor::zeros(&[n, dv]);
        // Suffix accumulators for the history-mean tokens: the mean at row i
        // feeds every j <= i with weight 1/(i+1).
        let mut vm_suffix = vec![0f32; n * dv];
        let mut km_suffix = vec![0f32; n * dk];

        // Query-parallel main loop: dql / km_suffix / vm_suffix rows are
        // disjoint per query; dkl / dvt scatter across candidate keys, so
        // workers accumulate into private buffers merged after the join.
        let grain = pool.grain(n, 32);
        let parts: Vec<(Vec<f32>, Vec<f32>)> = {
            let dqlsh = SharedSlice::new(&mut dql);
            let kmsh = SharedSlice::new(&mut km_suffix);
            let vmsh = SharedSlice::new(&mut vm_suffix);
            pool.run_chunked(n, grain, |queue| {
                let mut dkl_local = vec![0f32; n * dk];
                let mut dvt_local = vec![0f32; n * dv];
                while let Some(rows) = queue.next_chunk() {
                    for i in rows {
                        let qi = &ql[i * dk..(i + 1) * dk];
                        let gi = w.dout.row(i);
                        let oi = o.row(i);
                        let z = zsum[i];
                        let base = i * cands.k;

                        let mut dq_acc = [0f32; 16];
                        debug_assert!(dk <= 16);
                        for slot in 0..=cands.k {
                            // slot == cands.k is the smoothing token
                            let (kj, vj, jj): (&[f32], &[f32], Option<usize>) =
                                if slot == cands.k {
                                    (
                                        &km[i * dk..(i + 1) * dk],
                                        &vm[i * dv..(i + 1) * dv],
                                        None,
                                    )
                                } else {
                                    let j = cands.idx[base + slot];
                                    if j == u32::MAX {
                                        continue;
                                    }
                                    let jj = j as usize;
                                    (
                                        &kl[jj * dk..(jj + 1) * dk],
                                        &w.v.data[jj * dv..(jj + 1) * dv],
                                        Some(jj),
                                    )
                                };
                            let delta = sqdist(qi, kj) + self.eps;
                            let s = 1.0 / delta;
                            let a = s / z;
                            // dL/dS = g . (v_j - o_i) / Z ; dL/ddelta = -dL/dS * s^2
                            let mut gdot = 0.0;
                            for c in 0..dv {
                                gdot += gi[c] * (vj[c] - oi[c]);
                            }
                            let ds = gdot / z;
                            let ddelta = -ds * s * s;
                            // dq += ddelta * 2 (q - k); dk_j -= ddelta * 2 (q - k)
                            match jj {
                                Some(j) => {
                                    let dkj = &mut dkl_local[j * dk..(j + 1) * dk];
                                    for c in 0..dk {
                                        let diff = 2.0 * (qi[c] - kj[c]) * ddelta;
                                        dq_acc[c] += diff;
                                        dkj[c] -= diff;
                                    }
                                    let dvj = &mut dvt_local[j * dv..(j + 1) * dv];
                                    for c in 0..dv {
                                        dvj[c] += a * gi[c];
                                    }
                                }
                                None => {
                                    // smoothing token: gradient flows into the
                                    // running means; defer via suffix
                                    // accumulators (rows disjoint per query).
                                    // Safety: row i claimed by one chunk.
                                    let kms = unsafe {
                                        kmsh.range_mut(i * dk..(i + 1) * dk)
                                    };
                                    for c in 0..dk {
                                        let diff = 2.0 * (qi[c] - kj[c]) * ddelta;
                                        dq_acc[c] += diff;
                                        kms[c] -= diff;
                                    }
                                    let vms = unsafe {
                                        vmsh.range_mut(i * dv..(i + 1) * dv)
                                    };
                                    for c in 0..dv {
                                        vms[c] += a * gi[c];
                                    }
                                }
                            }
                        }
                        // Safety: row i claimed by exactly one chunk.
                        let dqli = unsafe { dqlsh.range_mut(i * dk..(i + 1) * dk) };
                        for c in 0..dk {
                            dqli[c] += dq_acc[c];
                        }
                    }
                }
                (dkl_local, dvt_local)
            })
        };
        merge_partials(&mut dkl, parts.iter().map(|(dkl_p, _)| dkl_p.as_slice()));
        merge_partials(&mut dvt.data, parts.iter().map(|(_, dvt_p)| dvt_p.as_slice()));

        // Propagate history-mean gradients: contribution of row i spreads to
        // all positions j <= i with weight 1/(i+1). Reverse prefix sum of
        // (suffix_i / (i+1)) — inherently sequential, O(N·d), left serial.
        let mut acc_v = vec![0f32; dv];
        let mut acc_k = vec![0f32; dk];
        for i in (0..n).rev() {
            let wgt = 1.0 / (i + 1) as f32;
            for c in 0..dv {
                acc_v[c] += vm_suffix[i * dv + c] * wgt;
            }
            for c in 0..dk {
                acc_k[c] += km_suffix[i * dk + c] * wgt;
            }
            let dvj = &mut dvt.data[i * dv..(i + 1) * dv];
            for c in 0..dv {
                dvj[c] += acc_v[c];
            }
            let dkj = &mut dkl[i * dk..(i + 1) * dk];
            for c in 0..dk {
                dkj[c] += acc_k[c];
            }
        }

        // Map low-dim grads back into full-width dq/dk (slice projection).
        let mut dq = Tensor::zeros(&[n, d]);
        let mut dkt = Tensor::zeros(&[n, d]);
        let dcopy = dk.min(d);
        for i in 0..n {
            dq.row_mut(i)[..dcopy].copy_from_slice(&dql[i * dk..i * dk + dcopy]);
            dkt.row_mut(i)[..dcopy].copy_from_slice(&dkl[i * dk..i * dk + dcopy]);
        }

        let partial_bytes: usize =
            parts.iter().map(|(a, b)| (a.len() + b.len()) * 4).sum();
        let mem = MemReport {
            workspace_bytes: ws
                + (dql.len() + dkl.len() + vm_suffix.len() + km_suffix.len()) * 4
                + partial_bytes
                + o.bytes(),
            output_bytes: dq.bytes() + dkt.bytes() + dvt.bytes(),
        };
        (Grads { dq, dk: dkt, dv: dvt }, mem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ZetaNative {
        ZetaNative { d_k: 2, k: 4, chunk: 4, window: 16, eps: 0.5, range: 4.0 }
    }

    #[test]
    fn outputs_finite_and_convex() {
        let w = Workload::random(64, 8, 4, 0);
        let mut wc = w;
        wc.v = Tensor::from_vec(&[64, 4], vec![1.0; 256]);
        let (o, _) = tiny().forward(&wc);
        for (i, v) in o.data.iter().enumerate() {
            // row 0..chunk has only the smoothing token; still mean of ones
            assert!((v - 1.0).abs() < 1e-4, "elem {i}: {v}");
        }
    }

    #[test]
    fn causality_no_future_candidates() {
        // All values beyond position p are poisoned with a huge magnitude;
        // outputs for queries in chunks <= p/chunk must stay bounded.
        let n = 64;
        let mut w = Workload::random(n, 8, 4, 1);
        for i in 32..n {
            for c in 0..4 {
                w.v.row_mut(i)[c] = 1e6;
            }
        }
        let z = tiny();
        let (o, _) = z.forward(&w);
        for i in 0..32 {
            // history mean at i < 32 only includes v[..=i], all sane
            for &v in o.row(i) {
                assert!(v.abs() < 1e3, "row {i} leaked future value: {v}");
            }
        }
    }

    #[test]
    fn grads_match_finite_difference() {
        let n = 12;
        let d = 3;
        let dv = 2;
        let z = ZetaNative { d_k: 2, k: 3, chunk: 4, window: 16, eps: 0.4, range: 4.0 };
        let w = Workload::random(n, d, dv, 2);
        let (g, _) = z.forward_backward(&w);

        // loss = sum(o * dout); check dv (candidate selection is fixed w.r.t.
        // v, so the v-gradient is exact).
        let loss_v = |vdata: &[f32]| {
            let w2 = Workload {
                q: w.q.clone(),
                k: w.k.clone(),
                v: Tensor::from_vec(&[n, dv], vdata.to_vec()),
                dout: w.dout.clone(),
            };
            let (o, _) = z.forward(&w2);
            o.data.iter().zip(&w2.dout.data).map(|(a, b)| a * b).sum::<f32>()
        };
        let mut v0 = w.v.data.clone();
        super::super::numeric_grad_check(loss_v, &mut v0, &g.dv.data, 2e-3);
    }

    #[test]
    fn grad_q_matches_fd_where_selection_stable() {
        // q perturbations can flip candidate selection (non-differentiable
        // boundary); use a case with eps large enough to be smooth and
        // tolerate outliers by checking the median agreement.
        let n = 12;
        let d = 2;
        let dv = 2;
        let z = ZetaNative { d_k: 2, k: 3, chunk: 4, window: 16, eps: 0.8, range: 6.0 };
        let w = Workload::random(n, d, dv, 3);
        let (g, _) = z.forward_backward(&w);
        let loss_q = |qdata: &[f32]| {
            let w2 = Workload {
                q: Tensor::from_vec(&[n, d], qdata.to_vec()),
                k: w.k.clone(),
                v: w.v.clone(),
                dout: w.dout.clone(),
            };
            let (o, _) = z.forward(&w2);
            o.data.iter().zip(&w2.dout.data).map(|(a, b)| a * b).sum::<f32>()
        };
        let mut q0 = w.q.data.clone();
        let h = 1e-3;
        let mut agree = 0;
        let total = q0.len();
        for i in 0..total {
            let orig = q0[i];
            q0[i] = orig + h;
            let fp = loss_q(&q0);
            q0[i] = orig - h;
            let fm = loss_q(&q0);
            q0[i] = orig;
            let fd = (fp - fm) / (2.0 * h);
            if (fd - g.dq.data[i]).abs() <= 2e-3 + 0.05 * fd.abs().max(g.dq.data[i].abs()) {
                agree += 1;
            }
        }
        assert!(agree * 10 >= total * 8, "only {agree}/{total} agree");
    }

    #[test]
    fn memory_scales_linearithmically() {
        let z = ZetaNative::default();
        let (_, m1) = z.forward(&Workload::random(1024, 8, 8, 4));
        let (_, m2) = z.forward(&Workload::random(4096, 8, 8, 4));
        let ratio = m2.workspace_bytes as f64 / m1.workspace_bytes as f64;
        assert!(ratio < 5.0, "ratio {ratio}"); // ~4x for 4x N
    }

    #[test]
    fn parallel_matches_serial() {
        let z = ZetaNative { chunk: 32, ..ZetaNative::default() };
        let w = Workload::random(512, 16, 8, 13);
        let (os, _) = z.forward_with(&w, &Pool::serial());
        let (op, _) = z.forward_with(&w, &Pool::new(4));
        assert!(os.max_abs_diff(&op) < 1e-5);
        let (gs, _) = z.forward_backward_with(&w, &Pool::serial());
        let (gp, _) = z.forward_backward_with(&w, &Pool::new(4));
        assert!(gs.dq.max_abs_diff(&gp.dq) < 1e-4);
        assert!(gs.dk.max_abs_diff(&gp.dk) < 1e-4);
        assert!(gs.dv.max_abs_diff(&gp.dv) < 1e-4);
    }
}
