//! Rust-native attention kernels — the efficiency-benchmark substrate.
//!
//! The paper's Tables 3 and 4 time four implementations on a GPU (Torch
//! attention, FlashAttention, Mamba, ZETA/Triton). Our testbed is CPU, so
//! these are faithful CPU implementations with the same *asymptotic*
//! structure (see DESIGN.md §5 substitutions):
//!
//!   naive  — materializes the full causal score matrix. O(N²) time+memory.
//!   flash  — blocked streaming softmax, recompute backward.
//!            O(N²) time, O(N) extra memory.
//!   zeta   — Z-order sort + windowed candidate search + Cauchy top-k
//!            attention (paper Algorithm 1 + Appendix E backward).
//!            O(N log N) time, O(N·k) memory.
//!   mamba  — selective-SSM scan baseline. O(N) time, O(1)-per-step memory.
//!
//! Every implementation reports a `MemReport` whose `workspace_bytes` is the
//! *actual* sum of buffer bytes it allocated, so Table 4 is measured, not
//! modeled.

pub mod flash;
pub mod mamba;
pub mod naive;
pub mod zeta;

use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// One attention problem instance (single head; batch = repeat).
pub struct Workload {
    pub q: Tensor,    // (N, d)
    pub k: Tensor,    // (N, d)
    pub v: Tensor,    // (N, dv)
    pub dout: Tensor, // (N, dv) upstream gradient for fwd+bwd timing
}

impl Workload {
    pub fn random(n: usize, d: usize, dv: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        Workload {
            q: Tensor::randn(&[n, d], &mut rng, 1.0),
            k: Tensor::randn(&[n, d], &mut rng, 1.0),
            v: Tensor::randn(&[n, dv], &mut rng, 1.0),
            dout: Tensor::randn(&[n, dv], &mut rng, 1.0),
        }
    }

    pub fn n(&self) -> usize {
        self.q.shape[0]
    }

    pub fn input_bytes(&self) -> usize {
        self.q.bytes() + self.k.bytes() + self.v.bytes()
    }
}

/// Gradients w.r.t. the workload inputs.
pub struct Grads {
    pub dq: Tensor,
    pub dk: Tensor,
    pub dv: Tensor,
}

/// Memory accounting for one run.
#[derive(Debug, Clone, Copy, Default)]
pub struct MemReport {
    /// Bytes of intermediate buffers actually allocated by the kernel
    /// (excludes inputs and final outputs).
    pub workspace_bytes: usize,
    /// Bytes of outputs (o, or grads for fwd+bwd).
    pub output_bytes: usize,
}

impl MemReport {
    pub fn total_with_inputs(&self, w: &Workload) -> usize {
        self.workspace_bytes + self.output_bytes + w.input_bytes()
    }

    pub fn add(&mut self, t: &Tensor) {
        self.workspace_bytes += t.bytes();
    }
}

/// The interface every benchmark implementation provides.
pub trait AttentionImpl {
    fn name(&self) -> &'static str;
    /// Forward only: returns output (N, dv) and memory report.
    fn forward(&self, w: &Workload) -> (Tensor, MemReport);
    /// Forward + backward: returns grads and memory report.
    fn forward_backward(&self, w: &Workload) -> (Grads, MemReport);
    /// Analytic memory model for problem sizes too expensive to *execute*
    /// on this testbed (Table 4's starred rows). None = always measure.
    fn analytic_mem(&self, _n: usize, _d: usize, _dv: usize, _fb: bool) -> Option<MemReport> {
        None
    }
}

/// All benchmark implementations at their paper-default settings.
pub fn all_impls() -> Vec<Box<dyn AttentionImpl>> {
    vec![
        Box::new(naive::Naive),
        Box::new(flash::Flash { block: 128 }),
        Box::new(zeta::ZetaNative::default()),
        Box::new(mamba::MambaLite::default()),
    ]
}

#[cfg(test)]
pub(crate) fn numeric_grad_check<F>(f: F, x0: &mut [f32], analytic: &[f32], atol: f32)
where
    F: Fn(&[f32]) -> f32,
{
    // Central differences over every coordinate (use tiny problems only).
    let h = 1e-3;
    for i in 0..x0.len() {
        let orig = x0[i];
        x0[i] = orig + h;
        let fp = f(x0);
        x0[i] = orig - h;
        let fm = f(x0);
        x0[i] = orig;
        let fd = (fp - fm) / (2.0 * h);
        assert!(
            (fd - analytic[i]).abs() <= atol + 0.05 * fd.abs().max(analytic[i].abs()),
            "grad[{i}]: fd {fd} vs analytic {}",
            analytic[i]
        );
    }
}
