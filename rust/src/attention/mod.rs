//! Rust-native attention kernels — the parallel efficiency-benchmark
//! substrate.
//!
//! The paper's Tables 3 and 4 time four implementations on a GPU (Torch
//! attention, FlashAttention, Mamba, ZETA/Triton). Our testbed is CPU, so
//! these are faithful CPU implementations with the same *asymptotic*
//! structure (see DESIGN.md §5 substitutions):
//!
//!   naive  — materializes the full causal score matrix. O(N²) time+memory.
//!   flash  — blocked streaming softmax, recompute backward.
//!            O(N²) time, O(N) extra memory.
//!   zeta   — Z-order sort + windowed candidate search + Cauchy top-k
//!            attention (paper Algorithm 1 + Appendix E backward).
//!            O(N log N) time, O(N·k) memory.
//!   mamba  — selective-SSM scan baseline. O(N) time, O(1)-per-step memory.
//!
//! ## Execution model
//!
//! Every kernel runs on the shared worker pool ([`crate::util::pool::Pool`],
//! `ZETA_THREADS` knob). The paper's central systems claim — Z-order
//! sorting makes top-k selection *parallel*, all queries searched
//! simultaneously — is realized here as:
//!
//! * **row-parallel forwards**: queries (flash: query blocks, mamba: value
//!   channels) are split into chunks claimed dynamically off a lock-free
//!   queue, each worker writing disjoint output rows;
//! * **chunk-parallel backwards**: gradients that scatter across keys
//!   (`dk`, `dv`) accumulate into per-thread buffers merged once after the
//!   scope joins, so there is no locking on the hot path;
//! * **`threads = 1` degrades to the old serial loops** — the determinism
//!   gate in `rust/tests/parallel_determinism.rs` pins parallel output to
//!   serial output within 1e-4 for all four kernels.
//!
//! The [`AttentionImpl`] trait carries both the single-problem path
//! (`forward_with` / `forward_backward_with`, explicit pool) and a batched
//! multi-head path ([`MultiWorkload`], `forward_batch` /
//! `forward_backward_batch`) whose default implementations loop the
//! single-head kernels so every implementation stays correct by
//! construction.
//!
//! Every implementation reports a [`MemReport`] whose `workspace_bytes` is
//! the *actual* sum of buffer bytes it allocated — including the per-thread
//! scratch and gradient accumulators — so Table 4 stays measured, not
//! modeled, under the pool.

pub mod flash;
pub mod mamba;
pub mod naive;
pub mod speculate;
pub mod zeta;

use std::sync::Arc;

use crate::tensor::Tensor;
use crate::util::arena::PageArena;
use crate::util::breakeven::{fan_out, PARALLEL_STEP_MIN_OPS};
use crate::util::pool::{Pool, SharedSlice};
use crate::util::rng::Rng;

/// One attention problem instance (single head; batch = repeat).
pub struct Workload {
    pub q: Tensor,    // (N, d)
    pub k: Tensor,    // (N, d)
    pub v: Tensor,    // (N, dv)
    pub dout: Tensor, // (N, dv) upstream gradient for fwd+bwd timing
}

impl Workload {
    pub fn random(n: usize, d: usize, dv: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        Workload {
            q: Tensor::randn(&[n, d], &mut rng, 1.0),
            k: Tensor::randn(&[n, d], &mut rng, 1.0),
            v: Tensor::randn(&[n, dv], &mut rng, 1.0),
            dout: Tensor::randn(&[n, dv], &mut rng, 1.0),
        }
    }

    pub fn n(&self) -> usize {
        self.q.shape[0]
    }

    pub fn input_bytes(&self) -> usize {
        self.q.bytes() + self.k.bytes() + self.v.bytes()
    }
}

/// A batched multi-head attention workload: `batch × heads` independent
/// single-head problems stored head-major, row block `p` of each tensor
/// holding problem `p`'s `(N, ·)` matrix.
///
/// This is the serving/training shape: the coordinator batches requests and
/// every layer runs all heads of all sequences through one kernel call.
pub struct MultiWorkload {
    pub batch: usize,
    pub heads: usize,
    pub q: Tensor,    // (batch*heads*N, d)
    pub k: Tensor,    // (batch*heads*N, d)
    pub v: Tensor,    // (batch*heads*N, dv)
    pub dout: Tensor, // (batch*heads*N, dv)
}

impl MultiWorkload {
    pub fn random(batch: usize, heads: usize, n: usize, d: usize, dv: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let rows = batch * heads * n;
        MultiWorkload {
            batch,
            heads,
            q: Tensor::randn(&[rows, d], &mut rng, 1.0),
            k: Tensor::randn(&[rows, d], &mut rng, 1.0),
            v: Tensor::randn(&[rows, dv], &mut rng, 1.0),
            dout: Tensor::randn(&[rows, dv], &mut rng, 1.0),
        }
    }

    /// Independent single-head problems in this workload.
    pub fn num_problems(&self) -> usize {
        self.batch * self.heads
    }

    /// Sequence length N of each problem.
    pub fn seq_len(&self) -> usize {
        let p = self.num_problems().max(1);
        self.q.shape[0] / p
    }

    pub fn input_bytes(&self) -> usize {
        self.q.bytes() + self.k.bytes() + self.v.bytes()
    }

    /// Materialize problem `idx` as a standalone [`Workload`] (copies the
    /// four row blocks; the single-head kernels own their inputs).
    pub fn problem(&self, idx: usize) -> Workload {
        assert!(idx < self.num_problems());
        let n = self.seq_len();
        let slice_rows = |t: &Tensor| -> Tensor {
            let w = t.shape[1];
            Tensor::from_vec(&[n, w], t.data[idx * n * w..(idx + 1) * n * w].to_vec())
        };
        Workload {
            q: slice_rows(&self.q),
            k: slice_rows(&self.k),
            v: slice_rows(&self.v),
            dout: slice_rows(&self.dout),
        }
    }
}

/// Incremental per-token decode state for one single-head attention stream
/// — the kernel-level KV cache. Produced by [`AttentionImpl::begin_decode`];
/// one instance per serving request, owned by the coordinator's sessions.
///
/// Contract (the decode-equivalence gate in
/// `rust/tests/decode_equivalence.rs`): after ingesting tokens `0..=t` via
/// [`DecodeState::step`], the returned output rows match rows `0..=t` of
/// the kernel's full-sequence `forward` on the same inputs within 1e-4 —
/// prefill and decode are two schedules of one computation.
pub trait DecodeState: Send {
    /// Ingest `(k_t, v_t)` at the next position and write the attention
    /// output row for `q_t` into `out` (length `dv`).
    fn step(&mut self, q_t: &[f32], k_t: &[f32], v_t: &[f32], out: &mut [f32]);

    /// Ingest a run of `n` consecutive tokens in one call: `qs` / `ks` are
    /// `n` rows of width `qs.len() / n`, `vs` is `n` rows of width
    /// `out.len()`, and `out` receives the *last* position's output row —
    /// exactly what a serial [`DecodeState::step`] loop leaves behind.
    ///
    /// Contract (the prefill-pipelining gate in
    /// `rust/tests/prefill_parallel.rs`): the resulting state *and* `out`
    /// are bit-identical to stepping the same rows one at a time, at every
    /// pool size. The default is that serial loop; kernels whose prefill
    /// has internal parallelism override it — ZETA fans the per-position
    /// candidate search out across frozen index snapshots, which is how a
    /// single long prompt uses the whole pool during prefill.
    fn prefill_run(
        &mut self,
        n: usize,
        qs: &[f32],
        ks: &[f32],
        vs: &[f32],
        out: &mut [f32],
        _pool: &Pool,
    ) {
        if n == 0 {
            return;
        }
        let d = qs.len() / n;
        let dv = out.len();
        for i in 0..n {
            self.step(
                &qs[i * d..(i + 1) * d],
                &ks[i * d..(i + 1) * d],
                &vs[i * dv..(i + 1) * dv],
                out,
            );
        }
    }

    /// Tokens ingested so far.
    fn pos(&self) -> usize;

    /// Bytes of persistent per-request state (KV cache / Z-order index /
    /// SSM state) — the serving-memory analogue of [`MemReport`]. Counts
    /// the arena pages this state references: pages shared with forks are
    /// counted in each handle, while the owning
    /// [`crate::util::arena::PageArena`] counts every live page exactly
    /// once (the number the serving byte budget enforces).
    fn state_bytes(&self) -> usize;

    /// Copy-on-write fork: the returned state has ingested exactly the
    /// same token history and continues independently. Full arena pages
    /// are *shared* (refcount bumps — the arena's live bytes barely grow);
    /// only the partial tail page and the O(1) running scalars are copied.
    /// Contract (the paged-state gate in `rust/tests/paged_state.rs`):
    /// stepping a fork is bit-identical to stepping a fresh state fed the
    /// same full sequence, and never perturbs the original.
    fn fork(&self) -> Box<dyn DecodeState>;

    /// Return every arena page to the arena and reset to the empty state
    /// (pos 0). Called when a session is preempted or retired so its
    /// memory is reusable immediately; dropping the state releases pages
    /// too, so `release` is about *when*, not *whether*. A released state
    /// must be re-prefilled from scratch before further `step`s.
    fn release(&mut self);

    /// Self-speculation fork: a state over the *same* ingested stream
    /// whose future `step`s run a deliberately narrowed (cheaper,
    /// approximate) configuration of the kernel — the draft side of
    /// speculative decoding. Like [`DecodeState::fork`] it shares the
    /// arena pages copy-on-write and never perturbs the original; unlike
    /// `fork` its outputs are *proposals*, not the kernel's answer, so
    /// every token it suggests must be re-scored by the full state before
    /// it may be emitted. `None` when the kernel has no cheaper
    /// configuration to offer (the exact-softmax kernels and mamba);
    /// ZETA narrows its windowed top-k.
    fn fork_draft(&self) -> Option<Box<dyn DecodeState>> {
        None
    }

    /// Rough scalar-op estimate of the *next* [`DecodeState::step`] call,
    /// used by [`AttentionImpl::step_batch`] to decide whether a fused
    /// cross-stream sweep is worth a pool fan-out (waking the resident
    /// team costs a few µs; tiny steps stay inline). Kernels override with
    /// their per-token complexity; the default models the exact-softmax
    /// O(t) regime.
    fn step_cost_hint(&self) -> usize {
        (self.pos() + 1) * 8
    }
}

/// One stream's slot in a fused cross-session decode sweep: its live
/// [`DecodeState`] plus this step's q/k/v rows and output row. Slots are
/// independent (disjoint states and outputs), which is what makes the
/// sweep embarrassingly parallel.
pub struct DecodeStep<'a> {
    pub state: &'a mut dyn DecodeState,
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub out: &'a mut [f32],
}

/// Run a whole workload through the decode path one token at a time,
/// returning the `(N, dv)` outputs. This is the subject of the
/// decode-vs-prefill equivalence gate: it must match `forward` row-for-row.
pub fn decode_full(imp: &dyn AttentionImpl, w: &Workload) -> Tensor {
    let n = w.n();
    let d = w.q.shape[1];
    let dv = w.v.shape[1];
    let mut o = Tensor::zeros(&[n, dv]);
    let mut st = imp.begin_decode(d, dv);
    for t in 0..n {
        st.step(w.q.row(t), w.k.row(t), w.v.row(t), o.row_mut(t));
    }
    o
}

/// Gradients w.r.t. the workload inputs.
pub struct Grads {
    pub dq: Tensor,
    pub dk: Tensor,
    pub dv: Tensor,
}

/// Memory accounting for one run.
#[derive(Debug, Clone, Copy, Default)]
pub struct MemReport {
    /// Bytes of intermediate buffers actually allocated by the kernel
    /// (excludes inputs and final outputs). Under the pool this includes
    /// every worker's scratch and per-thread gradient accumulators.
    pub workspace_bytes: usize,
    /// Bytes of outputs (o, or grads for fwd+bwd).
    pub output_bytes: usize,
}

impl MemReport {
    pub fn total_with_inputs(&self, w: &Workload) -> usize {
        self.workspace_bytes + self.output_bytes + w.input_bytes()
    }

    pub fn add(&mut self, t: &Tensor) {
        self.workspace_bytes += t.bytes();
    }
}

/// The interface every benchmark implementation provides.
///
/// Implementations supply the pool-aware `*_with` methods; the pool-free
/// `forward` / `forward_backward` wrappers run on the process-global pool
/// ([`Pool::global`], `ZETA_THREADS`). The batched multi-head entry points
/// default to looping the single-head path, so a new kernel is correct on
/// batched workloads before it is ever specialized.
pub trait AttentionImpl {
    fn name(&self) -> &'static str;

    /// Forward only on an explicit pool: returns output (N, dv) + memory.
    fn forward_with(&self, w: &Workload, pool: &Pool) -> (Tensor, MemReport);

    /// Forward + backward on an explicit pool: returns grads + memory.
    fn forward_backward_with(&self, w: &Workload, pool: &Pool) -> (Grads, MemReport);

    /// Forward on the process-global pool.
    fn forward(&self, w: &Workload) -> (Tensor, MemReport) {
        self.forward_with(w, Pool::global())
    }

    /// Forward + backward on the process-global pool.
    fn forward_backward(&self, w: &Workload) -> (Grads, MemReport) {
        self.forward_backward_with(w, Pool::global())
    }

    /// Begin incremental decoding for a stream with q/k width `d` and value
    /// width `dv`, with all persistent state on `arena` pages. Prefill
    /// stays on `forward_with` (or on `step` loops for strict streaming);
    /// each subsequent token costs the kernel's per-token complexity
    /// instead of a full-sequence recompute: O(log N + k) for `zeta`,
    /// O(N) for the exact-softmax kernels, O(1) for `mamba`.
    fn begin_decode_in(
        &self,
        d: usize,
        dv: usize,
        arena: &Arc<PageArena>,
    ) -> Box<dyn DecodeState>;

    /// [`AttentionImpl::begin_decode_in`] on the process-wide default
    /// arena ([`PageArena::global`]).
    fn begin_decode(&self, d: usize, dv: usize) -> Box<dyn DecodeState> {
        self.begin_decode_in(d, dv, PageArena::global())
    }

    /// Fused cross-stream decode: advance every slot's [`DecodeState`] by
    /// one token in a *single* pool-parallel kernel call — the serving
    /// sweep's replacement for N serial `step` calls across concurrent
    /// sessions. Slots are claimed dynamically off the chunk queue, and
    /// each slot runs the exact single-stream `step` arithmetic on its own
    /// state, so fused and serial sweeps produce bit-identical outputs
    /// (the fused-sweep equivalence gate in `rust/tests/fused_sweep.rs`).
    /// Sweeps whose total estimated work is below the fan-out break-even
    /// ([`PARALLEL_STEP_MIN_OPS`]) run inline serially.
    fn step_batch(&self, batch: &mut [DecodeStep<'_>], pool: &Pool) {
        let n = batch.len();
        let total: usize = batch.iter().map(|s| s.state.step_cost_hint()).sum();
        if !fan_out(n, total, pool.threads(), PARALLEL_STEP_MIN_OPS) {
            for s in batch.iter_mut() {
                s.state.step(s.q, s.k, s.v, s.out);
            }
            return;
        }
        let share = SharedSlice::new(batch);
        pool.run_chunked(n, 1, |queue| {
            while let Some(slots) = queue.next_chunk() {
                for i in slots {
                    // Safety: slot i is claimed by exactly one chunk, and
                    // every slot owns a distinct state/output pair.
                    let s = unsafe { &mut share.range_mut(i..i + 1)[0] };
                    s.state.step(s.q, s.k, s.v, s.out);
                }
            }
        });
    }

    /// Analytic memory model for problem sizes too expensive to *execute*
    /// on this testbed (Table 4's starred rows). `threads` is the pool size
    /// whose per-worker scratch should be modeled. None = always measure.
    fn analytic_mem(
        &self,
        _n: usize,
        _d: usize,
        _dv: usize,
        _fb: bool,
        _threads: usize,
    ) -> Option<MemReport> {
        None
    }

    /// Batched multi-head forward: output is `(batch*heads*N, dv)` with the
    /// same head-major row-block layout as the inputs. Default: loop the
    /// single-head path; `workspace_bytes` reports the peak across problems
    /// (buffers are freed between heads), `output_bytes` the sum.
    fn forward_batch(&self, mw: &MultiWorkload, pool: &Pool) -> (Tensor, MemReport) {
        let p = mw.num_problems();
        let n = mw.seq_len();
        let dv = mw.v.shape[1];
        let mut o = Tensor::zeros(&[p * n, dv]);
        let mut mem = MemReport::default();
        for idx in 0..p {
            let wl = mw.problem(idx);
            let head_copy = wl.input_bytes() + wl.dout.bytes();
            let (oh, mh) = self.forward_with(&wl, pool);
            o.data[idx * n * dv..(idx + 1) * n * dv].copy_from_slice(&oh.data);
            mem.workspace_bytes = mem.workspace_bytes.max(mh.workspace_bytes + head_copy);
            mem.output_bytes += mh.output_bytes;
        }
        (o, mem)
    }

    /// Batched multi-head forward + backward; grads share the inputs'
    /// head-major layout. Default: loop the single-head path.
    fn forward_backward_batch(&self, mw: &MultiWorkload, pool: &Pool) -> (Grads, MemReport) {
        let p = mw.num_problems();
        let n = mw.seq_len();
        let d = mw.q.shape[1];
        let dv = mw.v.shape[1];
        let mut dq = Tensor::zeros(&[p * n, d]);
        let mut dk = Tensor::zeros(&[p * n, d]);
        let mut dvt = Tensor::zeros(&[p * n, dv]);
        let mut mem = MemReport::default();
        for idx in 0..p {
            let wl = mw.problem(idx);
            let head_copy = wl.input_bytes() + wl.dout.bytes();
            let (g, mh) = self.forward_backward_with(&wl, pool);
            dq.data[idx * n * d..(idx + 1) * n * d].copy_from_slice(&g.dq.data);
            dk.data[idx * n * d..(idx + 1) * n * d].copy_from_slice(&g.dk.data);
            dvt.data[idx * n * dv..(idx + 1) * n * dv].copy_from_slice(&g.dv.data);
            mem.workspace_bytes = mem.workspace_bytes.max(mh.workspace_bytes + head_copy);
            mem.output_bytes += mh.output_bytes;
        }
        (Grads { dq, dk, dv: dvt }, mem)
    }
}

/// All benchmark implementations at their paper-default settings.
pub fn all_impls() -> Vec<Box<dyn AttentionImpl>> {
    vec![
        Box::new(naive::Naive),
        Box::new(flash::Flash { block: 128 }),
        Box::new(zeta::ZetaNative::default()),
        Box::new(mamba::MambaLite::default()),
    ]
}

/// The one `kernel-name → AttentionImpl` factory, at *serving* settings —
/// used by the coordinator's native backend, the `exp` serving benchmarks
/// and the serving-level tests, so the name→config mapping can never
/// drift between them. (`all_impls` stays on the paper-default benchmark
/// settings: flash block 128, zeta chunk 64.) Returns `None` for unknown
/// names; callers own the error message.
pub fn kernel_by_name(name: &str) -> Option<Box<dyn AttentionImpl + Send + Sync>> {
    Some(match name {
        "naive" => Box::new(naive::Naive) as Box<dyn AttentionImpl + Send + Sync>,
        "flash" => Box::new(flash::Flash { block: 64 }),
        // chunk 16: fine-grained causal limits so short serving prompts
        // already exercise the windowed search.
        "zeta" => Box::new(zeta::ZetaNative { chunk: 16, ..zeta::ZetaNative::default() }),
        "mamba" => Box::new(mamba::MambaLite::default()),
        _ => return None,
    })
}

#[cfg(test)]
pub(crate) fn numeric_grad_check<F>(f: F, x0: &mut [f32], analytic: &[f32], atol: f32)
where
    F: Fn(&[f32]) -> f32,
{
    // Central differences over every coordinate (use tiny problems only).
    let h = 1e-3;
    for i in 0..x0.len() {
        let orig = x0[i];
        x0[i] = orig + h;
        let fp = f(x0);
        x0[i] = orig - h;
        let fm = f(x0);
        x0[i] = orig;
        let fd = (fp - fm) / (2.0 * h);
        assert!(
            (fd - analytic[i]).abs() <= atol + 0.05 * fd.abs().max(analytic[i].abs()),
            "grad[{i}]: fd {fd} vs analytic {}",
            analytic[i]
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multi_workload_problem_extracts_row_blocks() {
        let mw = MultiWorkload::random(2, 3, 8, 4, 5, 7);
        assert_eq!(mw.num_problems(), 6);
        assert_eq!(mw.seq_len(), 8);
        let w2 = mw.problem(2);
        assert_eq!(w2.q.shape, vec![8, 4]);
        assert_eq!(w2.v.shape, vec![8, 5]);
        assert_eq!(w2.q.data[..], mw.q.data[2 * 8 * 4..3 * 8 * 4]);
        assert_eq!(w2.dout.data[..], mw.dout.data[2 * 8 * 5..3 * 8 * 5]);
    }

    #[test]
    fn default_batch_matches_per_head_forward() {
        let mw = MultiWorkload::random(2, 2, 16, 8, 4, 3);
        let pool = Pool::serial();
        let imp = naive::Naive;
        let (o, _) = imp.forward_batch(&mw, &pool);
        assert_eq!(o.shape, vec![4 * 16, 4]);
        for idx in 0..mw.num_problems() {
            let (oh, _) = imp.forward_with(&mw.problem(idx), &pool);
            let got = &o.data[idx * 16 * 4..(idx + 1) * 16 * 4];
            assert_eq!(got, &oh.data[..]);
        }
    }

    #[test]
    fn default_batch_backward_shapes_and_agreement() {
        let mw = MultiWorkload::random(1, 3, 12, 6, 4, 5);
        let pool = Pool::serial();
        let imp = flash::Flash { block: 8 };
        let (g, _) = imp.forward_backward_batch(&mw, &pool);
        assert_eq!(g.dq.shape, vec![3 * 12, 6]);
        assert_eq!(g.dv.shape, vec![3 * 12, 4]);
        let (g0, _) = imp.forward_backward_with(&mw.problem(0), &pool);
        assert_eq!(&g.dq.data[..12 * 6], &g0.dq.data[..]);
    }
}
