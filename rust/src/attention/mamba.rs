//! Mamba-lite: selective state-space scan baseline for Tables 3–4.
//!
//! A faithful *shape* stand-in for Mamba's selective SSM (DESIGN.md §5):
//! input-dependent (delta, B, C) computed from the value stream, then a
//! linear recurrence per (channel, state) pair:
//!
//!   h_t = exp(-softplus(dt_t) * A) h_{t-1} + dt_t * B_t * x_t
//!   y_t = C_t . h_t
//!
//! O(N * dv * n_state) time, O(dv * n_state) live state — the O(N) curve
//! the paper's Tables 3–4 compare against. The backward pass recomputes the
//! recurrence in reverse (storing only the forward h trajectory, which is
//! what gives Mamba-style implementations their small-but-not-tiny memory).

use super::{AttentionImpl, Grads, MemReport, Workload};
use crate::tensor::Tensor;

pub struct MambaLite {
    pub n_state: usize,
}

impl Default for MambaLite {
    fn default() -> Self {
        MambaLite { n_state: 16 }
    }
}

#[inline]
fn softplus(x: f32) -> f32 {
    if x > 20.0 {
        x
    } else {
        (1.0 + x.exp()).ln()
    }
}

impl MambaLite {
    /// Derive (dt, b, c) deterministically from q/k rows — stand-ins for the
    /// learned projections; keeps the workload interface shared.
    fn gates(&self, w: &Workload, t: usize) -> (f32, Vec<f32>, Vec<f32>) {
        let d = w.q.shape[1];
        let qr = w.q.row(t);
        let kr = w.k.row(t);
        let dt = softplus(qr[0]);
        let ns = self.n_state;
        let mut b = vec![0f32; ns];
        let mut c = vec![0f32; ns];
        for s in 0..ns {
            b[s] = kr[s % d] * 0.5;
            c[s] = qr[s % d] * 0.5;
        }
        (dt, b, c)
    }

    /// Forward storing the full h trajectory (needed by bwd).
    fn fwd_traj(&self, w: &Workload) -> (Tensor, Vec<f32>, MemReport) {
        let n = w.n();
        let dv = w.v.shape[1];
        let ns = self.n_state;
        let mut y = Tensor::zeros(&[n, dv]);
        // h trajectory: (N, dv, ns)
        let mut htraj = vec![0f32; n * dv * ns];
        let mut h = vec![0f32; dv * ns];
        // A_s = (s+1)/ns: a spread of decay rates, as in S4/Mamba inits.
        for t in 0..n {
            let (dt, b, c) = self.gates(w, t);
            let vr = w.v.row(t);
            let yr = y.row_mut(t);
            for ch in 0..dv {
                let x = vr[ch];
                let hrow = &mut h[ch * ns..(ch + 1) * ns];
                let mut acc = 0.0;
                for s in 0..ns {
                    let a = (s + 1) as f32 / ns as f32;
                    let decay = (-dt * a).exp();
                    hrow[s] = decay * hrow[s] + dt * b[s] * x;
                    acc += c[s] * hrow[s];
                }
                yr[ch] = acc;
            }
            htraj[t * dv * ns..(t + 1) * dv * ns].copy_from_slice(&h);
        }
        let mem = MemReport {
            workspace_bytes: (htraj.len() + h.len()) * 4,
            output_bytes: y.bytes(),
        };
        (y, htraj, mem)
    }
}

impl AttentionImpl for MambaLite {
    fn name(&self) -> &'static str {
        "mamba"
    }

    fn forward(&self, w: &Workload) -> (Tensor, MemReport) {
        // Forward-only does not need the trajectory: O(dv*ns) live state.
        let n = w.n();
        let dv = w.v.shape[1];
        let ns = self.n_state;
        let mut y = Tensor::zeros(&[n, dv]);
        let mut h = vec![0f32; dv * ns];
        for t in 0..n {
            let (dt, b, c) = self.gates(w, t);
            let vr = w.v.row(t);
            let yr = y.row_mut(t);
            for ch in 0..dv {
                let x = vr[ch];
                let hrow = &mut h[ch * ns..(ch + 1) * ns];
                let mut acc = 0.0;
                for s in 0..ns {
                    let a = (s + 1) as f32 / ns as f32;
                    hrow[s] = (-dt * a).exp() * hrow[s] + dt * b[s] * x;
                    acc += c[s] * hrow[s];
                }
                yr[ch] = acc;
            }
        }
        let mem = MemReport { workspace_bytes: h.len() * 4, output_bytes: y.bytes() };
        (y, mem)
    }

    fn forward_backward(&self, w: &Workload) -> (Grads, MemReport) {
        let n = w.n();
        let dv = w.v.shape[1];
        let d = w.q.shape[1];
        let ns = self.n_state;
        let (_, htraj, mut mem) = self.fwd_traj(w);

        // Only d/dv is propagated exactly (the gates derive from q/k through
        // fixed stand-in projections; their gradients flow in the real model
        // at L2). dv_t = sum over s of adjoint paths.
        let mut dvt = Tensor::zeros(&[n, dv]);
        let dq = Tensor::zeros(&[n, d]);
        let dk = Tensor::zeros(&[n, d]);

        // Adjoint of h, swept in reverse.
        let mut dh = vec![0f32; dv * ns];
        for t in (0..n).rev() {
            let (dt, b, c) = self.gates(w, t);
            let g = w.dout.row(t);
            for ch in 0..dv {
                let dhrow = &mut dh[ch * ns..(ch + 1) * ns];
                let mut dx = 0.0;
                for s in 0..ns {
                    let a = (s + 1) as f32 / ns as f32;
                    // y_t contributes c_s to dh_t
                    dhrow[s] += c[s] * g[ch];
                    // x enters h via dt*b_s
                    dx += dhrow[s] * dt * b[s];
                    // pass adjoint to h_{t-1}
                    dhrow[s] *= (-dt * a).exp();
                }
                dvt.row_mut(t)[ch] = dx;
            }
        }
        let _ = htraj; // trajectory retained to model real memory behaviour
        mem.workspace_bytes += dh.len() * 4;
        mem.output_bytes = dq.bytes() + dk.bytes() + dvt.bytes();
        (Grads { dq, dk, dv: dvt }, mem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_is_causal() {
        let mut w = Workload::random(32, 8, 4, 0);
        let (y1, _) = MambaLite::default().forward(&w);
        // poison the tail; prefix outputs unchanged
        for i in 16..32 {
            for c in 0..4 {
                w.v.row_mut(i)[c] = 1e5;
            }
        }
        let (y2, _) = MambaLite::default().forward(&w);
        for i in 0..16 {
            for c in 0..4 {
                assert!((y1.row(i)[c] - y2.row(i)[c]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn state_decays() {
        // An impulse at t=0 should fade: |y_t| decreasing for a lone input.
        let n = 64;
        let mut w = Workload::random(n, 8, 1, 1);
        for i in 0..n {
            w.v.row_mut(i)[0] = if i == 0 { 1.0 } else { 0.0 };
            // constant gates
            for c in 0..8 {
                w.q.row_mut(i)[c] = 0.5;
                w.k.row_mut(i)[c] = 0.5;
            }
        }
        let (y, _) = MambaLite::default().forward(&w);
        let early = y.row(1)[0].abs();
        let late = y.row(40)[0].abs();
        assert!(late < early, "late {late} !< early {early}");
    }

    #[test]
    fn dv_grad_matches_fd() {
        let n = 10;
        let dv = 2;
        let m = MambaLite { n_state: 4 };
        let w = Workload::random(n, 4, dv, 2);
        let (g, _) = m.forward_backward(&w);
        let loss = |vdata: &[f32]| {
            let w2 = Workload {
                q: w.q.clone(),
                k: w.k.clone(),
                v: Tensor::from_vec(&[n, dv], vdata.to_vec()),
                dout: w.dout.clone(),
            };
            let (y, _) = m.forward(&w2);
            y.data.iter().zip(&w2.dout.data).map(|(a, b)| a * b).sum::<f32>()
        };
        let mut v0 = w.v.data.clone();
        super::super::numeric_grad_check(loss, &mut v0, &g.dv.data, 1e-3);
    }

    #[test]
    fn forward_memory_is_constant_in_n() {
        let m = MambaLite::default();
        let (_, m1) = m.forward(&Workload::random(256, 8, 8, 3));
        let (_, m2) = m.forward(&Workload::random(2048, 8, 8, 3));
        assert_eq!(m1.workspace_bytes, m2.workspace_bytes);
    }
}
