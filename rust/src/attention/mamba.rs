//! Mamba-lite: selective state-space scan baseline for Tables 3–4.
//!
//! A faithful *shape* stand-in for Mamba's selective SSM (DESIGN.md §5):
//! input-dependent (delta, B, C) computed from the value stream, then a
//! linear recurrence per (channel, state) pair:
//!
//!   h_t = exp(-softplus(dt_t) * A) h_{t-1} + dt_t * B_t * x_t
//!   y_t = C_t . h_t
//!
//! O(N * dv * n_state) time, O(dv * n_state) live state — the O(N) curve
//! the paper's Tables 3–4 compare against. The backward pass recomputes the
//! recurrence in reverse (storing only the forward h trajectory, which is
//! what gives Mamba-style implementations their small-but-not-tiny memory).
//!
//! Parallel decomposition: the time recurrence is sequential, but every
//! value *channel* scans independently — workers claim channel chunks, own
//! the corresponding slice of hidden state, and write disjoint (t, ch)
//! elements of the output. Workers recompute the shared per-step gates
//! (O(n_state) per step) rather than materializing O(N·n_state) gate
//! arrays, preserving the O(1)-in-N forward workspace.

use std::sync::Arc;

use super::{AttentionImpl, DecodeState, Grads, MemReport, Workload};
use crate::tensor::Tensor;
use crate::util::arena::{PageArena, PagedKv};
use crate::util::pool::{Pool, SharedSlice};
use crate::util::simd;

pub struct MambaLite {
    pub n_state: usize,
}

impl Default for MambaLite {
    fn default() -> Self {
        MambaLite { n_state: 16 }
    }
}

#[inline]
fn softplus(x: f32) -> f32 {
    if x > 20.0 {
        x
    } else {
        (1.0 + x.exp()).ln()
    }
}

/// Per-token decay factors `exp(-dt·a_s)` with `a_s = (s+1)/ns` (the
/// S4/Mamba-style spread of rates). Hoisted out of the channel loop: one
/// exp per state per *token* instead of per (channel, state), with the
/// exact same values the seed recomputed inline.
fn fill_decay(decay: &mut [f32], dt: f32, ns: usize) {
    for (s, dec) in decay.iter_mut().enumerate() {
        let a = (s + 1) as f32 / ns as f32;
        *dec = (-dt * a).exp();
    }
}

/// One channel's recurrence step: advance its hidden-state row by one token
/// and return the output y contribution. Shared verbatim by the batch
/// forwards and [`MambaDecode::step`], so decode stays bit-identical to
/// prefill by construction. Runs on the SIMD layer: the carried `hrow`
/// update is elementwise (bit-identical on every backend); only the
/// returned readout uses the lane reduction tree.
#[inline]
fn scan_channel_step(
    decay: &[f32],
    b: &[f32],
    c: &[f32],
    dt: f32,
    x: f32,
    hrow: &mut [f32],
) -> f32 {
    simd::ssm_step(decay, b, c, dt, x, hrow)
}

impl MambaLite {
    /// Fill (b, c) and return dt for step `t` — stand-ins for the learned
    /// projections; keeps the workload interface shared.
    fn gates_into(&self, w: &Workload, t: usize, b: &mut [f32], c: &mut [f32]) -> f32 {
        let d = w.q.shape[1];
        let qr = w.q.row(t);
        let kr = w.k.row(t);
        let dt = softplus(qr[0]);
        for s in 0..self.n_state {
            b[s] = kr[s % d] * 0.5;
            c[s] = qr[s % d] * 0.5;
        }
        dt
    }

    /// Channel chunk size: a few chunks per worker for load balance.
    fn channel_grain(&self, dv: usize, pool: &Pool) -> usize {
        (dv / (pool.threads() * 2).max(1)).max(1)
    }

    /// Forward storing the full h trajectory (needed by bwd).
    fn fwd_traj(&self, w: &Workload, pool: &Pool) -> (Tensor, Vec<f32>, MemReport) {
        let n = w.n();
        let dv = w.v.shape[1];
        let ns = self.n_state;
        let mut y = Tensor::zeros(&[n, dv]);
        // h trajectory: (N, dv, ns)
        let mut htraj = vec![0f32; n * dv * ns];
        let grain = self.channel_grain(dv, pool);
        let scratch_ws;
        {
            let ysh = SharedSlice::new(&mut y.data);
            let hsh = SharedSlice::new(&mut htraj);
            // A_s = (s+1)/ns: a spread of decay rates, as in S4/Mamba inits.
            scratch_ws = pool.parallel_for_stats(dv, grain, |chs, st| {
                let nch = chs.end - chs.start;
                let mut h = vec![0f32; nch * ns];
                let mut b = vec![0f32; ns];
                let mut c = vec![0f32; ns];
                let mut decay = vec![0f32; ns];
                st.workspace_bytes += (h.len() + b.len() + c.len() + decay.len()) * 4;
                for t in 0..n {
                    let dt = self.gates_into(w, t, &mut b, &mut c);
                    fill_decay(&mut decay, dt, ns);
                    let vr = w.v.row(t);
                    for (hi, ch) in chs.clone().enumerate() {
                        let x = vr[ch];
                        let hrow = &mut h[hi * ns..(hi + 1) * ns];
                        let acc = scan_channel_step(&decay, &b, &c, dt, x, hrow);
                        // Safety: element (t, ch) / trajectory row (t, ch)
                        // belong to this channel chunk only.
                        unsafe {
                            ysh.write(t * dv + ch, acc);
                            let dst = hsh.range_mut(
                                t * dv * ns + ch * ns..t * dv * ns + (ch + 1) * ns,
                            );
                            dst.copy_from_slice(hrow);
                        }
                    }
                }
            });
        }
        let mem = MemReport {
            workspace_bytes: htraj.len() * 4 + scratch_ws,
            output_bytes: y.bytes(),
        };
        (y, htraj, mem)
    }
}

/// Recurrent decode state — decoding is the SSM's natural form: the live
/// hidden state `(dv, n_state)` advances one step per token, O(dv·n_state)
/// time and O(1)-in-N memory. The per-(token, channel) arithmetic is the
/// same sequence of operations as the batch forward, so decode outputs are
/// bit-identical to prefill. The hidden state lives on arena pages (one
/// `n_state`-wide row per channel): a fork shares the pages until either
/// side's next step, whose copy-on-write `update_row` privatizes them. On
/// a quantized arena the recurrence is carried *through* the codec — each
/// step decodes the row, advances it, and re-encodes — so a fork and its
/// original evolve from identical (quantized) state.
pub struct MambaDecode {
    ns: usize,
    d: usize,
    dv: usize,
    h: PagedKv, // (dv, ns): one row per value channel
    b: Vec<f32>,
    c: Vec<f32>,
    decay: Vec<f32>,
    scratch: Vec<f32>,
    t: usize,
}

impl DecodeState for MambaDecode {
    fn step(&mut self, q_t: &[f32], k_t: &[f32], v_t: &[f32], out: &mut [f32]) {
        let (ns, d, dv) = (self.ns, self.d, self.dv);
        debug_assert_eq!(v_t.len(), dv);
        debug_assert_eq!(out.len(), dv);
        if self.h.is_empty() {
            // Re-prefilling after release(): the hidden-state rows
            // re-materialize lazily, so a released state holds zero pages
            // until it is actually stepped again (the release contract).
            let zero = vec![0f32; ns];
            for _ in 0..dv {
                self.h.push_row(&zero);
            }
        }
        // Same stand-in gate projections as `MambaLite::gates_into`.
        let dt = softplus(q_t[0]);
        for s in 0..ns {
            self.b[s] = k_t[s % d] * 0.5;
            self.c[s] = q_t[s % d] * 0.5;
        }
        fill_decay(&mut self.decay, dt, ns);
        let (decay, b, c) = (&self.decay, &self.b, &self.c);
        let (h, scratch) = (&mut self.h, &mut self.scratch);
        for (ch, (&x, o)) in v_t.iter().zip(out.iter_mut()).enumerate() {
            *o = h.update_row(ch, scratch, |hrow| scan_channel_step(decay, b, c, dt, x, hrow));
        }
        self.t += 1;
    }

    fn pos(&self) -> usize {
        self.t
    }

    fn step_cost_hint(&self) -> usize {
        // One recurrent step: O(dv·n_state), constant in context length.
        self.dv * self.ns * 6 + self.ns * 4
    }

    fn state_bytes(&self) -> usize {
        self.h.bytes() + (self.b.len() + self.c.len() + self.decay.len()) * 4
    }

    fn fork(&self) -> Box<dyn DecodeState> {
        Box::new(MambaDecode {
            ns: self.ns,
            d: self.d,
            dv: self.dv,
            h: self.h.fork(),
            b: self.b.clone(),
            c: self.c.clone(),
            decay: self.decay.clone(),
            scratch: Vec::new(),
            t: self.t,
        })
    }

    fn release(&mut self) {
        self.h.release();
        self.t = 0;
    }
}

impl AttentionImpl for MambaLite {
    fn name(&self) -> &'static str {
        "mamba"
    }

    fn begin_decode_in(
        &self,
        d: usize,
        dv: usize,
        arena: &Arc<PageArena>,
    ) -> Box<dyn DecodeState> {
        let ns = self.n_state;
        let mut h = PagedKv::new(arena, ns);
        let zero = vec![0f32; ns];
        for _ in 0..dv {
            h.push_row(&zero);
        }
        Box::new(MambaDecode {
            ns,
            d,
            dv,
            h,
            b: vec![0f32; ns],
            c: vec![0f32; ns],
            decay: vec![0f32; ns],
            scratch: Vec::new(),
            t: 0,
        })
    }

    fn forward_with(&self, w: &Workload, pool: &Pool) -> (Tensor, MemReport) {
        // Forward-only does not need the trajectory: O(dv*ns) live state.
        let n = w.n();
        let dv = w.v.shape[1];
        let ns = self.n_state;
        let mut y = Tensor::zeros(&[n, dv]);
        let grain = self.channel_grain(dv, pool);
        let scratch_ws;
        {
            let ysh = SharedSlice::new(&mut y.data);
            scratch_ws = pool.parallel_for_stats(dv, grain, |chs, st| {
                let nch = chs.end - chs.start;
                let mut h = vec![0f32; nch * ns];
                let mut b = vec![0f32; ns];
                let mut c = vec![0f32; ns];
                let mut decay = vec![0f32; ns];
                st.workspace_bytes += (h.len() + b.len() + c.len() + decay.len()) * 4;
                for t in 0..n {
                    let dt = self.gates_into(w, t, &mut b, &mut c);
                    fill_decay(&mut decay, dt, ns);
                    let vr = w.v.row(t);
                    for (hi, ch) in chs.clone().enumerate() {
                        let x = vr[ch];
                        let hrow = &mut h[hi * ns..(hi + 1) * ns];
                        let acc = scan_channel_step(&decay, &b, &c, dt, x, hrow);
                        // Safety: element (t, ch) owned by this chunk.
                        unsafe { ysh.write(t * dv + ch, acc) };
                    }
                }
            });
        }
        let mem = MemReport { workspace_bytes: scratch_ws, output_bytes: y.bytes() };
        (y, mem)
    }

    fn forward_backward_with(&self, w: &Workload, pool: &Pool) -> (Grads, MemReport) {
        let n = w.n();
        let dv = w.v.shape[1];
        let d = w.q.shape[1];
        let ns = self.n_state;
        let (_, htraj, mut mem) = self.fwd_traj(w, pool);

        // Only d/dv is propagated exactly (the gates derive from q/k through
        // fixed stand-in projections; their gradients flow in the real model
        // at L2). dv_t = sum over s of adjoint paths.
        let mut dvt = Tensor::zeros(&[n, dv]);
        let dq = Tensor::zeros(&[n, d]);
        let dk = Tensor::zeros(&[n, d]);

        // Channel-parallel reverse sweep: each worker owns the adjoint
        // slice for its channels and writes disjoint (t, ch) grads.
        let grain = self.channel_grain(dv, pool);
        let scratch_ws;
        {
            let dvsh = SharedSlice::new(&mut dvt.data);
            scratch_ws = pool.parallel_for_stats(dv, grain, |chs, st| {
                let nch = chs.end - chs.start;
                let mut dh = vec![0f32; nch * ns];
                let mut b = vec![0f32; ns];
                let mut c = vec![0f32; ns];
                let mut decay = vec![0f32; ns];
                st.workspace_bytes += (dh.len() + b.len() + c.len() + decay.len()) * 4;
                for t in (0..n).rev() {
                    let dt = self.gates_into(w, t, &mut b, &mut c);
                    fill_decay(&mut decay, dt, ns);
                    let g = w.dout.row(t);
                    for (hi, ch) in chs.clone().enumerate() {
                        let dhrow = &mut dh[hi * ns..(hi + 1) * ns];
                        let mut dx = 0.0;
                        for s in 0..ns {
                            // y_t contributes c_s to dh_t
                            dhrow[s] += c[s] * g[ch];
                            // x enters h via dt*b_s
                            dx += dhrow[s] * dt * b[s];
                            // pass adjoint to h_{t-1}
                            dhrow[s] *= decay[s];
                        }
                        // Safety: element (t, ch) owned by this chunk.
                        unsafe { dvsh.write(t * dv + ch, dx) };
                    }
                }
            });
        }
        let _ = htraj; // trajectory retained to model real memory behaviour
        mem.workspace_bytes += scratch_ws;
        mem.output_bytes = dq.bytes() + dk.bytes() + dvt.bytes();
        (Grads { dq, dk, dv: dvt }, mem)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_is_causal() {
        let mut w = Workload::random(32, 8, 4, 0);
        let (y1, _) = MambaLite::default().forward(&w);
        // poison the tail; prefix outputs unchanged
        for i in 16..32 {
            for c in 0..4 {
                w.v.row_mut(i)[c] = 1e5;
            }
        }
        let (y2, _) = MambaLite::default().forward(&w);
        for i in 0..16 {
            for c in 0..4 {
                assert!((y1.row(i)[c] - y2.row(i)[c]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn state_decays() {
        // An impulse at t=0 should fade: |y_t| decreasing for a lone input.
        let n = 64;
        let mut w = Workload::random(n, 8, 1, 1);
        for i in 0..n {
            w.v.row_mut(i)[0] = if i == 0 { 1.0 } else { 0.0 };
            // constant gates
            for c in 0..8 {
                w.q.row_mut(i)[c] = 0.5;
                w.k.row_mut(i)[c] = 0.5;
            }
        }
        let (y, _) = MambaLite::default().forward(&w);
        let early = y.row(1)[0].abs();
        let late = y.row(40)[0].abs();
        assert!(late < early, "late {late} !< early {early}");
    }

    #[test]
    fn dv_grad_matches_fd() {
        let n = 10;
        let dv = 2;
        let m = MambaLite { n_state: 4 };
        let w = Workload::random(n, 4, dv, 2);
        let (g, _) = m.forward_backward(&w);
        let loss = |vdata: &[f32]| {
            let w2 = Workload {
                q: w.q.clone(),
                k: w.k.clone(),
                v: Tensor::from_vec(&[n, dv], vdata.to_vec()),
                dout: w.dout.clone(),
            };
            let (y, _) = m.forward(&w2);
            y.data.iter().zip(&w2.dout.data).map(|(a, b)| a * b).sum::<f32>()
        };
        let mut v0 = w.v.data.clone();
        super::super::numeric_grad_check(loss, &mut v0, &g.dv.data, 1e-3);
    }

    #[test]
    fn forward_memory_is_constant_in_n() {
        let m = MambaLite::default();
        let (_, m1) = m.forward(&Workload::random(256, 8, 8, 3));
        let (_, m2) = m.forward(&Workload::random(2048, 8, 8, 3));
        assert_eq!(m1.workspace_bytes, m2.workspace_bytes);
    }

    #[test]
    fn parallel_matches_serial() {
        let m = MambaLite::default();
        let w = Workload::random(128, 8, 8, 4);
        let (ys, _) = m.forward_with(&w, &Pool::serial());
        let (yp, _) = m.forward_with(&w, &Pool::new(4));
        // channel scans are independent: identical arithmetic per channel
        assert_eq!(ys.data, yp.data);
        let (gs, _) = m.forward_backward_with(&w, &Pool::serial());
        let (gp, _) = m.forward_backward_with(&w, &Pool::new(4));
        assert_eq!(gs.dv.data, gp.dv.data);
    }
}
