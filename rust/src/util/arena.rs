//! Paged decode-state memory — the L0 storage substrate of the serving
//! stack.
//!
//! Every per-request decode state (KV rows, Morton codes, SSM state) used
//! to own flat `Vec<f32>` buffers: memory was invisible to the scheduler,
//! identical prompt prefixes were materialized once per session, and a
//! preempted session had nothing to give back. [`PageArena`] replaces that
//! with fixed-size **pages** of `page_tokens` rows each:
//!
//! * **Refcounted sharing** — a page handle is an `Arc` ([`PageRef`]).
//!   Forking a decode state shares every *full* page by bumping refcounts
//!   and deep-copies only the partial tail page (copy-on-write at page
//!   granularity), so a prompt-prefix fork costs O(pages) pointer clones
//!   plus one page copy instead of re-materializing the whole prefix.
//! * **Free list** — released pages return to a per-size free list and are
//!   recycled by later allocations, so steady-state serving stops hitting
//!   the system allocator on the per-token path.
//! * **Byte accounting** — the arena tracks live bytes (each page counted
//!   once no matter how many forks share it), the high-water mark, and
//!   alloc/recycle counters; the coordinator's `--kv-mem-budget` admission
//!   gate and the serving telemetry read these.
//!
//! * **Element codecs** — pages store raw f32 words, but a [`KvQuant`]
//!   codec decides how row elements are packed into them: bit-exact `f32`,
//!   two IEEE halfs per word (`f16`), or a per-row scale plus four
//!   symmetric int8 lanes per word (`int8`). Quantized rows are scored in
//!   place by the [`RowStore`] lane ops, and the byte accounting above is
//!   codec-accurate (a page of f16 rows is half the bytes of its f32
//!   twin), which is what lets `--kv-quant` stretch a fixed
//!   `--kv-mem-budget` 2–4× in admitted sessions.
//!
//! [`PagedKv`] is the row store built on top: append-only rows of a fixed
//! width with O(1) row addressing (`page = i / page_rows`), plus
//! [`PagedKv::fork`] / [`PagedKv::row_mut`] (copy-on-write) and a `Drop`
//! that returns every page to its arena, so cancelled or preempted
//! sessions can never leak accounting. [`PagedU32`] stores `u32` Morton
//! codes in the same f32 pages via lossless bit-casts, so one arena (and
//! one free list) serves every cache.

use crate::util::simd;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Default page size in tokens (rows) — the `--kv-page` default.
pub const DEFAULT_PAGE_TOKENS: usize = 64;

/// Element codec for [`PagedKv`] pages. Pages are always f32 words in the
/// arena (one free list serves every codec); the codec decides how row
/// elements pack into those words:
///
/// * [`KvQuant::F32`] — one element per word, bit-exact (the default).
/// * [`KvQuant::F16`] — two IEEE-754 half elements per word (low half
///   first; round-to-nearest-even, finite overflow saturates to ±65504).
/// * [`KvQuant::Int8`] — one per-row f32 scale word, then four symmetric
///   int8 elements per word (little-endian lanes; `scale = max|x| / 127`).
///
/// Encoding is deterministic — the same row always produces the same
/// words — so forked and budget-replayed sessions reproduce their streams
/// exactly even on lossy codecs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvQuant {
    F32,
    F16,
    Int8,
}

impl KvQuant {
    /// Accepted `--kv-quant` spellings, for startup error messages.
    pub const ACCEPTED: &'static str = "f32 | f16 | int8";

    /// Parse a codec name as accepted by `--kv-quant`.
    pub fn parse(s: &str) -> Option<KvQuant> {
        match s {
            "f32" => Some(KvQuant::F32),
            "f16" => Some(KvQuant::F16),
            "int8" => Some(KvQuant::Int8),
            _ => None,
        }
    }

    /// Canonical codec name (the `--kv-quant` spelling).
    pub fn name(self) -> &'static str {
        match self {
            KvQuant::F32 => "f32",
            KvQuant::F16 => "f16",
            KvQuant::Int8 => "int8",
        }
    }

    /// Encoded words (f32 storage elements) per `width`-element row.
    pub fn enc_row_elems(self, width: usize) -> usize {
        match self {
            KvQuant::F32 => width,
            KvQuant::F16 => width.div_ceil(2),
            KvQuant::Int8 => 1 + width.div_ceil(4),
        }
    }

    /// Encode one row into `enc` (exactly `enc_row_elems(row.len())`
    /// words).
    pub fn encode_row(self, row: &[f32], enc: &mut [f32]) {
        debug_assert_eq!(enc.len(), self.enc_row_elems(row.len()));
        match self {
            KvQuant::F32 => enc.copy_from_slice(row),
            KvQuant::F16 => {
                for (wi, pair) in row.chunks(2).enumerate() {
                    let lo = simd::f16_bits(pair[0]) as u32;
                    let hi = if pair.len() > 1 { simd::f16_bits(pair[1]) as u32 } else { 0 };
                    enc[wi] = f32::from_bits(lo | (hi << 16));
                }
            }
            KvQuant::Int8 => {
                let maxabs = row.iter().fold(0f32, |m, &x| m.max(x.abs()));
                let scale = maxabs / 127.0;
                let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
                enc[0] = scale;
                for (wi, quad) in row.chunks(4).enumerate() {
                    let mut w = 0u32;
                    for (bi, &x) in quad.iter().enumerate() {
                        let q = (x * inv).round().clamp(-127.0, 127.0) as i8;
                        w |= ((q as u8) as u32) << (8 * bi);
                    }
                    enc[1 + wi] = f32::from_bits(w);
                }
            }
        }
    }

    /// Decode one encoded row into `row` — the inverse of
    /// [`KvQuant::encode_row`] up to the codec's quantization error (exact
    /// for `F32`).
    pub fn decode_row(self, enc: &[f32], row: &mut [f32]) {
        debug_assert_eq!(enc.len(), self.enc_row_elems(row.len()));
        match self {
            KvQuant::F32 => row.copy_from_slice(enc),
            KvQuant::F16 => {
                for (i, x) in row.iter_mut().enumerate() {
                    let w = enc[i / 2].to_bits();
                    let h = if i % 2 == 0 { w as u16 } else { (w >> 16) as u16 };
                    *x = simd::f16_to_f32(h);
                }
            }
            KvQuant::Int8 => {
                let scale = enc[0];
                for (i, x) in row.iter_mut().enumerate() {
                    let q = (enc[1 + i / 4].to_bits() >> (8 * (i % 4))) as u8 as i8;
                    *x = q as f32 * scale;
                }
            }
        }
    }
}

/// One fixed-size arena page. Immutable while shared: appends only ever
/// write the unshared tail page, and [`PagedKv::row_mut`] copies a shared
/// page before writing (the copy-on-write contract that keeps forks
/// bit-exact).
pub struct Page {
    data: Box<[f32]>,
}

impl Page {
    /// The page's raw element storage.
    pub fn data(&self) -> &[f32] {
        &self.data
    }
}

/// Refcounted page handle; clones share the page.
pub type PageRef = Arc<Page>;

/// Arena counters. `live_bytes` counts each live page exactly once — pages
/// shared by several forks contribute once — which is what makes the
/// serving byte budget truthful under prefix sharing.
#[derive(Debug, Default, Clone, Copy)]
pub struct ArenaStats {
    /// Bytes in pages currently handed out (each page counted once).
    pub live_bytes: usize,
    /// Maximum `live_bytes` ever observed.
    pub high_water_bytes: usize,
    /// Bytes parked on the free lists, ready for reuse.
    pub free_bytes: usize,
    /// Pages currently handed out.
    pub live_pages: usize,
    /// Pages allocated from the system allocator.
    pub page_allocs: u64,
    /// Allocations served by recycling a freed page.
    pub page_reuses: u64,
}

struct ArenaInner {
    /// Free lists keyed by page element count (row widths differ between
    /// caches, so pages come in a handful of size classes).
    free: HashMap<usize, Vec<Box<[f32]>>>,
    stats: ArenaStats,
}

/// Shared arena of fixed-size KV pages. Internally locked, so one arena
/// can serve decode states stepping on pool worker threads; the lock is
/// only taken when a page is allocated or released (once per
/// `page_tokens` appends per stream), never on the per-row read path.
pub struct PageArena {
    page_tokens: usize,
    quant: KvQuant,
    inner: Mutex<ArenaInner>,
}

impl PageArena {
    /// New arena with `page_tokens` rows per page (clamped to >= 1) and
    /// the bit-exact [`KvQuant::F32`] codec.
    pub fn new(page_tokens: usize) -> Arc<PageArena> {
        PageArena::new_quant(page_tokens, KvQuant::F32)
    }

    /// New arena whose [`PagedKv`] stores default to `quant` — what
    /// `--kv-quant` selects server-wide.
    pub fn new_quant(page_tokens: usize, quant: KvQuant) -> Arc<PageArena> {
        Arc::new(PageArena {
            page_tokens: page_tokens.max(1),
            quant,
            inner: Mutex::new(ArenaInner { free: HashMap::new(), stats: ArenaStats::default() }),
        })
    }

    /// The process-wide default arena ([`DEFAULT_PAGE_TOKENS`] rows per
    /// page) — what `AttentionImpl::begin_decode` uses when no explicit
    /// arena is supplied. Servers carry their own arena so `--kv-page` and
    /// budget accounting stay per-server.
    pub fn global() -> &'static Arc<PageArena> {
        static GLOBAL: OnceLock<Arc<PageArena>> = OnceLock::new();
        GLOBAL.get_or_init(|| PageArena::new(DEFAULT_PAGE_TOKENS))
    }

    /// Rows per page.
    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// Element codec newly created [`PagedKv`] stores inherit.
    pub fn quant(&self) -> KvQuant {
        self.quant
    }

    /// Allocate a page of `elems` f32 elements (recycling a freed page of
    /// the same size class when one is parked). Recycled pages are *not*
    /// re-zeroed — every consumer ([`PagedKv`]) writes a row before it can
    /// be read (`row`/`row_mut` are bounded by the row count, and fork's
    /// whole-page tail copy only fills slots that are equally unreadable),
    /// so zeroing would be a second full-page write serialized under the
    /// arena lock for nothing.
    pub fn alloc(&self, elems: usize) -> PageRef {
        let mut inner = self.inner.lock().unwrap();
        let bytes = elems * 4;
        let data = match inner.free.get_mut(&elems).and_then(|v| v.pop()) {
            Some(d) => {
                inner.stats.free_bytes -= bytes;
                inner.stats.page_reuses += 1;
                d
            }
            None => {
                inner.stats.page_allocs += 1;
                vec![0f32; elems].into_boxed_slice()
            }
        };
        inner.stats.live_pages += 1;
        inner.stats.live_bytes += bytes;
        inner.stats.high_water_bytes = inner.stats.high_water_bytes.max(inner.stats.live_bytes);
        Arc::new(Page { data })
    }

    /// Drop one handle's reference to a page. The page returns to the free
    /// list (and leaves the live count) only when this was the last
    /// reference. All releases run under the arena lock, so the
    /// last-reference check cannot race between two forks releasing the
    /// same page.
    pub fn release(&self, page: PageRef) {
        let mut inner = self.inner.lock().unwrap();
        if let Ok(p) = Arc::try_unwrap(page) {
            let bytes = p.data.len() * 4;
            inner.stats.live_pages -= 1;
            inner.stats.live_bytes -= bytes;
            inner.stats.free_bytes += bytes;
            inner.free.entry(p.data.len()).or_default().push(p.data);
        }
    }

    /// Snapshot of the arena counters.
    pub fn stats(&self) -> ArenaStats {
        self.inner.lock().unwrap().stats
    }
}

/// Row-addressable storage scored through codec-aware lane ops:
/// implemented by flat f32 slices (the batch kernels' buffers) and by
/// [`PagedKv`] (decode states, possibly quantized), so one scoring routine
/// serves both schedules — and every codec — without materializing
/// dequantized rows. On `F32` storage each op lowers to exactly the
/// `util::simd` call the pre-codec kernels made, keeping that path
/// bit-identical.
pub trait RowStore {
    /// Squared Euclidean distance between `q` and row `i`.
    fn sqdist_row(&self, i: usize, q: &[f32]) -> f32;
    /// Dot product of `q` and row `i`.
    fn dot_row(&self, i: usize, q: &[f32]) -> f32;
    /// `out += a * row_i`.
    fn axpy_row(&self, i: usize, a: f32, out: &mut [f32]);
}

/// Flat `(len, width)` row-major storage over a borrowed slice.
pub struct FlatRows<'a> {
    pub data: &'a [f32],
    pub width: usize,
}

impl FlatRows<'_> {
    /// Row `i` as a raw f32 slice (flat storage is always unquantized).
    #[inline]
    pub fn row_at(&self, i: usize) -> &[f32] {
        &self.data[i * self.width..(i + 1) * self.width]
    }
}

impl RowStore for FlatRows<'_> {
    #[inline]
    fn sqdist_row(&self, i: usize, q: &[f32]) -> f32 {
        simd::sqdist(q, self.row_at(i))
    }

    #[inline]
    fn dot_row(&self, i: usize, q: &[f32]) -> f32 {
        simd::dot(q, self.row_at(i))
    }

    #[inline]
    fn axpy_row(&self, i: usize, a: f32, out: &mut [f32]) {
        simd::axpy(out, a, self.row_at(i));
    }
}

impl RowStore for PagedKv {
    #[inline]
    fn sqdist_row(&self, i: usize, q: &[f32]) -> f32 {
        match self.quant {
            KvQuant::F32 => simd::sqdist(q, self.raw_row(i)),
            KvQuant::F16 => simd::sqdist_dequant_f16(q, self.raw_row(i)),
            KvQuant::Int8 => {
                let raw = self.raw_row(i);
                simd::sqdist_dequant_i8(q, &raw[1..], raw[0])
            }
        }
    }

    #[inline]
    fn dot_row(&self, i: usize, q: &[f32]) -> f32 {
        match self.quant {
            KvQuant::F32 => simd::dot(q, self.raw_row(i)),
            KvQuant::F16 => simd::dot_dequant_f16(q, self.raw_row(i)),
            KvQuant::Int8 => {
                let raw = self.raw_row(i);
                simd::dot_dequant_i8(q, &raw[1..], raw[0])
            }
        }
    }

    #[inline]
    fn axpy_row(&self, i: usize, a: f32, out: &mut [f32]) {
        match self.quant {
            KvQuant::F32 => simd::axpy(out, a, self.raw_row(i)),
            KvQuant::F16 => simd::axpy_dequant_f16(out, a, self.raw_row(i)),
            KvQuant::Int8 => {
                let raw = self.raw_row(i);
                simd::axpy_dequant_i8(out, a, &raw[1..], raw[0]);
            }
        }
    }
}

/// Append-only store of fixed-width f32 rows on arena pages — the decode
/// states' KV storage. `page_tokens` rows per page, O(1) row addressing,
/// copy-on-write forks, and `Drop` returns every page to the arena.
pub struct PagedKv {
    arena: Arc<PageArena>,
    width: usize,
    enc_width: usize,
    quant: KvQuant,
    page_rows: usize,
    pages: Vec<PageRef>,
    rows: usize,
}

impl PagedKv {
    /// Empty store of `width`-element rows on `arena`'s page size, using
    /// the arena's default codec.
    pub fn new(arena: &Arc<PageArena>, width: usize) -> PagedKv {
        PagedKv::with_quant(arena, width, arena.quant())
    }

    /// Empty store with an explicit element codec, overriding the arena's
    /// default ([`PagedU32`] forces `F32` so its bit-casts stay lossless).
    pub fn with_quant(arena: &Arc<PageArena>, width: usize, quant: KvQuant) -> PagedKv {
        let width = width.max(1);
        PagedKv {
            arena: arena.clone(),
            width,
            enc_width: quant.enc_row_elems(width),
            quant,
            page_rows: arena.page_tokens(),
            pages: Vec::new(),
            rows: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// This store's element codec.
    pub fn quant(&self) -> KvQuant {
        self.quant
    }

    fn page_elems(&self) -> usize {
        self.page_rows * self.enc_width
    }

    /// Append one row. Allocates a fresh page when the tail is full; the
    /// tail page is always uniquely owned (forks deep-copy it), so the
    /// write never touches shared storage.
    pub fn push_row(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.width);
        let slot = self.rows % self.page_rows;
        if slot == 0 {
            let elems = self.page_elems();
            self.pages.push(self.arena.alloc(elems));
        }
        let page = self.pages.last_mut().expect("tail page pushed above");
        let data = &mut Arc::get_mut(page)
            .expect("tail page is uniquely owned (forks deep-copy the tail)")
            .data;
        let quant = self.quant;
        quant.encode_row(row, &mut data[slot * self.enc_width..(slot + 1) * self.enc_width]);
        self.rows += 1;
    }

    /// Row `i` as raw f32 elements — only meaningful on the bit-exact
    /// `F32` codec; quantized stores read through the [`RowStore`] lane
    /// ops or [`PagedKv::decode_row_into`].
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.quant, KvQuant::F32, "row() reads raw f32 elements");
        self.raw_row(i)
    }

    /// The encoded words of row `i` (codec-dependent layout; equals the
    /// row itself on `F32`).
    #[inline]
    pub fn raw_row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        let p = i / self.page_rows;
        let slot = i % self.page_rows;
        &self.pages[p].data[slot * self.enc_width..(slot + 1) * self.enc_width]
    }

    /// Decode row `i` into `out` (`width` elements; exact on `F32`).
    pub fn decode_row_into(&self, i: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.width);
        self.quant.decode_row(self.raw_row(i), out);
    }

    /// Mutable access to row `i`, copy-on-write: a page still shared with
    /// a fork is replaced by a private copy before the first write, so the
    /// fork keeps reading the original values. `F32` only — quantized
    /// stores mutate through [`PagedKv::update_row`].
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert_eq!(self.quant, KvQuant::F32, "row_mut() writes raw f32 elements");
        self.enc_row_mut(i)
    }

    /// CoW access to the encoded words of row `i` (see [`PagedKv::row_mut`]
    /// for the sharing contract).
    fn enc_row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        let p = i / self.page_rows;
        let slot = i % self.page_rows;
        if Arc::strong_count(&self.pages[p]) > 1 {
            let mut fresh = self.arena.alloc(self.page_elems());
            Arc::get_mut(&mut fresh)
                .expect("fresh page is unshared")
                .data
                .copy_from_slice(&self.pages[p].data);
            let old = std::mem::replace(&mut self.pages[p], fresh);
            self.arena.release(old);
        }
        let page = Arc::get_mut(&mut self.pages[p]).expect("page is private after CoW");
        &mut page.data[slot * self.enc_width..(slot + 1) * self.enc_width]
    }

    /// Read-modify-write row `i` through the codec, copy-on-write like
    /// [`PagedKv::row_mut`]. `F32` edits in place; quantized codecs decode
    /// into `scratch`, apply `f`, and re-encode — so the closure always
    /// sees the row exactly as the next reader will (quantization error
    /// included), which keeps recurrences carried this way deterministic
    /// across forks and replays.
    pub fn update_row<R>(
        &mut self,
        i: usize,
        scratch: &mut Vec<f32>,
        f: impl FnOnce(&mut [f32]) -> R,
    ) -> R {
        if self.quant == KvQuant::F32 {
            return f(self.enc_row_mut(i));
        }
        scratch.resize(self.width, 0.0);
        self.quant.decode_row(self.raw_row(i), scratch);
        let r = f(&mut scratch[..]);
        let quant = self.quant;
        quant.encode_row(&scratch[..], self.enc_row_mut(i));
        r
    }

    /// Copy-on-write fork: full pages are shared (refcount bumps — the
    /// arena's live bytes do not grow), only the partial tail page is
    /// deep-copied. The fork and the original then append and mutate
    /// independently while reading identical history.
    pub fn fork(&self) -> PagedKv {
        let full = self.rows / self.page_rows;
        let mut pages: Vec<PageRef> = self.pages[..full.min(self.pages.len())].to_vec();
        if self.pages.len() > full {
            let mut fresh = self.arena.alloc(self.page_elems());
            Arc::get_mut(&mut fresh)
                .expect("fresh page is unshared")
                .data
                .copy_from_slice(&self.pages[full].data);
            pages.push(fresh);
        }
        PagedKv {
            arena: self.arena.clone(),
            width: self.width,
            enc_width: self.enc_width,
            quant: self.quant,
            page_rows: self.page_rows,
            pages,
            rows: self.rows,
        }
    }

    /// Bytes of arena pages this handle references. Pages shared with
    /// forks are counted fully in *each* handle; the arena's own
    /// [`ArenaStats::live_bytes`] counts every live page exactly once.
    pub fn bytes(&self) -> usize {
        self.pages.len() * self.page_elems() * 4
    }

    /// Return every page to the arena and reset to empty.
    pub fn release(&mut self) {
        for p in self.pages.drain(..) {
            self.arena.release(p);
        }
        self.rows = 0;
    }
}

impl Drop for PagedKv {
    fn drop(&mut self) {
        self.release();
    }
}

/// Append-only store of `u32` values (Morton codes) bit-cast into f32
/// pages — lossless (`to_bits`/`from_bits` round-trip all 32 bits), and it
/// keeps every decode-state allocation in one arena.
pub struct PagedU32 {
    kv: PagedKv,
}

impl PagedU32 {
    pub fn new(arena: &Arc<PageArena>) -> PagedU32 {
        // Always F32: the bit-cast round trip must stay lossless even on a
        // quantized arena.
        PagedU32 { kv: PagedKv::with_quant(arena, 1, KvQuant::F32) }
    }

    pub fn push(&mut self, value: u32) {
        self.kv.push_row(&[f32::from_bits(value)]);
    }

    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        self.kv.row(i)[0].to_bits()
    }

    pub fn len(&self) -> usize {
        self.kv.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kv.is_empty()
    }

    pub fn fork(&self) -> PagedU32 {
        PagedU32 { kv: self.kv.fork() }
    }

    pub fn bytes(&self) -> usize {
        self.kv.bytes()
    }

    pub fn release(&mut self) {
        self.kv.release();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_round_trip_across_pages() {
        let arena = PageArena::new(4);
        let mut kv = PagedKv::new(&arena, 3);
        for i in 0..11 {
            let row = [i as f32, i as f32 + 0.5, -(i as f32)];
            kv.push_row(&row);
        }
        assert_eq!(kv.len(), 11);
        for i in 0..11 {
            assert_eq!(kv.row(i), &[i as f32, i as f32 + 0.5, -(i as f32)]);
        }
        // 11 rows at 4 rows/page = 3 pages
        assert_eq!(kv.bytes(), 3 * 4 * 3 * 4);
        assert_eq!(arena.stats().live_pages, 3);
    }

    #[test]
    fn fork_shares_full_pages_and_copies_tail() {
        let arena = PageArena::new(4);
        let mut a = PagedKv::new(&arena, 2);
        for i in 0..10 {
            a.push_row(&[i as f32, 2.0 * i as f32]);
        }
        // 10 rows = 2 full pages + 1 partial tail
        let live_before = arena.stats().live_bytes;
        let b = a.fork();
        // sharing: only the tail page was duplicated
        let page_bytes = 4 * 2 * 4;
        assert_eq!(arena.stats().live_bytes, live_before + page_bytes);
        for i in 0..10 {
            assert_eq!(a.row(i), b.row(i));
        }
        assert!(Arc::ptr_eq(&a.pages[0], &b.pages[0]));
        assert!(Arc::ptr_eq(&a.pages[1], &b.pages[1]));
        assert!(!Arc::ptr_eq(&a.pages[2], &b.pages[2]));
    }

    #[test]
    fn post_fork_appends_diverge_without_cross_talk() {
        let arena = PageArena::new(2);
        let mut a = PagedKv::new(&arena, 1);
        for i in 0..5 {
            a.push_row(&[i as f32]);
        }
        let mut b = a.fork();
        a.push_row(&[100.0]);
        b.push_row(&[200.0]);
        b.push_row(&[201.0]);
        assert_eq!(a.len(), 6);
        assert_eq!(b.len(), 7);
        assert_eq!(a.row(5), &[100.0]);
        assert_eq!(b.row(5), &[200.0]);
        assert_eq!(b.row(6), &[201.0]);
        // shared history unchanged on both sides
        for i in 0..5 {
            assert_eq!(a.row(i), &[i as f32]);
            assert_eq!(b.row(i), &[i as f32]);
        }
    }

    #[test]
    fn row_mut_copies_shared_pages_before_writing() {
        let arena = PageArena::new(4);
        let mut a = PagedKv::new(&arena, 1);
        for i in 0..8 {
            a.push_row(&[i as f32]);
        }
        let mut b = a.fork();
        // page 0 is shared; writing through b must not disturb a
        b.row_mut(1)[0] = 99.0;
        assert_eq!(a.row(1), &[1.0]);
        assert_eq!(b.row(1), &[99.0]);
        // a second write to the now-private page does not copy again
        let live = arena.stats().live_bytes;
        b.row_mut(2)[0] = 98.0;
        assert_eq!(arena.stats().live_bytes, live);
        assert_eq!(a.row(2), &[2.0]);
    }

    #[test]
    fn release_returns_pages_and_free_list_recycles() {
        let arena = PageArena::new(8);
        let mut kv = PagedKv::new(&arena, 2);
        for i in 0..20 {
            kv.push_row(&[i as f32, 0.0]);
        }
        let hw = arena.stats().high_water_bytes;
        assert!(hw > 0);
        kv.release();
        let st = arena.stats();
        assert_eq!(st.live_bytes, 0);
        assert_eq!(st.live_pages, 0);
        assert_eq!(st.free_bytes, hw);
        assert_eq!(st.high_water_bytes, hw);
        // a fresh store of the same width recycles the freed pages
        let mut kv2 = PagedKv::new(&arena, 2);
        for i in 0..20 {
            kv2.push_row(&[i as f32, 1.0]);
        }
        let st = arena.stats();
        assert!(st.page_reuses >= 3, "reuses {}", st.page_reuses);
        assert_eq!(st.live_bytes, hw);
        assert_eq!(st.high_water_bytes, hw);
    }

    #[test]
    fn shared_pages_count_once_and_release_on_last_ref() {
        let arena = PageArena::new(4);
        let mut a = PagedKv::new(&arena, 1);
        for i in 0..4 {
            a.push_row(&[i as f32]); // exactly one full page
        }
        let b = a.fork(); // page shared, no tail to copy
        let page_bytes = 4 * 4;
        assert_eq!(arena.stats().live_bytes, page_bytes);
        drop(a);
        // b still holds the page: live, not freed
        assert_eq!(arena.stats().live_bytes, page_bytes);
        assert_eq!(b.row(3), &[3.0]);
        drop(b);
        assert_eq!(arena.stats().live_bytes, 0);
        assert_eq!(arena.stats().free_bytes, page_bytes);
    }

    #[test]
    fn paged_u32_round_trips_all_bit_patterns() {
        let arena = PageArena::new(3);
        let mut c = PagedU32::new(&arena);
        let vals = [0u32, 1, 0x7FFF_FFFF, 0xFFFF_FFFF, 0x8000_0000, 12345, u32::MAX - 1];
        for &v in &vals {
            c.push(v);
        }
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(c.get(i), v);
        }
        let f = c.fork();
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(f.get(i), v);
        }
    }

    #[test]
    fn global_arena_uses_default_page_size() {
        assert_eq!(PageArena::global().page_tokens(), DEFAULT_PAGE_TOKENS);
        assert_eq!(PageArena::global().quant(), KvQuant::F32);
    }

    #[test]
    fn quant_parse_and_row_elems() {
        assert_eq!(KvQuant::parse("f32"), Some(KvQuant::F32));
        assert_eq!(KvQuant::parse("f16"), Some(KvQuant::F16));
        assert_eq!(KvQuant::parse("int8"), Some(KvQuant::Int8));
        assert_eq!(KvQuant::parse("fp8"), None);
        assert_eq!(KvQuant::parse(""), None);
        assert_eq!(KvQuant::F16.name(), "f16");
        assert_eq!(KvQuant::F32.enc_row_elems(16), 16);
        assert_eq!(KvQuant::F16.enc_row_elems(16), 8);
        assert_eq!(KvQuant::F16.enc_row_elems(5), 3);
        assert_eq!(KvQuant::Int8.enc_row_elems(16), 5);
        assert_eq!(KvQuant::Int8.enc_row_elems(5), 3);
        assert_eq!(KvQuant::Int8.enc_row_elems(1), 2);
    }

    #[test]
    fn quantized_rows_round_trip_within_tolerance() {
        for (quant, tol) in [(KvQuant::F16, 1e-3f32), (KvQuant::Int8, 1.6e-2f32)] {
            let arena = PageArena::new_quant(4, quant);
            assert_eq!(arena.quant(), quant);
            let mut kv = PagedKv::new(&arena, 3);
            assert_eq!(kv.quant(), quant);
            let rows: Vec<[f32; 3]> = (0..11)
                .map(|i| [(i as f32) * 0.37 - 1.5, (i as f32).sin(), -(i as f32) * 0.11])
                .collect();
            for r in &rows {
                kv.push_row(r);
            }
            let mut out = [0f32; 3];
            for (i, r) in rows.iter().enumerate() {
                kv.decode_row_into(i, &mut out);
                for (a, b) in r.iter().zip(out.iter()) {
                    let err = (a - b).abs();
                    assert!(err <= tol * (1.0 + a.abs()), "{quant:?} row {i}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn quantized_bytes_shrink_and_account_exactly() {
        let arenas = [
            PageArena::new(4),
            PageArena::new_quant(4, KvQuant::F16),
            PageArena::new_quant(4, KvQuant::Int8),
        ];
        // 9 rows of width 16 at 4 rows/page = 3 pages; words/row: 16, 8, 5.
        let words = [16usize, 8, 5];
        for (arena, w) in arenas.iter().zip(words) {
            let mut kv = PagedKv::new(arena, 16);
            for i in 0..9 {
                kv.push_row(&[i as f32 * 0.1; 16]);
            }
            assert_eq!(kv.bytes(), 3 * 4 * w * 4);
            assert_eq!(arena.stats().live_bytes, kv.bytes());
            assert_eq!(arena.stats().high_water_bytes, kv.bytes());
        }
    }

    #[test]
    fn update_row_is_cow_isolated_on_quantized_forks() {
        let arena = PageArena::new_quant(4, KvQuant::F16);
        let mut a = PagedKv::new(&arena, 2);
        for i in 0..8 {
            a.push_row(&[i as f32, 0.5]);
        }
        let mut b = a.fork();
        let mut scratch = Vec::new();
        let mut before = [0f32; 2];
        a.decode_row_into(1, &mut before);
        b.update_row(1, &mut scratch, |row| row[0] = 99.0);
        let mut out = [0f32; 2];
        a.decode_row_into(1, &mut out);
        assert_eq!(out, before, "fork write must not disturb the original");
        b.decode_row_into(1, &mut out);
        // 99.0 and 0.5 are exactly representable in f16.
        assert_eq!(out, [99.0, 0.5]);
        // 2 shared pages + 1 CoW copy, each 4 rows × 1 word × 4 bytes.
        assert_eq!(arena.stats().live_bytes, 3 * 4 * 4);
    }

    #[test]
    fn paged_u32_is_lossless_on_quantized_arenas() {
        let arena = PageArena::new_quant(3, KvQuant::Int8);
        let mut c = PagedU32::new(&arena);
        let vals = [0u32, 0xFFFF_FFFF, 0x8000_0001, 7];
        for &v in &vals {
            c.push(v);
        }
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(c.get(i), v);
        }
    }
}
