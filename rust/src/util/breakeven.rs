//! Fan-out break-even thresholds — the one table of inline-vs-pool
//! decisions for every small-region call site, re-tuned for the resident
//! parked worker team ([`crate::util::pool`]).
//!
//! Rationale: with per-region scoped-thread spawns (the pre-resident pool)
//! entering a parallel region cost tens of µs per worker, so the fused
//! serving sweeps — the common case under many-user decode traffic — ran
//! inline unless a sweep carried ≥ 2^17 estimated scalar ops. A parked
//! team is woken with one shared region descriptor and a condvar
//! broadcast: the `exp pool` micro-benchmark (`BENCH_pool.json`) puts the
//! launch+join handshake at single-digit µs at 4–8 workers, roughly an
//! order of magnitude below the scoped-spawn baseline it also measures.
//! That lowered the thresholds ~16× (PR 4).
//!
//! The SIMD kernel layer ([`crate::util::simd`], `exp kernels` /
//! `BENCH_kernels.json`) then made each estimated "scalar op" ~4× cheaper
//! in wall-clock on the vector backends: a region that used to carry a
//! launch-worth of work now finishes inline before the team wakes. The
//! op-denominated thresholds move back *up* by that kernel speedup so the
//! break-even stays pinned to wall-clock, not op counts. The pad bound is
//! memcpy-bound (not vectorized by the kernel layer) and the search bound
//! counts index-window lookups (select/scan, not lane math), so both stay.
//!
//! The pipelined prefill path (PR 7) scores *every* chunk phase of a long
//! prompt in one region instead of one region per phase, so its bound is
//! denominated in window lookups across the whole run, not per phase: a
//! run has to carry at least a few phases' worth of lookups (4× the
//! per-phase bound) before snapshotting the index at every chunk boundary
//! and waking the team beats the inline chunk-sequential loop.
//!
//! | constant | spawns | resident (PR 4) | SIMD (now) | unit |
//! |---|---|---|---|---|
//! | [`PARALLEL_STEP_MIN_OPS`]     | 2^17 | 2^13 | 2^15 | est. scalar ops / sweep |
//! | [`PARALLEL_PREFILL_MIN_OPS`]  | 2^17 | 2^13 | 2^15 | est. scalar ops / wave |
//! | [`PARALLEL_READOUT_MIN_OPS`]  | 2^18 | 2^14 | 2^16 | scalar ops (slots·vocab·dv) |
//! | [`PARALLEL_PAD_MIN_ELEMS`]    | 2^20 | 2^16 | 2^16 | i32 token elements |
//! | [`PARALLEL_SEARCH_MIN_LOOKUPS`] | 256 | 64 | 64 | window lookups / phase |
//! | [`PARALLEL_PREFILL_SCORE_MIN_LOOKUPS`] | — | — | 256 | window lookups / prefill run |
//!
//! Every call site funnels through [`fan_out`], and the unit tests here pin
//! the decision boundary to the documented values — change a threshold and
//! the table, the sites and the tests move together.

/// Minimum estimated scalar ops across a fused cross-stream decode sweep
/// before [`crate::attention::AttentionImpl::step_batch`] fans out.
pub const PARALLEL_STEP_MIN_OPS: usize = 1 << 15;

/// Minimum estimated scalar ops across a batched prefill wave before
/// `NativeDecodeModel::prefill_batch` fans out.
pub const PARALLEL_PREFILL_MIN_OPS: usize = 1 << 15;

/// Minimum `slots · vocab · dv` scalar ops before the batched
/// readout/argmax phase of `NativeDecodeModel::step_batch` fans out.
pub const PARALLEL_READOUT_MIN_OPS: usize = 1 << 16;

/// Minimum total i32 token elements (`rows · seq_len`) before the
/// coordinator's batch padding fans out off the scheduler thread.
pub const PARALLEL_PAD_MIN_ELEMS: usize = 1 << 16;

/// Minimum `(head, query)` window lookups in one ZETA chunk-search phase
/// before the phase fans out (each lookup is a sorted-index window scan +
/// top-k select, far heavier than one scalar op — hence the smaller bound).
pub const PARALLEL_SEARCH_MIN_LOOKUPS: usize = 64;

/// Minimum `(chunk, head, query)` window lookups across a whole pipelined
/// prefill run before the sequence-parallel path snapshots the index at
/// every chunk boundary and fans all scoring out in one region (PR 7).
/// Small prompts stay on the inline chunk-sequential loop — 4× the
/// per-phase search bound, since the pipelined schedule also pays the
/// O(log N) `ZIndex::fork` per chunk boundary up front.
pub const PARALLEL_PREFILL_SCORE_MIN_LOOKUPS: usize = 256;

/// The single inline-vs-fan-out decision: a region is worth waking the
/// resident team when it has at least two independent slots, the pool has
/// more than one thread, and the estimated work clears the call site's
/// break-even from the table above. Below that, the serial inline loop is
/// faster *and* bit-identical to the fan-out schedule.
pub fn fan_out(slots: usize, est_ops: usize, threads: usize, min_ops: usize) -> bool {
    slots >= 2 && threads > 1 && est_ops >= min_ops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thresholds_match_documented_table() {
        assert_eq!(PARALLEL_STEP_MIN_OPS, 32768);
        assert_eq!(PARALLEL_PREFILL_MIN_OPS, 32768);
        assert_eq!(PARALLEL_READOUT_MIN_OPS, 65536);
        assert_eq!(PARALLEL_PAD_MIN_ELEMS, 65536);
        assert_eq!(PARALLEL_SEARCH_MIN_LOOKUPS, 64);
        assert_eq!(PARALLEL_PREFILL_SCORE_MIN_LOOKUPS, 256);
    }

    #[test]
    fn decision_boundary_is_exactly_the_threshold() {
        for min in [
            PARALLEL_STEP_MIN_OPS,
            PARALLEL_PREFILL_MIN_OPS,
            PARALLEL_READOUT_MIN_OPS,
            PARALLEL_PAD_MIN_ELEMS,
            PARALLEL_SEARCH_MIN_LOOKUPS,
            PARALLEL_PREFILL_SCORE_MIN_LOOKUPS,
        ] {
            assert!(!fan_out(2, min - 1, 4, min), "one op under the break-even must stay inline");
            assert!(fan_out(2, min, 4, min), "at the break-even the region must fan out");
        }
    }

    #[test]
    fn single_slot_or_serial_pool_never_fans_out() {
        let min = PARALLEL_STEP_MIN_OPS;
        assert!(!fan_out(1, min * 100, 8, min), "one slot has no parallelism to exploit");
        assert!(!fan_out(0, min * 100, 8, min));
        assert!(!fan_out(64, min * 100, 1, min), "threads=1 is the bit-identical serial path");
    }
}
