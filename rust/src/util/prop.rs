//! Tiny property-testing helper (the offline environment has no proptest).
//!
//! `check(cases, seed, f)` runs `f` against `cases` independently-seeded
//! RNGs and reports the failing case's seed so it can be replayed with
//! `replay(seed_reported, f)`.

use super::rng::Rng;

/// Run `f` for `cases` random cases. `f` may panic or return Err to fail.
pub fn check<F>(cases: u64, seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        match result {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => panic!(
                "property failed on case {case} (replay seed {case_seed:#x}): {msg}"
            ),
            Err(p) => {
                let msg = p
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "panic".into());
                panic!("property panicked on case {case} (replay seed {case_seed:#x}): {msg}");
            }
        }
    }
}

/// Replay a single failing case by its reported seed.
pub fn replay<F>(case_seed: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(case_seed);
    f(&mut rng).expect("replayed property failed");
}

/// Structural equality assertion that returns Err instead of panicking, so
/// properties compose.
pub fn assert_eq_prop<T: PartialEq + std::fmt::Debug>(a: &T, b: &T) -> Result<(), String> {
    if a == b {
        Ok(())
    } else {
        Err(format!("{a:?} != {b:?}"))
    }
}

/// Approximate float comparison for properties.
pub fn assert_close(a: f32, b: f32, atol: f32) -> Result<(), String> {
    if (a - b).abs() <= atol {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (atol {atol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(20, 1, |rng| {
            let x = rng.below(100);
            assert_eq_prop(&(x < 100), &true)
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        check(20, 2, |rng| {
            let x = rng.below(10);
            if x == 3 {
                return Err("hit 3".into());
            }
            Ok(())
        });
    }

    #[test]
    fn close_helper() {
        assert!(assert_close(1.0, 1.0 + 1e-7, 1e-6).is_ok());
        assert!(assert_close(1.0, 2.0, 0.5).is_err());
    }
}
