//! Shared worker pool: the parallel execution substrate for every kernel,
//! the zorder codec, the experiment harness and the serving coordinator.
//!
//! Design (std-only, no rayon offline):
//!
//! * A [`Pool`] is a *thread-count policy*, cheap to copy and share. Work
//!   executes on a process-wide **resident team** of worker threads that
//!   park on a condvar between parallel regions and are woken with a
//!   region descriptor (trampoline fn + context ptr).
//!   Entering a region therefore costs one park/wake handshake (single-digit
//!   µs) instead of a `std::thread::scope` spawn per region (tens of µs per
//!   worker) — the `exp pool` micro-benchmark measures both sides and writes
//!   `BENCH_pool.json`. That drop is what funds the lowered
//!   [`crate::util::breakeven`] fan-out thresholds.
//! * Closures may still borrow the caller's stack freely: the submitting
//!   thread publishes the region, runs a share of it itself, and blocks
//!   until every participating resident has retired the region — so every
//!   borrow outlives every use, the same guarantee `std::thread::scope`
//!   gave, enforced by the region join instead of the scope join.
//! * Worker ids are *logical*: participants (the submitter plus the
//!   residents) claim ids off an atomic counter, so a region may run
//!   several ids on one thread. Oversubscription (`threads ≫ cores`) just
//!   multiplexes ids over the capped team; results are still collected in
//!   worker-id order. Closures must not synchronize *across* worker ids.
//! * One region is live at a time (parallelism lives *within* a region),
//!   but nobody ever waits on another submitter: a thread that finds the
//!   team busy runs its whole region **inline**, and a thread already
//!   inside a region — a resident, or a submitter running its own share —
//!   executes nested submissions inline too. Re-entrant by construction,
//!   so nested and cross-thread-concurrent submission cannot deadlock
//!   (`rust/tests/pool_stress.rs`). Only as many residents as a region
//!   asks for participate in it, so a 2-slot sweep joins in two
//!   handshakes even on a 64-thread team.
//! * Worker panics are caught per worker id, the first payload is
//!   re-raised on the submitting thread after the join, and the residents
//!   park normally — the next region sees a clean, un-poisoned team.
//! * At `threads = 1` everything degrades to a plain inline loop,
//!   bit-identical to the old serial kernels; the team is never woken.
//! * Chunks are handed out by a lock-free [`ChunkQueue`] (one saturating
//!   compare-and-swap per chunk), so triangular workloads (causal attention
//!   row costs grow with i) load-balance without a scheduler thread.
//! * Per-thread accounting: workers accumulate into a stack-local
//!   [`WorkerStats`] and results are merged once after the region joins —
//!   `MemReport` stays *measured* with zero locks on the hot path.
//! * [`SharedSlice`] lets workers write disjoint rows of one output buffer
//!   (the idiom rayon's `par_chunks_mut` provides); callers assert
//!   disjointness at the single `unsafe` call site.
//!
//! The global pool reads `ZETA_THREADS` once (unset or `0` = auto-detect
//! from `available_parallelism`). The resident team spawns lazily on the
//! first fan-out, is capped at `2 × available_parallelism` threads (min 8,
//! max 64 — logical worker ids beyond the cap multiplex), and parks between
//! regions. Dropping a team signals shutdown and joins its residents; the
//! process-global team lives for the process and dies with it.

use std::any::Any;
use std::cell::{Cell, UnsafeCell};
use std::marker::PhantomData;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Stack-local per-worker statistics, merged after a parallel region joins.
#[derive(Debug, Default, Clone, Copy)]
pub struct WorkerStats {
    /// Bytes of scratch buffers this worker actually allocated.
    pub workspace_bytes: usize,
}

/// Thread-count policy handle. `Copy` so kernels, the experiment harness and
/// the coordinator can share one without reference-counting. All pools fan
/// out onto the one process-wide resident team; the policy only bounds how
/// many logical workers a region uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    pub fn new(threads: usize) -> Pool {
        Pool { threads: threads.max(1) }
    }

    /// Strictly serial pool (the old single-threaded behaviour).
    pub fn serial() -> Pool {
        Pool::new(1)
    }

    /// Thread count from `ZETA_THREADS` (unset / 0 / unparsable = number of
    /// available hardware threads).
    pub fn auto() -> Pool {
        let detected = || {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        };
        match std::env::var("ZETA_THREADS").ok().and_then(|v| v.parse::<usize>().ok()) {
            Some(0) | None => Pool::new(detected()),
            Some(t) => Pool::new(t),
        }
    }

    /// The process-wide pool (env read once, first use wins).
    pub fn global() -> &'static Pool {
        static GLOBAL: OnceLock<Pool> = OnceLock::new();
        GLOBAL.get_or_init(Pool::auto)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// A sensible dynamic-stealing grain for `n` items: small enough for
    /// load balance (≈8 chunks per worker), never below `min`.
    pub fn grain(&self, n: usize, min: usize) -> usize {
        let target = n / (self.threads * 8).max(1);
        target.max(min).max(1)
    }

    /// Run `f(worker_id)` for each worker id in `0..workers` and collect the
    /// results in worker-id order. `workers` is clamped to the pool size.
    ///
    /// With one effective worker — or when the calling thread is already
    /// inside a pool region (nested submission) — every id runs inline on
    /// the caller's thread, bit-identical to the serial loop. Otherwise the
    /// resident team is woken and ids are claimed dynamically by the
    /// submitter plus the parked workers; a panic in any id is re-raised
    /// here, on the submitting thread, once the region has joined.
    pub fn run_workers<R, F>(&self, workers: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let workers = workers.clamp(1, self.threads);
        if workers == 1 || in_pool_context() {
            return (0..workers).map(&f).collect();
        }
        run_region_on(Team::global(), workers, &f)
    }

    /// Run `f(worker_id)` once per pool thread.
    pub fn run<R, F>(&self, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        self.run_workers(self.threads, f)
    }

    /// One call per worker over a shared chunk queue for `0..n`: each
    /// worker owns whatever per-worker state it builds inside `f` (scratch
    /// buffers, gradient accumulators), drains chunks via the queue handle,
    /// and returns a result collected in worker order. This is the one
    /// place the worker-count formula lives — every chunk-parallel kernel
    /// phase goes through here.
    pub fn run_chunked<R, F>(&self, n: usize, grain: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(&ChunkQueue) -> R + Sync,
    {
        let grain = grain.max(1);
        let queue = ChunkQueue::new(n, grain);
        let workers = self.threads.min(((n + grain - 1) / grain).max(1));
        self.run_workers(workers, |_| f(&queue))
    }

    /// Chunked parallel loop over `0..n` with per-worker stats; returns the
    /// summed workspace bytes across workers. Chunks of `grain` indices are
    /// claimed dynamically, so uneven per-index costs still balance.
    pub fn parallel_for_stats<F>(&self, n: usize, grain: usize, f: F) -> usize
    where
        F: Fn(Range<usize>, &mut WorkerStats) + Sync,
    {
        if n == 0 {
            return 0;
        }
        let stats = self.run_chunked(n, grain, |queue| {
            let mut st = WorkerStats::default();
            while let Some(r) = queue.next_chunk() {
                f(r, &mut st);
            }
            st
        });
        stats.iter().map(|s| s.workspace_bytes).sum()
    }

    /// Chunked parallel loop over `0..n` (no accounting).
    pub fn parallel_for<F>(&self, n: usize, grain: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        self.parallel_for_stats(n, grain, |r, _| f(r));
    }
}

// ---------------------------------------------------------------------------
// Resident team: parked worker threads + participant-counted region dispatch
// ---------------------------------------------------------------------------

thread_local! {
    /// True while this thread is executing inside a pool region — set
    /// permanently on resident workers, and around the submitter's own
    /// share of a region. Nested submissions from such threads run inline,
    /// which is what makes region submission re-entrant and deadlock-free.
    static IN_POOL: Cell<bool> = Cell::new(false);
}

fn in_pool_context() -> bool {
    IN_POOL.with(|c| c.get())
}

/// Type-erased parallel region: a trampoline instantiated for the concrete
/// closure/result types plus a pointer to the [`RegionCtx`] on the
/// submitter's stack. The context stays valid for the whole region because
/// the submitter blocks until every participant has retired the region.
#[derive(Clone, Copy)]
struct RegionDesc {
    run: unsafe fn(*const ()),
    ctx: *const (),
}

// Safety: the context outlives the region (the submitter joins it before
// returning) and every field reachable through it is Sync (see RegionCtx).
unsafe impl Send for RegionDesc {}

/// Per-worker-id result slot: written exactly once by whichever participant
/// claims the id, read by the submitter after the region joins (the team
/// mutex orders the write before the read).
struct Slot<R>(UnsafeCell<Option<R>>);

// Safety: each slot is written by exactly one participant (unique id claim
// off the atomic counter) and only read after the region join.
unsafe impl<R: Send> Sync for Slot<R> {}

/// Stack-allocated state of one parallel region.
struct RegionCtx<'f, F, R> {
    f: &'f F,
    /// Next logical worker id to claim; participants multiplex ids.
    next_id: AtomicUsize,
    workers: usize,
    slots: Vec<Slot<R>>,
    /// Set on the first panic so other participants stop claiming ids.
    poisoned: AtomicBool,
    /// First panic payload, re-raised on the submitting thread.
    panic: Mutex<Option<Box<dyn Any + Send + 'static>>>,
}

/// Region trampoline: claim logical worker ids until the region is drained
/// (or poisoned), catching panics so residents always park clean.
unsafe fn region_main<R, F>(ptr: *const ())
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let ctx = &*(ptr as *const RegionCtx<'_, F, R>);
    while !ctx.poisoned.load(Ordering::Relaxed) {
        let id = ctx.next_id.fetch_add(1, Ordering::Relaxed);
        if id >= ctx.workers {
            break;
        }
        match catch_unwind(AssertUnwindSafe(|| (ctx.f)(id))) {
            Ok(r) => *ctx.slots[id].0.get() = Some(r),
            Err(p) => {
                ctx.poisoned.store(true, Ordering::Relaxed);
                let mut slot = ctx.panic.lock().unwrap_or_else(|e| e.into_inner());
                if slot.is_none() {
                    *slot = Some(p);
                }
            }
        }
    }
}

/// Execute a `workers >= 2` region on `team`, blocking until it joins.
/// Re-raises the first worker panic on the calling thread. When another
/// region is already in flight the submitter runs every id inline instead
/// of queueing — same results, and a busy team never stalls a caller.
fn run_region_on<R, F>(team: &Team, workers: usize, f: &F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    debug_assert!(workers >= 2);
    // In-context callers must take the inline path (`Pool::run_workers`
    // short-circuits them); a direct call from inside a region would have
    // its IN_POOL flag cleared by the submitter share below.
    debug_assert!(!in_pool_context(), "run_region_on called from inside a pool region");
    let ctx = RegionCtx {
        f,
        next_id: AtomicUsize::new(0),
        workers,
        slots: (0..workers).map(|_| Slot(UnsafeCell::new(None))).collect(),
        poisoned: AtomicBool::new(false),
        panic: Mutex::new(None),
    };
    let desc = RegionDesc {
        run: region_main::<R, F>,
        ctx: &ctx as *const RegionCtx<'_, F, R> as *const (),
    };
    if !team.run_region(workers - 1, desc) {
        return (0..workers).map(f).collect();
    }
    let RegionCtx { slots, panic, .. } = ctx;
    if let Some(p) = panic.into_inner().unwrap_or_else(|e| e.into_inner()) {
        resume_unwind(p);
    }
    slots
        .into_iter()
        .map(|s| s.0.into_inner().expect("pool region missed a worker id"))
        .collect()
}

/// State shared between the residents and the submitters, guarded by one
/// mutex: the current region (if any) and the participation accounting
/// that bounds a region's join to the workers it actually asked for.
struct TeamState {
    region: Option<RegionDesc>,
    /// Unclaimed participant slots for the current region: only residents
    /// that decrement this (under the lock, while the region is live) may
    /// touch the region descriptor — which is what keeps a small region's
    /// launch cost proportional to *its* worker count, not to the largest
    /// team the process ever grew. This count is the *sole* claim guard:
    /// a resident that already helped the live region may claim a second
    /// slot after retiring its first (benign — `region_main` drains no
    /// ids once the region is exhausted, and `outstanding` counts claims,
    /// not threads), which is what makes the targeted `notify_one`
    /// publish in [`Team::run_region`] immune to lost wakeups.
    participants: usize,
    /// Participants that have not yet retired the current region; the
    /// submitter's join waits for this to reach zero.
    outstanding: usize,
    /// Resident threads spawned so far.
    residents: usize,
    shutdown: bool,
}

struct TeamCore {
    state: Mutex<TeamState>,
    /// Residents park here between regions.
    wake: Condvar,
    /// The submitter parks here until `outstanding == 0`.
    done: Condvar,
}

/// A team of resident worker threads, parked between regions. The process
/// owns exactly one (`Team::global`), spawned lazily and capped; dropping a
/// team (unit tests construct private ones) signals shutdown and joins all
/// residents.
struct Team {
    core: Arc<TeamCore>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    /// Admits one live region at a time — parallelism is *within* a
    /// region. Never waited on: a submitter that finds it held runs its
    /// region inline instead, and nested submissions never reach it at
    /// all, so the gate can neither stall a caller nor self-deadlock.
    gate: Mutex<()>,
    /// Maximum residents this team will spawn; logical worker ids beyond
    /// it multiplex.
    cap: usize,
}

impl Team {
    fn with_cap(cap: usize) -> Team {
        Team {
            core: Arc::new(TeamCore {
                state: Mutex::new(TeamState {
                    region: None,
                    participants: 0,
                    outstanding: 0,
                    residents: 0,
                    shutdown: false,
                }),
                wake: Condvar::new(),
                done: Condvar::new(),
            }),
            handles: Mutex::new(Vec::new()),
            gate: Mutex::new(()),
            cap: cap.max(1),
        }
    }

    fn global() -> &'static Team {
        static TEAM: OnceLock<Team> = OnceLock::new();
        TEAM.get_or_init(|| Team::with_cap(default_team_cap()))
    }

    /// Publish `desc` to the residents, run the submitter's own share, and
    /// block until every participating resident has retired the region.
    /// Returns `false` without running anything when another region is in
    /// flight — the caller then runs the whole region inline instead of
    /// idling behind the gate (a blocked submitter has work of its own).
    fn run_region(&self, helpers: usize, desc: RegionDesc) -> bool {
        let _gate = match self.gate.try_lock() {
            Ok(g) => g,
            Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner(),
            Err(std::sync::TryLockError::WouldBlock) => return false,
        };
        {
            let mut st = self.core.state.lock().unwrap_or_else(|e| e.into_inner());
            self.ensure_residents(&mut st, helpers.min(self.cap));
            // Only as many residents as the region asked for participate;
            // the rest find the participant quota drained and park straight
            // away, so a 2-slot sweep never waits on a 64-thread team.
            let joining = st.residents.min(helpers);
            st.region = Some(desc);
            st.participants = joining;
            st.outstanding = joining;
            drop(st);
            // Targeted wakes instead of a notify_all thundering herd: only
            // `joining` residents are needed. A wake may land on a resident
            // that cannot help — e.g. one counted in `joining` that already
            // claimed, drained the (small) region, retired, and re-parked
            // before this loop finished, then got picked by a later notify
            // (Condvar wake order is unspecified). That is harmless:
            // claiming is guarded only by `participants`, so *any* resident
            // that wakes while slots remain claims one and makes progress,
            // and once the quota is drained the remaining `outstanding`
            // retirements are owed exclusively by participants that are
            // already awake — no parked resident is needed, so no wakeup
            // can be lost where it matters.
            for _ in 0..joining {
                self.core.wake.notify_one();
            }
        }
        // The submitter is a participant too: it drains worker ids itself,
        // so a region completes even if the team spawned zero residents.
        // Save/restore rather than hard-set the flag so the nested-inline
        // protection survives a future in-context caller of this path.
        let was_in_pool = IN_POOL.with(|c| c.replace(true));
        unsafe { (desc.run)(desc.ctx) };
        IN_POOL.with(|c| c.set(was_in_pool));
        let mut st = self.core.state.lock().unwrap_or_else(|e| e.into_inner());
        while st.outstanding > 0 {
            st = self.core.done.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.region = None;
        true
    }

    /// Spawn residents (under the state lock) until `want` are live or the
    /// OS refuses; fewer residents only means more id multiplexing.
    fn ensure_residents(&self, st: &mut TeamState, want: usize) {
        while st.residents < want {
            let core = Arc::clone(&self.core);
            let name = format!("zeta-pool-{}", st.residents);
            match std::thread::Builder::new().name(name).spawn(move || worker_loop(core)) {
                Ok(h) => {
                    st.residents += 1;
                    self.handles.lock().unwrap_or_else(|e| e.into_inner()).push(h);
                }
                Err(_) => break,
            }
        }
    }
}

impl Drop for Team {
    fn drop(&mut self) {
        {
            let mut st = self.core.state.lock().unwrap_or_else(|e| e.into_inner());
            st.shutdown = true;
        }
        self.core.wake.notify_all();
        let mut handles = self.handles.lock().unwrap_or_else(|e| e.into_inner());
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Resident main loop: park on the condvar until a live region with an
/// unclaimed participant slot (or shutdown) appears, claim the slot —
/// only counted participants may touch the region descriptor — run the
/// trampoline, and retire the region.
///
/// Claiming is deliberately *not* gated on whether this resident already
/// helped the live region: a repeat claim just re-enters `region_main`,
/// which drains nothing once the worker ids are exhausted, and retires
/// again — `outstanding` counts claims, not distinct threads. Gating on a
/// region stamp instead (as an earlier revision did) loses wakeups: a
/// fast resident can drain a small region, re-park while the submitter is
/// still issuing its targeted notifies, swallow a notify meant for a
/// still-parked peer, and refuse to claim — leaving a participant slot
/// unclaimed and the submitter waiting on `done` forever.
fn worker_loop(core: Arc<TeamCore>) {
    IN_POOL.with(|c| c.set(true));
    loop {
        let desc = {
            let mut st = core.state.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(d) = st.region {
                    if st.participants > 0 {
                        st.participants -= 1;
                        break d;
                    }
                    // Quota filled: this region needs no more hands; park.
                }
                st = core.wake.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        // Run outside the lock; the trampoline catches panics, so the
        // retirement below always happens and the team is never poisoned.
        unsafe { (desc.run)(desc.ctx) };
        let mut st = core.state.lock().unwrap_or_else(|e| e.into_inner());
        st.outstanding -= 1;
        if st.outstanding == 0 {
            core.done.notify_all();
        }
    }
}

/// Resident cap: oversubscribed pools multiplex logical worker ids instead
/// of spawning unboundedly many OS threads.
fn default_team_cap() -> usize {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    (hw * 2).clamp(8, 64)
}

// ---------------------------------------------------------------------------
// Chunk dispenser + shared output slice + partial merge
// ---------------------------------------------------------------------------

/// Lock-free dynamic chunk dispenser over `0..n`.
pub struct ChunkQueue {
    next: AtomicUsize,
    n: usize,
    grain: usize,
}

impl ChunkQueue {
    pub fn new(n: usize, grain: usize) -> ChunkQueue {
        ChunkQueue { next: AtomicUsize::new(0), n, grain: grain.max(1) }
    }

    /// Claim the next chunk, or `None` when the range is exhausted.
    ///
    /// The cursor advances by *saturating* compare-and-swap: the old
    /// unconditional `fetch_add(grain)` kept advancing after exhaustion, so
    /// repeated polling with a huge grain could wrap `usize` and land the
    /// cursor back below `n` — handing out already-claimed chunks again.
    /// Pinned by `chunk_queue_saturates_after_exhaustion`.
    pub fn next_chunk(&self) -> Option<Range<usize>> {
        let mut start = self.next.load(Ordering::Relaxed);
        loop {
            if start >= self.n {
                return None;
            }
            let end = start.saturating_add(self.grain);
            let claim = self.next.compare_exchange_weak(
                start,
                end,
                Ordering::Relaxed,
                Ordering::Relaxed,
            );
            match claim {
                Ok(_) => return Some(start..end.min(self.n)),
                Err(cur) => start = cur,
            }
        }
    }
}

/// A mutable slice shared across workers that write *disjoint* regions
/// (e.g. each worker owns a distinct row range of an output matrix).
///
/// The `unsafe` obligation is concentrated in [`SharedSlice::range_mut`] /
/// [`SharedSlice::write`]: callers must guarantee that concurrently-claimed
/// regions never overlap. Every use in this crate derives the region from a
/// chunk claimed off a [`ChunkQueue`], which hands out each index exactly
/// once.
pub struct SharedSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for SharedSlice<'_, T> {}
unsafe impl<T: Send> Sync for SharedSlice<'_, T> {}

impl<'a, T> SharedSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> SharedSlice<'a, T> {
        SharedSlice { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable view of `range`.
    ///
    /// # Safety
    /// Concurrent callers must claim non-overlapping ranges.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range_mut(&self, range: Range<usize>) -> &mut [T] {
        assert!(range.start <= range.end && range.end <= self.len, "range out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(range.start), range.end - range.start)
    }

    /// Write one element.
    ///
    /// # Safety
    /// Concurrent callers must write non-overlapping indices.
    pub unsafe fn write(&self, idx: usize, value: T) {
        assert!(idx < self.len, "index out of bounds");
        *self.ptr.add(idx) = value;
    }
}

/// Merge per-worker accumulator buffers into `dst` (`dst[i] += part[i]`).
/// The single merge path for every kernel's per-thread gradient
/// accumulators; the serial path (one worker) reduces to a plain add,
/// preserving the old accumulation order exactly.
pub fn merge_partials<'a, I>(dst: &mut [f32], partials: I)
where
    I: IntoIterator<Item = &'a [f32]>,
{
    for part in partials {
        debug_assert_eq!(part.len(), dst.len());
        for (d, s) in dst.iter_mut().zip(part.iter()) {
            *d += *s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn serial_pool_runs_inline() {
        let p = Pool::serial();
        assert_eq!(p.threads(), 1);
        let main_id = std::thread::current().id();
        let ids = p.run(|w| (w, std::thread::current().id()));
        assert_eq!(ids.len(), 1);
        assert_eq!(ids[0].0, 0);
        assert_eq!(ids[0].1, main_id);
    }

    #[test]
    fn parallel_for_covers_every_index_once() {
        for threads in [1usize, 2, 4] {
            let p = Pool::new(threads);
            let n = 1037;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            p.parallel_for(n, 16, |r| {
                for i in r {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "threads={threads}");
        }
    }

    #[test]
    fn stats_sum_across_workers() {
        let p = Pool::new(4);
        let total = p.parallel_for_stats(100, 10, |r, st| {
            st.workspace_bytes += r.len();
        });
        assert_eq!(total, 100);
    }

    #[test]
    fn shared_slice_disjoint_rows() {
        let n = 64;
        let d = 8;
        let mut buf = vec![0f32; n * d];
        {
            let sh = SharedSlice::new(&mut buf);
            let p = Pool::new(4);
            p.parallel_for(n, 4, |rows| {
                for i in rows {
                    let row = unsafe { sh.range_mut(i * d..(i + 1) * d) };
                    for (j, v) in row.iter_mut().enumerate() {
                        *v = (i * d + j) as f32;
                    }
                }
            });
        }
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn chunk_queue_exhausts() {
        let q = ChunkQueue::new(10, 3);
        let mut seen = Vec::new();
        while let Some(r) = q.next_chunk() {
            seen.extend(r);
        }
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
        assert!(q.next_chunk().is_none());
    }

    #[test]
    fn chunk_queue_saturates_after_exhaustion() {
        // Old behaviour: `fetch_add(grain)` advanced the cursor past
        // exhaustion; with a huge grain a handful of polls wrapped `usize`
        // and the cursor landed back below `n`, re-issuing chunk 0.
        let q = ChunkQueue::new(usize::MAX, usize::MAX);
        assert_eq!(q.next_chunk(), Some(0..usize::MAX));
        for _ in 0..8 {
            assert!(q.next_chunk().is_none(), "exhausted queue re-issued a chunk");
        }
        let q2 = ChunkQueue::new(10, usize::MAX / 2);
        assert_eq!(q2.next_chunk(), Some(0..10));
        for _ in 0..8 {
            assert!(q2.next_chunk().is_none());
        }
    }

    #[test]
    fn merge_partials_sums() {
        let mut dst = vec![1.0, 2.0];
        let parts = [vec![0.5f32, 0.5], vec![1.0, -1.0]];
        merge_partials(&mut dst, parts.iter().map(|p| p.as_slice()));
        assert_eq!(dst, vec![2.5, 1.5]);
    }

    #[test]
    fn grain_never_zero() {
        let p = Pool::new(8);
        assert!(p.grain(0, 1) >= 1);
        assert!(p.grain(5, 16) == 16);
        assert!(p.grain(100_000, 1) >= 1);
    }

    #[test]
    fn run_workers_results_in_worker_id_order() {
        let p = Pool::new(16);
        assert_eq!(p.run_workers(16, |w| w), (0..16).collect::<Vec<_>>());
        // Oversubscribed: ids multiplex over the capped team, order kept.
        let p = Pool::new(300);
        assert_eq!(p.run_workers(300, |w| w * 2), (0..300).map(|w| w * 2).collect::<Vec<_>>());
    }

    // Panic propagation, nested submission, oversubscription and
    // concurrent-submitter contention are covered by the integration gate
    // in `rust/tests/pool_stress.rs`; the tests here stick to private
    // internals and the serial/inline contracts.

    #[test]
    fn small_region_hammer_no_lost_wakeups() {
        // Regression for a lost-wakeup deadlock: when claims were gated on
        // a region generation stamp, a fast resident could claim, drain a
        // tiny region, retire, and re-park while the submitter was still
        // issuing its targeted notifies; a later notify could then wake
        // that re-parked resident (Condvar wake order is unspecified),
        // which saw a stale-for-it generation, refused to claim, and
        // re-waited — swallowing the signal meant for a still-parked peer
        // and hanging the submitter on `done.wait` with a participant slot
        // forever unclaimed. Hammering near-empty multi-helper regions
        // reproduces that interleaving with high probability; with claims
        // guarded by the slot count alone the loop must always join.
        let team = Team::with_cap(3);
        for i in 0..20_000usize {
            let out: Vec<usize> = run_region_on(&team, 4, &|w| w + i);
            assert_eq!(out, vec![i, i + 1, i + 2, i + 3]);
        }
    }

    #[test]
    fn private_team_shutdown_on_drop_joins_residents() {
        let team = Team::with_cap(3);
        let hits = AtomicUsize::new(0);
        let out: Vec<usize> = run_region_on(&team, 5, &|w| {
            hits.fetch_add(1, Ordering::Relaxed);
            w
        });
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
        assert_eq!(hits.load(Ordering::Relaxed), 5);
        assert!(team.core.state.lock().unwrap().residents <= 3);
        // Drop parks → shutdown → join; must not hang.
        drop(team);
    }
}
