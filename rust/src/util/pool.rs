//! Shared worker pool: the parallel execution substrate for every kernel,
//! the zorder codec, the experiment harness and the serving coordinator.
//!
//! Design (std-only, no rayon offline):
//!
//! * A [`Pool`] is a *thread-count policy*, cheap to copy and share. Work is
//!   executed on scoped threads (`std::thread::scope`) spawned per parallel
//!   region, so closures may borrow the caller's stack freely and no
//!   `'static` boxing or channel plumbing is needed. At `threads = 1`
//!   everything degrades to a plain inline loop — bit-identical to the old
//!   serial kernels.
//! * Chunks are handed out by a lock-free [`ChunkQueue`] (one atomic
//!   `fetch_add` per chunk), so triangular workloads (causal attention row
//!   costs grow with i) load-balance without a scheduler thread.
//! * Per-thread accounting: workers accumulate into a stack-local
//!   [`WorkerStats`] and results are merged once after the scope joins —
//!   `MemReport` stays *measured* with zero locks on the hot path.
//! * [`SharedSlice`] lets workers write disjoint rows of one output buffer
//!   (the idiom rayon's `par_chunks_mut` provides); callers assert
//!   disjointness at the single `unsafe` call site.
//!
//! The global pool reads `ZETA_THREADS` once (unset or `0` = auto-detect
//! from `available_parallelism`).

use std::marker::PhantomData;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Stack-local per-worker statistics, merged after a parallel region joins.
#[derive(Debug, Default, Clone, Copy)]
pub struct WorkerStats {
    /// Bytes of scratch buffers this worker actually allocated.
    pub workspace_bytes: usize,
}

/// Thread-count policy handle. `Copy` so kernels, the experiment harness and
/// the coordinator can share one without reference-counting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    pub fn new(threads: usize) -> Pool {
        Pool { threads: threads.max(1) }
    }

    /// Strictly serial pool (the old single-threaded behaviour).
    pub fn serial() -> Pool {
        Pool::new(1)
    }

    /// Thread count from `ZETA_THREADS` (unset / 0 / unparsable = number of
    /// available hardware threads).
    pub fn auto() -> Pool {
        let detected = || {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        };
        match std::env::var("ZETA_THREADS").ok().and_then(|v| v.parse::<usize>().ok()) {
            Some(0) | None => Pool::new(detected()),
            Some(t) => Pool::new(t),
        }
    }

    /// The process-wide pool (env read once, first use wins).
    pub fn global() -> &'static Pool {
        static GLOBAL: OnceLock<Pool> = OnceLock::new();
        GLOBAL.get_or_init(Pool::auto)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// A sensible dynamic-stealing grain for `n` items: small enough for
    /// load balance (≈8 chunks per worker), never below `min`.
    pub fn grain(&self, n: usize, min: usize) -> usize {
        let target = n / (self.threads * 8).max(1);
        target.max(min).max(1)
    }

    /// Run `f(worker_id)` on up to `workers` scoped threads and collect the
    /// results in worker order. `workers` is clamped to the pool size; with
    /// one effective worker, `f(0)` runs inline on the caller's thread.
    pub fn run_workers<R, F>(&self, workers: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let workers = workers.clamp(1, self.threads);
        if workers == 1 {
            return vec![f(0)];
        }
        std::thread::scope(|s| {
            let f = &f;
            let handles: Vec<_> = (0..workers).map(|id| s.spawn(move || f(id))).collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("pool worker panicked"))
                .collect()
        })
    }

    /// Run `f(worker_id)` once per pool thread.
    pub fn run<R, F>(&self, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        self.run_workers(self.threads, f)
    }

    /// One call per worker over a shared chunk queue for `0..n`: each
    /// worker owns whatever per-worker state it builds inside `f` (scratch
    /// buffers, gradient accumulators), drains chunks via the queue handle,
    /// and returns a result collected in worker order. This is the one
    /// place the worker-count formula lives — every chunk-parallel kernel
    /// phase goes through here.
    pub fn run_chunked<R, F>(&self, n: usize, grain: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(&ChunkQueue) -> R + Sync,
    {
        let grain = grain.max(1);
        let queue = ChunkQueue::new(n, grain);
        let workers = self.threads.min(((n + grain - 1) / grain).max(1));
        self.run_workers(workers, |_| f(&queue))
    }

    /// Chunked parallel loop over `0..n` with per-worker stats; returns the
    /// summed workspace bytes across workers. Chunks of `grain` indices are
    /// claimed dynamically, so uneven per-index costs still balance.
    pub fn parallel_for_stats<F>(&self, n: usize, grain: usize, f: F) -> usize
    where
        F: Fn(Range<usize>, &mut WorkerStats) + Sync,
    {
        if n == 0 {
            return 0;
        }
        let stats = self.run_chunked(n, grain, |queue| {
            let mut st = WorkerStats::default();
            while let Some(r) = queue.next_chunk() {
                f(r, &mut st);
            }
            st
        });
        stats.iter().map(|s| s.workspace_bytes).sum()
    }

    /// Chunked parallel loop over `0..n` (no accounting).
    pub fn parallel_for<F>(&self, n: usize, grain: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        self.parallel_for_stats(n, grain, |r, _| f(r));
    }
}

/// Lock-free dynamic chunk dispenser over `0..n`.
pub struct ChunkQueue {
    next: AtomicUsize,
    n: usize,
    grain: usize,
}

impl ChunkQueue {
    pub fn new(n: usize, grain: usize) -> ChunkQueue {
        ChunkQueue { next: AtomicUsize::new(0), n, grain: grain.max(1) }
    }

    /// Claim the next chunk, or `None` when the range is exhausted.
    pub fn next_chunk(&self) -> Option<Range<usize>> {
        let start = self.next.fetch_add(self.grain, Ordering::Relaxed);
        if start >= self.n {
            None
        } else {
            Some(start..(start + self.grain).min(self.n))
        }
    }
}

/// A mutable slice shared across workers that write *disjoint* regions
/// (e.g. each worker owns a distinct row range of an output matrix).
///
/// The `unsafe` obligation is concentrated in [`SharedSlice::range_mut`] /
/// [`SharedSlice::write`]: callers must guarantee that concurrently-claimed
/// regions never overlap. Every use in this crate derives the region from a
/// chunk claimed off a [`ChunkQueue`], which hands out each index exactly
/// once.
pub struct SharedSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for SharedSlice<'_, T> {}
unsafe impl<T: Send> Sync for SharedSlice<'_, T> {}

impl<'a, T> SharedSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> SharedSlice<'a, T> {
        SharedSlice { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable view of `range`.
    ///
    /// # Safety
    /// Concurrent callers must claim non-overlapping ranges.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range_mut(&self, range: Range<usize>) -> &mut [T] {
        assert!(range.start <= range.end && range.end <= self.len, "range out of bounds");
        std::slice::from_raw_parts_mut(self.ptr.add(range.start), range.end - range.start)
    }

    /// Write one element.
    ///
    /// # Safety
    /// Concurrent callers must write non-overlapping indices.
    pub unsafe fn write(&self, idx: usize, value: T) {
        assert!(idx < self.len, "index out of bounds");
        *self.ptr.add(idx) = value;
    }
}

/// Merge per-worker accumulator buffers into `dst` (`dst[i] += part[i]`).
/// The single merge path for every kernel's per-thread gradient
/// accumulators; the serial path (one worker) reduces to a plain add,
/// preserving the old accumulation order exactly.
pub fn merge_partials<'a, I>(dst: &mut [f32], partials: I)
where
    I: IntoIterator<Item = &'a [f32]>,
{
    for part in partials {
        debug_assert_eq!(part.len(), dst.len());
        for (d, s) in dst.iter_mut().zip(part.iter()) {
            *d += *s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn serial_pool_runs_inline() {
        let p = Pool::serial();
        assert_eq!(p.threads(), 1);
        let main_id = std::thread::current().id();
        let ids = p.run(|w| (w, std::thread::current().id()));
        assert_eq!(ids.len(), 1);
        assert_eq!(ids[0].0, 0);
        assert_eq!(ids[0].1, main_id);
    }

    #[test]
    fn parallel_for_covers_every_index_once() {
        for threads in [1usize, 2, 4] {
            let p = Pool::new(threads);
            let n = 1037;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            p.parallel_for(n, 16, |r| {
                for i in r {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "threads={threads}");
        }
    }

    #[test]
    fn stats_sum_across_workers() {
        let p = Pool::new(4);
        let total = p.parallel_for_stats(100, 10, |r, st| {
            st.workspace_bytes += r.len();
        });
        assert_eq!(total, 100);
    }

    #[test]
    fn shared_slice_disjoint_rows() {
        let n = 64;
        let d = 8;
        let mut buf = vec![0f32; n * d];
        {
            let sh = SharedSlice::new(&mut buf);
            let p = Pool::new(4);
            p.parallel_for(n, 4, |rows| {
                for i in rows {
                    let row = unsafe { sh.range_mut(i * d..(i + 1) * d) };
                    for (j, v) in row.iter_mut().enumerate() {
                        *v = (i * d + j) as f32;
                    }
                }
            });
        }
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn chunk_queue_exhausts() {
        let q = ChunkQueue::new(10, 3);
        let mut seen = Vec::new();
        while let Some(r) = q.next_chunk() {
            seen.extend(r);
        }
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
        assert!(q.next_chunk().is_none());
    }

    #[test]
    fn merge_partials_sums() {
        let mut dst = vec![1.0, 2.0];
        let parts = [vec![0.5f32, 0.5], vec![1.0, -1.0]];
        merge_partials(&mut dst, parts.iter().map(|p| p.as_slice()));
        assert_eq!(dst, vec![2.5, 1.5]);
    }

    #[test]
    fn grain_never_zero() {
        let p = Pool::new(8);
        assert!(p.grain(0, 1) >= 1);
        assert!(p.grain(5, 16) == 16);
        assert!(p.grain(100_000, 1) >= 1);
    }
}
