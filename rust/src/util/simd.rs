//! Portable SIMD layer for the f32 kernel inner loops.
//!
//! Every hot loop in the engine — Cauchy top-k scoring, exact-attention
//! softmax rows, the mamba recurrence, Morton interleaving, and the
//! readout matvec — funnels through the lane ops here instead of open-coded
//! scalar loops. One backend is picked per process at first use:
//!
//! * **x86_64 + AVX2** — 8 × f32 lanes (`std::arch::x86_64`, runtime
//!   `is_x86_feature_detected!`).
//! * **aarch64 + NEON** — 4 × f32 lanes (`std::arch::aarch64`).
//! * **scalar** — the seed's reference loops, bit-for-bit; also forced by
//!   `ZETA_SIMD=scalar` or when no vector unit is detected.
//!
//! ## Determinism contract
//!
//! * Scalar mode reproduces the pre-SIMD loops exactly, so every bitwise
//!   gate in the repo holds unchanged under `ZETA_SIMD=scalar`.
//! * Elementwise ops ([`axpy`], [`scale`], and the `hrow` state update of
//!   [`ssm_step`]) use one IEEE mul/add per element in both modes, so they
//!   are bit-identical to scalar on every backend.
//! * Reductions ([`dot`], [`sqdist`], the [`ssm_step`] readout) block over
//!   lanes *by element index* with unaligned loads and collapse the lane
//!   accumulator through a fixed pairwise tree, so a given input length
//!   always sums in the same order — results are independent of buffer
//!   alignment and of how rows were parallelized across threads, and stay
//!   within 1e-4 of scalar per element (pinned by `tests/simd_equivalence`).
//! * [`interleave`] is integer-only: the magic-shift fast path is
//!   bit-identical to the seed loop on every input (property-tested).
//!
//! The dispatch is process-global (a [`OnceLock`]), never per-call, so the
//! same routine — and therefore the same rounding — runs on both sides of
//! every decode-vs-forward / fused-vs-serial equivalence gate. The `_with`
//! variants take an explicit [`Backend`] for micro-benchmarks and
//! equivalence tests; they fall back to scalar if the requested backend is
//! not available on the running CPU.

use std::sync::OnceLock;

/// A vector instruction set the dispatcher can select.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Backend {
    /// Seed-exact reference loops (also the `ZETA_SIMD=scalar` override).
    Scalar,
    /// 8 × f32 AVX2 lanes.
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// 4 × f32 NEON lanes.
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => "neon",
        }
    }

    /// f32 lanes per vector register (1 for scalar).
    pub fn lanes(self) -> usize {
        match self {
            Backend::Scalar => 1,
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 => 8,
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => 4,
        }
    }

    /// Whether the running CPU can execute this backend.
    pub fn available(self) -> bool {
        match self {
            Backend::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Backend::Avx2 => is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "aarch64")]
            Backend::Neon => std::arch::is_aarch64_feature_detected!("neon"),
        }
    }
}

/// The process-wide backend: `ZETA_SIMD=scalar` forces the scalar loops,
/// otherwise the widest available vector unit is used. Cached on first call.
pub fn backend() -> Backend {
    static BACKEND: OnceLock<Backend> = OnceLock::new();
    *BACKEND.get_or_init(detect)
}

pub fn backend_name() -> &'static str {
    backend().name()
}

/// f32 lanes of the active backend.
pub fn lanes() -> usize {
    backend().lanes()
}

fn detect() -> Backend {
    if let Ok(v) = std::env::var("ZETA_SIMD") {
        if v.eq_ignore_ascii_case("scalar") {
            return Backend::Scalar;
        }
    }
    #[cfg(target_arch = "x86_64")]
    {
        if Backend::Avx2.available() {
            return Backend::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if Backend::Neon.available() {
            return Backend::Neon;
        }
    }
    Backend::Scalar
}

/// Dispatch on a backend that is known to be executable (the global
/// [`backend`] by construction, `_with` arguments after an availability
/// check). The vector arm is sound because the only non-scalar variants a
/// caller can hold on this architecture were gated on feature detection.
macro_rules! dispatch {
    ($be:expr, $scalar:expr, $vector:expr) => {
        match $be {
            Backend::Scalar => $scalar,
            #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
            _ => unsafe { $vector },
        }
    };
}

fn checked(be: Backend) -> Backend {
    if be.available() {
        be
    } else {
        Backend::Scalar
    }
}

// ---------------------------------------------------------------------------
// Reductions: dot / sqdist
// ---------------------------------------------------------------------------

/// `Σ a[i]·b[i]`. Scalar mode is the seed's sequential accumulation;
/// vector mode blocks by index and reduces through a fixed lane tree.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dispatch!(backend(), dot_scalar(a, b), vecimpl::dot(a, b))
}

/// [`dot`] on an explicit backend (benches/tests only).
pub fn dot_with(be: Backend, a: &[f32], b: &[f32]) -> f32 {
    dispatch!(checked(be), dot_scalar(a, b), vecimpl::dot(a, b))
}

fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let mut s = 0.0;
    for i in 0..n {
        s += a[i] * b[i];
    }
    s
}

/// `Σ (a[i]-b[i])²` — the Cauchy-scoring distance kernel.
#[inline]
pub fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    dispatch!(backend(), sqdist_scalar(a, b), vecimpl::sqdist(a, b))
}

/// [`sqdist`] on an explicit backend (benches/tests only).
pub fn sqdist_with(be: Backend, a: &[f32], b: &[f32]) -> f32 {
    dispatch!(checked(be), sqdist_scalar(a, b), vecimpl::sqdist(a, b))
}

fn sqdist_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let mut s = 0.0;
    for i in 0..n {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

// ---------------------------------------------------------------------------
// Elementwise: axpy / scale (bit-identical to scalar on every backend)
// ---------------------------------------------------------------------------

/// `out[i] += a·x[i]` over `min(out.len(), x.len())` — the AV-accumulate
/// of every attention kernel. One mul + one add per element in both modes,
/// so vector output is bit-identical to scalar.
#[inline]
pub fn axpy(out: &mut [f32], a: f32, x: &[f32]) {
    dispatch!(backend(), axpy_scalar(out, a, x), vecimpl::axpy(out, a, x))
}

/// [`axpy`] on an explicit backend (benches/tests only).
pub fn axpy_with(be: Backend, out: &mut [f32], a: f32, x: &[f32]) {
    dispatch!(checked(be), axpy_scalar(out, a, x), vecimpl::axpy(out, a, x))
}

fn axpy_scalar(out: &mut [f32], a: f32, x: &[f32]) {
    let n = out.len().min(x.len());
    for i in 0..n {
        out[i] += a * x[i];
    }
}

/// `out[i] *= s` — softmax normalization. Bit-identical to scalar.
#[inline]
pub fn scale(out: &mut [f32], s: f32) {
    dispatch!(backend(), scale_scalar(out, s), vecimpl::scale(out, s))
}

/// [`scale`] on an explicit backend (benches/tests only).
pub fn scale_with(be: Backend, out: &mut [f32], s: f32) {
    dispatch!(checked(be), scale_scalar(out, s), vecimpl::scale(out, s))
}

fn scale_scalar(out: &mut [f32], s: f32) {
    for v in out.iter_mut() {
        *v *= s;
    }
}

// ---------------------------------------------------------------------------
// f16 codec (hand-rolled IEEE binary16 <-> f32 conversions)
// ---------------------------------------------------------------------------

/// Round an f32 to IEEE binary16 bits: round-to-nearest-even, values beyond
/// the f16 range saturate to ±65504 (the largest finite f16) instead of
/// overflowing to infinity, so decoding an encoded page can never introduce
/// non-finite values the f32 path did not have. NaN maps to a quiet NaN.
/// Deterministic — re-encoding the same f32 always yields the same bits,
/// which is what keeps quantized forks byte-identical to fresh prefills.
pub fn f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        return if man == 0 { sign | 0x7C00 } else { sign | 0x7E00 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7BFF; // saturate to the largest finite f16
    }
    if e <= 0 {
        // Subnormal (or zero) in f16: shift the significand (implicit bit
        // included) into place, rounding the dropped bits to nearest-even.
        if e < -10 {
            return sign; // underflows to ±0
        }
        let man = man | 0x0080_0000;
        let shift = (14 - e) as u32; // 14..=24
        let half = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded = match rem.cmp(&halfway) {
            std::cmp::Ordering::Greater => half + 1,
            std::cmp::Ordering::Equal => half + (half & 1),
            std::cmp::Ordering::Less => half,
        };
        return sign | rounded as u16;
    }
    // Normal: top 10 mantissa bits, round-to-nearest-even; a rounding carry
    // propagates into the exponent naturally (0x3FF -> next exponent).
    let half = man >> 13;
    let rem = man & 0x1FFF;
    let mut h = ((e as u32) << 10) | half;
    match rem.cmp(&0x1000) {
        std::cmp::Ordering::Greater => h += 1,
        std::cmp::Ordering::Equal => h += h & 1,
        std::cmp::Ordering::Less => {}
    }
    if (h & 0x7FFF) >= 0x7C00 {
        return sign | 0x7BFF; // rounding carried into infinity: saturate
    }
    sign | h as u16
}

/// Exact IEEE binary16 -> f32 conversion (every f16 value is representable).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    if exp == 0 {
        if man == 0 {
            return f32::from_bits(sign); // ±0
        }
        // Subnormal: renormalize. The leading set bit of `man` (position
        // 10-shift) becomes the implicit bit.
        let shift = man.leading_zeros() - 21; // 1..=10
        let man23 = (man << (13 + shift)) & 0x007F_FFFF;
        return f32::from_bits(sign | ((113 - shift) << 23) | man23);
    }
    if exp == 0x1F {
        return f32::from_bits(sign | 0x7F80_0000 | (man << 13)); // inf / NaN
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

/// Decode logical element `i` of an f16-packed row: two halves per f32
/// word, element `2w` in the low 16 bits of word `w`, `2w+1` in the high.
#[inline]
fn f16_lane(enc: &[f32], i: usize) -> f32 {
    let w = enc[i / 2].to_bits();
    f16_to_f32(if i % 2 == 0 { w as u16 } else { (w >> 16) as u16 })
}

/// Unscaled logical element `i` of an int8-packed row: four two's-complement
/// bytes per f32 word, element `4w+b` in byte `b` (little-endian lanes).
#[inline]
fn i8_lane(enc: &[f32], i: usize) -> f32 {
    ((enc[i / 4].to_bits() >> (8 * (i % 4))) as u8 as i8) as f32
}

// ---------------------------------------------------------------------------
// Dequant-and-score ops (quantized KV pages)
//
// `enc` holds packed words (see `f16_lane`/`i8_lane` for the layouts); the
// logical length is `q.len()` / `out.len()`. Per-lane decoding is exact and
// identical on every backend (bit manipulation for f16, exact i8->f32
// conversion plus one IEEE mul by the row scale for int8), so the
// determinism contract matches the f32 ops: elementwise dequant-axpy is
// bit-identical to scalar, dequant reductions block by element index and
// collapse through the same fixed lane trees.
// ---------------------------------------------------------------------------

/// `Σ q[i]·dec16(enc)[i]` — dot against an f16-packed row.
#[inline]
pub fn dot_dequant_f16(q: &[f32], enc: &[f32]) -> f32 {
    dispatch!(backend(), dot_dequant_f16_scalar(q, enc), vecimpl::dot_dequant_f16(q, enc))
}

/// [`dot_dequant_f16`] on an explicit backend (benches/tests only).
pub fn dot_dequant_f16_with(be: Backend, q: &[f32], enc: &[f32]) -> f32 {
    dispatch!(checked(be), dot_dequant_f16_scalar(q, enc), vecimpl::dot_dequant_f16(q, enc))
}

fn dot_dequant_f16_scalar(q: &[f32], enc: &[f32]) -> f32 {
    let mut s = 0.0;
    for (i, &qi) in q.iter().enumerate() {
        s += qi * f16_lane(enc, i);
    }
    s
}

/// `Σ (q[i]−dec16(enc)[i])²` — Cauchy distance against an f16-packed row.
#[inline]
pub fn sqdist_dequant_f16(q: &[f32], enc: &[f32]) -> f32 {
    dispatch!(backend(), sqdist_dequant_f16_scalar(q, enc), vecimpl::sqdist_dequant_f16(q, enc))
}

/// [`sqdist_dequant_f16`] on an explicit backend (benches/tests only).
pub fn sqdist_dequant_f16_with(be: Backend, q: &[f32], enc: &[f32]) -> f32 {
    dispatch!(checked(be), sqdist_dequant_f16_scalar(q, enc), vecimpl::sqdist_dequant_f16(q, enc))
}

fn sqdist_dequant_f16_scalar(q: &[f32], enc: &[f32]) -> f32 {
    let mut s = 0.0;
    for (i, &qi) in q.iter().enumerate() {
        let d = qi - f16_lane(enc, i);
        s += d * d;
    }
    s
}

/// `out[i] += a·dec16(enc)[i]` — AV-accumulate from an f16-packed row.
#[inline]
pub fn axpy_dequant_f16(out: &mut [f32], a: f32, enc: &[f32]) {
    dispatch!(
        backend(),
        axpy_dequant_f16_scalar(out, a, enc),
        vecimpl::axpy_dequant_f16(out, a, enc)
    )
}

/// [`axpy_dequant_f16`] on an explicit backend (benches/tests only).
pub fn axpy_dequant_f16_with(be: Backend, out: &mut [f32], a: f32, enc: &[f32]) {
    dispatch!(
        checked(be),
        axpy_dequant_f16_scalar(out, a, enc),
        vecimpl::axpy_dequant_f16(out, a, enc)
    )
}

fn axpy_dequant_f16_scalar(out: &mut [f32], a: f32, enc: &[f32]) {
    for (i, o) in out.iter_mut().enumerate() {
        *o += a * f16_lane(enc, i);
    }
}

/// `Σ q[i]·(dec8(enc)[i]·scale)` — dot against an int8-packed row with its
/// per-row scale.
#[inline]
pub fn dot_dequant_i8(q: &[f32], enc: &[f32], scale: f32) -> f32 {
    dispatch!(
        backend(),
        dot_dequant_i8_scalar(q, enc, scale),
        vecimpl::dot_dequant_i8(q, enc, scale)
    )
}

/// [`dot_dequant_i8`] on an explicit backend (benches/tests only).
pub fn dot_dequant_i8_with(be: Backend, q: &[f32], enc: &[f32], scale: f32) -> f32 {
    dispatch!(
        checked(be),
        dot_dequant_i8_scalar(q, enc, scale),
        vecimpl::dot_dequant_i8(q, enc, scale)
    )
}

fn dot_dequant_i8_scalar(q: &[f32], enc: &[f32], scale: f32) -> f32 {
    let mut s = 0.0;
    for (i, &qi) in q.iter().enumerate() {
        s += qi * (i8_lane(enc, i) * scale);
    }
    s
}

/// `Σ (q[i]−dec8(enc)[i]·scale)²` — Cauchy distance against an int8 row.
#[inline]
pub fn sqdist_dequant_i8(q: &[f32], enc: &[f32], scale: f32) -> f32 {
    dispatch!(
        backend(),
        sqdist_dequant_i8_scalar(q, enc, scale),
        vecimpl::sqdist_dequant_i8(q, enc, scale)
    )
}

/// [`sqdist_dequant_i8`] on an explicit backend (benches/tests only).
pub fn sqdist_dequant_i8_with(be: Backend, q: &[f32], enc: &[f32], scale: f32) -> f32 {
    dispatch!(
        checked(be),
        sqdist_dequant_i8_scalar(q, enc, scale),
        vecimpl::sqdist_dequant_i8(q, enc, scale)
    )
}

fn sqdist_dequant_i8_scalar(q: &[f32], enc: &[f32], scale: f32) -> f32 {
    let mut s = 0.0;
    for (i, &qi) in q.iter().enumerate() {
        let d = qi - i8_lane(enc, i) * scale;
        s += d * d;
    }
    s
}

/// `out[i] += a·(dec8(enc)[i]·scale)` — AV-accumulate from an int8 row.
#[inline]
pub fn axpy_dequant_i8(out: &mut [f32], a: f32, enc: &[f32], scale: f32) {
    dispatch!(
        backend(),
        axpy_dequant_i8_scalar(out, a, enc, scale),
        vecimpl::axpy_dequant_i8(out, a, enc, scale)
    )
}

/// [`axpy_dequant_i8`] on an explicit backend (benches/tests only).
pub fn axpy_dequant_i8_with(be: Backend, out: &mut [f32], a: f32, enc: &[f32], scale: f32) {
    dispatch!(
        checked(be),
        axpy_dequant_i8_scalar(out, a, enc, scale),
        vecimpl::axpy_dequant_i8(out, a, enc, scale)
    )
}

fn axpy_dequant_i8_scalar(out: &mut [f32], a: f32, enc: &[f32], scale: f32) {
    for (i, o) in out.iter_mut().enumerate() {
        *o += a * (i8_lane(enc, i) * scale);
    }
}

// ---------------------------------------------------------------------------
// Mamba recurrence step
// ---------------------------------------------------------------------------

/// One SSM channel step: `hrow[s] = decay[s]·hrow[s] + dt·b[s]·x`, returns
/// `Σ c[s]·hrow[s]`. The carried state `hrow` is updated elementwise
/// (bit-identical to scalar on every backend); only the returned readout
/// uses the lane reduction tree.
#[inline]
pub fn ssm_step(decay: &[f32], b: &[f32], c: &[f32], dt: f32, x: f32, hrow: &mut [f32]) -> f32 {
    dispatch!(
        backend(),
        ssm_step_scalar(decay, b, c, dt, x, hrow),
        vecimpl::ssm_step(decay, b, c, dt, x, hrow)
    )
}

/// [`ssm_step`] on an explicit backend (benches/tests only).
pub fn ssm_step_with(
    be: Backend,
    decay: &[f32],
    b: &[f32],
    c: &[f32],
    dt: f32,
    x: f32,
    hrow: &mut [f32],
) -> f32 {
    dispatch!(
        checked(be),
        ssm_step_scalar(decay, b, c, dt, x, hrow),
        vecimpl::ssm_step(decay, b, c, dt, x, hrow)
    )
}

fn ssm_step_scalar(decay: &[f32], b: &[f32], c: &[f32], dt: f32, x: f32, hrow: &mut [f32]) -> f32 {
    let ns = hrow.len();
    let mut acc = 0.0;
    for s in 0..ns {
        hrow[s] = decay[s] * hrow[s] + dt * b[s] * x;
        acc += c[s] * hrow[s];
    }
    acc
}

// ---------------------------------------------------------------------------
// Morton interleave (integer-only: accelerated path is bit-identical)
// ---------------------------------------------------------------------------

/// Interleave the low `bits` bits of each coordinate: bit `b` of coordinate
/// `j` lands at output position `b·d + j`. Scalar mode keeps the seed's
/// bit-by-bit loop; accelerated modes use branch-free magic-shift bit
/// spreading for `d ≤ 3` (the only dims `bits_for_dim` produces codes for
/// in practice), which is bit-identical since everything is integer math.
#[inline]
pub fn interleave(coords: &[u32], bits: u32) -> u32 {
    interleave_with(backend(), coords, bits)
}

/// [`interleave`] on an explicit backend (benches/tests only).
pub fn interleave_with(be: Backend, coords: &[u32], bits: u32) -> u32 {
    if be == Backend::Scalar {
        return interleave_scalar(coords, bits);
    }
    let mask = 1u32.checked_shl(bits).unwrap_or(0).wrapping_sub(1);
    match coords.len() {
        1 => coords[0] & mask,
        2 if bits <= 16 => part1by1(coords[0] & mask) | (part1by1(coords[1] & mask) << 1),
        3 if bits <= 10 => {
            part1by2(coords[0] & mask)
                | (part1by2(coords[1] & mask) << 1)
                | (part1by2(coords[2] & mask) << 2)
        }
        _ => interleave_scalar(coords, bits),
    }
}

/// The seed's reference loop (also the scalar-mode path).
pub fn interleave_scalar(coords: &[u32], bits: u32) -> u32 {
    let d = coords.len();
    let mut z = 0u32;
    for b in 0..bits {
        for (j, &c) in coords.iter().enumerate() {
            z |= ((c >> b) & 1) << (b as usize * d + j);
        }
    }
    z
}

/// Spread the low 16 bits of `x` so bit `i` lands at position `2i`.
fn part1by1(mut x: u32) -> u32 {
    x &= 0x0000_FFFF;
    x = (x ^ (x << 8)) & 0x00FF_00FF;
    x = (x ^ (x << 4)) & 0x0F0F_0F0F;
    x = (x ^ (x << 2)) & 0x3333_3333;
    x = (x ^ (x << 1)) & 0x5555_5555;
    x
}

/// Spread the low 10 bits of `x` so bit `i` lands at position `3i`.
fn part1by2(mut x: u32) -> u32 {
    x &= 0x0000_03FF;
    x = (x ^ (x << 16)) & 0xFF00_00FF;
    x = (x ^ (x << 8)) & 0x0300_F00F;
    x = (x ^ (x << 4)) & 0x030C_30C3;
    x = (x ^ (x << 2)) & 0x0924_9249;
    x
}

// ---------------------------------------------------------------------------
// AVX2 lane implementations
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod vecimpl {
    //! 8 × f32 AVX2 arms. All loads/stores are unaligned; blocking is by
    //! element index so a given length always reduces in the same order.
    //! Every `unsafe fn` here requires AVX2 (guaranteed by the dispatcher).

    use std::arch::x86_64::*;

    const LANES: usize = 8;

    /// Fixed pairwise reduction tree ((l0+l4)+(l1+l5)) + ((l2+l6)+(l3+l7)).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256) -> f32 {
        let mut t = [0f32; LANES];
        _mm256_storeu_ps(t.as_mut_ptr(), v);
        ((t[0] + t[4]) + (t[1] + t[5])) + ((t[2] + t[6]) + (t[3] + t[7]))
    }

    /// # Safety
    /// Caller must guarantee AVX2 is available on the running CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + LANES <= n {
            let va = _mm256_loadu_ps(a.as_ptr().add(i));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
            i += LANES;
        }
        let mut s = hsum(acc);
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    /// # Safety
    /// Caller must guarantee AVX2 is available on the running CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sqdist(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + LANES <= n {
            let va = _mm256_loadu_ps(a.as_ptr().add(i));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i));
            let d = _mm256_sub_ps(va, vb);
            acc = _mm256_add_ps(acc, _mm256_mul_ps(d, d));
            i += LANES;
        }
        let mut s = hsum(acc);
        while i < n {
            let d = a[i] - b[i];
            s += d * d;
            i += 1;
        }
        s
    }

    /// # Safety
    /// Caller must guarantee AVX2 is available on the running CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(out: &mut [f32], a: f32, x: &[f32]) {
        let n = out.len().min(x.len());
        let va = _mm256_set1_ps(a);
        let mut i = 0usize;
        while i + LANES <= n {
            let vx = _mm256_loadu_ps(x.as_ptr().add(i));
            let vo = _mm256_loadu_ps(out.as_ptr().add(i));
            let r = _mm256_add_ps(vo, _mm256_mul_ps(va, vx));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), r);
            i += LANES;
        }
        while i < n {
            out[i] += a * x[i];
            i += 1;
        }
    }

    /// # Safety
    /// Caller must guarantee AVX2 is available on the running CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scale(out: &mut [f32], s: f32) {
        let n = out.len();
        let vs = _mm256_set1_ps(s);
        let mut i = 0usize;
        while i + LANES <= n {
            let vo = _mm256_loadu_ps(out.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(vo, vs));
            i += LANES;
        }
        while i < n {
            out[i] *= s;
            i += 1;
        }
    }

    /// # Safety
    /// Caller must guarantee AVX2 is available on the running CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn ssm_step(
        decay: &[f32],
        b: &[f32],
        c: &[f32],
        dt: f32,
        x: f32,
        hrow: &mut [f32],
    ) -> f32 {
        let ns = hrow.len().min(decay.len()).min(b.len()).min(c.len());
        let vdt = _mm256_set1_ps(dt);
        let vx = _mm256_set1_ps(x);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + LANES <= ns {
            let vb = _mm256_loadu_ps(b.as_ptr().add(i));
            let vd = _mm256_loadu_ps(decay.as_ptr().add(i));
            let vh = _mm256_loadu_ps(hrow.as_ptr().add(i));
            let term = _mm256_mul_ps(_mm256_mul_ps(vdt, vb), vx);
            let hn = _mm256_add_ps(_mm256_mul_ps(vd, vh), term);
            _mm256_storeu_ps(hrow.as_mut_ptr().add(i), hn);
            let vc = _mm256_loadu_ps(c.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(vc, hn));
            i += LANES;
        }
        let mut s = hsum(acc);
        while i < ns {
            hrow[i] = decay[i] * hrow[i] + dt * b[i] * x;
            s += c[i] * hrow[i];
            i += 1;
        }
        s
    }

    /// Decode one lane block of an f16-packed row into `buf`. The per-lane
    /// conversion is the scalar bit-exact decode (no F16C dependency — AVX2
    /// does not imply it); the arithmetic and reduction tree downstream are
    /// the same vector ops as the f32 arms.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn load_f16x8(enc: &[f32], i: usize, buf: &mut [f32; LANES]) -> __m256 {
        for (l, b) in buf.iter_mut().enumerate() {
            *b = super::f16_lane(enc, i + l);
        }
        _mm256_loadu_ps(buf.as_ptr())
    }

    /// Load 8 consecutive int8 elements starting at element `i` (a multiple
    /// of 8, so two whole packed words) and widen to f32 exactly. x86 is
    /// little-endian, so the packed u32 words are byte-contiguous.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn load_i8x8(enc: &[f32], i: usize) -> __m256 {
        let p = (enc.as_ptr() as *const u8).add(i);
        let v8 = _mm_loadl_epi64(p as *const __m128i);
        _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(v8))
    }

    /// # Safety
    /// Caller must guarantee AVX2 is available on the running CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_dequant_f16(q: &[f32], enc: &[f32]) -> f32 {
        let n = q.len();
        let mut acc = _mm256_setzero_ps();
        let mut buf = [0f32; LANES];
        let mut i = 0usize;
        while i + LANES <= n {
            let vx = load_f16x8(enc, i, &mut buf);
            let vq = _mm256_loadu_ps(q.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(vq, vx));
            i += LANES;
        }
        let mut s = hsum(acc);
        while i < n {
            s += q[i] * super::f16_lane(enc, i);
            i += 1;
        }
        s
    }

    /// # Safety
    /// Caller must guarantee AVX2 is available on the running CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sqdist_dequant_f16(q: &[f32], enc: &[f32]) -> f32 {
        let n = q.len();
        let mut acc = _mm256_setzero_ps();
        let mut buf = [0f32; LANES];
        let mut i = 0usize;
        while i + LANES <= n {
            let vx = load_f16x8(enc, i, &mut buf);
            let vq = _mm256_loadu_ps(q.as_ptr().add(i));
            let d = _mm256_sub_ps(vq, vx);
            acc = _mm256_add_ps(acc, _mm256_mul_ps(d, d));
            i += LANES;
        }
        let mut s = hsum(acc);
        while i < n {
            let d = q[i] - super::f16_lane(enc, i);
            s += d * d;
            i += 1;
        }
        s
    }

    /// # Safety
    /// Caller must guarantee AVX2 is available on the running CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_dequant_f16(out: &mut [f32], a: f32, enc: &[f32]) {
        let n = out.len();
        let va = _mm256_set1_ps(a);
        let mut buf = [0f32; LANES];
        let mut i = 0usize;
        while i + LANES <= n {
            let vx = load_f16x8(enc, i, &mut buf);
            let vo = _mm256_loadu_ps(out.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_add_ps(vo, _mm256_mul_ps(va, vx)));
            i += LANES;
        }
        while i < n {
            out[i] += a * super::f16_lane(enc, i);
            i += 1;
        }
    }

    /// # Safety
    /// Caller must guarantee AVX2 is available on the running CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_dequant_i8(q: &[f32], enc: &[f32], scale: f32) -> f32 {
        let n = q.len();
        let vs = _mm256_set1_ps(scale);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + LANES <= n {
            let vx = _mm256_mul_ps(load_i8x8(enc, i), vs);
            let vq = _mm256_loadu_ps(q.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(vq, vx));
            i += LANES;
        }
        let mut s = hsum(acc);
        while i < n {
            s += q[i] * (super::i8_lane(enc, i) * scale);
            i += 1;
        }
        s
    }

    /// # Safety
    /// Caller must guarantee AVX2 is available on the running CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sqdist_dequant_i8(q: &[f32], enc: &[f32], scale: f32) -> f32 {
        let n = q.len();
        let vs = _mm256_set1_ps(scale);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + LANES <= n {
            let vx = _mm256_mul_ps(load_i8x8(enc, i), vs);
            let vq = _mm256_loadu_ps(q.as_ptr().add(i));
            let d = _mm256_sub_ps(vq, vx);
            acc = _mm256_add_ps(acc, _mm256_mul_ps(d, d));
            i += LANES;
        }
        let mut s = hsum(acc);
        while i < n {
            let d = q[i] - super::i8_lane(enc, i) * scale;
            s += d * d;
            i += 1;
        }
        s
    }

    /// # Safety
    /// Caller must guarantee AVX2 is available on the running CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_dequant_i8(out: &mut [f32], a: f32, enc: &[f32], scale: f32) {
        let n = out.len();
        let va = _mm256_set1_ps(a);
        let vs = _mm256_set1_ps(scale);
        let mut i = 0usize;
        while i + LANES <= n {
            let vx = _mm256_mul_ps(load_i8x8(enc, i), vs);
            let vo = _mm256_loadu_ps(out.as_ptr().add(i));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_add_ps(vo, _mm256_mul_ps(va, vx)));
            i += LANES;
        }
        while i < n {
            out[i] += a * (super::i8_lane(enc, i) * scale);
            i += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// NEON lane implementations
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod vecimpl {
    //! 4 × f32 NEON arms; same blocking and reduction-tree conventions as
    //! the AVX2 module.

    use std::arch::aarch64::*;

    const LANES: usize = 4;

    /// Fixed pairwise reduction tree (l0+l2) + (l1+l3).
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn hsum(v: float32x4_t) -> f32 {
        let mut t = [0f32; LANES];
        vst1q_f32(t.as_mut_ptr(), v);
        (t[0] + t[2]) + (t[1] + t[3])
    }

    /// # Safety
    /// Caller must guarantee NEON is available on the running CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + LANES <= n {
            let va = vld1q_f32(a.as_ptr().add(i));
            let vb = vld1q_f32(b.as_ptr().add(i));
            acc = vaddq_f32(acc, vmulq_f32(va, vb));
            i += LANES;
        }
        let mut s = hsum(acc);
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    /// # Safety
    /// Caller must guarantee NEON is available on the running CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn sqdist(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len().min(b.len());
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + LANES <= n {
            let va = vld1q_f32(a.as_ptr().add(i));
            let vb = vld1q_f32(b.as_ptr().add(i));
            let d = vsubq_f32(va, vb);
            acc = vaddq_f32(acc, vmulq_f32(d, d));
            i += LANES;
        }
        let mut s = hsum(acc);
        while i < n {
            let d = a[i] - b[i];
            s += d * d;
            i += 1;
        }
        s
    }

    /// # Safety
    /// Caller must guarantee NEON is available on the running CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(out: &mut [f32], a: f32, x: &[f32]) {
        let n = out.len().min(x.len());
        let va = vdupq_n_f32(a);
        let mut i = 0usize;
        while i + LANES <= n {
            let vx = vld1q_f32(x.as_ptr().add(i));
            let vo = vld1q_f32(out.as_ptr().add(i));
            vst1q_f32(out.as_mut_ptr().add(i), vaddq_f32(vo, vmulq_f32(va, vx)));
            i += LANES;
        }
        while i < n {
            out[i] += a * x[i];
            i += 1;
        }
    }

    /// # Safety
    /// Caller must guarantee NEON is available on the running CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn scale(out: &mut [f32], s: f32) {
        let n = out.len();
        let vs = vdupq_n_f32(s);
        let mut i = 0usize;
        while i + LANES <= n {
            let vo = vld1q_f32(out.as_ptr().add(i));
            vst1q_f32(out.as_mut_ptr().add(i), vmulq_f32(vo, vs));
            i += LANES;
        }
        while i < n {
            out[i] *= s;
            i += 1;
        }
    }

    /// # Safety
    /// Caller must guarantee NEON is available on the running CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn ssm_step(
        decay: &[f32],
        b: &[f32],
        c: &[f32],
        dt: f32,
        x: f32,
        hrow: &mut [f32],
    ) -> f32 {
        let ns = hrow.len().min(decay.len()).min(b.len()).min(c.len());
        let vdt = vdupq_n_f32(dt);
        let vx = vdupq_n_f32(x);
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + LANES <= ns {
            let vb = vld1q_f32(b.as_ptr().add(i));
            let vd = vld1q_f32(decay.as_ptr().add(i));
            let vh = vld1q_f32(hrow.as_ptr().add(i));
            let term = vmulq_f32(vmulq_f32(vdt, vb), vx);
            let hn = vaddq_f32(vmulq_f32(vd, vh), term);
            vst1q_f32(hrow.as_mut_ptr().add(i), hn);
            let vc = vld1q_f32(c.as_ptr().add(i));
            acc = vaddq_f32(acc, vmulq_f32(vc, hn));
            i += LANES;
        }
        let mut s = hsum(acc);
        while i < ns {
            hrow[i] = decay[i] * hrow[i] + dt * b[i] * x;
            s += c[i] * hrow[i];
            i += 1;
        }
        s
    }

    /// Decode one lane block of an f16-packed row into `buf` (scalar
    /// bit-exact decode per lane, vector math downstream — same contract as
    /// the AVX2 module).
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn load_f16x4(enc: &[f32], i: usize, buf: &mut [f32; LANES]) -> float32x4_t {
        for (l, b) in buf.iter_mut().enumerate() {
            *b = super::f16_lane(enc, i + l);
        }
        vld1q_f32(buf.as_ptr())
    }

    /// Widen the 4 int8 elements of one packed word to f32 exactly. `i` is
    /// a multiple of 4 inside the blocked loops, so the block is exactly
    /// word `i / 4`.
    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn i8x4_to_f32(w: u32) -> float32x4_t {
        let v8 = vcreate_s8(w as u64);
        let v16 = vget_low_s16(vmovl_s8(v8));
        vcvtq_f32_s32(vmovl_s16(v16))
    }

    /// # Safety
    /// Caller must guarantee NEON is available on the running CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_dequant_f16(q: &[f32], enc: &[f32]) -> f32 {
        let n = q.len();
        let mut acc = vdupq_n_f32(0.0);
        let mut buf = [0f32; LANES];
        let mut i = 0usize;
        while i + LANES <= n {
            let vx = load_f16x4(enc, i, &mut buf);
            let vq = vld1q_f32(q.as_ptr().add(i));
            acc = vaddq_f32(acc, vmulq_f32(vq, vx));
            i += LANES;
        }
        let mut s = hsum(acc);
        while i < n {
            s += q[i] * super::f16_lane(enc, i);
            i += 1;
        }
        s
    }

    /// # Safety
    /// Caller must guarantee NEON is available on the running CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn sqdist_dequant_f16(q: &[f32], enc: &[f32]) -> f32 {
        let n = q.len();
        let mut acc = vdupq_n_f32(0.0);
        let mut buf = [0f32; LANES];
        let mut i = 0usize;
        while i + LANES <= n {
            let vx = load_f16x4(enc, i, &mut buf);
            let vq = vld1q_f32(q.as_ptr().add(i));
            let d = vsubq_f32(vq, vx);
            acc = vaddq_f32(acc, vmulq_f32(d, d));
            i += LANES;
        }
        let mut s = hsum(acc);
        while i < n {
            let d = q[i] - super::f16_lane(enc, i);
            s += d * d;
            i += 1;
        }
        s
    }

    /// # Safety
    /// Caller must guarantee NEON is available on the running CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_dequant_f16(out: &mut [f32], a: f32, enc: &[f32]) {
        let n = out.len();
        let va = vdupq_n_f32(a);
        let mut buf = [0f32; LANES];
        let mut i = 0usize;
        while i + LANES <= n {
            let vx = load_f16x4(enc, i, &mut buf);
            let vo = vld1q_f32(out.as_ptr().add(i));
            vst1q_f32(out.as_mut_ptr().add(i), vaddq_f32(vo, vmulq_f32(va, vx)));
            i += LANES;
        }
        while i < n {
            out[i] += a * super::f16_lane(enc, i);
            i += 1;
        }
    }

    /// # Safety
    /// Caller must guarantee NEON is available on the running CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_dequant_i8(q: &[f32], enc: &[f32], scale: f32) -> f32 {
        let n = q.len();
        let vs = vdupq_n_f32(scale);
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + LANES <= n {
            let vx = vmulq_f32(i8x4_to_f32(enc[i / 4].to_bits()), vs);
            let vq = vld1q_f32(q.as_ptr().add(i));
            acc = vaddq_f32(acc, vmulq_f32(vq, vx));
            i += LANES;
        }
        let mut s = hsum(acc);
        while i < n {
            s += q[i] * (super::i8_lane(enc, i) * scale);
            i += 1;
        }
        s
    }

    /// # Safety
    /// Caller must guarantee NEON is available on the running CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn sqdist_dequant_i8(q: &[f32], enc: &[f32], scale: f32) -> f32 {
        let n = q.len();
        let vs = vdupq_n_f32(scale);
        let mut acc = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + LANES <= n {
            let vx = vmulq_f32(i8x4_to_f32(enc[i / 4].to_bits()), vs);
            let vq = vld1q_f32(q.as_ptr().add(i));
            let d = vsubq_f32(vq, vx);
            acc = vaddq_f32(acc, vmulq_f32(d, d));
            i += LANES;
        }
        let mut s = hsum(acc);
        while i < n {
            let d = q[i] - super::i8_lane(enc, i) * scale;
            s += d * d;
            i += 1;
        }
        s
    }

    /// # Safety
    /// Caller must guarantee NEON is available on the running CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_dequant_i8(out: &mut [f32], a: f32, enc: &[f32], scale: f32) {
        let n = out.len();
        let va = vdupq_n_f32(a);
        let vs = vdupq_n_f32(scale);
        let mut i = 0usize;
        while i + LANES <= n {
            let vx = vmulq_f32(i8x4_to_f32(enc[i / 4].to_bits()), vs);
            let vo = vld1q_f32(out.as_ptr().add(i));
            vst1q_f32(out.as_mut_ptr().add(i), vaddq_f32(vo, vmulq_f32(va, vx)));
            i += LANES;
        }
        while i < n {
            out[i] += a * (super::i8_lane(enc, i) * scale);
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn backend_is_consistent() {
        let be = backend();
        assert!(be.available());
        assert_eq!(be.name(), backend_name());
        assert_eq!(be.lanes(), lanes());
        match be.name() {
            "scalar" => assert_eq!(be.lanes(), 1),
            "avx2" => assert_eq!(be.lanes(), 8),
            "neon" => assert_eq!(be.lanes(), 4),
            other => panic!("unknown backend {other}"),
        }
    }

    #[test]
    fn scalar_arms_are_seed_exact() {
        // The seed's tensor tests pin these exact values; the scalar arms
        // must keep them bit-for-bit.
        assert_eq!(dot_with(Backend::Scalar, &[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(sqdist_with(Backend::Scalar, &[1.0, 2.0], &[1.0, 4.0]), 4.0);
    }

    #[test]
    fn reductions_match_scalar_at_every_remainder() {
        // n = lane·m + r for every remainder r (two full blocks worth).
        let be = backend();
        let mut rng = Rng::new(0x51D0);
        for n in 0..(2 * be.lanes().max(4) + 3) {
            let mut a = vec![0f32; n];
            let mut b = vec![0f32; n];
            rng.fill_normal(&mut a, 1.0);
            rng.fill_normal(&mut b, 1.0);
            let (ds, dv) = (dot_with(Backend::Scalar, &a, &b), dot_with(be, &a, &b));
            assert!((ds - dv).abs() <= 1e-4 * (1.0 + ds.abs()), "dot n={n}: {ds} vs {dv}");
            let (ss, sv) = (sqdist_with(Backend::Scalar, &a, &b), sqdist_with(be, &a, &b));
            assert!((ss - sv).abs() <= 1e-4 * (1.0 + ss.abs()), "sqdist n={n}: {ss} vs {sv}");
        }
    }

    #[test]
    fn elementwise_ops_are_bit_identical_to_scalar() {
        let be = backend();
        let mut rng = Rng::new(0x51D1);
        for n in 0..20 {
            let mut x = vec![0f32; n];
            let mut o = vec![0f32; n];
            rng.fill_normal(&mut x, 1.0);
            rng.fill_normal(&mut o, 1.0);
            let (mut o1, mut o2) = (o.clone(), o.clone());
            axpy_with(Backend::Scalar, &mut o1, 0.37, &x);
            axpy_with(be, &mut o2, 0.37, &x);
            assert_eq!(o1, o2, "axpy n={n}");
            scale_with(Backend::Scalar, &mut o1, 1.7);
            scale_with(be, &mut o2, 1.7);
            assert_eq!(o1, o2, "scale n={n}");
        }
    }

    #[test]
    fn ssm_step_state_bitwise_readout_close() {
        let be = backend();
        let mut rng = Rng::new(0x51D2);
        for ns in [1usize, 3, 4, 7, 8, 11, 16, 33] {
            let mut b = vec![0f32; ns];
            let mut c = vec![0f32; ns];
            let mut h = vec![0f32; ns];
            rng.fill_normal(&mut b, 1.0);
            rng.fill_normal(&mut c, 1.0);
            rng.fill_normal(&mut h, 1.0);
            let mut decay = vec![0f32; ns];
            for (s, d) in decay.iter_mut().enumerate() {
                *d = (-0.3 * (s + 1) as f32 / ns as f32).exp();
            }
            let (mut h1, mut h2) = (h.clone(), h.clone());
            let y1 = ssm_step_with(Backend::Scalar, &decay, &b, &c, 0.3, 0.9, &mut h1);
            let y2 = ssm_step_with(be, &decay, &b, &c, 0.3, 0.9, &mut h2);
            assert_eq!(h1, h2, "carried state must be bit-identical (ns={ns})");
            assert!((y1 - y2).abs() <= 1e-4 * (1.0 + y1.abs()), "ns={ns}: {y1} vs {y2}");
        }
    }

    #[test]
    fn interleave_fast_path_is_bit_identical() {
        let be = backend();
        prop::check(200, 0x51D3, |rng| {
            let d = 1 + rng.usize_below(4);
            let bits = crate::zorder::bits_for_dim(d);
            let coords: Vec<u32> = (0..d).map(|_| rng.next_u32()).collect();
            let a = interleave_scalar(
                &coords.iter().map(|&c| c & ((1 << bits) - 1)).collect::<Vec<_>>(),
                bits,
            );
            // The fast path masks internally; feed it unmasked coords too.
            let masked: Vec<u32> = coords.iter().map(|&c| c & ((1 << bits) - 1)).collect();
            let b = interleave_with(be, &masked, bits);
            prop::assert_eq_prop(&a, &b)
        });
    }

    fn pack_f16_row(row: &[f32]) -> Vec<f32> {
        let mut enc = vec![0f32; row.len().div_ceil(2)];
        for (i, &x) in row.iter().enumerate() {
            let w = enc[i / 2].to_bits() | ((f16_bits(x) as u32) << (16 * (i % 2)));
            enc[i / 2] = f32::from_bits(w);
        }
        enc
    }

    fn pack_i8_row(row: &[f32]) -> (Vec<f32>, f32) {
        let maxabs = row.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let scale = maxabs / 127.0;
        let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
        let mut enc = vec![0f32; row.len().div_ceil(4)];
        for (i, &x) in row.iter().enumerate() {
            let q = (x * inv).round().clamp(-127.0, 127.0) as i8;
            let w = enc[i / 4].to_bits() | (((q as u8) as u32) << (8 * (i % 4)));
            enc[i / 4] = f32::from_bits(w);
        }
        (enc, scale)
    }

    #[test]
    fn f16_codec_round_trips_all_finite_patterns() {
        // Every finite f16 is exactly representable in f32, so decode→encode
        // must be the identity over the whole finite bit space.
        for h in 0..=u16::MAX {
            if (h >> 10) & 0x1F == 0x1F {
                continue; // inf / NaN payloads don't round-trip by design
            }
            let x = f16_to_f32(h);
            assert_eq!(f16_bits(x), h, "h={h:#06x} x={x}");
        }
    }

    #[test]
    fn f16_codec_pins_known_values() {
        assert_eq!(f16_bits(1.0), 0x3C00);
        assert_eq!(f16_bits(0.5), 0x3800);
        assert_eq!(f16_bits(-2.5), 0xC100);
        assert_eq!(f16_bits(65504.0), 0x7BFF);
        // Finite overflow saturates to the largest finite f16, never inf.
        assert_eq!(f16_bits(1e9), 0x7BFF);
        assert_eq!(f16_bits(-1e9), 0xFBFF);
        assert_eq!(f16_bits(f32::NAN), 0x7E00);
        assert_eq!(f16_to_f32(0x0001), 2.0f32.powi(-24));
        assert_eq!(f16_bits(-0.0).to_be_bytes(), [0x80, 0x00]);
    }

    #[test]
    fn scalar_dequant_matches_explicit_decode() {
        let mut rng = Rng::new(0x51D5);
        for n in [1usize, 5, 8, 13] {
            let mut q = vec![0f32; n];
            let mut row = vec![0f32; n];
            rng.fill_normal(&mut q, 1.0);
            rng.fill_normal(&mut row, 1.0);
            let f16 = pack_f16_row(&row);
            let (i8e, scale) = pack_i8_row(&row);
            let dec16: Vec<f32> = row.iter().map(|&x| f16_to_f32(f16_bits(x))).collect();
            let dec8: Vec<f32> = row
                .iter()
                .map(|&x| {
                    let inv = if scale > 0.0 { 1.0 / scale } else { 0.0 };
                    ((x * inv).round().clamp(-127.0, 127.0) as i8) as f32 * scale
                })
                .collect();
            let mut s16 = 0f32;
            let mut s8 = 0f32;
            for i in 0..n {
                s16 += q[i] * dec16[i];
                s8 += q[i] * dec8[i];
            }
            assert_eq!(dot_dequant_f16_with(Backend::Scalar, &q, &f16), s16, "f16 n={n}");
            assert_eq!(dot_dequant_i8_with(Backend::Scalar, &q, &i8e, scale), s8, "i8 n={n}");
        }
    }

    #[test]
    fn dequant_reductions_match_scalar_at_every_remainder() {
        let be = backend();
        let mut rng = Rng::new(0x51D4);
        for n in 1..(2 * be.lanes().max(4) + 3) {
            let mut q = vec![0f32; n];
            let mut row = vec![0f32; n];
            rng.fill_normal(&mut q, 1.0);
            rng.fill_normal(&mut row, 1.0);
            let f16 = pack_f16_row(&row);
            let (i8e, scale) = pack_i8_row(&row);
            let close = |tag: &str, s: f32, v: f32| {
                assert!((s - v).abs() <= 1e-4 * (1.0 + s.abs()), "{tag} n={n}: {s} vs {v}");
            };
            let s = dot_dequant_f16_with(Backend::Scalar, &q, &f16);
            close("dot_f16", s, dot_dequant_f16_with(be, &q, &f16));
            let s = sqdist_dequant_f16_with(Backend::Scalar, &q, &f16);
            close("sqdist_f16", s, sqdist_dequant_f16_with(be, &q, &f16));
            let s = dot_dequant_i8_with(Backend::Scalar, &q, &i8e, scale);
            close("dot_i8", s, dot_dequant_i8_with(be, &q, &i8e, scale));
            let s = sqdist_dequant_i8_with(Backend::Scalar, &q, &i8e, scale);
            close("sqdist_i8", s, sqdist_dequant_i8_with(be, &q, &i8e, scale));
        }
    }

    #[test]
    fn dequant_axpy_is_bit_identical_to_scalar() {
        let be = backend();
        let mut rng = Rng::new(0x51D6);
        for n in 1..(2 * be.lanes().max(4) + 3) {
            let mut row = vec![0f32; n];
            let mut o = vec![0f32; n];
            rng.fill_normal(&mut row, 1.0);
            rng.fill_normal(&mut o, 1.0);
            let f16 = pack_f16_row(&row);
            let (i8e, scale) = pack_i8_row(&row);
            let (mut o1, mut o2) = (o.clone(), o.clone());
            axpy_dequant_f16_with(Backend::Scalar, &mut o1, 0.37, &f16);
            axpy_dequant_f16_with(be, &mut o2, 0.37, &f16);
            assert_eq!(o1, o2, "axpy_f16 n={n}");
            axpy_dequant_i8_with(Backend::Scalar, &mut o1, 0.37, &i8e, scale);
            axpy_dequant_i8_with(be, &mut o2, 0.37, &i8e, scale);
            assert_eq!(o1, o2, "axpy_i8 n={n}");
        }
    }

    #[test]
    fn unavailable_backend_falls_back_to_scalar() {
        // `checked` must route any backend the CPU lacks to the scalar arm;
        // with the process backend it is the identity.
        let be = backend();
        assert_eq!(checked(be), be);
        assert_eq!(checked(Backend::Scalar), Backend::Scalar);
        assert_eq!(dot_with(Backend::Scalar, &[2.0, 3.0], &[4.0, 5.0]), 23.0);
    }
}
