//! Std-only utility substrates (the offline build has no third-party crates
//! beyond the `xla` stub and `anyhow`): JSON, PRNG, property tests,
//! benchmarking, the shared worker pool every parallel kernel runs on, and
//! the SIMD dispatch layer every kernel inner loop runs through.

pub mod arena;
pub mod bench;
pub mod breakeven;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod simd;
