//! Std-only utility substrates (the offline build has no third-party crates
//! beyond the `xla` stub and `anyhow`): JSON, PRNG, property tests,
//! benchmarking, and the shared worker pool every parallel kernel runs on.

pub mod arena;
pub mod bench;
pub mod breakeven;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
