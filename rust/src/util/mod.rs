//! Std-only utility substrates (the offline build has no third-party crates
//! beyond `xla`/`anyhow`): JSON, PRNG, property testing, benchmarking.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
