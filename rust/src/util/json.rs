//! Minimal JSON parser/serializer (std-only).
//!
//! The offline build environment ships no serde, so the manifest reader and
//! every experiment report uses this module. It supports the full JSON value
//! model with the restrictions that suit our use: numbers are f64, strings
//! support the standard escapes (\uXXXX included, surrogate pairs folded).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; returns Null for missing keys (chains nicely).
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
}

// ---------------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or("unterminated string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or("unterminated escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    0x10000 + (((hi - 0xD800) as u32) << 10)
                                        + (lo - 0xDC00) as u32
                                } else {
                                    return Err("lone surrogate".into());
                                }
                            } else {
                                hi as u32
                            };
                            out.push(char::from_u32(cp).ok_or("bad codepoint")?);
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting at c.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err("truncated utf-8".into());
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, String> {
        if self.i + 4 > self.b.len() {
            return Err("truncated \\u escape".into());
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4]).map_err(|e| e.to_string())?;
        self.i += 4;
        u16::from_str_radix(s, 16).map_err(|e| e.to_string())
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>().map(Json::Num).map_err(|e| e.to_string())
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// Serialization
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("c"));
        assert_eq!(v.get("d"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn parse_unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x\"y"],"n":-7,"o":{"t":true}}"#;
        let v = parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
    }
}
