//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Measures wall-clock with warmup, reports median / mean / MAD over
//! repeated samples, and supports a target measurement budget so big and
//! small workloads both get stable numbers. Used by `rust/benches/*` and
//! the `zeta exp table3` harness.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub samples: usize,
    /// Median per-iteration time in seconds.
    pub median_s: f64,
    pub mean_s: f64,
    /// Median absolute deviation (robust spread).
    pub mad_s: f64,
    pub min_s: f64,
}

impl Stats {
    pub fn median_ms(&self) -> f64 {
        self.median_s * 1e3
    }

    pub fn median_us(&self) -> f64 {
        self.median_s * 1e6
    }
}

/// Benchmark `f`, aiming for `budget` of total measurement time with at
/// least `min_samples` samples. `f` runs once per sample; use closures that
/// capture pre-built inputs. Returns robust statistics.
pub fn bench<F: FnMut()>(budget: Duration, min_samples: usize, mut f: F) -> Stats {
    // Warmup: one run, plus more until 10% of budget or 3 runs.
    let warm_start = Instant::now();
    let mut warmups = 0;
    while warmups < 3 || (warm_start.elapsed() < budget / 10 && warmups < 50) {
        f();
        warmups += 1;
        if warm_start.elapsed() > budget / 2 {
            break;
        }
    }

    let mut times = Vec::new();
    let start = Instant::now();
    while times.len() < min_samples || (start.elapsed() < budget && times.len() < 10_000) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
        if times.len() >= min_samples && start.elapsed() >= budget {
            break;
        }
    }
    stats_from(&mut times)
}

/// Quick preset: 300 ms budget, >= 5 samples.
pub fn quick<F: FnMut()>(f: F) -> Stats {
    bench(Duration::from_millis(300), 5, f)
}

fn stats_from(times: &mut [f64]) -> Stats {
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = times.len();
    let median = times[n / 2];
    let mean = times.iter().sum::<f64>() / n as f64;
    let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Stats {
        samples: n,
        median_s: median,
        mean_s: mean,
        mad_s: devs[n / 2],
        min_s: times[0],
    }
}

/// Format seconds in a human unit.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2} µs", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep() {
        let st = bench(Duration::from_millis(60), 3, || {
            std::thread::sleep(Duration::from_millis(5));
        });
        assert!(st.samples >= 3);
        assert!(st.median_s >= 0.004, "median {}", st.median_s);
        assert!(st.median_s < 0.05, "median {}", st.median_s);
    }

    #[test]
    fn stats_median_robust() {
        let mut t = vec![1.0, 1.0, 1.0, 100.0];
        let s = stats_from(&mut t);
        assert_eq!(s.median_s, 1.0);
        assert!(s.mean_s > 20.0);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
