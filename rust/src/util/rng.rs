//! Deterministic PRNG (std-only): SplitMix64 seeding + xoshiro256++ core.
//!
//! Every data generator, experiment and property test in the crate draws
//! from this generator so runs are exactly reproducible from a u64 seed.

/// xoshiro256++ with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (for per-worker / per-epoch splits).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill with i.i.d. N(0, sigma^2).
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * sigma;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.usize_below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        self.shuffle(&mut out);
        out
    }

    /// Zipf-distributed value in [0, n) with exponent `s` (rejection-free
    /// inverse-CDF over a precomputed table is overkill; this uses the
    /// standard rejection sampler).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Rejection method of Devroye for Zipf on {1..n}.
        let n_f = n as f64;
        loop {
            let u = self.f64();
            let v = self.f64();
            let x = ((n_f.powf(1.0 - s) - 1.0) * u + 1.0).powf(1.0 / (1.0 - s));
            let k = x.floor().max(1.0);
            let ratio = (k / x).powf(s) * (1.0 + 1.0 / x).powf(0.0);
            if v * k.powf(s) * ((1.0 + 1.0 / k).powf(1.0 - s) - 1.0)
                <= ratio * (2f64.powf(1.0 - s) - 1.0)
            {
                return (k as usize - 1).min(n - 1);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Rng::new(5);
        for _ in 0..50 {
            let s = r.sample_distinct(20, 8);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 8);
            assert!(s.iter().all(|&x| x < 20));
        }
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 16];
        for _ in 0..4000 {
            counts[r.zipf(16, 1.2)] += 1;
        }
        assert!(counts[0] > counts[8], "{counts:?}");
        assert!(counts[0] > 400);
    }

    #[test]
    fn fork_streams_differ() {
        let mut a = Rng::new(11);
        let mut f1 = a.fork(1);
        let mut f2 = a.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
