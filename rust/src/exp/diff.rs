//! `zeta bench diff` — regression triage between two `BENCH_*.json`
//! perf-trajectory envelopes.
//!
//! Every [`super::write_bench`] file carries a provenance header precisely
//! so two trajectories can be compared honestly: `diff` refuses files
//! recorded at different thread counts, SIMD backends, or KV codecs
//! (`git_rev` is *expected* to differ — that is the point of a diff).
//! Rows pair up by their identity fields (every string field such as
//! `scenario` / `kernel` / `bench` / `source`, plus the configuration
//! numerics in [`ID_NUMS`]); shared metric fields then diff directionally
//! — throughput-like metrics regress when they *fall*, latency-like
//! metrics when they *rise* — and `--fail-above <pct>` turns the worst
//! regression into a non-zero exit for CI.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Numeric row fields that are configuration axes, not measurements —
/// they join the string fields in a row's identity key.
const ID_NUMS: &[&str] = &[
    "n", "threads", "seed", "lanes", "draft_len", "kv_mem_budget", "requests", "d", "dv", "page",
    "chunk", "batch", "k", "window", "ctx", "sessions", "prompt_len",
];

/// How a numeric field diffs. Identity-key fields never reach this.
enum Direction {
    /// Throughput-like: a drop is a regression.
    HigherBetter,
    /// Latency/size-like: a rise is a regression.
    LowerBetter,
    /// Deterministic counter (tokens, hits, evictions…): changes are
    /// reported but never gate `--fail-above`.
    Counter,
}

fn direction(key: &str) -> Direction {
    const HIGHER: &[&str] = &["per_sec", "speedup", "accept_rate", "gbps", "throughput"];
    const LOWER: &[&str] = &["_ns", "_us", "_ms", "ns_per", "us_per", "ms_per", "wall", "_mb"];
    if HIGHER.iter().any(|m| key.contains(m)) {
        Direction::HigherBetter
    } else if LOWER.iter().any(|m| key.contains(m)) {
        Direction::LowerBetter
    } else {
        Direction::Counter
    }
}

struct Bench {
    provenance: Json,
    rows: Vec<Json>,
}

fn load(path: &str) -> Result<Bench> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let doc = json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    if doc.get("provenance").as_obj().is_none() {
        bail!("{path}: no provenance header — not a BENCH_*.json envelope");
    }
    let rows = doc
        .get("rows")
        .as_arr()
        .with_context(|| format!("{path}: no rows array"))?
        .to_vec();
    Ok(Bench { provenance: doc.get("provenance").clone(), rows })
}

/// The row-matching key: every string field plus the [`ID_NUMS`]
/// numerics, in sorted-key order. Digest fields are skipped entirely so
/// an intentional stream change still diffs the row's timing.
fn identity(row: &Json) -> String {
    let mut parts: Vec<String> = Vec::new();
    if let Some(obj) = row.as_obj() {
        for (k, v) in obj {
            if k.contains("digest") {
                continue;
            }
            match v {
                Json::Str(s) => parts.push(format!("{k}={s}")),
                Json::Num(n) if ID_NUMS.contains(&k.as_str()) => parts.push(format!("{k}={n}")),
                _ => {}
            }
        }
    }
    parts.join(" ")
}

/// Diff `new_path` against `old_path`. Returns `Ok(true)` when the worst
/// directional regression stays within `fail_above` percent (always true
/// when no threshold is given); the caller maps `false` to exit code 1.
pub fn bench_diff(old_path: &str, new_path: &str, fail_above: Option<f64>) -> Result<bool> {
    let old = load(old_path)?;
    let new = load(new_path)?;
    for key in ["threads", "zeta_simd", "kv_quant"] {
        let (a, b) = (old.provenance.get(key), new.provenance.get(key));
        if a != b {
            bail!(
                "refusing to diff: provenance {key} differs ({a} vs {b}) — trajectories \
                 from different {key} settings are not comparable"
            );
        }
    }
    println!(
        "bench diff: {old_path} (rev {}) -> {new_path} (rev {})",
        old.provenance.get("git_rev").as_str().unwrap_or("unknown"),
        new.provenance.get("git_rev").as_str().unwrap_or("unknown")
    );

    let mut old_rows: BTreeMap<String, &Json> = BTreeMap::new();
    for r in &old.rows {
        old_rows.insert(identity(r), r);
    }
    let mut matched = 0usize;
    let mut added: Vec<String> = Vec::new();
    // Worst directional regression in percent (positive = got worse).
    let mut worst: Option<(f64, String)> = None;
    for r in &new.rows {
        let id = identity(r);
        let Some(o) = old_rows.remove(&id) else {
            added.push(id);
            continue;
        };
        matched += 1;
        let (Some(nobj), Some(oobj)) = (r.as_obj(), o.as_obj()) else {
            continue;
        };
        for (key, nval) in nobj {
            let (Some(nv), Some(ov)) = (nval.as_f64(), oobj.get(key).and_then(Json::as_f64))
            else {
                continue;
            };
            if ov.abs() < 1e-12 {
                continue; // no baseline to take a percentage of
            }
            let delta_pct = (nv - ov) / ov * 100.0;
            match direction(key) {
                Direction::Counter => {
                    if nv != ov {
                        println!("  {id} :: {key}: {ov} -> {nv}");
                    }
                }
                dir => {
                    let regress = match dir {
                        Direction::HigherBetter => -delta_pct,
                        _ => delta_pct,
                    };
                    let verdict = if regress > 0.0 { "worse" } else { "better" };
                    println!("  {id} :: {key}: {ov:.3} -> {nv:.3} ({delta_pct:+.1}%, {verdict})");
                    let is_worst = match &worst {
                        Some((w, _)) => regress > *w,
                        None => true,
                    };
                    if is_worst {
                        worst = Some((regress, format!("{id} :: {key}")));
                    }
                }
            }
        }
    }
    for id in old_rows.keys() {
        println!("  only in {old_path}: {id}");
    }
    for id in &added {
        println!("  only in {new_path}: {id}");
    }
    if matched == 0 {
        bail!("no comparable rows between {old_path} and {new_path}");
    }
    match &worst {
        Some((r, at)) if *r > 0.0 => println!("worst regression: {r:+.1}% at {at}"),
        _ => println!("no metric regressed across {matched} matched rows"),
    }
    if let Some(limit) = fail_above {
        if let Some((r, at)) = &worst {
            if *r > limit {
                println!("FAIL: {r:+.1}% exceeds --fail-above {limit}% ({at})");
                return Ok(false);
            }
        }
        println!("OK: worst regression within --fail-above {limit}%");
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn envelope(threads: f64, rows: Vec<Json>) -> Json {
        Json::obj(vec![
            (
                "provenance",
                Json::obj(vec![
                    ("git_rev", Json::str("abc")),
                    ("threads", Json::num(threads)),
                    ("zeta_simd", Json::str("scalar")),
                    ("kv_quant", Json::str("f32")),
                ]),
            ),
            ("rows", Json::Arr(rows)),
        ])
    }

    fn row(scenario: &str, tps: f64, wall: f64) -> Json {
        Json::obj(vec![
            ("scenario", Json::str(scenario)),
            ("threads", Json::num(8.0)),
            ("tok_per_sec", Json::num(tps)),
            ("wall_ms", Json::num(wall)),
            ("stepped_tokens", Json::num(100.0)),
        ])
    }

    fn write_tmp(tag: &str, doc: &Json) -> String {
        let path = std::env::temp_dir()
            .join(format!("zeta_bdiff_{}_{tag}.json", std::process::id()));
        std::fs::write(&path, doc.to_string()).unwrap();
        path.to_string_lossy().into_owned()
    }

    #[test]
    fn directions_classify_known_bench_fields() {
        assert!(matches!(direction("tok_per_sec"), Direction::HigherBetter));
        assert!(matches!(direction("incr_toks_per_sec"), Direction::HigherBetter));
        assert!(matches!(direction("speedup_vs_off"), Direction::HigherBetter));
        assert!(matches!(direction("scalar_ns_per_elem"), Direction::LowerBetter));
        assert!(matches!(direction("ttft_p50_us"), Direction::LowerBetter));
        assert!(matches!(direction("wall_ms"), Direction::LowerBetter));
        assert!(matches!(direction("state_mb"), Direction::LowerBetter));
        assert!(matches!(direction("stepped_tokens"), Direction::Counter));
        assert!(matches!(direction("expect_ok"), Direction::Counter));
    }

    #[test]
    fn identity_uses_strings_and_config_numerics_only() {
        let a = row("spec", 100.0, 5.0);
        let b = row("spec", 250.0, 2.0); // metrics differ, identity equal
        assert_eq!(identity(&a), identity(&b));
        assert!(identity(&a).contains("scenario=spec"));
        assert!(identity(&a).contains("threads=8"));
        assert!(!identity(&a).contains("tok_per_sec"));
        let c = row("storm", 100.0, 5.0);
        assert_ne!(identity(&a), identity(&c));
    }

    #[test]
    fn diff_gates_on_the_worst_directional_regression() {
        let old = write_tmp("old", &envelope(8.0, vec![row("spec", 100.0, 5.0)]));
        // tok/s fell 20% — a regression even though wall_ms also fell.
        let new = write_tmp("new", &envelope(8.0, vec![row("spec", 80.0, 4.0)]));
        assert!(bench_diff(&old, &new, None).unwrap(), "no threshold: always ok");
        assert!(!bench_diff(&old, &new, Some(10.0)).unwrap(), "20% > 10% must fail");
        assert!(bench_diff(&old, &new, Some(25.0)).unwrap(), "20% < 25% passes");
        // Improvement in both metrics passes any threshold.
        let better = write_tmp("better", &envelope(8.0, vec![row("spec", 140.0, 3.0)]));
        assert!(bench_diff(&old, &better, Some(0.5)).unwrap());
        for p in [old, new, better] {
            let _ = std::fs::remove_file(p);
        }
    }

    #[test]
    fn diff_refuses_mismatched_provenance_and_garbage() {
        let old = write_tmp("p_old", &envelope(8.0, vec![row("spec", 100.0, 5.0)]));
        let new = write_tmp("p_new", &envelope(4.0, vec![row("spec", 100.0, 5.0)]));
        let err = bench_diff(&old, &new, None).unwrap_err().to_string();
        assert!(err.contains("threads"), "must name the mismatched field: {err}");
        let bare = write_tmp("p_bare", &Json::obj(vec![("rows", Json::Arr(vec![]))]));
        assert!(bench_diff(&old, &bare, None).is_err(), "no provenance header");
        let disjoint = write_tmp("p_disj", &envelope(8.0, vec![row("storm", 1.0, 1.0)]));
        assert!(bench_diff(&old, &disjoint, None).is_err(), "zero matched rows");
        for p in [old, new, bare, disjoint] {
            let _ = std::fs::remove_file(p);
        }
    }
}
