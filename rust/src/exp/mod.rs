//! Experiment harness: regenerates every table and figure of the paper.
//!
//! Each `fig*` / `table*` function prints the same rows/series the paper
//! reports and appends a JSON record under `results/`. Training-based
//! experiments consume the AOT sweeps built by `make artifacts-full`;
//! kernel-level experiments (fig3, table3, table4) run on the Rust-native
//! substrates. See DESIGN.md §4 for the experiment index and §5 for the
//! scale substitutions.

pub mod diff;

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::attention::{flash::Flash, mamba::MambaLite, naive::Naive, zeta::ZetaNative};
use crate::attention::{decode_full, AttentionImpl, Workload};
use crate::data::{corpus::CorpusLm, task_for_config};
use crate::runtime::Engine;
use crate::trainer::Trainer;
use crate::util::arena::{FlatRows, KvQuant, PageArena, DEFAULT_PAGE_TOKENS};
use crate::util::bench;
use crate::util::json::Json;
use crate::util::pool::{Pool, SharedSlice};
use crate::util::rng::Rng;
use crate::util::simd::{self, Backend};
use crate::zorder;

/// Options shared by all experiments (CLI flags).
#[derive(Debug, Clone)]
pub struct Opts {
    pub steps: usize,
    pub eval_batches: usize,
    pub seed: u64,
    pub max_len: usize,
    pub out_dir: String,
    pub verbose: bool,
    /// Pool size for the parallel kernel benchmarks (0 = the global pool,
    /// i.e. `ZETA_THREADS` / auto-detect). Tables 3/4 report each row at
    /// threads = 1 and threads = this value.
    pub threads: usize,
    /// KV page codec (`--kv-quant f32|f16|int8`) for serving-path
    /// experiments; also stamped into every `BENCH_*.json` provenance
    /// header.
    pub kv_quant: String,
    /// `--kv-mem-budget` byte cap for serving-path experiments (0 =
    /// unlimited; `exp scenarios` substitutes its own tight default for
    /// the budget-constrained replay arm when unset).
    pub kv_mem_budget: usize,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            steps: 200,
            eval_batches: 8,
            seed: 0,
            max_len: 16384,
            out_dir: "results".into(),
            verbose: false,
            threads: 0,
            kv_quant: "f32".into(),
            kv_mem_budget: 0,
        }
    }
}

/// Thread counts benchmarked per row: serial plus the configured pool size.
fn thread_counts(opts: &Opts) -> Vec<usize> {
    let t = if opts.threads == 0 { Pool::global().threads() } else { opts.threads };
    if t <= 1 {
        vec![1]
    } else {
        vec![1, t]
    }
}

fn record(opts: &Opts, name: &str, value: Json) -> Result<()> {
    std::fs::create_dir_all(&opts.out_dir)?;
    let path = format!("{}/{name}.json", opts.out_dir);
    std::fs::write(&path, value.to_string())?;
    Ok(())
}

/// Provenance header stamped into every `BENCH_*.json`: without it, two
/// trajectory files from different PRs / thread counts / SIMD backends /
/// KV codecs are not comparable (and silently diffing them is worse than
/// not diffing).
fn bench_provenance(opts: &Opts) -> Json {
    let git_rev = std::process::Command::new("git")
        .args(["rev-parse", "--short=12", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into());
    let threads = if opts.threads == 0 { Pool::global().threads() } else { opts.threads };
    Json::obj(vec![
        ("git_rev", Json::str(git_rev)),
        ("threads", Json::num(threads as f64)),
        ("zeta_simd", Json::str(simd::backend_name())),
        ("kv_quant", Json::str(opts.kv_quant.clone())),
    ])
}

/// Write the machine-readable `BENCH_<name>.json` perf trajectory: a
/// `{provenance, rows}` envelope (see [`bench_provenance`]). These live at
/// a fixed top-level name (the comparison anchor future PRs diff
/// against), so an unwritable CWD only warns — the same numbers were
/// already recorded under `--out-dir` by [`record`].
fn write_bench(opts: &Opts, name: &str, rows: Vec<Json>) {
    let path = format!("BENCH_{name}.json");
    let doc = Json::obj(vec![
        ("provenance", bench_provenance(opts)),
        ("rows", Json::Arr(rows)),
    ]);
    match std::fs::write(&path, doc.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}

/// Train one preset on its config-matched task, return eval accuracy (cls /
/// masked-token accuracy for MQAR) in [0, 1].
fn train_eval_accuracy(engine: &Engine, preset: &str, opts: &Opts) -> Result<f64> {
    let pspec = engine.manifest.preset(preset)?;
    let task = task_for_config(&pspec.config);
    let mut rng = Rng::new(opts.seed ^ 0x7A57);
    let mut tr = Trainer::new(engine, preset, opts.seed as i32)?;
    let verbose = opts.verbose;
    tr.train_loop(&*task, opts.steps, &mut rng, |s, l| {
        if verbose && s % 50 == 0 {
            eprintln!("    [{preset}] step {s}: loss {l:.4}");
        }
    })
    .with_context(|| format!("training {preset}"))?;
    let mut eval_rng = Rng::new(opts.seed ^ 0xE7A1);
    let stats = tr.eval(&*task, opts.eval_batches, &mut eval_rng)?;
    Ok(stats.accuracy)
}

fn print_matrix(title: &str, cols: &str, rows: &[(String, Vec<String>)]) {
    println!("\n== {title} ==");
    println!("{cols}");
    for (name, cells) in rows {
        println!("{name:<24}{}", cells.join("  "));
    }
}

// ---------------------------------------------------------------------------
// Figure 2a — MQAR accuracy vs model dimension, 4 architectures
// ---------------------------------------------------------------------------

pub fn fig2a(engine: &Engine, opts: &Opts) -> Result<()> {
    let dims = [32usize, 64, 128, 256];
    let archs = ["vanilla", "performer", "based", "zeta"];
    let mut rows = Vec::new();
    let mut rec = BTreeMap::new();
    for arch in archs {
        let mut cells = Vec::new();
        for dm in dims {
            let preset = format!("fig2a_{arch}_d{dm}");
            let acc = train_eval_accuracy(engine, &preset, opts)?;
            eprintln!("  fig2a {arch} d={dm}: acc {acc:.3}");
            cells.push(format!("{:>6.3}", acc));
            rec.insert(format!("{arch}_d{dm}"), Json::num(acc));
        }
        rows.push((arch.to_string(), cells));
    }
    print_matrix(
        "Figure 2a: MQAR accuracy vs model dim {32,64,128,256}",
        &format!("{:<24}{}", "model", "  d=32    d=64   d=128   d=256"),
        &rows,
    );
    record(opts, "fig2a", Json::Obj(rec))
}

// ---------------------------------------------------------------------------
// Figure 2b — vanilla transformer, d_K sweep
// ---------------------------------------------------------------------------

pub fn fig2b(engine: &Engine, opts: &Opts) -> Result<()> {
    let dims = [32usize, 64, 128];
    let dks = [1usize, 2, 3, 8];
    let mut rows = Vec::new();
    let mut rec = BTreeMap::new();
    for dm in dims {
        let mut cells = Vec::new();
        for dk in dks {
            let preset = format!("fig2b_d{dm}_dk{dk}");
            let acc = train_eval_accuracy(engine, &preset, opts)?;
            eprintln!("  fig2b d_model={dm} d_K={dk}: acc {acc:.3}");
            cells.push(format!("{:>6.3}", acc));
            rec.insert(format!("d{dm}_dk{dk}"), Json::num(acc));
        }
        rows.push((format!("d_model={dm}"), cells));
    }
    print_matrix(
        "Figure 2b: Transformer on MQAR with low-dim QK (accuracy)",
        &format!("{:<24}{}", "", "d_K=1   d_K=2   d_K=3   d_K=8"),
        &rows,
    );
    record(opts, "fig2b", Json::Obj(rec))
}

// ---------------------------------------------------------------------------
// Figure 2c / Table 6 — Euclidean softmax operators vs d_K
// ---------------------------------------------------------------------------

pub fn fig2c(engine: &Engine, opts: &Opts) -> Result<()> {
    let ops = ["cauchy", "neg_euclid", "inv_euclid", "norm_dot"];
    let dks = [1usize, 2, 3, 4];
    let mut rows = Vec::new();
    let mut rec = BTreeMap::new();
    for op in ops {
        let mut cells = Vec::new();
        for dk in dks {
            let preset = format!("fig2c_{op}_dk{dk}");
            let acc = train_eval_accuracy(engine, &preset, opts)?;
            eprintln!("  fig2c {op} d_K={dk}: acc {acc:.3}");
            cells.push(format!("{:>6.1}", acc * 100.0));
            rec.insert(format!("{op}_dk{dk}"), Json::num(acc));
        }
        rows.push((op.to_string(), cells));
    }
    print_matrix(
        "Figure 2c / Table 6: Euclidean-based softmax operators on MQAR (% acc)",
        &format!("{:<24}{}", "operator", "d_K=1   d_K=2   d_K=3   d_K=4"),
        &rows,
    );
    record(opts, "fig2c_table6", Json::Obj(rec))
}

pub fn table6(engine: &Engine, opts: &Opts) -> Result<()> {
    fig2c(engine, opts)
}

// ---------------------------------------------------------------------------
// Figure 2d — ZETA ablation over k
// ---------------------------------------------------------------------------

pub fn fig2d(engine: &Engine, opts: &Opts) -> Result<()> {
    let dims = [64usize, 256];
    let mut rows = Vec::new();
    let mut rec = BTreeMap::new();
    for dm in dims {
        let mut cells = Vec::new();
        for (k, preset) in [
            (16, format!("fig2d_d{dm}_k16")),
            (32, format!("fig2a_zeta_d{dm}")), // k=32 is the fig2a default
            (48, format!("fig2d_d{dm}_k48")),
        ] {
            let acc = train_eval_accuracy(engine, &preset, opts)?;
            eprintln!("  fig2d d={dm} k={k}: acc {acc:.3}");
            cells.push(format!("{:>6.3}", acc));
            rec.insert(format!("d{dm}_k{k}"), Json::num(acc));
        }
        rows.push((format!("d_model={dm}"), cells));
    }
    print_matrix(
        "Figure 2d: ZETA accuracy vs k on MQAR",
        &format!("{:<24}{}", "", " k=16    k=32    k=48"),
        &rows,
    );
    record(opts, "fig2d", Json::Obj(rec))
}

// ---------------------------------------------------------------------------
// Figure 3 — Z-order locality preservation (pure Rust, no artifacts)
// ---------------------------------------------------------------------------

pub fn fig3(opts: &Opts) -> Result<()> {
    let ns = [512usize, 1024, 2048];
    let dks = [1usize, 2, 3, 4, 6, 8, 12, 16];
    let k = 64;
    let mut rec = BTreeMap::new();
    println!("\n== Figure 3: top-{k} neighbour overlap before/after Z-order projection ==");
    print!("{:<8}", "d_K");
    for n in ns {
        print!("  N={n:<6}");
    }
    println!();
    for dk in dks {
        print!("{dk:<8}");
        for n in ns {
            let mut rng = Rng::new(opts.seed ^ (n as u64) ^ ((dk as u64) << 32));
            let mut pts = vec![0f32; n * dk];
            rng.fill_normal(&mut pts, 1.0);
            let codes = zorder::encode_points_fit(&pts, dk, zorder::bits_for_dim(dk));
            let ov = zorder::knn::mean_topk_overlap(&pts, dk, &codes, k);
            print!("  {ov:<7.3}");
            rec.insert(format!("n{n}_dk{dk}"), Json::num(ov));
        }
        println!();
    }
    record(opts, "fig3", Json::Obj(rec))
}

// ---------------------------------------------------------------------------
// Table 1 — language modeling perplexity
// ---------------------------------------------------------------------------

pub fn table1(engine: &Engine, opts: &Opts) -> Result<()> {
    let archs = ["vanilla", "performer", "based", "zeta"];
    let mut rows = Vec::new();
    let mut rec = BTreeMap::new();
    for arch in archs {
        let preset = format!("table1_{arch}");
        let pspec = engine.manifest.preset(&preset)?;
        let seq = pspec.seq_len();
        let train_task = CorpusLm::new(seq, 0xC0FFEE);
        let test_task = CorpusLm::test_view(seq, 0xC0FFEE);
        let mut tr = Trainer::new(engine, &preset, opts.seed as i32)?;
        let mut rng = Rng::new(opts.seed ^ 0x1AB1E);
        let verbose = opts.verbose;
        tr.train_loop(&train_task, opts.steps, &mut rng, |s, l| {
            if verbose && s % 50 == 0 {
                eprintln!("    [{preset}] step {s}: loss {l:.4}");
            }
        })?;
        let mut erng = Rng::new(opts.seed ^ 0xE7A1);
        let st = tr.eval(&test_task, opts.eval_batches, &mut erng)?;
        let ppl = st.perplexity();
        eprintln!("  table1 {arch}: test ppl {ppl:.2} ({} params)", pspec.param_count);
        rows.push((arch.to_string(), vec![
            format!("{:>8}", pspec.param_count),
            format!("{ppl:>9.2}"),
        ]));
        rec.insert(arch.to_string(), Json::num(ppl));
    }
    print_matrix(
        "Table 1: test perplexity on the synthetic wiki-like corpus",
        &format!("{:<24}{}", "model", "  params   test PPL"),
        &rows,
    );
    record(opts, "table1", Json::Obj(rec))
}

// ---------------------------------------------------------------------------
// Table 2 — LRA-style task accuracy
// ---------------------------------------------------------------------------

pub fn table2(engine: &Engine, opts: &Opts) -> Result<()> {
    let tasks = ["listops", "text", "retrieval", "image", "pathfinder"];
    let archs = ["vanilla", "zeta", "performer", "based"];
    let mut rows = Vec::new();
    let mut rec = BTreeMap::new();
    for arch in archs {
        let mut cells = Vec::new();
        let mut sum = 0.0;
        for task in tasks {
            let preset = format!("table2_{task}_{arch}");
            let acc = train_eval_accuracy(engine, &preset, opts)? * 100.0;
            eprintln!("  table2 {task} {arch}: {acc:.2}%");
            cells.push(format!("{acc:>7.2}"));
            rec.insert(format!("{task}_{arch}"), Json::num(acc));
            sum += acc;
        }
        cells.push(format!("{:>7.2}", sum / tasks.len() as f64));
        rows.push((arch.to_string(), cells));
    }
    print_matrix(
        "Table 2: LRA-style synthetic tasks (% accuracy)",
        &format!("{:<24}{}", "model", "ListOps    Text  Retrieval  Image  Pathfinder  Average"),
        &rows,
    );
    record(opts, "table2", Json::Obj(rec))
}

// ---------------------------------------------------------------------------
// Table 3 — wall-clock vs sequence length (Rust-native kernels)
// ---------------------------------------------------------------------------

/// Cost guards: above these lengths a kernel is reported as impractical on
/// this testbed (the paper reports OOM for Torch attention the same way).
const NAIVE_MAX: usize = 4096;
const FLASH_MAX: usize = 16384;

pub fn table3(opts: &Opts) -> Result<()> {
    let lens: Vec<usize> = [1024usize, 2048, 4096, 8192, 16384, 32768, 65536]
        .into_iter()
        .filter(|&n| n <= opts.max_len)
        .collect();
    let d = 64;
    let dv = 64;
    let tcounts = thread_counts(opts);
    println!("\n== Table 3: time (ms) per op, CPU testbed (thr = worker-pool size) ==");
    println!(
        "{:<8}{:<5}{:>12}{:>14}{:>12}{:>14}{:>12}{:>14}{:>12}{:>14}",
        "N", "thr", "naive-F", "naive-FB", "mamba-F", "mamba-FB", "flash-F", "flash-FB",
        "zeta-F", "zeta-FB"
    );
    let mut rec = BTreeMap::new();
    let mut bench_rows: Vec<Json> = Vec::new();
    for &n in &lens {
        let w = Workload::random(n, d, dv, opts.seed);
        let zeta = ZetaNative { chunk: (n / 16).max(64), ..ZetaNative::default() };
        for &t in &tcounts {
            let pool = Pool::new(t);
            let mut cells: Vec<String> = Vec::new();
            let budget = Duration::from_millis(500);
            let mut time_impl = |im: &dyn AttentionImpl, fb: bool, cap: usize| -> String {
                if n > cap {
                    return "    skip".into();
                }
                let st = if fb {
                    bench::bench(budget, 3, || {
                        bench::black_box(im.forward_backward_with(&w, &pool));
                    })
                } else {
                    bench::bench(budget, 3, || {
                        bench::black_box(im.forward_with(&w, &pool));
                    })
                };
                let pass = if fb { "fb" } else { "f" };
                rec.insert(
                    format!("{}_{}_{}_t{}", im.name(), pass, n, t),
                    Json::num(st.median_ms()),
                );
                bench_rows.push(Json::obj(vec![
                    ("kernel", Json::str(im.name())),
                    ("pass", Json::str(pass)),
                    ("n", Json::num(n as f64)),
                    ("threads", Json::num(t as f64)),
                    ("ms", Json::num(st.median_ms())),
                ]));
                format!("{:>8.2}", st.median_ms())
            };
            cells.push(time_impl(&Naive, false, NAIVE_MAX));
            cells.push(time_impl(&Naive, true, NAIVE_MAX));
            cells.push(time_impl(&MambaLite::default(), false, usize::MAX));
            cells.push(time_impl(&MambaLite::default(), true, usize::MAX));
            cells.push(time_impl(&Flash { block: 128 }, false, FLASH_MAX));
            cells.push(time_impl(&Flash { block: 128 }, true, FLASH_MAX));
            cells.push(time_impl(&zeta, false, usize::MAX));
            cells.push(time_impl(&zeta, true, usize::MAX));
            println!("{n:<8}{t:<5}{}", cells.join("      "));
        }
    }
    // Parallel-speedup summary: serial vs pooled zeta forward, largest N.
    if let (Some(&tmax), Some(&nmax)) = (tcounts.last(), lens.last()) {
        if tmax > 1 {
            let k1 = format!("zeta_f_{nmax}_t1");
            let kt = format!("zeta_f_{nmax}_t{tmax}");
            if let (Some(a), Some(b)) = (
                rec.get(&k1).and_then(|j| j.as_f64()),
                rec.get(&kt).and_then(|j| j.as_f64()),
            ) {
                if b > 0.0 {
                    println!(
                        "zeta-F N={nmax}: parallel speedup {:.2}x at {tmax} threads",
                        a / b
                    );
                }
            }
        }
    }
    println!("(skip = impractical on this testbed, analogous to the paper's OOM rows)");
    record(opts, "table3", Json::Obj(rec))?;
    // Machine-readable perf trajectory (per-kernel ms by N and threads) so
    // future PRs can diff against this run.
    write_bench(opts, "table3", bench_rows);
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 4 — memory vs sequence length
// ---------------------------------------------------------------------------

pub fn table4(opts: &Opts) -> Result<()> {
    let lens: Vec<usize> = [1024usize, 2048, 4096, 8192, 16384, 32768, 65536]
        .into_iter()
        .filter(|&n| n <= opts.max_len)
        .collect();
    let d = 64;
    let dv = 64;
    let tcounts = thread_counts(opts);
    println!(
        "\n== Table 4: memory (MB) per op (measured workspace + outputs + inputs; \
         thr = worker-pool size) =="
    );
    println!(
        "{:<8}{:<5}{:>12}{:>14}{:>12}{:>14}{:>12}{:>14}{:>12}{:>14}",
        "N", "thr", "naive-F", "naive-FB", "mamba-F", "mamba-FB", "flash-F", "flash-FB",
        "zeta-F", "zeta-FB"
    );
    let mut rec = BTreeMap::new();
    for &n in &lens {
        let w = Workload::random(n, d, dv, opts.seed);
        let zeta = ZetaNative { chunk: (n / 16).max(64), ..ZetaNative::default() };
        for &t in &tcounts {
            let pool = Pool::new(t);
            let mut cells = Vec::new();
            let mut mem_impl = |im: &dyn AttentionImpl, fb: bool, cap: usize| -> String {
                let mb = if n > cap {
                    // analytic model of the buffers it *would* allocate
                    let rep = im
                        .analytic_mem(n, d, dv, fb, t)
                        .expect("capped impl must provide an analytic memory model");
                    rep.total_with_inputs(&w) as f64 / 1e6
                } else {
                    let rep = if fb {
                        im.forward_backward_with(&w, &pool).1
                    } else {
                        im.forward_with(&w, &pool).1
                    };
                    rep.total_with_inputs(&w) as f64 / 1e6
                };
                rec.insert(
                    format!("{}_{}_{}_t{}", im.name(), if fb { "fb" } else { "f" }, n, t),
                    Json::num(mb),
                );
                if n > cap {
                    format!("{mb:>7.1}*")
                } else {
                    format!("{mb:>8.1}")
                }
            };
            cells.push(mem_impl(&Naive, false, NAIVE_MAX));
            cells.push(mem_impl(&Naive, true, NAIVE_MAX));
            cells.push(mem_impl(&MambaLite::default(), false, usize::MAX));
            cells.push(mem_impl(&MambaLite::default(), true, usize::MAX));
            cells.push(mem_impl(&Flash { block: 128 }, false, FLASH_MAX));
            cells.push(mem_impl(&Flash { block: 128 }, true, FLASH_MAX));
            cells.push(mem_impl(&zeta, false, usize::MAX));
            cells.push(mem_impl(&zeta, true, usize::MAX));
            println!("{n:<8}{t:<5}{}", cells.join("      "));
        }
    }
    println!("(* = analytic, buffer too large to allocate — the paper's OOM)");
    record(opts, "table4", Json::Obj(rec))
}

// ---------------------------------------------------------------------------
// Decode — per-token serving cost: incremental decode vs full recompute
// ---------------------------------------------------------------------------

/// Caps for the *full-recompute* column (one full forward per emitted token
/// — the regime the incremental engine replaces; above the cap the column
/// is skipped the way Table 3 skips impractical rows).
const DECODE_FULL_NAIVE_MAX: usize = 4096;
const DECODE_FULL_FLASH_MAX: usize = 8192;

/// `exp decode`: per-token decode cost at context length N for all four
/// kernels, incremental (`decode_step` on a live [`crate::attention::DecodeState`])
/// vs full-recompute (one `forward` over the whole prefix per token).
/// Writes `results/decode.json` and the machine-readable
/// `BENCH_decode.json` trajectory, and runs the decode-vs-prefill
/// equivalence gate first — benchmarking a wrong kernel is worse than not
/// benchmarking.
pub fn decode(opts: &Opts) -> Result<()> {
    // Equivalence gate: decode must reproduce forward row-for-row.
    {
        let w = Workload::random(256, 32, 16, opts.seed ^ 0xD0DE);
        let pool = Pool::serial();
        for im in crate::attention::all_impls() {
            let (of, _) = im.forward_with(&w, &pool);
            let od = decode_full(im.as_ref(), &w);
            let diff = of.max_abs_diff(&od);
            if diff >= 1e-4 {
                bail!("decode equivalence gate failed for {}: max |Δ| = {diff}", im.name());
            }
            println!("equivalence {:<6} ✓ (max |Δ| = {diff:.2e})", im.name());
        }
    }

    let lens: Vec<usize> = [512usize, 1024, 2048, 4096, 8192]
        .into_iter()
        .filter(|&n| n <= opts.max_len.min(8192))
        .collect();
    let d = 64;
    let dv = 64;
    let pool = if opts.threads == 0 { *Pool::global() } else { Pool::new(opts.threads) };
    println!(
        "\n== Decode: per-token cost at context N — incremental decode_step vs \
         full-recompute forward =="
    );
    println!(
        "{:<8}{:<8}{:>14}{:>14}{:>10}{:>14}{:>12}",
        "kernel", "N", "incr µs/tok", "full µs/tok", "speedup", "incr tok/s", "state MB"
    );
    let mut rec = BTreeMap::new();
    let mut bench_rows: Vec<Json> = Vec::new();
    let mut zeta_curve: Vec<(usize, f64)> = Vec::new();
    for &n in &lens {
        let w = Workload::random(n, d, dv, opts.seed);
        let naive = Naive;
        let flash = Flash { block: 128 };
        let mamba = MambaLite::default();
        let zeta = ZetaNative { chunk: (n / 16).max(64), ..ZetaNative::default() };
        let impls: [(&dyn AttentionImpl, usize); 4] = [
            (&naive, DECODE_FULL_NAIVE_MAX),
            (&mamba, usize::MAX),
            (&flash, DECODE_FULL_FLASH_MAX),
            (&zeta, usize::MAX),
        ];
        for (im, full_cap) in impls {
            // Incremental: stream the whole sequence once through a live
            // decode state; the timed last quarter measures per-token cost
            // *at* context ~N (thousands of steps, no bench harness needed).
            let tail_start = n - n / 4;
            let mut st = im.begin_decode(d, dv);
            let mut out = vec![0f32; dv];
            for t in 0..tail_start {
                st.step(w.q.row(t), w.k.row(t), w.v.row(t), &mut out);
            }
            let t0 = Instant::now();
            for t in tail_start..n {
                st.step(w.q.row(t), w.k.row(t), w.v.row(t), &mut out);
            }
            let incr_us = t0.elapsed().as_secs_f64() * 1e6 / (n - tail_start) as f64;
            bench::black_box(&out);
            let state_mb = st.state_bytes() as f64 / 1e6;
            // Full recompute: one forward over the n-token prefix is the
            // cost of ONE emitted token without the incremental engine.
            let full_us = if n <= full_cap {
                let stt = bench::bench(Duration::from_millis(300), 2, || {
                    bench::black_box(im.forward_with(&w, &pool));
                });
                Some(stt.median_us())
            } else {
                None
            };
            let name = im.name();
            rec.insert(format!("{name}_incr_us_{n}"), Json::num(incr_us));
            let mut row = vec![
                ("kernel", Json::str(name)),
                ("n", Json::num(n as f64)),
                ("threads", Json::num(pool.threads() as f64)),
                ("incr_us_per_tok", Json::num(incr_us)),
                ("incr_toks_per_sec", Json::num(1e6 / incr_us.max(1e-9))),
                ("state_mb", Json::num(state_mb)),
            ];
            let full_cell = match full_us {
                Some(us) => {
                    rec.insert(format!("{name}_full_us_{n}"), Json::num(us));
                    row.push(("full_us_per_tok", Json::num(us)));
                    row.push(("full_toks_per_sec", Json::num(1e6 / us.max(1e-9))));
                    format!("{us:>14.1}")
                }
                None => format!("{:>14}", "skip"),
            };
            let speedup = match full_us {
                Some(us) if incr_us > 0.0 => format!("{:>9.0}x", us / incr_us),
                _ => format!("{:>10}", "-"),
            };
            bench_rows.push(Json::obj(row));
            println!(
                "{name:<8}{n:<8}{incr_us:>14.2}{full_cell}{speedup}{:>14.0}{state_mb:>12.2}",
                1e6 / incr_us.max(1e-9)
            );
            if name == "zeta" {
                zeta_curve.push((n, incr_us));
            }
        }
    }
    // Sublinearity check: ZETA's per-token cost must grow slower than N.
    if let (Some(&(n0, c0)), Some(&(n1, c1))) = (zeta_curve.first(), zeta_curve.last()) {
        if n1 > n0 && c0 > 0.0 {
            let cost_ratio = c1 / c0;
            let n_ratio = n1 as f64 / n0 as f64;
            let verdict = if cost_ratio < n_ratio { "sublinear ✓" } else { "NOT sublinear ✗" };
            println!(
                "zeta incremental per-token cost: {cost_ratio:.2}x across a {n_ratio:.0}x \
                 context sweep — {verdict}"
            );
        }
    }
    println!("(full = one forward per token; skip = impractical at this N, as in Table 3)");
    record(opts, "decode", Json::Obj(rec))?;
    write_bench(opts, "decode", bench_rows);
    decode_batch(opts)
}

// ---------------------------------------------------------------------------
// Decode batch — fused cross-session sweeps vs serial per-session stepping
// ---------------------------------------------------------------------------

/// Multi-session decode sweep benchmark (the serving coordinator's hot
/// path): per-token µs when N concurrent sessions step serially (one
/// `step_token` per session per sweep — the pre-fusion scheduler) vs
/// through the fused `step_batch` sweep (one pool-parallel kernel call +
/// batched readout/argmax), over a sessions × threads grid. Serial and
/// fused rounds alternate on the *same* live states so context-growth
/// drift between the two measurements cancels. Writes
/// `results/decode_batch.json` and the machine-readable
/// `BENCH_decode_batch.json`.
pub fn decode_batch(opts: &Opts) -> Result<()> {
    use crate::coordinator::session::{
        NativeDecodeModel, NativeModelConfig, PrefillStep, SessionStep, StepScratch,
    };
    let ctx = opts.max_len.clamp(64, 1024);
    let steps_per_round = 16usize;
    let rounds = 4usize;
    let session_counts = [1usize, 2, 4, 8, 16];
    let tcounts = thread_counts(opts);
    println!(
        "\n== Decode batch: fused step_batch sweep vs serial per-session stepping \
         (per-token µs, ctx {ctx}) =="
    );
    println!(
        "{:<8}{:<10}{:<5}{:>14}{:>14}{:>10}",
        "kernel", "sessions", "thr", "serial µs", "fused µs", "speedup"
    );
    let mut rec = BTreeMap::new();
    let mut bench_rows: Vec<Json> = Vec::new();
    for kernel in ["naive", "mamba", "flash", "zeta"] {
        // Serving-scale dims (the coordinator's defaults are toy-sized):
        // the batched vocab × dv readout is part of the fused win.
        let model = NativeDecodeModel::new(NativeModelConfig {
            kernel: kernel.into(),
            d: 64,
            dv: 64,
            vocab: 1024,
            seed: opts.seed,
            max_context: 0,
            ..Default::default()
        })?;
        for &sess in &session_counts {
            let mut rng = Rng::new(opts.seed ^ 0xBA7C4);
            let prompts: Vec<Vec<i32>> =
                (0..sess).map(|_| (0..ctx).map(|_| rng.below(1024) as i32).collect()).collect();
            for &t in &tcounts {
                let pool = Pool::new(t);
                let mut scratch = StepScratch::default();
                let mut states: Vec<_> = (0..sess).map(|_| model.begin()).collect();
                {
                    let mut items: Vec<PrefillStep> = states
                        .iter_mut()
                        .zip(&prompts)
                        .map(|(st, p)| PrefillStep {
                            state: st.as_mut(),
                            tokens: p.as_slice(),
                            emit: true,
                        })
                        .collect();
                    model.prefill_batch(&mut items, &mut scratch, &pool);
                }
                let mut toks: Vec<i32> = scratch.next.clone();
                let (mut orow, mut logits) = (Vec::new(), Vec::new());
                let mut serial_ns = 0u128;
                let mut fused_ns = 0u128;
                for _ in 0..rounds {
                    let t0 = Instant::now();
                    for _ in 0..steps_per_round {
                        for (st, tok) in states.iter_mut().zip(toks.iter_mut()) {
                            model.step_token(st.as_mut(), *tok, &mut orow, &mut logits);
                            *tok = NativeDecodeModel::argmax(&logits);
                        }
                    }
                    serial_ns += t0.elapsed().as_nanos();
                    let t0 = Instant::now();
                    for _ in 0..steps_per_round {
                        let mut items: Vec<SessionStep> = states
                            .iter_mut()
                            .zip(&toks)
                            .map(|(st, &tok)| SessionStep { state: st.as_mut(), tok })
                            .collect();
                        model.step_batch(&mut items, &mut scratch, &pool);
                        drop(items);
                        toks.copy_from_slice(&scratch.next);
                    }
                    fused_ns += t0.elapsed().as_nanos();
                }
                let denom = (rounds * steps_per_round * sess) as f64;
                let serial_us = serial_ns as f64 / 1e3 / denom;
                let fused_us = fused_ns as f64 / 1e3 / denom;
                let speedup = serial_us / fused_us.max(1e-9);
                println!(
                    "{kernel:<8}{sess:<10}{t:<5}{serial_us:>14.2}{fused_us:>14.2}{speedup:>9.2}x"
                );
                rec.insert(
                    format!("{kernel}_s{sess}_t{t}"),
                    Json::obj(vec![
                        ("serial_us", Json::num(serial_us)),
                        ("fused_us", Json::num(fused_us)),
                    ]),
                );
                bench_rows.push(Json::obj(vec![
                    ("kernel", Json::str(kernel)),
                    ("sessions", Json::num(sess as f64)),
                    ("threads", Json::num(t as f64)),
                    ("ctx", Json::num(ctx as f64)),
                    ("serial_us_per_tok", Json::num(serial_us)),
                    ("fused_us_per_tok", Json::num(fused_us)),
                    ("speedup", Json::num(speedup)),
                ]));
            }
        }
    }
    record(opts, "decode_batch", Json::Obj(rec))?;
    write_bench(opts, "decode_batch", bench_rows);
    Ok(())
}

// ---------------------------------------------------------------------------
// Prefill — pipelined sequence-parallel prompt scoring vs the serial wall
// ---------------------------------------------------------------------------

/// `exp prefill`: time-to-first-token for one long prompt through the
/// serving prefill path. The sequential arm feeds the whole prompt through
/// `prefill_batch` on a 1-thread pool: the fan-out gate keeps it on the
/// inline chunk-sequential step loop, i.e. the pre-pipelining
/// serialization wall. The pipelined arm runs the same call on a t-thread
/// pool, which Morton-encodes and appends all keys up front, snapshots the
/// Z-order index at every chunk boundary (`ZIndex::fork`, O(log N) each),
/// and fans all (chunk, head, query) scoring across the resident team in
/// one region (break-even:
/// [`crate::util::breakeven::PARALLEL_PREFILL_SCORE_MIN_LOOKUPS`]). An
/// equivalence gate first proves both schedules hand decode the same
/// state: same first token, then eight bitwise-identical greedy
/// continuation steps. Writes `results/prefill.json` and the
/// machine-readable `BENCH_prefill.json`; `seq_ms` is the shared serial
/// baseline repeated in every row, the same convention as
/// `BENCH_kernels.json`.
pub fn prefill(opts: &Opts) -> Result<()> {
    use crate::coordinator::session::{
        NativeDecodeModel, NativeModelConfig, PrefillStep, StepScratch,
    };

    let lens: Vec<usize> =
        [4096usize, 16384, 65536].into_iter().filter(|&n| n <= opts.max_len).collect();
    if lens.is_empty() {
        bail!("exp prefill needs --max-len >= 4096");
    }
    let tcounts = [1usize, 2, 4, 8];
    let model = NativeDecodeModel::new(NativeModelConfig {
        kernel: "zeta".into(),
        d: 64,
        dv: 64,
        vocab: 1024,
        seed: opts.seed,
        max_context: 0,
        ..Default::default()
    })?;
    let mut rng = Rng::new(opts.seed ^ 0x9EF1);
    let max_n = *lens.iter().max().unwrap();
    let prompt: Vec<i32> = (0..max_n).map(|_| rng.below(1024) as i32).collect();

    // Equivalence gate: the pipelined schedule must leave exactly the
    // decode state the serial per-token step loop would have.
    {
        let n = lens[0];
        let (mut orow, mut la, mut lb) = (Vec::new(), Vec::new(), Vec::new());
        let mut a = model.begin();
        for &tok in &prompt[..n] {
            model.step_token(a.as_mut(), tok, &mut orow, &mut la);
        }
        let mut b = model.begin();
        let mut scratch = StepScratch::default();
        let pool = Pool::new(4);
        {
            let mut items = vec![PrefillStep {
                state: b.as_mut(),
                tokens: &prompt[..n],
                emit: true,
            }];
            model.prefill_batch(&mut items, &mut scratch, &pool);
        }
        let mut tok_a = NativeDecodeModel::argmax(&la);
        let mut tok_b = scratch.next[0];
        if tok_a != tok_b {
            bail!("prefill equivalence gate failed: first token {tok_b} != serial {tok_a}");
        }
        for step in 0..8 {
            model.step_token(a.as_mut(), tok_a, &mut orow, &mut la);
            model.step_token(b.as_mut(), tok_b, &mut orow, &mut lb);
            if la != lb {
                bail!("prefill equivalence gate failed: logits diverge at decode step {step}");
            }
            tok_a = NativeDecodeModel::argmax(&la);
            tok_b = NativeDecodeModel::argmax(&lb);
        }
        println!(
            "equivalence zeta   ✓ (pipelined prefill == serial step loop, {n} prompt tokens \
             + 8 decode steps bitwise)"
        );
    }

    println!(
        "\n== Prefill: time-to-first-token — pipelined sequence-parallel scoring vs the \
         serial chunk loop =="
    );
    println!(
        "{:<8}{:<8}{:<5}{:>12}{:>12}{:>10}",
        "kernel", "N", "thr", "seq ms", "pipe ms", "speedup"
    );
    let mut rec = BTreeMap::new();
    let mut bench_rows: Vec<Json> = Vec::new();
    for &n in &lens {
        // One TTFT sample: fresh state, whole prompt, one emitted token.
        // Short prompts take the best of two runs to damp scheduler noise.
        let ttft = |pool: &Pool| -> f64 {
            let reps = if n <= 4096 { 2 } else { 1 };
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                let mut st = model.begin();
                let mut scratch = StepScratch::default();
                let t0 = Instant::now();
                {
                    let mut items = vec![PrefillStep {
                        state: st.as_mut(),
                        tokens: &prompt[..n],
                        emit: true,
                    }];
                    model.prefill_batch(&mut items, &mut scratch, pool);
                }
                best = best.min(t0.elapsed().as_secs_f64() * 1e3);
                bench::black_box(&scratch.next);
            }
            best
        };
        let seq_ms = ttft(&Pool::serial());
        for &t in &tcounts {
            let pool = Pool::new(t);
            let pipe_ms = ttft(&pool);
            let speedup = seq_ms / pipe_ms.max(1e-9);
            println!("{:<8}{n:<8}{t:<5}{seq_ms:>12.1}{pipe_ms:>12.1}{speedup:>9.2}x", "zeta");
            rec.insert(
                format!("zeta_n{n}_t{t}"),
                Json::obj(vec![("seq_ms", Json::num(seq_ms)), ("pipe_ms", Json::num(pipe_ms))]),
            );
            bench_rows.push(Json::obj(vec![
                ("bench", Json::str("prefill")),
                ("kernel", Json::str("zeta")),
                ("n", Json::num(n as f64)),
                ("threads", Json::num(t as f64)),
                ("seq_ms", Json::num(seq_ms)),
                ("pipe_ms", Json::num(pipe_ms)),
                ("speedup", Json::num(speedup)),
            ]));
        }
    }
    println!("(seq = prefill_batch on a 1-thread pool: the inline chunk-sequential step loop)");
    record(opts, "prefill", Json::Obj(rec))?;
    write_bench(opts, "prefill", bench_rows);
    Ok(())
}

// ---------------------------------------------------------------------------
// Pool — parallel-region launch latency: resident team vs scoped spawns
// ---------------------------------------------------------------------------

/// `exp pool`: the region-launch micro-benchmark behind the
/// [`crate::util::breakeven`] thresholds. Measures (a) per-region
/// launch+join overhead of the resident parked worker team against a
/// `std::thread::scope` spawn baseline (what every region cost before the
/// persistent pool) at several worker counts, and (b) a fused-sweep-shaped
/// inline-vs-fan-out sweep that locates the measured break-even in total
/// scalar ops. Writes `results/pool.json` and the machine-readable
/// `BENCH_pool.json` trajectory (rows tagged `bench = region_launch |
/// sweep | breakeven_const`).
pub fn pool(opts: &Opts) -> Result<()> {
    use crate::util::breakeven;

    let budget = Duration::from_millis(250);
    let mut rec = BTreeMap::new();
    let mut bench_rows: Vec<Json> = Vec::new();

    println!(
        "\n== Pool: per-region launch+join overhead — resident parked team vs \
         per-region scoped spawns =="
    );
    println!("{:<10}{:>14}{:>14}{:>10}", "workers", "pool µs", "scoped µs", "spawn/wake");
    for wkr in [2usize, 4, 8] {
        let p = Pool::new(wkr);
        // Warm the team: the first regions spawn + park the residents.
        for _ in 0..32 {
            bench::black_box(p.run_workers(wkr, |w| w));
        }
        let pooled = bench::bench(budget, 16, || {
            bench::black_box(p.run_workers(wkr, |w| w));
        });
        let scoped = bench::bench(budget, 16, || {
            std::thread::scope(|s| {
                let hs: Vec<_> = (0..wkr).map(|w| s.spawn(move || bench::black_box(w))).collect();
                for h in hs {
                    let _ = h.join();
                }
            });
        });
        let (pu, su) = (pooled.median_us(), scoped.median_us());
        println!("{wkr:<10}{pu:>14.2}{su:>14.2}{:>9.1}x", su / pu.max(1e-9));
        rec.insert(
            format!("region_launch_w{wkr}"),
            Json::obj(vec![("pool_us", Json::num(pu)), ("scoped_us", Json::num(su))]),
        );
        bench_rows.push(Json::obj(vec![
            ("bench", Json::str("region_launch")),
            ("workers", Json::num(wkr as f64)),
            ("pool_us", Json::num(pu)),
            ("scoped_us", Json::num(su)),
            ("spawn_over_wake", Json::num(su / pu.max(1e-9))),
        ]));
    }

    // Fused-sweep-shaped break-even: 8 independent slots of `ops` xorshift
    // chains each (a synthetic step_batch wave), timed inline vs fanned
    // out. `parallel_for` applies no break-even of its own, so the
    // crossover in total ops is the measured justification for
    // PARALLEL_STEP_MIN_OPS. `--threads` is honored exactly, like every
    // other experiment (0 = default 4).
    let threads = if opts.threads == 0 { 4 } else { opts.threads };
    if threads == 1 {
        println!(
            "note: --threads 1 makes the fan-out column degenerate to the \
             inline loop (a serial pool never wakes the team)"
        );
    }
    let p = Pool::new(threads);
    let slots = 8usize;
    println!(
        "\n== Pool: synthetic fused sweep ({slots} slots, {threads} threads) — \
         inline vs fan-out per-sweep µs =="
    );
    println!("{:<14}{:<12}{:>12}{:>12}", "ops/slot", "total ops", "inline µs", "pool µs");
    let mut crossover: Option<usize> = None;
    let mut out = vec![0u64; slots];
    for ops in [256usize, 1024, 4096, 16384, 65536] {
        let total = slots * ops;
        // Per-slot xorshift chain: ~3 dependent scalar ops per iteration,
        // unvectorizable — the same shape as a kernel decode step's
        // serial inner loop.
        let work = |slot: usize| -> u64 {
            let mut x = slot as u64 + 0x9E37_79B9_7F4A_7C15;
            for _ in 0..ops {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
            }
            x
        };
        let inline_st = bench::bench(budget, 16, || {
            for (s, o) in out.iter_mut().enumerate() {
                *o = work(s);
            }
            bench::black_box(&out);
        });
        let pooled_st = bench::bench(budget, 16, || {
            let osh = SharedSlice::new(&mut out);
            p.parallel_for(slots, 1, |r| {
                for s in r {
                    // Safety: slot s claimed by exactly one chunk.
                    unsafe { osh.write(s, work(s)) };
                }
            });
        });
        let (iu, pu) = (inline_st.median_us(), pooled_st.median_us());
        if pu <= iu && crossover.is_none() {
            crossover = Some(total);
        }
        println!("{ops:<14}{total:<12}{iu:>12.2}{pu:>12.2}");
        rec.insert(
            format!("sweep_ops{ops}"),
            Json::obj(vec![("inline_us", Json::num(iu)), ("pool_us", Json::num(pu))]),
        );
        bench_rows.push(Json::obj(vec![
            ("bench", Json::str("sweep")),
            ("threads", Json::num(threads as f64)),
            ("slots", Json::num(slots as f64)),
            ("ops_per_slot", Json::num(ops as f64)),
            ("total_ops", Json::num(total as f64)),
            ("inline_us", Json::num(iu)),
            ("pool_us", Json::num(pu)),
        ]));
    }
    match crossover {
        Some(c) => println!(
            "measured fan-out break-even ≈ {c} total ops (configured \
             PARALLEL_STEP_MIN_OPS = {})",
            breakeven::PARALLEL_STEP_MIN_OPS
        ),
        None => println!(
            "fan-out never beat inline in this sweep (configured \
             PARALLEL_STEP_MIN_OPS = {}) — likely a 1-2 core machine",
            breakeven::PARALLEL_STEP_MIN_OPS
        ),
    }
    // Record the active thresholds so the trajectory is self-describing.
    for (name, v) in [
        ("PARALLEL_STEP_MIN_OPS", breakeven::PARALLEL_STEP_MIN_OPS),
        ("PARALLEL_PREFILL_MIN_OPS", breakeven::PARALLEL_PREFILL_MIN_OPS),
        ("PARALLEL_READOUT_MIN_OPS", breakeven::PARALLEL_READOUT_MIN_OPS),
        ("PARALLEL_PAD_MIN_ELEMS", breakeven::PARALLEL_PAD_MIN_ELEMS),
        ("PARALLEL_SEARCH_MIN_LOOKUPS", breakeven::PARALLEL_SEARCH_MIN_LOOKUPS),
    ] {
        bench_rows.push(Json::obj(vec![
            ("bench", Json::str("breakeven_const")),
            ("name", Json::str(name)),
            ("value", Json::num(v as f64)),
        ]));
    }
    record(opts, "pool", Json::Obj(rec))?;
    write_bench(opts, "pool", bench_rows);
    Ok(())
}

// ---------------------------------------------------------------------------
// Kernels — per-loop micro-bench: seed-exact scalar arm vs SIMD dispatch
// ---------------------------------------------------------------------------

/// One `exp kernels` table row: per-element timings for a loop at size `n`,
/// printed and appended to both the `results/kernels.json` record and the
/// `BENCH_kernels.json` trajectory rows (the scalar baseline travels in
/// every row, so the trajectory diffs without re-running a baseline).
fn kernel_row(
    name: &str,
    n: usize,
    elems: f64,
    scalar: &bench::Stats,
    vector: &bench::Stats,
    rec: &mut BTreeMap<String, Json>,
    rows: &mut Vec<Json>,
) {
    let sc_ns = scalar.median_s * 1e9 / elems;
    let si_ns = vector.median_s * 1e9 / elems;
    let speedup = sc_ns / si_ns.max(1e-12);
    println!("{name:<14}{n:<8}{sc_ns:>16.3}{si_ns:>16.3}{speedup:>9.2}x");
    rec.insert(
        format!("{name}_n{n}"),
        Json::obj(vec![
            ("scalar_ns_per_elem", Json::num(sc_ns)),
            ("simd_ns_per_elem", Json::num(si_ns)),
            ("speedup", Json::num(speedup)),
        ]),
    );
    rows.push(Json::obj(vec![
        ("bench", Json::str(name)),
        ("n", Json::num(n as f64)),
        ("backend", Json::str(crate::util::simd::backend_name())),
        ("lanes", Json::num(crate::util::simd::lanes() as f64)),
        ("scalar_ns_per_elem", Json::num(sc_ns)),
        ("simd_ns_per_elem", Json::num(si_ns)),
        ("speedup", Json::num(speedup)),
    ]));
}

/// Seed-exact scalar replica of [`crate::attention::zeta::cauchy_row`],
/// built from the `_with(Backend::Scalar, ..)` primitives. This is the
/// baseline column of `exp kernels`; the dispatched real routine is the
/// other column, so the pair prices exactly the restructuring the SIMD
/// layer performed on the ZETA scoring row.
#[allow(clippy::too_many_arguments)]
fn cauchy_row_scalar(
    eps: f32,
    irow: &[u32],
    qi: &[f32],
    kl: &FlatRows<'_>,
    km_i: &[f32],
    vm_i: &[f32],
    v: &FlatRows<'_>,
    scores: &mut [f32],
    out: &mut [f32],
) -> f32 {
    let mut z = 0.0f32;
    let mut nc = 0usize;
    for (slot, &j) in irow.iter().enumerate() {
        if j == u32::MAX {
            break;
        }
        let jj = j as usize;
        let s = 1.0 / (simd::sqdist_with(Backend::Scalar, qi, kl.row_at(jj)) + eps);
        scores[slot] = s;
        z += s;
        nc = slot + 1;
    }
    let sm = 1.0 / (simd::sqdist_with(Backend::Scalar, qi, km_i) + eps);
    z += sm;
    let inv = 1.0 / z;
    for o in out.iter_mut() {
        *o = 0.0;
    }
    for slot in 0..nc {
        let jj = irow[slot] as usize;
        simd::axpy_with(Backend::Scalar, out, scores[slot] * inv, v.row_at(jj));
    }
    simd::axpy_with(Backend::Scalar, out, sm * inv, vm_i);
    z
}

/// One exact-attention softmax row (the shape of `ExactKvDecode::step` and
/// `Naive::fwd_full`): score every key, running max, exp-normalize,
/// AV-accumulate. Backend-parameterized so `exp kernels` prices the same
/// arithmetic on the scalar and vector arms.
#[allow(clippy::too_many_arguments)]
fn softmax_row(
    be: Backend,
    q: &[f32],
    kbuf: &[f32],
    vbuf: &[f32],
    d: usize,
    dv: usize,
    scores: &mut [f32],
    out: &mut [f32],
) {
    let nk = scores.len();
    let scale = 1.0 / (d as f32).sqrt();
    let mut maxv = f32::NEG_INFINITY;
    for j in 0..nk {
        let s = simd::dot_with(be, q, &kbuf[j * d..(j + 1) * d]) * scale;
        scores[j] = s;
        maxv = maxv.max(s);
    }
    let mut z = 0.0;
    for s in scores.iter_mut() {
        *s = (*s - maxv).exp();
        z += *s;
    }
    let inv = 1.0 / z;
    for o in out.iter_mut() {
        *o = 0.0;
    }
    for j in 0..nk {
        simd::axpy_with(be, out, scores[j] * inv, &vbuf[j * dv..(j + 1) * dv]);
    }
}

/// `exp kernels`: the per-loop micro-benchmark behind the SIMD kernel layer
/// ([`crate::util::simd`]). For each hot loop — `dot`, `sqdist`, `axpy`,
/// Morton `interleave`, the mamba `ssm_step`, the ZETA `cauchy_row`, and an
/// exact-attention softmax row — reports ns/element for the seed-exact
/// scalar arm vs the dispatched backend at n ∈ {256, 4096, 65536} elements
/// (small sizes amortized over repetitions so per-call overhead cancels).
/// Writes `results/kernels.json` and the machine-readable
/// `BENCH_kernels.json`. Under `ZETA_SIMD=scalar` both columns price the
/// same loops, so the speedup column pins at ~1 — the self-describing
/// `backend` field records which regime a trajectory row came from.
pub fn kernels(opts: &Opts) -> Result<()> {
    use crate::attention::zeta::cauchy_row;
    let be = simd::backend();
    let budget = Duration::from_millis(200);
    let mut rng = Rng::new(opts.seed ^ 0x51D5);
    let mut rec = BTreeMap::new();
    let mut rows: Vec<Json> = Vec::new();
    println!(
        "\n== Kernels: per-loop ns/element — scalar arm vs dispatched backend \
         ({}, {} × f32 lanes) ==",
        be.name(),
        be.lanes()
    );
    println!(
        "{:<14}{:<8}{:>16}{:>16}{:>10}",
        "loop", "n", "scalar ns/el", "simd ns/el", "speedup"
    );
    for &n in &[256usize, 4096, 65536] {
        let reps = (65536 / n).max(1);

        // dot / sqdist: lane reductions over length-n vectors.
        let mut a = vec![0f32; n];
        let mut b = vec![0f32; n];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        let elems = (reps * n) as f64;
        let sc = bench::bench(budget, 8, || {
            let mut y = 0.0;
            for _ in 0..reps {
                y += simd::dot_with(Backend::Scalar, &a, &b);
            }
            bench::black_box(y);
        });
        let si = bench::bench(budget, 8, || {
            let mut y = 0.0;
            for _ in 0..reps {
                y += simd::dot_with(be, &a, &b);
            }
            bench::black_box(y);
        });
        kernel_row("dot", n, elems, &sc, &si, &mut rec, &mut rows);
        let sc = bench::bench(budget, 8, || {
            let mut y = 0.0;
            for _ in 0..reps {
                y += simd::sqdist_with(Backend::Scalar, &a, &b);
            }
            bench::black_box(y);
        });
        let si = bench::bench(budget, 8, || {
            let mut y = 0.0;
            for _ in 0..reps {
                y += simd::sqdist_with(be, &a, &b);
            }
            bench::black_box(y);
        });
        kernel_row("sqdist", n, elems, &sc, &si, &mut rec, &mut rows);

        // dequant reductions: the quantized-page scoring path (--kv-quant)
        // — dot straight out of f16- / int8-packed rows, scalar vs lanes.
        let mut encf16 = vec![0f32; KvQuant::F16.enc_row_elems(n)];
        let mut enci8 = vec![0f32; KvQuant::Int8.enc_row_elems(n)];
        KvQuant::F16.encode_row(&b, &mut encf16);
        KvQuant::Int8.encode_row(&b, &mut enci8);
        let i8scale = enci8[0];
        let i8body = &enci8[1..];
        let sc = bench::bench(budget, 8, || {
            let mut y = 0.0;
            for _ in 0..reps {
                y += simd::dot_dequant_f16_with(Backend::Scalar, &a, &encf16);
            }
            bench::black_box(y);
        });
        let si = bench::bench(budget, 8, || {
            let mut y = 0.0;
            for _ in 0..reps {
                y += simd::dot_dequant_f16_with(be, &a, &encf16);
            }
            bench::black_box(y);
        });
        kernel_row("dot_dq_f16", n, elems, &sc, &si, &mut rec, &mut rows);
        let sc = bench::bench(budget, 8, || {
            let mut y = 0.0;
            for _ in 0..reps {
                y += simd::dot_dequant_i8_with(Backend::Scalar, &a, i8body, i8scale);
            }
            bench::black_box(y);
        });
        let si = bench::bench(budget, 8, || {
            let mut y = 0.0;
            for _ in 0..reps {
                y += simd::dot_dequant_i8_with(be, &a, i8body, i8scale);
            }
            bench::black_box(y);
        });
        kernel_row("dot_dq_i8", n, elems, &sc, &si, &mut rec, &mut rows);

        // axpy: the AV-accumulate of every attention kernel (elementwise,
        // so the vector arm is bit-identical — only speed differs).
        let mut acc = vec![0f32; n];
        let sc = bench::bench(budget, 8, || {
            for _ in 0..reps {
                simd::axpy_with(Backend::Scalar, &mut acc, 0.5, &a);
            }
            bench::black_box(&acc);
        });
        let si = bench::bench(budget, 8, || {
            for _ in 0..reps {
                simd::axpy_with(be, &mut acc, 0.5, &a);
            }
            bench::black_box(&acc);
        });
        kernel_row("axpy", n, elems, &sc, &si, &mut rec, &mut rows);

        // ssm_step: one mamba channel step over an n-state row (decay < 1
        // keeps the carried state bounded across benchmark iterations).
        let mut hrow = vec![0f32; n];
        let mut bb = vec![0f32; n];
        let mut cc = vec![0f32; n];
        rng.fill_normal(&mut hrow, 1.0);
        rng.fill_normal(&mut bb, 1.0);
        rng.fill_normal(&mut cc, 1.0);
        let mut decay = vec![0f32; n];
        for (s, dec) in decay.iter_mut().enumerate() {
            *dec = (-0.3 * (s + 1) as f32 / n as f32).exp();
        }
        let sc = bench::bench(budget, 8, || {
            let mut y = 0.0;
            for _ in 0..reps {
                y = simd::ssm_step_with(Backend::Scalar, &decay, &bb, &cc, 0.3, 0.9, &mut hrow);
            }
            bench::black_box(y);
        });
        let si = bench::bench(budget, 8, || {
            let mut y = 0.0;
            for _ in 0..reps {
                y = simd::ssm_step_with(be, &decay, &bb, &cc, 0.3, 0.9, &mut hrow);
            }
            bench::black_box(y);
        });
        kernel_row("ssm_step", n, elems, &sc, &si, &mut rec, &mut rows);

        // interleave: n Morton codes at d = 3 (the paper's d_K). The fast
        // path is bit-identical to scalar, so only the timing differs.
        let bits = zorder::bits_for_dim(3);
        let mask = (1u32 << bits) - 1;
        let coords: Vec<u32> = (0..3 * n).map(|_| rng.next_u32() & mask).collect();
        let sc = bench::bench(budget, 8, || {
            let mut acc = 0u32;
            for c in coords.chunks_exact(3) {
                acc ^= simd::interleave_with(Backend::Scalar, c, bits);
            }
            bench::black_box(acc);
        });
        let si = bench::bench(budget, 8, || {
            let mut acc = 0u32;
            for c in coords.chunks_exact(3) {
                acc ^= simd::interleave_with(be, c, bits);
            }
            bench::black_box(acc);
        });
        kernel_row("interleave", n, n as f64, &sc, &si, &mut rec, &mut rows);

        // cauchy_row: the ZETA scoring row (d_k = 3, dv = 64, n/64
        // candidates) — the dispatched routine vs its scalar replica.
        let (dk, dv) = (3usize, 64usize);
        let nc = (n / 64).max(1);
        let mut qi = vec![0f32; dk];
        let mut km = vec![0f32; dk];
        let mut vm = vec![0f32; dv];
        let mut klbuf = vec![0f32; nc * dk];
        let mut vbuf = vec![0f32; nc * dv];
        rng.fill_normal(&mut qi, 1.0);
        rng.fill_normal(&mut km, 1.0);
        rng.fill_normal(&mut vm, 1.0);
        rng.fill_normal(&mut klbuf, 1.0);
        rng.fill_normal(&mut vbuf, 1.0);
        let irow: Vec<u32> = (0..nc as u32).collect();
        let kl = FlatRows { data: &klbuf, width: dk };
        let vstore = FlatRows { data: &vbuf, width: dv };
        let mut scores = vec![0f32; nc];
        let mut orow = vec![0f32; dv];
        let elems = (reps * nc * (dk + dv)) as f64;
        let sc = bench::bench(budget, 8, || {
            let mut z = 0.0;
            for _ in 0..reps {
                z = cauchy_row_scalar(
                    0.5,
                    &irow,
                    &qi,
                    &kl,
                    &km,
                    &vm,
                    &vstore,
                    &mut scores,
                    &mut orow,
                );
            }
            bench::black_box(z);
        });
        let si = bench::bench(budget, 8, || {
            let mut z = 0.0;
            for _ in 0..reps {
                z = cauchy_row(0.5, &irow, &qi, &kl, &km, &vm, &vstore, &mut scores, &mut orow);
            }
            bench::black_box(z);
        });
        kernel_row("cauchy_row", n, elems, &sc, &si, &mut rec, &mut rows);

        // softmax row: n/128 keys at d = dv = 64 — the exact-attention
        // decode-step shape.
        let nk = (n / 128).max(1);
        let d = 64usize;
        let mut q = vec![0f32; d];
        let mut kbuf = vec![0f32; nk * d];
        let mut vrows = vec![0f32; nk * dv];
        rng.fill_normal(&mut q, 1.0);
        rng.fill_normal(&mut kbuf, 1.0);
        rng.fill_normal(&mut vrows, 1.0);
        let mut skey = vec![0f32; nk];
        let elems = (reps * nk * (d + dv)) as f64;
        let sc = bench::bench(budget, 8, || {
            for _ in 0..reps {
                softmax_row(Backend::Scalar, &q, &kbuf, &vrows, d, dv, &mut skey, &mut orow);
            }
            bench::black_box(&orow);
        });
        let si = bench::bench(budget, 8, || {
            for _ in 0..reps {
                softmax_row(be, &q, &kbuf, &vrows, d, dv, &mut skey, &mut orow);
            }
            bench::black_box(&orow);
        });
        kernel_row("softmax_row", n, elems, &sc, &si, &mut rec, &mut rows);
    }
    rec.insert("backend".into(), Json::str(be.name()));
    rec.insert("lanes".into(), Json::num(be.lanes() as f64));
    record(opts, "kernels", Json::Obj(rec))?;
    write_bench(opts, "kernels", rows);
    Ok(())
}

// ---------------------------------------------------------------------------
// Mem — paged decode-state memory: paging overhead, prefix-cache speedup,
// eviction-thrash throughput
// ---------------------------------------------------------------------------

/// Pre-arena flat `Vec`-backed exact-KV decode state, kept here verbatim as
/// the baseline the paged refactor is priced against (same arithmetic as
/// `attention::naive::ExactKvDecode`, contiguous storage instead of pages).
struct FlatExactKv {
    d: usize,
    dv: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    scores: Vec<f32>,
    t: usize,
}

impl FlatExactKv {
    fn new(d: usize, dv: usize) -> FlatExactKv {
        FlatExactKv { d, dv, k: Vec::new(), v: Vec::new(), scores: Vec::new(), t: 0 }
    }

    fn step(&mut self, q_t: &[f32], k_t: &[f32], v_t: &[f32], out: &mut [f32]) {
        use crate::tensor::dot;
        let (d, dv) = (self.d, self.dv);
        self.k.extend_from_slice(k_t);
        self.v.extend_from_slice(v_t);
        let t = self.t;
        self.t += 1;
        let scale = 1.0 / (d as f32).sqrt();
        self.scores.clear();
        let mut maxv = f32::NEG_INFINITY;
        for j in 0..=t {
            let s = dot(q_t, &self.k[j * d..(j + 1) * d]) * scale;
            self.scores.push(s);
            maxv = maxv.max(s);
        }
        let mut z = 0.0;
        for s in self.scores.iter_mut() {
            *s = (*s - maxv).exp();
            z += *s;
        }
        let inv = 1.0 / z;
        for o in out.iter_mut() {
            *o = 0.0;
        }
        for j in 0..=t {
            let a = self.scores[j] * inv;
            let vr = &self.v[j * dv..(j + 1) * dv];
            for (o, &vv) in out.iter_mut().zip(vr) {
                *o += a * vv;
            }
        }
    }
}

/// `exp mem`: the serving-memory benchmark behind the paged KV arena.
/// (a) *paged vs flat* per-token decode step cost on the exact-KV state
/// (the memory-heaviest kernel state — prices the page-indirection
/// overhead); (b) *prefix-cache hit speedup*: forking a cached page-aligned
/// prompt prefix vs re-prefilling the whole prompt; (c) *eviction-thrash
/// throughput*: a session wave generating under a deliberately tight
/// `--kv-mem-budget` (constant preemption + re-prefill) vs unlimited.
/// Writes `results/mem.json` and the machine-readable `BENCH_mem.json`.
pub fn mem(opts: &Opts) -> Result<()> {
    use crate::coordinator::metrics::Metrics;
    use crate::coordinator::{
        NativeDecodeModel, NativeModelConfig, NativeServing, PrefixCache,
    };
    use std::sync::{Arc, Mutex};

    let mut rec = BTreeMap::new();
    let mut bench_rows: Vec<Json> = Vec::new();
    let budget = Duration::from_millis(300);

    // (a) Paged vs flat per-token step cost.
    let (d, dv) = (64usize, 64usize);
    println!("\n== Mem: paged vs flat per-token decode step cost (exact-KV state) ==");
    println!("{:<8}{:>14}{:>14}{:>10}", "ctx", "flat µs", "paged µs", "ratio");
    for &n in &[512usize, 2048] {
        if n > opts.max_len {
            continue;
        }
        let w = Workload::random(n, d, dv, opts.seed);
        let tail = n - n / 4;
        let mut out = vec![0f32; dv];
        let mut flat = FlatExactKv::new(d, dv);
        for t in 0..tail {
            flat.step(w.q.row(t), w.k.row(t), w.v.row(t), &mut out);
        }
        let t0 = Instant::now();
        for t in tail..n {
            flat.step(w.q.row(t), w.k.row(t), w.v.row(t), &mut out);
        }
        let flat_us = t0.elapsed().as_secs_f64() * 1e6 / (n - tail) as f64;
        bench::black_box(&out);
        let mut st = Naive.begin_decode(d, dv);
        for t in 0..tail {
            st.step(w.q.row(t), w.k.row(t), w.v.row(t), &mut out);
        }
        let t0 = Instant::now();
        for t in tail..n {
            st.step(w.q.row(t), w.k.row(t), w.v.row(t), &mut out);
        }
        let paged_us = t0.elapsed().as_secs_f64() * 1e6 / (n - tail) as f64;
        bench::black_box(&out);
        let ratio = paged_us / flat_us.max(1e-9);
        println!("{n:<8}{flat_us:>14.2}{paged_us:>14.2}{ratio:>9.2}x");
        rec.insert(
            format!("paged_vs_flat_ctx{n}"),
            Json::obj(vec![
                ("flat_us", Json::num(flat_us)),
                ("paged_us", Json::num(paged_us)),
            ]),
        );
        bench_rows.push(Json::obj(vec![
            ("bench", Json::str("paged_vs_flat")),
            ("ctx", Json::num(n as f64)),
            ("flat_us_per_tok", Json::num(flat_us)),
            ("paged_us_per_tok", Json::num(paged_us)),
            ("paged_over_flat", Json::num(ratio)),
        ]));
    }

    // (b) Prefix-cache hit speedup: fork the cached page-aligned prompt
    // prefix vs re-prefilling the full prompt from scratch.
    let model = NativeDecodeModel::new(NativeModelConfig {
        kernel: "zeta".into(),
        d: 64,
        dv: 64,
        vocab: 1024,
        seed: opts.seed,
        max_context: 0,
        ..Default::default()
    })?;
    let page = model.page_tokens();
    let prompt: Vec<i32> = (0..4 * page).map(|i| ((i * 31 + 7) % 1024) as i32).collect();
    let boundary = ((prompt.len() - 1) / page) * page;
    let (mut orow, mut logits) = (Vec::new(), Vec::new());
    let mut base = model.begin();
    for &t in &prompt[..boundary] {
        model.step_token(base.as_mut(), t, &mut orow, &mut logits);
    }
    let mut pc = PrefixCache::new(page, 4);
    pc.insert(&prompt[..boundary], base.fork());
    let cold = bench::bench(budget, 4, || {
        let mut st = model.begin();
        for &t in &prompt {
            model.step_token(st.as_mut(), t, &mut orow, &mut logits);
        }
        bench::black_box(&logits);
    });
    let hit = bench::bench(budget, 4, || {
        let (l, mut st) = pc.lookup(&prompt[..prompt.len() - 1]).expect("cached prefix");
        for &t in &prompt[l..] {
            model.step_token(st.as_mut(), t, &mut orow, &mut logits);
        }
        bench::black_box(&logits);
    });
    let (cold_us, hit_us) = (cold.median_us(), hit.median_us());
    println!(
        "\n== Mem: prompt-prefix cache — {}-token prompt, {boundary}-token cached prefix ==",
        prompt.len()
    );
    println!(
        "cold prefill {cold_us:.1} µs  vs  fork+tail {hit_us:.1} µs  ({:.2}x speedup)",
        cold_us / hit_us.max(1e-9)
    );
    rec.insert(
        "prefix_cache".into(),
        Json::obj(vec![("cold_us", Json::num(cold_us)), ("hit_us", Json::num(hit_us))]),
    );
    bench_rows.push(Json::obj(vec![
        ("bench", Json::str("prefix_cache")),
        ("prompt_tokens", Json::num(prompt.len() as f64)),
        ("cached_tokens", Json::num(boundary as f64)),
        ("cold_us", Json::num(cold_us)),
        ("hit_us", Json::num(hit_us)),
        ("speedup", Json::num(cold_us / hit_us.max(1e-9))),
    ]));

    // (c) Eviction-thrash throughput: a wave of sessions generating under
    // a tight byte budget (constant LRU preemption + transparent
    // re-prefill) vs the same wave unconstrained, through the same
    // `NativeServing::drive_to_completion` harness the paged-state gate
    // uses.
    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|s| (0..100).map(|i| ((i * 13 + s * 29 + 7) % 31) as i32).collect())
        .collect();
    let drive = |kv_budget: usize| -> Result<(f64, u64, u64, usize)> {
        let model = NativeDecodeModel::new(NativeModelConfig {
            kernel: "naive".into(),
            seed: opts.seed,
            max_context: 0,
            ..Default::default()
        })?;
        let mut serving = NativeServing::new(model, kv_budget, 32);
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let t0 = Instant::now();
        let streams = serving.drive_to_completion(&prompts, 32, &metrics, &Pool::serial());
        let elapsed = t0.elapsed().as_secs_f64();
        let tokens: u64 = streams.iter().map(|s| s.len() as u64).sum();
        let (evictions, hw) = {
            let m = metrics.lock().unwrap();
            (m.evictions, m.arena_high_water_bytes)
        };
        Ok((tokens as f64 / elapsed.max(1e-9), tokens, evictions, hw))
    };
    // ~1.6 sessions' worth of pages: all four admit while small, then
    // thrash as their contexts grow past the budget.
    let tight = 26_000usize;
    let (free_tps, free_toks, _, free_hw) = drive(0)?;
    let (tight_tps, tight_toks, tight_ev, tight_hw) = drive(tight)?;
    println!("\n== Mem: eviction-thrash throughput (4 sessions, naive exact-KV) ==");
    println!(
        "{:<14}{:>12}{:>12}{:>12}{:>14}",
        "budget", "tok/s", "tokens", "evictions", "arena hw B"
    );
    println!("{:<14}{free_tps:>12.0}{free_toks:>12}{:>12}{free_hw:>14}", "unlimited", 0);
    println!("{tight:<14}{tight_tps:>12.0}{tight_toks:>12}{tight_ev:>12}{tight_hw:>14}");
    println!(
        "thrash cost: {:.2}x slower under the tight budget ({tight_ev} preemptions)",
        free_tps / tight_tps.max(1e-9)
    );
    rec.insert(
        "eviction_thrash".into(),
        Json::obj(vec![
            ("free_toks_per_sec", Json::num(free_tps)),
            ("tight_toks_per_sec", Json::num(tight_tps)),
            ("evictions", Json::num(tight_ev as f64)),
        ]),
    );
    bench_rows.push(Json::obj(vec![
        ("bench", Json::str("eviction_thrash")),
        ("budget_bytes", Json::num(tight as f64)),
        ("free_toks_per_sec", Json::num(free_tps)),
        ("tight_toks_per_sec", Json::num(tight_tps)),
        ("slowdown", Json::num(free_tps / tight_tps.max(1e-9))),
        ("evictions", Json::num(tight_ev as f64)),
        ("free_arena_hw_bytes", Json::num(free_hw as f64)),
        ("tight_arena_hw_bytes", Json::num(tight_hw as f64)),
    ]));

    // (d) KV codec matrix: per-codec paged step cost and bytes/token on
    // the exact-KV state, plus admission headroom at a fixed byte budget
    // (the --kv-quant economics). The f32 row is the same measurement as
    // paged_vs_flat's paged column — the pre-codec baseline.
    let n = 512usize.min(opts.max_len.max(128));
    println!("\n== Mem: KV codec matrix (exact-KV state, ctx {n}) ==");
    println!("{:<8}{:>14}{:>14}{:>16}", "codec", "step µs/tok", "bytes/tok", "sessions@1MiB");
    let wq = Workload::random(n, d, dv, opts.seed);
    for quant in [KvQuant::F32, KvQuant::F16, KvQuant::Int8] {
        let arena = PageArena::new_quant(DEFAULT_PAGE_TOKENS, quant);
        let mut st = Naive.begin_decode_in(d, dv, &arena);
        let mut out = vec![0f32; dv];
        let tail = n - n / 4;
        for t in 0..tail {
            st.step(wq.q.row(t), wq.k.row(t), wq.v.row(t), &mut out);
        }
        let t0 = Instant::now();
        for t in tail..n {
            st.step(wq.q.row(t), wq.k.row(t), wq.v.row(t), &mut out);
        }
        let step_us = t0.elapsed().as_secs_f64() * 1e6 / (n - tail) as f64;
        bench::black_box(&out);
        let bytes_per_tok = arena.stats().live_bytes as f64 / n as f64;
        // Admission headroom: how many ~100-token sessions the byte-budget
        // gate admits into 1 MiB, using the same codec-aware estimate the
        // scheduler uses.
        let qmodel = NativeDecodeModel::new(NativeModelConfig {
            kv_quant: quant.name().into(),
            ..Default::default()
        })?;
        let sessions = (1usize << 20) / qmodel.estimate_state_bytes(100).max(1);
        println!("{:<8}{step_us:>14.2}{bytes_per_tok:>14.1}{sessions:>16}", quant.name());
        rec.insert(
            format!("quant_{}", quant.name()),
            Json::obj(vec![
                ("step_us_per_tok", Json::num(step_us)),
                ("bytes_per_tok", Json::num(bytes_per_tok)),
                ("sessions_at_1mib_100tok", Json::num(sessions as f64)),
            ]),
        );
        bench_rows.push(Json::obj(vec![
            ("bench", Json::str("quant_matrix")),
            ("codec", Json::str(quant.name())),
            ("ctx", Json::num(n as f64)),
            ("step_us_per_tok", Json::num(step_us)),
            ("bytes_per_tok", Json::num(bytes_per_tok)),
            ("sessions_at_1mib_100tok", Json::num(sessions as f64)),
        ]));
    }

    record(opts, "mem", Json::Obj(rec))?;
    write_bench(opts, "mem", bench_rows);
    Ok(())
}

// ---------------------------------------------------------------------------
// Scenarios — seeded serving-trace record/replay suite
// ---------------------------------------------------------------------------

/// `exp scenarios`: the serving-scenario suite. Generates the four seeded
/// workload traces (needle retrieval, agent fleet, bursty chat,
/// cancellation storm), writes each as JSONL under `--out-dir`, then
/// replays each three ways and scores every replay into
/// `BENCH_scenarios.json`:
///
/// 1. **lockstep ×2** — the deterministic virtual-clock replay, run
///    twice; the second run must reproduce the first's stream digest and
///    counters bit-for-bit (the record/replay contract), and on the
///    default `f32` codec every non-cancelled stream must equal the
///    reference stream recorded into the trace at generation time.
/// 2. **lockstep under a tight `--kv-mem-budget`** — eviction/re-prefill
///    pressure must leave every token stream identical to the
///    unconstrained replay.
/// 3. **serve** — the same trace through the real coordinator
///    ([`crate::coordinator::Server`]), where tokens/s and TTFT p50/p99
///    are wall-clock-real; gated on invariants only (token accounting
///    balances, the arena drains to zero pages after shutdown).
pub fn scenarios(opts: &Opts) -> Result<()> {
    use crate::scenario::replay::{lockstep, score, serve, ReplayCfg, Score};
    use crate::scenario::{scenarios as registry, GenCfg};

    let ctx = opts.max_len.clamp(64, 512);
    let gen_cfg = GenCfg { seed: opts.seed, kernel: "zeta".into(), requests: 16, ctx };
    let cfg = ReplayCfg {
        threads: opts.threads,
        kv_quant: opts.kv_quant.clone(),
        ..ReplayCfg::default()
    };
    // Tight enough to force evictions at these context lengths, roomy
    // enough that the largest single session still fits.
    let tight_budget = if opts.kv_mem_budget > 0 { opts.kv_mem_budget } else { 256 * 1024 };
    let exact = cfg.kv_quant == "f32"; // quantized codecs diverge from the
                                       // f32-recorded reference streams
    println!(
        "\n== Scenarios: seeded serving traces — record/replay + regression scores \
         (ctx {ctx}, {} requests/scenario base, budget arm {tight_budget} B) ==",
        gen_cfg.requests
    );
    let mut rec = BTreeMap::new();
    let mut bench_rows: Vec<Json> = Vec::new();
    let push_row = |s: &Score, budget: usize, rows: &mut Vec<Json>| {
        let mut j = s.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("kv_mem_budget".into(), Json::num(budget as f64));
        }
        rows.push(j);
    };
    std::fs::create_dir_all(&opts.out_dir)?;
    for sc in registry() {
        let trace = sc.generate(&gen_cfg)?;
        let path = format!("{}/trace_{}.jsonl", opts.out_dir, trace.name);
        trace.write(&path)?;
        if opts.verbose {
            eprintln!("  {}: {} — {} requests -> {path}", sc.name(), sc.description(),
                trace.requests.len());
        }

        // (1) lockstep ×2: record/replay bit-reproducibility.
        let a = lockstep(&trace, &cfg)?;
        let b = lockstep(&trace, &cfg)?;
        if a.stream_digest() != b.stream_digest() || a.counters != b.counters {
            bail!(
                "scenario {} lockstep replay is not reproducible: digest {:016x} vs {:016x}",
                trace.name,
                a.stream_digest(),
                b.stream_digest()
            );
        }
        if !a.counters.balanced() {
            bail!(
                "scenario {}: token accounting unbalanced ({} + {} != {})",
                trace.name,
                a.counters.delivered,
                a.counters.dropped,
                a.counters.stepped
            );
        }
        if a.live_pages_after_teardown != 0 {
            bail!(
                "scenario {}: {} arena pages leaked after teardown",
                trace.name,
                a.live_pages_after_teardown
            );
        }
        let sa = score(&trace, &a);
        if exact && sa.expect_ok != sa.expect_total {
            bail!(
                "scenario {}: only {}/{} replayed streams match the recorded reference",
                trace.name,
                sa.expect_ok,
                sa.expect_total
            );
        }
        println!("{}", sa.line());
        rec.insert(
            format!("{}_lockstep_digest", trace.name),
            Json::str(format!("{:016x}", sa.stream_digest)),
        );
        rec.insert(format!("{}_evictions", trace.name), Json::num(sa.counters.evictions as f64));
        rec.insert(
            format!("{}_prefix_hits", trace.name),
            Json::num(sa.counters.prefix_hits as f64),
        );
        push_row(&sa, 0, &mut bench_rows);

        // (2) budget-constrained lockstep: eviction pressure must not
        // change a single output token.
        let bcfg = ReplayCfg { kv_mem_budget: tight_budget, ..cfg.clone() };
        let c = lockstep(&trace, &bcfg)?;
        if c.stream_digest() != a.stream_digest() {
            bail!(
                "scenario {}: budget-constrained replay diverged from unconstrained \
                 ({:016x} vs {:016x}, {} evictions)",
                trace.name,
                c.stream_digest(),
                a.stream_digest(),
                c.counters.evictions
            );
        }
        let sb = score(&trace, &c);
        println!("{}  [budget {tight_budget} B]", sb.line());
        rec.insert(
            format!("{}_budget_evictions", trace.name),
            Json::num(sb.counters.evictions as f64),
        );
        push_row(&sb, tight_budget, &mut bench_rows);

        // (3) serve: the real coordinator, wall-clock scores.
        let d = serve(&trace, &cfg)?;
        if !d.counters.balanced() {
            bail!(
                "scenario {} (serve): token accounting unbalanced ({} + {} != {})",
                trace.name,
                d.counters.delivered,
                d.counters.dropped,
                d.counters.stepped
            );
        }
        if d.live_pages_after_teardown != 0 {
            bail!(
                "scenario {} (serve): {} arena pages leaked after shutdown",
                trace.name,
                d.live_pages_after_teardown
            );
        }
        let sd = score(&trace, &d);
        println!("{}", sd.line());
        rec.insert(format!("{}_serve_tok_per_sec", trace.name), Json::num(sd.tok_per_sec));
        rec.insert(
            format!("{}_serve_ttft_p50_us", trace.name),
            Json::num(sd.ttft_p50_us as f64),
        );
        push_row(&sd, 0, &mut bench_rows);
    }
    println!(
        "(lockstep rows are bit-reproducible for a fixed seed at any thread count; \
         serve rows carry real wall-clock timing)"
    );
    record(opts, "scenarios", Json::Obj(rec))?;
    write_bench(opts, "scenarios", bench_rows);
    Ok(())
}

// ---------------------------------------------------------------------------
// Speculative decoding — accept-rate × speedup matrix (BENCH_spec.json)
// ---------------------------------------------------------------------------

/// `exp spec`: the speculative-decoding matrix on the `spec` trace —
/// draft source × draft length {2,4,8} × threads {1,4,8}. A lockstep
/// pre-gate first proves both draft sources leave the token streams
/// bit-identical to `--speculate off` (else the timing is meaningless);
/// the timing arms then run serve replays and report tok/s, speedup over
/// the same-thread-count non-speculative baseline, and accept rate.
pub fn spec(opts: &Opts) -> Result<()> {
    use crate::scenario::replay::{lockstep, serve, ReplayCfg};
    use crate::scenario::{GenCfg, Scenario};

    let ctx = opts.max_len.clamp(64, 512);
    let gen_cfg = GenCfg { seed: opts.seed, kernel: "zeta".into(), requests: 16, ctx };
    let trace = crate::scenario::gen::Spec.generate(&gen_cfg)?;
    std::fs::create_dir_all(&opts.out_dir)?;
    trace.write(&format!("{}/trace_spec.jsonl", opts.out_dir))?;
    println!(
        "\n== Speculative decoding: draft source × draft length × threads on the spec \
         trace (ctx {ctx}, {} requests) ==",
        trace.requests.len()
    );
    let base = ReplayCfg {
        threads: opts.threads,
        kv_quant: opts.kv_quant.clone(),
        ..ReplayCfg::default()
    };

    // Correctness pre-gate: a speculative lockstep replay must be
    // bit-identical to the plain one before any of its timing counts.
    let off_lock = lockstep(&trace, &base)?;
    for source in ["mamba", "self"] {
        let cfg = ReplayCfg { speculate: source.into(), draft_len: 4, ..base.clone() };
        let out = lockstep(&trace, &cfg)?;
        if out.stream_digest() != off_lock.stream_digest() {
            bail!(
                "--speculate {source} changed the token streams ({:016x} vs {:016x})",
                out.stream_digest(),
                off_lock.stream_digest()
            );
        }
        if out.counters.drafted == 0 {
            bail!("--speculate {source} never drafted a token on the spec trace");
        }
    }

    let mut rows: Vec<Json> = Vec::new();
    let mut rec = BTreeMap::new();
    println!(
        "{:<7}{:>4}{:>9}{:>12}{:>10}{:>9}",
        "source", "L", "threads", "tok/s", "speedup", "accept"
    );
    for &threads in &[1usize, 4, 8] {
        let off_run = serve(&trace, &ReplayCfg { threads, ..base.clone() })?;
        let off_tps = off_run.tok_per_sec;
        println!("{:<7}{:>4}{threads:>9}{off_tps:>12.0}{:>9.2}x{:>9}", "off", "-", 1.0, "-");
        rows.push(Json::obj(vec![
            ("scenario", Json::str("spec")),
            ("source", Json::str("off")),
            ("draft_len", Json::num(0.0)),
            ("threads", Json::num(threads as f64)),
            ("tok_per_sec", Json::num(off_tps)),
            ("speedup_vs_off", Json::num(1.0)),
            ("accept_rate", Json::num(0.0)),
            ("drafted_tokens", Json::num(0.0)),
            ("accepted_tokens", Json::num(0.0)),
        ]));
        for source in ["mamba", "self"] {
            for &l in &[2usize, 4, 8] {
                let cfg = ReplayCfg {
                    threads,
                    speculate: source.into(),
                    draft_len: l,
                    ..base.clone()
                };
                let run = serve(&trace, &cfg)?;
                let c = &run.counters;
                let accept =
                    if c.drafted == 0 { 0.0 } else { c.accepted as f64 / c.drafted as f64 };
                let speedup = if off_tps > 0.0 { run.tok_per_sec / off_tps } else { 0.0 };
                println!(
                    "{source:<7}{l:>4}{threads:>9}{:>12.0}{speedup:>9.2}x{accept:>9.2}",
                    run.tok_per_sec
                );
                rec.insert(format!("{source}_l{l}_t{threads}_speedup"), Json::num(speedup));
                rec.insert(format!("{source}_l{l}_t{threads}_accept"), Json::num(accept));
                rows.push(Json::obj(vec![
                    ("scenario", Json::str("spec")),
                    ("source", Json::str(source)),
                    ("draft_len", Json::num(l as f64)),
                    ("threads", Json::num(threads as f64)),
                    ("tok_per_sec", Json::num(run.tok_per_sec)),
                    ("speedup_vs_off", Json::num(speedup)),
                    ("accept_rate", Json::num(accept)),
                    ("drafted_tokens", Json::num(c.drafted as f64)),
                    ("accepted_tokens", Json::num(c.accepted as f64)),
                ]));
            }
        }
    }
    println!(
        "(accepted streams are bit-identical to --speculate off — the pre-gate and \
         rust/tests/spec_decode.rs pin it; speedup is serve-replay wall-clock against \
         the same-thread-count baseline)"
    );
    record(opts, "spec", Json::Obj(rec))?;
    write_bench(opts, "spec", rows);
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 5 — d_K ablation on ListOps / Image
// ---------------------------------------------------------------------------

pub fn table5(engine: &Engine, opts: &Opts) -> Result<()> {
    let tasks = ["listops", "image"];
    let dks = [1usize, 2, 3, 32];
    let mut rows = Vec::new();
    let mut rec = BTreeMap::new();
    for task in tasks {
        let mut cells = Vec::new();
        for dk in dks {
            let preset = format!("table5_{task}_dk{dk}");
            let acc = train_eval_accuracy(engine, &preset, opts)? * 100.0;
            eprintln!("  table5 {task} d_K={dk}: {acc:.2}%");
            cells.push(format!("{acc:>7.2}"));
            rec.insert(format!("{task}_dk{dk}"), Json::num(acc));
        }
        rows.push((task.to_string(), cells));
    }
    print_matrix(
        "Table 5: attention accuracy vs d_K on LRA-style tasks (%)",
        &format!("{:<24}{}", "task", " d_K=1   d_K=2   d_K=3  d_K=32"),
        &rows,
    );
    record(opts, "table5", Json::Obj(rec))
}

/// Run every experiment in sequence (the paper's full evaluation).
pub fn all(engine: &Engine, opts: &Opts) -> Result<()> {
    fig2a(engine, opts)?;
    fig2b(engine, opts)?;
    fig2c(engine, opts)?;
    fig2d(engine, opts)?;
    fig3(opts)?;
    table1(engine, opts)?;
    table2(engine, opts)?;
    table3(opts)?;
    table4(opts)?;
    kernels(opts)?;
    decode(opts)?;
    prefill(opts)?;
    pool(opts)?;
    mem(opts)?;
    scenarios(opts)?;
    spec(opts)?;
    table5(engine, opts)?;
    Ok(())
}
