//! Streaming generation sessions — the L3 surface of the incremental
//! decode engine.
//!
//! A [`Session`] is the continuous-batching unit: one per in-flight
//! `generate` request, holding the request's tokens and (on the native
//! backend) its kernel-level [`DecodeState`] — the per-request KV cache /
//! Z-order index. The scheduler advances every active session by one
//! micro-batch per sweep (a prefill slice or a single decode step), so
//! prefill and decode interleave instead of head-of-line blocking.
//!
//! [`NativeDecodeModel`] is the engine that makes streaming generation run
//! *offline*: a deterministic token model over the native attention
//! kernels. Token embeddings and the readout are fixed seeded tables, and
//! decoding is argmax, so the incremental decode path and a full-recompute
//! forward must produce the *same token stream* — the session-level
//! equivalence gate. (The PJRT backend serves `generate` by full-recompute
//! forward batches instead; see `coordinator::engine_decode_sweep`.)

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::attention::{flash::Flash, mamba::MambaLite, naive::Naive, zeta::ZetaNative};
use crate::attention::{AttentionImpl, DecodeState, Workload};
use crate::tensor::{dot, Tensor};
use crate::util::pool::Pool;
use crate::util::rng::Rng;

/// Configuration of the in-process native decode backend.
#[derive(Debug, Clone)]
pub struct NativeModelConfig {
    /// Attention kernel: "zeta" | "naive" | "flash" | "mamba".
    pub kernel: String,
    /// q/k width fed to the kernel.
    pub d: usize,
    /// Value / output width.
    pub dv: usize,
    /// Token vocabulary size.
    pub vocab: usize,
    /// Seed of the fixed embedding / readout tables.
    pub seed: u64,
}

impl Default for NativeModelConfig {
    fn default() -> Self {
        NativeModelConfig { kernel: "zeta".into(), d: 16, dv: 16, vocab: 32, seed: 0 }
    }
}

/// Deterministic kernel-backed token model: embed -> attention kernel ->
/// linear readout -> argmax. Everything is a fixed seeded table, so the
/// model needs no artifacts, runs offline, and generation is exactly
/// reproducible — incremental decode vs full-recompute forward is a pure
/// scheduling difference.
pub struct NativeDecodeModel {
    imp: Box<dyn AttentionImpl>,
    cfg: NativeModelConfig,
    qe: Vec<f32>, // (vocab, d)
    ke: Vec<f32>, // (vocab, d)
    ve: Vec<f32>, // (vocab, dv)
    ro: Vec<f32>, // (vocab, dv) readout
}

impl NativeDecodeModel {
    pub fn new(cfg: NativeModelConfig) -> Result<NativeDecodeModel> {
        if cfg.vocab == 0 || cfg.d == 0 || cfg.dv == 0 {
            bail!("native model dims must be non-zero");
        }
        let imp: Box<dyn AttentionImpl> = match cfg.kernel.as_str() {
            "naive" => Box::new(Naive),
            "flash" => Box::new(Flash { block: 64 }),
            // chunk 16: fine-grained causal limits so short serving prompts
            // already exercise the windowed search.
            "zeta" => Box::new(ZetaNative { chunk: 16, ..ZetaNative::default() }),
            "mamba" => Box::new(MambaLite::default()),
            other => bail!("unknown native kernel {other:?} (want zeta|naive|flash|mamba)"),
        };
        let mut rng = Rng::new(cfg.seed ^ 0x5E55_1015);
        let mut qe = vec![0f32; cfg.vocab * cfg.d];
        let mut ke = vec![0f32; cfg.vocab * cfg.d];
        let mut ve = vec![0f32; cfg.vocab * cfg.dv];
        let mut ro = vec![0f32; cfg.vocab * cfg.dv];
        rng.fill_normal(&mut qe, 1.0);
        rng.fill_normal(&mut ke, 1.0);
        rng.fill_normal(&mut ve, 1.0);
        rng.fill_normal(&mut ro, 1.0);
        Ok(NativeDecodeModel { imp, cfg, qe, ke, ve, ro })
    }

    pub fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    pub fn kernel_name(&self) -> &'static str {
        self.imp.name()
    }

    /// Fresh per-request decode state (the kernel-level KV cache).
    pub fn begin(&self) -> Box<dyn DecodeState> {
        self.imp.begin_decode(self.cfg.d, self.cfg.dv)
    }

    fn embed_rows(&self, tok: i32) -> (&[f32], &[f32], &[f32]) {
        let (d, dv) = (self.cfg.d, self.cfg.dv);
        let t = tok.rem_euclid(self.cfg.vocab as i32) as usize;
        (
            &self.qe[t * d..(t + 1) * d],
            &self.ke[t * d..(t + 1) * d],
            &self.ve[t * dv..(t + 1) * dv],
        )
    }

    /// Feed one token through the decode state; `logits` afterwards hold
    /// the next-token distribution. `orow`/`logits` are caller scratch.
    pub fn step_token(
        &self,
        st: &mut dyn DecodeState,
        tok: i32,
        orow: &mut Vec<f32>,
        logits: &mut Vec<f32>,
    ) {
        let (q, k, v) = self.embed_rows(tok);
        orow.resize(self.cfg.dv, 0.0);
        st.step(q, k, v, orow);
        self.readout(orow, logits);
    }

    /// Linear readout: logits[w] = o . ro[w].
    pub fn readout(&self, orow: &[f32], logits: &mut Vec<f32>) {
        let dv = self.cfg.dv;
        logits.clear();
        for w in 0..self.cfg.vocab {
            logits.push(dot(orow, &self.ro[w * dv..(w + 1) * dv]));
        }
    }

    /// Greedy decoding: the first maximal logit wins (deterministic).
    pub fn argmax(logits: &[f32]) -> i32 {
        let mut best = 0usize;
        for (i, &l) in logits.iter().enumerate() {
            if l > logits[best] {
                best = i;
            }
        }
        best as i32
    }

    /// Full-recompute reference path: one batched forward over the whole
    /// token prefix, logits at the last position. This is what every token
    /// would cost without the incremental engine — `exp decode` benchmarks
    /// it, the session tests pin stream equality against it, and the
    /// one-shot `infer` path serves through it (prefill is exactly one
    /// full forward).
    pub fn forward_logits(&self, tokens: &[i32], pool: &Pool) -> Result<Vec<f32>> {
        if tokens.is_empty() {
            bail!("empty token prefix");
        }
        let n = tokens.len();
        let (d, dv) = (self.cfg.d, self.cfg.dv);
        let mut q = Tensor::zeros(&[n, d]);
        let mut k = Tensor::zeros(&[n, d]);
        let mut v = Tensor::zeros(&[n, dv]);
        for (i, &tok) in tokens.iter().enumerate() {
            let (qr, kr, vr) = self.embed_rows(tok);
            q.row_mut(i).copy_from_slice(qr);
            k.row_mut(i).copy_from_slice(kr);
            v.row_mut(i).copy_from_slice(vr);
        }
        let w = Workload { q, k, v, dout: Tensor::zeros(&[n, dv]) };
        let (o, _) = self.imp.forward_with(&w, pool);
        let mut logits = Vec::with_capacity(self.cfg.vocab);
        self.readout(o.row(n - 1), &mut logits);
        Ok(logits)
    }
}

/// Events on a generation stream, in order: `max_new` `Token`s, then one
/// `Done`.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// One generated token; `pos` counts from the end of the prompt.
    Token { token: i32, pos: usize },
    /// Generation finished (max_new reached, context full, or cancelled).
    Done { generated: usize, latency: Duration },
}

/// Client-side handle to a streaming generation: a receiver of
/// [`StreamEvent`]s. Dropping it cancels the session server-side.
pub struct GenStream {
    pub(crate) rx: mpsc::Receiver<Result<StreamEvent>>,
}

impl GenStream {
    /// Next event, or `None` once the server is done with the stream.
    pub fn recv(&self) -> Option<Result<StreamEvent>> {
        self.rx.recv().ok()
    }

    /// Drain the stream to completion and return the generated tokens.
    pub fn collect_tokens(self) -> Result<Vec<i32>> {
        let mut out = Vec::new();
        while let Some(ev) = self.recv() {
            match ev? {
                StreamEvent::Token { token, .. } => out.push(token),
                StreamEvent::Done { .. } => break,
            }
        }
        Ok(out)
    }
}

/// One in-flight generation request on the scheduler thread.
pub struct Session {
    /// Kernel decode state (native backend); `None` on the PJRT backend,
    /// which recomputes from `tokens` every step.
    pub state: Option<Box<dyn DecodeState>>,
    /// Prompt followed by the tokens generated so far.
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// Tokens fed into `state` so far (prefill progress; native only).
    pub fed: usize,
    pub generated: usize,
    pub max_new: usize,
    pub submitted: Instant,
    pub reply: mpsc::Sender<Result<StreamEvent>>,
}

impl Session {
    pub fn new(
        tokens: Vec<i32>,
        max_new: usize,
        submitted: Instant,
        reply: mpsc::Sender<Result<StreamEvent>>,
        state: Option<Box<dyn DecodeState>>,
    ) -> Session {
        let prompt_len = tokens.len();
        Session {
            state,
            tokens,
            prompt_len,
            fed: 0,
            generated: 0,
            max_new,
            submitted,
            reply,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_rejects_unknown_kernel() {
        let cfg = NativeModelConfig { kernel: "transformer".into(), ..Default::default() };
        assert!(NativeDecodeModel::new(cfg).is_err());
    }

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(NativeDecodeModel::argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(NativeDecodeModel::argmax(&[-1.0]), 0);
    }

    #[test]
    fn step_token_matches_forward_logits_per_prefix() {
        // Incremental step logits == full-recompute logits at every prefix
        // length, for the kernels whose decode path is bit-compatible.
        for kernel in ["zeta", "naive", "mamba"] {
            let model = NativeDecodeModel::new(NativeModelConfig {
                kernel: kernel.into(),
                ..Default::default()
            })
            .unwrap();
            let toks = [3i32, 7, 1, 1, 9, 0, 4, 2, 8, 5, 6, 3, 2, 7, 1, 0, 5, 9];
            let pool = Pool::serial();
            let mut st = model.begin();
            let mut orow = Vec::new();
            let mut logits = Vec::new();
            for l in 1..=toks.len() {
                model.step_token(st.as_mut(), toks[l - 1], &mut orow, &mut logits);
                let full = model.forward_logits(&toks[..l], &pool).unwrap();
                let maxdiff = logits
                    .iter()
                    .zip(&full)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(maxdiff < 1e-5, "{kernel} prefix {l}: {maxdiff}");
            }
        }
    }

    #[test]
    fn embeddings_are_deterministic_per_seed() {
        let a = NativeDecodeModel::new(NativeModelConfig::default()).unwrap();
        let b = NativeDecodeModel::new(NativeModelConfig::default()).unwrap();
        assert_eq!(a.qe, b.qe);
        let c = NativeDecodeModel::new(NativeModelConfig { seed: 1, ..Default::default() })
            .unwrap();
        assert_ne!(a.qe, c.qe);
    }
}
