//! Streaming generation sessions — the L3 surface of the incremental
//! decode engine.
//!
//! A [`Session`] is the continuous-batching unit: one per in-flight
//! `generate` request, holding the request's tokens and (on the native
//! backend) its kernel-level [`DecodeState`] — the per-request KV cache /
//! Z-order index. The scheduler advances every active session by one
//! micro-batch per sweep (a prefill slice or a single decode step), so
//! prefill and decode interleave instead of head-of-line blocking.
//!
//! [`NativeDecodeModel`] is the engine that makes streaming generation run
//! *offline*: a deterministic token model over the native attention
//! kernels. Token embeddings and the readout are fixed seeded tables, and
//! decoding is argmax, so the incremental decode path and a full-recompute
//! forward must produce the *same token stream* — the session-level
//! equivalence gate. (The PJRT backend serves `generate` by full-recompute
//! forward batches instead; see `coordinator::engine_decode_sweep`.)

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::attention::speculate::{drafter_for, Drafter, DraftSource};
use crate::attention::{kernel_by_name, AttentionImpl, DecodeState, DecodeStep, Workload};
use crate::tensor::{dot, Tensor};
use crate::util::arena::{KvQuant, PageArena, DEFAULT_PAGE_TOKENS};
use crate::util::breakeven::{fan_out, PARALLEL_PREFILL_MIN_OPS, PARALLEL_READOUT_MIN_OPS};
use crate::util::pool::{Pool, SharedSlice};
use crate::util::rng::Rng;

/// Configuration of the in-process native decode backend.
#[derive(Debug, Clone)]
pub struct NativeModelConfig {
    /// Attention kernel: "zeta" | "naive" | "flash" | "mamba".
    pub kernel: String,
    /// q/k width fed to the kernel.
    pub d: usize,
    /// Value / output width.
    pub dv: usize,
    /// Token vocabulary size.
    pub vocab: usize,
    /// Seed of the fixed embedding / readout tables.
    pub seed: u64,
    /// Hard cap on a session's total context (prompt + generated tokens).
    /// A session whose context reaches the cap terminates early with a
    /// `Done` event — the native analogue of the engine backend's
    /// `seq_len` bound, keeping per-request KV caches / Z-indices from
    /// growing without limit. 0 disables the cap.
    pub max_context: usize,
    /// Tokens per KV page (`--kv-page`): the granularity of the server's
    /// page arena — every decode state's caches grow, fork and release in
    /// pages of this many rows, and the prompt-prefix cache snapshots at
    /// whole-page boundaries. Must be >= 1.
    pub kv_page: usize,
    /// KV page element codec (`--kv-quant`): `"f32"` (bit-exact default),
    /// `"f16"`, or `"int8"` (per-row scale). Quantized codecs shrink
    /// per-token page bytes 2–4×, stretching a fixed `--kv-mem-budget` by
    /// the same factor at a bounded decode tolerance.
    pub kv_quant: String,
}

impl Default for NativeModelConfig {
    fn default() -> Self {
        NativeModelConfig {
            kernel: "zeta".into(),
            d: 16,
            dv: 16,
            vocab: 32,
            seed: 0,
            max_context: 4096,
            kv_page: DEFAULT_PAGE_TOKENS,
            kv_quant: "f32".into(),
        }
    }
}

/// Deterministic kernel-backed token model: embed -> attention kernel ->
/// linear readout -> argmax. Everything is a fixed seeded table, so the
/// model needs no artifacts, runs offline, and generation is exactly
/// reproducible — incremental decode vs full-recompute forward is a pure
/// scheduling difference.
pub struct NativeDecodeModel {
    // `Send + Sync` so fused sweep phases may capture `&self` in pool
    // closures (all four kernels are plain-data structs).
    imp: Box<dyn AttentionImpl + Send + Sync>,
    cfg: NativeModelConfig,
    /// Page arena every session's decode state allocates from — one arena
    /// per server, so `--kv-page` granularity and the `--kv-mem-budget`
    /// byte accounting are isolated per server instance.
    arena: Arc<PageArena>,
    qe: Vec<f32>, // (vocab, d)
    ke: Vec<f32>, // (vocab, d)
    ve: Vec<f32>, // (vocab, dv)
    ro: Vec<f32>, // (vocab, dv) readout
}

impl NativeDecodeModel {
    pub fn new(cfg: NativeModelConfig) -> Result<NativeDecodeModel> {
        if cfg.vocab == 0 || cfg.d == 0 || cfg.dv == 0 {
            bail!("native model dims must be non-zero");
        }
        if cfg.kv_page == 0 {
            bail!("--kv-page must be at least 1 token per page");
        }
        let quant = KvQuant::parse(&cfg.kv_quant).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown KV codec {:?} for --kv-quant (want {})",
                cfg.kv_quant,
                KvQuant::ACCEPTED
            )
        })?;
        let imp = kernel_by_name(&cfg.kernel).ok_or_else(|| {
            anyhow::anyhow!("unknown native kernel {:?} (want zeta|naive|flash|mamba)", cfg.kernel)
        })?;
        let arena = PageArena::new_quant(cfg.kv_page, quant);
        let mut rng = Rng::new(cfg.seed ^ 0x5E55_1015);
        let mut qe = vec![0f32; cfg.vocab * cfg.d];
        let mut ke = vec![0f32; cfg.vocab * cfg.d];
        let mut ve = vec![0f32; cfg.vocab * cfg.dv];
        let mut ro = vec![0f32; cfg.vocab * cfg.dv];
        rng.fill_normal(&mut qe, 1.0);
        rng.fill_normal(&mut ke, 1.0);
        rng.fill_normal(&mut ve, 1.0);
        rng.fill_normal(&mut ro, 1.0);
        Ok(NativeDecodeModel { imp, cfg, arena, qe, ke, ve, ro })
    }

    pub fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    /// Context cap (prompt + generated tokens) per session; 0 = unlimited.
    pub fn max_context(&self) -> usize {
        self.cfg.max_context
    }

    pub fn kernel_name(&self) -> &'static str {
        self.imp.name()
    }

    /// The server's page arena (budget accounting, telemetry).
    pub fn arena(&self) -> &Arc<PageArena> {
        &self.arena
    }

    /// Tokens per KV page.
    pub fn page_tokens(&self) -> usize {
        self.arena.page_tokens()
    }

    /// Upper-ish bound on the arena bytes a session holding `tokens` of
    /// context needs: one d-row plus one dv-row per token at the arena
    /// codec's encoded width, rounded up to whole pages, plus one page of
    /// slack for code/index storage. The budget admission gate compares
    /// this against the arena's live bytes; over-estimating only delays
    /// admission (never corrupts it), and the preemption path reclaims any
    /// overshoot. Codec-aware: under `--kv-quant f16`/`int8` the estimate
    /// shrinks with the pages, which is exactly what stretches admission
    /// at a fixed `--kv-mem-budget`.
    pub fn estimate_state_bytes(&self, tokens: usize) -> usize {
        let page = self.arena.page_tokens();
        let pages = tokens.div_ceil(page) + 1;
        let quant = self.arena.quant();
        let row_elems = quant.enc_row_elems(self.cfg.d) + quant.enc_row_elems(self.cfg.dv);
        pages * page * row_elems * 4
    }

    /// Fresh per-request decode state (the kernel-level KV cache) on the
    /// server's page arena.
    pub fn begin(&self) -> Box<dyn DecodeState> {
        self.imp.begin_decode_in(self.cfg.d, self.cfg.dv, &self.arena)
    }

    fn embed_rows(&self, tok: i32) -> (&[f32], &[f32], &[f32]) {
        let (d, dv) = (self.cfg.d, self.cfg.dv);
        let t = tok.rem_euclid(self.cfg.vocab as i32) as usize;
        (
            &self.qe[t * d..(t + 1) * d],
            &self.ke[t * d..(t + 1) * d],
            &self.ve[t * dv..(t + 1) * dv],
        )
    }

    /// Feed one token through the decode state; `logits` afterwards hold
    /// the next-token distribution. `orow`/`logits` are caller scratch.
    pub fn step_token(
        &self,
        st: &mut dyn DecodeState,
        tok: i32,
        orow: &mut Vec<f32>,
        logits: &mut Vec<f32>,
    ) {
        let (q, k, v) = self.embed_rows(tok);
        orow.resize(self.cfg.dv, 0.0);
        st.step(q, k, v, orow);
        self.readout(orow, logits);
    }

    /// Linear readout: logits[w] = o . ro[w].
    pub fn readout(&self, orow: &[f32], logits: &mut Vec<f32>) {
        logits.clear();
        logits.resize(self.cfg.vocab, 0.0);
        self.readout_into(orow, logits);
    }

    /// Readout into a pre-sized `vocab`-length row (the fused sweep's flat
    /// per-slot logits buffers). Each logit is one [`dot`] against a readout
    /// row, so the whole vocab·dv matvec rides the SIMD dispatch layer
    /// ([`crate::util::simd`]): blocked lane sums with a fixed reduction
    /// tree, identical across thread counts (parallelism here is across
    /// slots, never within a logit).
    pub fn readout_into(&self, orow: &[f32], logits: &mut [f32]) {
        let dv = self.cfg.dv;
        for (w, l) in logits.iter_mut().enumerate() {
            *l = dot(orow, &self.ro[w * dv..(w + 1) * dv]);
        }
    }

    /// Greedy decoding: the first maximal logit wins (deterministic). NaN
    /// logits are skipped — a NaN never compares greater, so the old `>`
    /// scan silently elected token 0 the moment the best-so-far slot held
    /// a NaN; a fully-NaN row still falls back to token 0.
    pub fn argmax(logits: &[f32]) -> i32 {
        let mut best: Option<usize> = None;
        for (i, &l) in logits.iter().enumerate() {
            if l.is_nan() {
                continue;
            }
            match best {
                Some(b) if logits[b] >= l => {}
                _ => best = Some(i),
            }
        }
        best.unwrap_or(0) as i32
    }

    /// Full-recompute reference path: one batched forward over the whole
    /// token prefix, logits at the last position. This is what every token
    /// would cost without the incremental engine — `exp decode` benchmarks
    /// it, the session tests pin stream equality against it, and the
    /// one-shot `infer` path serves through it (prefill is exactly one
    /// full forward).
    pub fn forward_logits(&self, tokens: &[i32], pool: &Pool) -> Result<Vec<f32>> {
        if tokens.is_empty() {
            bail!("empty token prefix");
        }
        let n = tokens.len();
        let (d, dv) = (self.cfg.d, self.cfg.dv);
        let mut q = Tensor::zeros(&[n, d]);
        let mut k = Tensor::zeros(&[n, d]);
        let mut v = Tensor::zeros(&[n, dv]);
        for (i, &tok) in tokens.iter().enumerate() {
            let (qr, kr, vr) = self.embed_rows(tok);
            q.row_mut(i).copy_from_slice(qr);
            k.row_mut(i).copy_from_slice(kr);
            v.row_mut(i).copy_from_slice(vr);
        }
        let w = Workload { q, k, v, dout: Tensor::zeros(&[n, dv]) };
        let (o, _) = self.imp.forward_with(&w, pool);
        let mut logits = Vec::with_capacity(self.cfg.vocab);
        self.readout(o.row(n - 1), &mut logits);
        Ok(logits)
    }

    /// Fused decode across sessions: batched embed → one pool-parallel
    /// kernel call ([`crate::attention::AttentionImpl::step_batch`]) across
    /// every slot's decode state → batched readout/argmax.
    /// `scratch.next[i]` holds slot i's next token afterwards. Each slot
    /// runs exactly the [`NativeDecodeModel::step_token`] arithmetic on its
    /// own state, so fused and serial sweeps generate identical token
    /// streams — only the schedule differs.
    pub fn step_batch(
        &self,
        items: &mut [SessionStep<'_>],
        scratch: &mut StepScratch,
        pool: &Pool,
    ) {
        let n = items.len();
        let (dv, vocab) = (self.cfg.dv, self.cfg.vocab);
        scratch.orows.clear();
        scratch.orows.resize(n * dv, 0.0);
        scratch.logits.clear();
        scratch.logits.resize(n * vocab, 0.0);
        scratch.next.clear();
        scratch.next.resize(n, 0);
        if n == 0 {
            return;
        }
        {
            let mut steps: Vec<DecodeStep<'_>> = items
                .iter_mut()
                .zip(scratch.orows.chunks_mut(dv))
                .map(|(item, orow)| {
                    let (q, k, v) = self.embed_rows(item.tok);
                    DecodeStep { state: &mut *item.state, q, k, v, out: orow }
                })
                .collect();
            self.imp.step_batch(&mut steps, pool);
        }
        // Batched readout + argmax: slot-parallel when the vocab·dv work
        // outweighs the pool fan-out, inline otherwise.
        if fan_out(n, n * vocab * dv, pool.threads(), PARALLEL_READOUT_MIN_OPS) {
            let orows = &scratch.orows;
            let lsh = SharedSlice::new(&mut scratch.logits);
            let nsh = SharedSlice::new(&mut scratch.next);
            pool.parallel_for(n, 1, |slots| {
                for i in slots {
                    // Safety: slot i is claimed by exactly one chunk.
                    let lrow = unsafe { lsh.range_mut(i * vocab..(i + 1) * vocab) };
                    self.readout_into(&orows[i * dv..(i + 1) * dv], lrow);
                    unsafe { nsh.write(i, Self::argmax(lrow)) };
                }
            });
        } else {
            for i in 0..n {
                let lrow = &mut scratch.logits[i * vocab..(i + 1) * vocab];
                self.readout_into(&scratch.orows[i * dv..(i + 1) * dv], lrow);
                scratch.next[i] = Self::argmax(lrow);
            }
        }
    }

    /// Batched prefill wave: every slot feeds its prompt micro-batch into
    /// its own state (within-stream order is inherent; across slots the
    /// wave is pool-parallel). Intermediate readouts are skipped — only the
    /// final prompt position's logits are ever consumed — so for slots
    /// with `emit` set, `scratch.next[i]` holds the argmax of the last
    /// token's logits (the session's first generated token); other slots
    /// get -1. Waves below the fan-out break-even run inline serially.
    pub fn prefill_batch(
        &self,
        items: &mut [PrefillStep<'_>],
        scratch: &mut StepScratch,
        pool: &Pool,
    ) {
        let n = items.len();
        scratch.next.clear();
        scratch.next.resize(n, -1);
        if n == 0 {
            return;
        }
        let total: usize = items
            .iter()
            .map(|it| it.tokens.len() * (it.state.step_cost_hint() + self.cfg.d + self.cfg.dv))
            .sum();
        if fan_out(n, total, pool.threads(), PARALLEL_PREFILL_MIN_OPS) {
            let ish = SharedSlice::new(items);
            let nsh = SharedSlice::new(&mut scratch.next);
            pool.run_chunked(n, 1, |queue| {
                let mut emb = PrefillEmbed::default();
                let mut logits = Vec::new();
                while let Some(slots) = queue.next_chunk() {
                    for i in slots {
                        // Safety: slot i is claimed by exactly one chunk,
                        // and every slot owns a distinct state.
                        let it = unsafe { &mut ish.range_mut(i..i + 1)[0] };
                        let nx = self.prefill_slot(it, &mut emb, &mut logits, pool);
                        unsafe { nsh.write(i, nx) };
                    }
                }
            });
        } else {
            // Single-slot and below-break-even waves run here with the
            // *real* pool: a lone long prompt still fans out inside
            // `prefill_run` (the pipelined ZETA path), which is what lets
            // one session's prefill use every worker.
            let mut emb = PrefillEmbed::default();
            let mut logits = Vec::new();
            for (i, it) in items.iter_mut().enumerate() {
                scratch.next[i] = self.prefill_slot(it, &mut emb, &mut logits, pool);
            }
        }
    }

    /// Feed one slot's prompt tokens through the state's run-at-a-time
    /// prefill entry ([`DecodeState::prefill_run`] — the serial step loop
    /// for most kernels, the pipelined snapshot-scored path for ZETA);
    /// returns the argmax of the final logits when the slot emits, else -1.
    fn prefill_slot(
        &self,
        it: &mut PrefillStep<'_>,
        emb: &mut PrefillEmbed,
        logits: &mut Vec<f32>,
        pool: &Pool,
    ) -> i32 {
        let (d, dv) = (self.cfg.d, self.cfg.dv);
        emb.orow.resize(dv, 0.0);
        let m = it.tokens.len();
        if m == 0 {
            return -1;
        }
        emb.qs.clear();
        emb.ks.clear();
        emb.vs.clear();
        for &tok in it.tokens {
            let (q, k, v) = self.embed_rows(tok);
            emb.qs.extend_from_slice(q);
            emb.ks.extend_from_slice(k);
            emb.vs.extend_from_slice(v);
        }
        debug_assert_eq!(emb.qs.len(), m * d);
        it.state.prefill_run(m, &emb.qs, &emb.ks, &emb.vs, &mut emb.orow, pool);
        if it.emit {
            self.readout(&emb.orow, logits);
            Self::argmax(logits)
        } else {
            -1
        }
    }

    /// Build a session's drafter for the configured `--speculate` source
    /// (`None` for `off`). The mamba drafter's private stream lives on the
    /// server arena, so its bytes count against `--kv-mem-budget` exactly
    /// like session KV state.
    pub fn make_drafter(&self, source: DraftSource) -> Option<SessionDrafter> {
        drafter_for(source, self.cfg.d, self.cfg.dv, &self.arena).map(SessionDrafter::new)
    }

    /// Feed the drafter's persistent context every committed token it has
    /// not seen yet — all of `tokens` *except* the last, which seeds the
    /// draft chain itself. Lazy catch-up makes one code path absorb the
    /// prompt, partial acceptances, budget sheds (context restarts from
    /// zero) and preemptions: the drafter is never rolled back, it only
    /// ever ingests the committed stream.
    pub fn drafter_catch_up(&self, dr: &mut SessionDrafter, tokens: &[i32], pool: &Pool) {
        let want = tokens.len().saturating_sub(1);
        debug_assert!(dr.fed <= want, "drafter context ahead of the committed stream");
        if dr.fed >= want {
            return;
        }
        let pending = &tokens[dr.fed..want];
        if let Some(ctx) = dr.inner.context() {
            let (d, dv) = (self.cfg.d, self.cfg.dv);
            let mut emb = PrefillEmbed::default();
            emb.orow.resize(dv, 0.0);
            for &tok in pending {
                let (q, k, v) = self.embed_rows(tok);
                emb.qs.extend_from_slice(q);
                emb.ks.extend_from_slice(k);
                emb.vs.extend_from_slice(v);
            }
            debug_assert_eq!(emb.qs.len(), pending.len() * d);
            ctx.prefill_run(pending.len(), &emb.qs, &emb.ks, &emb.vs, &mut emb.orow, pool);
        }
        dr.fed = want;
    }

    /// Step a scratch draft state `len` greedy tokens past `seed_tok` (the
    /// session's last committed token) and return the proposals. Serial by
    /// design: the chain is sequentially dependent and the drafter is
    /// priced to make these steps negligible next to one full-kernel step.
    pub fn draft_chain(
        &self,
        draft: &mut dyn DecodeState,
        seed_tok: i32,
        len: usize,
        orow: &mut Vec<f32>,
        logits: &mut Vec<f32>,
    ) -> Vec<i32> {
        let mut chain = Vec::with_capacity(len);
        let mut tok = seed_tok;
        for _ in 0..len {
            self.step_token(draft, tok, orow, logits);
            tok = Self::argmax(logits);
            chain.push(tok);
        }
        chain
    }

    /// Fused speculative verify wave: every slot feeds its whole draft
    /// chain — `[last committed token, d_1, .., d_L]` — through its *real*
    /// state, recording the argmax after each position into
    /// [`VerifyStep::preds`]. Each position runs exactly the
    /// [`NativeDecodeModel::step_token`] arithmetic, so `preds[0]` is the
    /// token non-speculative decode would have produced, and by induction
    /// every prediction after a matched prefix is too — which is what
    /// makes acceptance bit-exact. Within a slot the loop is serial
    /// (token i+1's step depends on token i's state mutation); across
    /// slots the wave fans out on the pool like a prefill wave.
    pub fn verify_batch(&self, items: &mut [VerifyStep<'_>], pool: &Pool) {
        let n = items.len();
        if n == 0 {
            return;
        }
        let per_tok = self.cfg.d + self.cfg.dv + self.cfg.vocab * self.cfg.dv;
        let total: usize = items
            .iter()
            .map(|it| it.chain.len() * (it.state.step_cost_hint() + per_tok))
            .sum();
        if fan_out(n, total, pool.threads(), PARALLEL_PREFILL_MIN_OPS) {
            let ish = SharedSlice::new(items);
            pool.run_chunked(n, 1, |queue| {
                let (mut orow, mut logits) = (Vec::new(), Vec::new());
                while let Some(slots) = queue.next_chunk() {
                    for i in slots {
                        // Safety: slot i is claimed by exactly one chunk,
                        // and every slot owns a distinct state.
                        let it = unsafe { &mut ish.range_mut(i..i + 1)[0] };
                        self.verify_slot(it, &mut orow, &mut logits);
                    }
                }
            });
        } else {
            let (mut orow, mut logits) = (Vec::new(), Vec::new());
            for it in items.iter_mut() {
                self.verify_slot(it, &mut orow, &mut logits);
            }
        }
    }

    fn verify_slot(&self, it: &mut VerifyStep<'_>, orow: &mut Vec<f32>, logits: &mut Vec<f32>) {
        it.preds.clear();
        for &tok in it.chain {
            self.step_token(&mut *it.state, tok, orow, logits);
            it.preds.push(Self::argmax(logits));
        }
    }
}

/// One session's slot in a fused decode sweep: its live kernel state plus
/// the token to feed (the session's last emitted token, or the final
/// prompt token).
pub struct SessionStep<'a> {
    pub state: &'a mut dyn DecodeState,
    pub tok: i32,
}

/// One session's slot in a batched prefill wave: the state, this sweep's
/// prompt micro-batch, and whether the chunk finishes the prompt (in which
/// case the final logits are read out to produce the first new token).
pub struct PrefillStep<'a> {
    pub state: &'a mut dyn DecodeState,
    pub tokens: &'a [i32],
    pub emit: bool,
}

/// One session's slot in a fused speculative verify wave: the state the
/// chain is scored on (the session's real state, pre-forked by the caller
/// for rollback), the chain `[last committed token, d_1..d_L]`, and the
/// per-position argmax predictions [`NativeDecodeModel::verify_batch`]
/// fills in.
pub struct VerifyStep<'a> {
    pub state: &'a mut dyn DecodeState,
    pub chain: &'a [i32],
    pub preds: Vec<i32>,
}

/// A session's speculative-decode drafter plus its catch-up cursor: how
/// many committed tokens the drafter's persistent context has ingested.
/// The cursor lives *outside* the [`Drafter`] so shedding can reset both
/// together — a shed context restarts empty and the next
/// [`NativeDecodeModel::drafter_catch_up`] re-feeds the committed stream
/// from zero.
pub struct SessionDrafter {
    inner: Box<dyn Drafter>,
    /// Committed tokens fed into the drafter context so far (always at
    /// most `session.tokens.len() - 1`: the last token seeds the chain).
    fed: usize,
}

impl SessionDrafter {
    pub fn new(inner: Box<dyn Drafter>) -> SessionDrafter {
        SessionDrafter { inner, fed: 0 }
    }

    /// Draft-source name (for logs/summaries).
    pub fn name(&self) -> &'static str {
        self.inner.name()
    }

    /// Fork the scratch state proposals are stepped on; `None` when the
    /// drafter cannot propose this wave (context shed / not grown yet, or
    /// the kernel offers no narrowed configuration).
    pub fn begin(&mut self, target: &dyn DecodeState) -> Option<Box<dyn DecodeState>> {
        self.inner.begin(target)
    }

    /// Arena bytes the drafter's persistent context pins.
    pub fn state_bytes(&self) -> usize {
        self.inner.state_bytes()
    }

    /// Drop the persistent context's pages (budget shedding) and rewind
    /// the catch-up cursor so a later wave rebuilds the context from the
    /// committed stream.
    pub fn shed(&mut self) {
        self.inner.shed();
        self.fed = 0;
    }
}

/// Reusable buffers for the fused sweep entry points
/// ([`NativeDecodeModel::step_batch`] / [`NativeDecodeModel::prefill_batch`]):
/// flat per-slot attention output rows, logits rows, and resulting tokens.
#[derive(Default)]
pub struct StepScratch {
    orows: Vec<f32>,
    logits: Vec<f32>,
    /// Per-slot argmax token after a fused call (-1 for prefill slots that
    /// did not finish their prompt).
    pub next: Vec<i32>,
}

/// Per-worker embed buffers for one prefill slot: the slot's whole token
/// run is embedded into flat q/k/v row blocks so the state ingests it in
/// one [`crate::attention::DecodeState::prefill_run`] call (reused across
/// slots — no per-slot allocation churn).
#[derive(Default)]
struct PrefillEmbed {
    qs: Vec<f32>,
    ks: Vec<f32>,
    vs: Vec<f32>,
    orow: Vec<f32>,
}

/// Events on a generation stream, in order: `max_new` `Token`s, then one
/// `Done`.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// One generated token; `pos` counts from the end of the prompt.
    Token { token: i32, pos: usize },
    /// Generation finished (max_new reached, context full, or cancelled).
    Done { generated: usize, latency: Duration },
}

/// Client-side handle to a streaming generation: a receiver of
/// [`StreamEvent`]s. Dropping it cancels the session server-side: a shared
/// cancel flag flips on drop, and the scheduler checks it at the top of
/// every sweep — so even a session still deep in prefill stops consuming
/// kernel time immediately, instead of being discovered only at its first
/// (failed) token send.
pub struct GenStream {
    pub(crate) rx: mpsc::Receiver<Result<StreamEvent>>,
    pub(crate) cancel: Arc<AtomicBool>,
}

impl Drop for GenStream {
    fn drop(&mut self) {
        self.cancel.store(true, Ordering::Relaxed);
    }
}

/// Outcome of a bounded wait on a [`GenStream`] — the scenario replayer's
/// timed-cancellation hook distinguishes "nothing yet" from "stream over".
pub enum RecvTimeout {
    Event(Result<StreamEvent>),
    /// The server closed the stream (scheduler gone).
    Closed,
    /// No event within the deadline; the stream is still live.
    TimedOut,
}

impl GenStream {
    /// Next event, or `None` once the server is done with the stream.
    pub fn recv(&self) -> Option<Result<StreamEvent>> {
        self.rx.recv().ok()
    }

    /// Next event within `timeout` — lets a client bound its wait (e.g. a
    /// replayed cancellation deadline) and then drop the stream.
    pub fn recv_timeout(&self, timeout: Duration) -> RecvTimeout {
        match self.rx.recv_timeout(timeout) {
            Ok(ev) => RecvTimeout::Event(ev),
            Err(mpsc::RecvTimeoutError::Timeout) => RecvTimeout::TimedOut,
            Err(mpsc::RecvTimeoutError::Disconnected) => RecvTimeout::Closed,
        }
    }

    /// Drain the stream to completion and return the generated tokens.
    pub fn collect_tokens(self) -> Result<Vec<i32>> {
        let mut out = Vec::new();
        while let Some(ev) = self.recv() {
            match ev? {
                StreamEvent::Token { token, .. } => out.push(token),
                StreamEvent::Done { .. } => break,
            }
        }
        Ok(out)
    }
}

/// One in-flight generation request on the scheduler thread.
pub struct Session {
    /// Kernel decode state (native backend). `None` on the PJRT backend
    /// (which recomputes from `tokens` every step) — and on the native
    /// backend while the session is *parked*: newly admitted or preempted
    /// under memory pressure, waiting for the budget gate to activate it.
    pub state: Option<Box<dyn DecodeState>>,
    /// Prompt followed by the tokens generated so far.
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// Tokens fed into `state` so far (prefill progress; native only).
    pub fed: usize,
    pub generated: usize,
    pub max_new: usize,
    pub submitted: Instant,
    pub reply: mpsc::Sender<Result<StreamEvent>>,
    /// Sweep counter value when this session last advanced — the LRU
    /// ordering the budget preemption evicts by.
    pub last_step: u64,
    /// Whether this session's page-aligned prompt prefix has already been
    /// offered to the prompt-prefix cache (insert once per session).
    pub prefix_cached: bool,
    /// Speculative-decode drafter (`--speculate mamba|self`), attached by
    /// the scheduler when the session activates. `None` when speculation
    /// is off — the decode sweep then takes the plain fused-step path.
    pub drafter: Option<SessionDrafter>,
    /// Set when the client dropped its [`GenStream`] — checked every sweep
    /// so cancelled sessions retire before consuming any further compute,
    /// including mid-prefill.
    cancel: Arc<AtomicBool>,
}

impl Session {
    pub fn new(
        tokens: Vec<i32>,
        max_new: usize,
        submitted: Instant,
        reply: mpsc::Sender<Result<StreamEvent>>,
        state: Option<Box<dyn DecodeState>>,
        cancel: Arc<AtomicBool>,
    ) -> Session {
        let prompt_len = tokens.len();
        Session {
            state,
            tokens,
            prompt_len,
            fed: 0,
            generated: 0,
            max_new,
            submitted,
            reply,
            last_step: 0,
            prefix_cached: false,
            drafter: None,
            cancel,
        }
    }

    /// Whether the client hung up (dropped its stream handle).
    pub fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }
}

/// Prompt-prefix cache: decode states snapshotted at whole-page prompt
/// boundaries, keyed by the exact token prefix they ingested. Identical
/// prompt heads (system prompts, few-shot headers) then cost one
/// [`DecodeState::fork`] — shared full pages and shared Z-order runs, one
/// tail-page copy — instead of a re-prefill of the whole prefix. Forked
/// continuations are bit-identical to fresh prefills (the paged-state
/// gate), so a cache hit can never change a token stream.
///
/// Entries hold real arena pages, so the cache counts toward the
/// `--kv-mem-budget`; the coordinator sheds LRU entries *before*
/// preempting live sessions when the budget tightens.
pub struct PrefixCache {
    /// Tokens per page — prefixes are cached at multiples of this.
    page: usize,
    /// Maximum entries; beyond it the least-recently-used entry is shed.
    cap: usize,
    entries: HashMap<Vec<i32>, PrefixEntry>,
    /// Entry count per prefix length: lookups hash-probe only lengths that
    /// actually exist, so a miss costs O(distinct lengths) probes instead
    /// of one O(prompt)-hash per page step down from the full length.
    lens: BTreeMap<usize, usize>,
    tick: u64,
    /// Lookups that found (and forked) a cached prefix.
    pub hits: u64,
    /// Total lookups.
    pub lookups: u64,
}

struct PrefixEntry {
    state: Box<dyn DecodeState>,
    last_used: u64,
}

impl PrefixCache {
    pub fn new(page: usize, cap: usize) -> PrefixCache {
        PrefixCache {
            page: page.max(1),
            cap,
            entries: HashMap::new(),
            lens: BTreeMap::new(),
            tick: 0,
            hits: 0,
            lookups: 0,
        }
    }

    /// The longest cacheable prefix of a `prompt_len`-token prompt: whole
    /// pages only, and strictly shorter than the prompt — the final
    /// prompt position must be fed by a live prefill step, because its
    /// logits produce the session's first generated token.
    pub fn cacheable_len(&self, prompt_len: usize) -> usize {
        (prompt_len.saturating_sub(1) / self.page) * self.page
    }

    /// Fork the state of the longest cached whole-page prefix of
    /// `tokens`, longest first. Returns `(prefix_len, forked_state)`; the
    /// session resumes prefill at `prefix_len`.
    pub fn lookup(&mut self, tokens: &[i32]) -> Option<(usize, Box<dyn DecodeState>)> {
        self.lookups += 1;
        self.tick += 1;
        let max_l = (tokens.len() / self.page) * self.page;
        if max_l < self.page {
            return None;
        }
        let candidates: Vec<usize> = self.lens.range(..=max_l).rev().map(|(&l, _)| l).collect();
        for l in candidates {
            if let Some(e) = self.entries.get_mut(&tokens[..l]) {
                e.last_used = self.tick;
                self.hits += 1;
                return Some((l, e.state.fork()));
            }
        }
        None
    }

    /// Insert a state snapshot for the exact page-aligned prefix it
    /// ingested (`state.pos() == prefix.len()`), shedding the LRU entry at
    /// capacity. Re-inserting an existing prefix refreshes it.
    pub fn insert(&mut self, prefix: &[i32], state: Box<dyn DecodeState>) {
        if self.cap == 0 || prefix.is_empty() {
            return;
        }
        debug_assert_eq!(state.pos(), prefix.len());
        debug_assert_eq!(prefix.len() % self.page, 0);
        self.tick += 1;
        if self.entries.len() >= self.cap && !self.entries.contains_key(prefix) {
            self.evict_lru();
        }
        let old = self
            .entries
            .insert(prefix.to_vec(), PrefixEntry { state, last_used: self.tick });
        if old.is_none() {
            *self.lens.entry(prefix.len()).or_insert(0) += 1;
        }
    }

    /// Shed the least-recently-used entry (its pages return to the
    /// arena); returns whether anything was evicted.
    pub fn evict_lru(&mut self) -> bool {
        let victim = self
            .entries
            .iter()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(k, _)| k.clone());
        match victim {
            Some(k) => {
                if let Some(mut e) = self.entries.remove(&k) {
                    e.state.release();
                }
                if let Some(c) = self.lens.get_mut(&k.len()) {
                    *c -= 1;
                    if *c == 0 {
                        self.lens.remove(&k.len());
                    }
                }
                true
            }
            None => false,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Arena bytes referenced by the cached states (per-handle view).
    pub fn state_bytes(&self) -> usize {
        self.entries.values().map(|e| e.state.state_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_rejects_unknown_kernel() {
        let cfg = NativeModelConfig { kernel: "transformer".into(), ..Default::default() };
        assert!(NativeDecodeModel::new(cfg).is_err());
    }

    #[test]
    fn model_rejects_unknown_kv_quant_listing_codecs() {
        for bad in ["fp16", "q8", "F32", ""] {
            let cfg = NativeModelConfig { kv_quant: bad.into(), ..Default::default() };
            let err = NativeDecodeModel::new(cfg).expect_err("codec must be rejected").to_string();
            assert!(err.contains("--kv-quant"), "{err}");
            assert!(err.contains(KvQuant::ACCEPTED), "must list accepted codecs: {err}");
        }
        for good in ["f32", "f16", "int8"] {
            let cfg = NativeModelConfig { kv_quant: good.into(), ..Default::default() };
            assert!(NativeDecodeModel::new(cfg).is_ok(), "{good} must be accepted");
        }
    }

    #[test]
    fn estimate_state_bytes_shrinks_with_codec() {
        let mk = |q: &str| {
            let cfg = NativeModelConfig { kv_quant: q.into(), ..Default::default() };
            NativeDecodeModel::new(cfg).unwrap()
        };
        let (f32m, f16m, i8m) = (mk("f32"), mk("f16"), mk("int8"));
        let page = f32m.page_tokens();
        // d = dv = 16: words/row-pair are 32 (f32), 16 (f16), 10 (int8).
        assert_eq!(f32m.estimate_state_bytes(1), 2 * page * 32 * 4);
        assert_eq!(f16m.estimate_state_bytes(1), 2 * page * 16 * 4);
        assert_eq!(i8m.estimate_state_bytes(1), 2 * page * 10 * 4);
    }

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(NativeDecodeModel::argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(NativeDecodeModel::argmax(&[-1.0]), 0);
    }

    #[test]
    fn argmax_skips_nan_logits() {
        // A NaN best-so-far used to freeze the scan at token 0; NaNs must
        // lose to any finite (or even -inf) logit.
        assert_eq!(NativeDecodeModel::argmax(&[f32::NAN, 1.0, 2.0]), 2);
        assert_eq!(NativeDecodeModel::argmax(&[f32::NAN, 5.0, 2.0, 5.0]), 1);
        assert_eq!(NativeDecodeModel::argmax(&[1.0, f32::NAN, 0.5]), 0);
        assert_eq!(NativeDecodeModel::argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(NativeDecodeModel::argmax(&[f32::NAN, f32::NEG_INFINITY]), 1);
    }

    #[test]
    fn fused_step_batch_matches_serial_step_token() {
        // The fused model-level sweep (batched embed → kernel step_batch →
        // batched readout/argmax) must generate the exact token stream of
        // per-session step_token loops, for every kernel, at 1 and 4
        // threads.
        for kernel in ["zeta", "naive", "flash", "mamba"] {
            let model = NativeDecodeModel::new(NativeModelConfig {
                kernel: kernel.into(),
                ..Default::default()
            })
            .unwrap();
            let prompts = [3i32, 9, 1, 14, 27];
            let steps = 12;
            for threads in [1usize, 2, 8] {
                let pool = Pool::new(threads);
                let (mut orow, mut logits) = (Vec::new(), Vec::new());
                let mut serial_toks: Vec<Vec<i32>> = prompts.iter().map(|&t| vec![t]).collect();
                for toks in serial_toks.iter_mut() {
                    let mut st = model.begin();
                    for _ in 0..steps {
                        let tok = *toks.last().unwrap();
                        model.step_token(st.as_mut(), tok, &mut orow, &mut logits);
                        toks.push(NativeDecodeModel::argmax(&logits));
                    }
                }
                let mut states: Vec<_> = prompts.iter().map(|_| model.begin()).collect();
                let mut scratch = StepScratch::default();
                let mut fused_toks: Vec<Vec<i32>> = prompts.iter().map(|&t| vec![t]).collect();
                for _ in 0..steps {
                    let mut items: Vec<SessionStep> = states
                        .iter_mut()
                        .zip(&fused_toks)
                        .map(|(st, toks)| SessionStep {
                            state: st.as_mut(),
                            tok: *toks.last().unwrap(),
                        })
                        .collect();
                    model.step_batch(&mut items, &mut scratch, &pool);
                    drop(items);
                    for (toks, &nx) in fused_toks.iter_mut().zip(&scratch.next) {
                        toks.push(nx);
                    }
                }
                assert_eq!(serial_toks, fused_toks, "{kernel} threads={threads}");
            }
        }
    }

    #[test]
    fn prefill_batch_matches_step_token_prefill() {
        let model = NativeDecodeModel::new(NativeModelConfig::default()).unwrap();
        let pool = Pool::new(2);
        let prompts: Vec<Vec<i32>> = vec![vec![1, 2, 3, 4, 5, 6, 7], vec![9, 8, 7], vec![4; 40]];
        let (mut orow, mut logits) = (Vec::new(), Vec::new());
        let mut want = Vec::new();
        for p in &prompts {
            let mut st = model.begin();
            for &t in p {
                model.step_token(st.as_mut(), t, &mut orow, &mut logits);
            }
            want.push(NativeDecodeModel::argmax(&logits));
        }
        let mut states: Vec<_> = prompts.iter().map(|_| model.begin()).collect();
        let mut scratch = StepScratch::default();
        {
            let mut items: Vec<PrefillStep> = states
                .iter_mut()
                .zip(&prompts)
                .map(|(st, p)| PrefillStep {
                    state: st.as_mut(),
                    tokens: p.as_slice(),
                    emit: true,
                })
                .collect();
            model.prefill_batch(&mut items, &mut scratch, &pool);
        }
        assert_eq!(scratch.next, want);
        // Slots that do not finish their prompt report -1 (no readout).
        let mut st2 = model.begin();
        let mut items = vec![PrefillStep {
            state: st2.as_mut(),
            tokens: prompts[0].as_slice(),
            emit: false,
        }];
        model.prefill_batch(&mut items, &mut scratch, &pool);
        drop(items);
        assert_eq!(scratch.next, vec![-1]);
    }

    #[test]
    fn step_token_matches_forward_logits_per_prefix() {
        // Incremental step logits == full-recompute logits at every prefix
        // length, for the kernels whose decode path is bit-compatible.
        for kernel in ["zeta", "naive", "mamba"] {
            let model = NativeDecodeModel::new(NativeModelConfig {
                kernel: kernel.into(),
                ..Default::default()
            })
            .unwrap();
            let toks = [3i32, 7, 1, 1, 9, 0, 4, 2, 8, 5, 6, 3, 2, 7, 1, 0, 5, 9];
            let pool = Pool::serial();
            let mut st = model.begin();
            let mut orow = Vec::new();
            let mut logits = Vec::new();
            for l in 1..=toks.len() {
                model.step_token(st.as_mut(), toks[l - 1], &mut orow, &mut logits);
                let full = model.forward_logits(&toks[..l], &pool).unwrap();
                let maxdiff = logits
                    .iter()
                    .zip(&full)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(maxdiff < 1e-5, "{kernel} prefix {l}: {maxdiff}");
            }
        }
    }

    #[test]
    fn cacheable_len_is_whole_pages_strictly_inside_the_prompt() {
        let pc = PrefixCache::new(64, 8);
        assert_eq!(pc.cacheable_len(0), 0);
        assert_eq!(pc.cacheable_len(1), 0);
        assert_eq!(pc.cacheable_len(64), 0); // == prompt_len not allowed
        assert_eq!(pc.cacheable_len(65), 64);
        assert_eq!(pc.cacheable_len(128), 64);
        assert_eq!(pc.cacheable_len(129), 128);
        assert_eq!(pc.cacheable_len(200), 128);
    }

    #[test]
    fn prefix_cache_fork_continues_bit_identical_to_fresh_prefill() {
        let model = NativeDecodeModel::new(NativeModelConfig::default()).unwrap();
        let toks: Vec<i32> = (0..100).map(|i| (i * 7 + 3) % 32).collect();
        let page = model.page_tokens();
        let boundary = (toks.len() / page) * page; // 64
        // Prefill a state to the page boundary and cache a snapshot.
        let mut pc = PrefixCache::new(page, 4);
        let (mut orow, mut logits) = (Vec::new(), Vec::new());
        let mut st = model.begin();
        for &t in &toks[..boundary] {
            model.step_token(st.as_mut(), t, &mut orow, &mut logits);
        }
        pc.insert(&toks[..boundary], st.fork());
        assert_eq!(pc.len(), 1);
        // A prompt sharing that prefix hits the cache...
        let (l, mut forked) = pc.lookup(&toks[..toks.len() - 1]).expect("hit");
        assert_eq!(l, boundary);
        assert_eq!(pc.hits, 1);
        // ...and continuing the fork matches a fresh full prefill bit-wise.
        let (mut orow2, mut logits2) = (Vec::new(), Vec::new());
        for &t in &toks[boundary..] {
            model.step_token(forked.as_mut(), t, &mut orow2, &mut logits2);
        }
        let mut fresh = model.begin();
        for &t in &toks {
            model.step_token(fresh.as_mut(), t, &mut orow, &mut logits);
        }
        assert_eq!(logits2, logits);
        // A prompt diverging before the boundary misses.
        let mut other = toks.clone();
        other[3] ^= 1;
        assert!(pc.lookup(&other[..other.len() - 1]).is_none());
        assert_eq!(pc.lookups, 2);
        assert_eq!(pc.hits, 1);
    }

    #[test]
    fn prefix_cache_sheds_lru_entries_at_capacity() {
        let model = NativeDecodeModel::new(NativeModelConfig::default()).unwrap();
        let mut pc = PrefixCache::new(4, 2);
        let (mut orow, mut logits) = (Vec::new(), Vec::new());
        let mut mk = |seed: i32| -> (Vec<i32>, Box<dyn DecodeState>) {
            let toks: Vec<i32> = (0..4).map(|i| (i + seed) % 32).collect();
            let mut st = model.begin();
            for &t in &toks {
                model.step_token(st.as_mut(), t, &mut orow, &mut logits);
            }
            (toks, st)
        };
        let (t1, s1) = mk(1);
        let (t2, s2) = mk(2);
        let (t3, s3) = mk(3);
        pc.insert(&t1, s1);
        pc.insert(&t2, s2);
        // Touch t1 so t2 becomes the LRU entry.
        let pad1: Vec<i32> = t1.iter().copied().chain([0]).collect();
        assert!(pc.lookup(&pad1).is_some());
        pc.insert(&t3, s3);
        assert_eq!(pc.len(), 2);
        let pad2: Vec<i32> = t2.iter().copied().chain([0]).collect();
        let pad3: Vec<i32> = t3.iter().copied().chain([0]).collect();
        assert!(pc.lookup(&pad2).is_none(), "t2 was LRU and must be shed");
        assert!(pc.lookup(&pad1).is_some());
        assert!(pc.lookup(&pad3).is_some());
        // evict_lru drains the rest.
        assert!(pc.evict_lru());
        assert!(pc.evict_lru());
        assert!(!pc.evict_lru());
        assert!(pc.is_empty());
    }

    #[test]
    fn estimate_state_bytes_rounds_up_to_pages() {
        let model = NativeDecodeModel::new(NativeModelConfig::default()).unwrap();
        let page = model.page_tokens(); // 64
        let per_page = page * (16 + 16) * 4;
        assert_eq!(model.estimate_state_bytes(0), per_page);
        assert_eq!(model.estimate_state_bytes(1), 2 * per_page);
        assert_eq!(model.estimate_state_bytes(page), 2 * per_page);
        assert_eq!(model.estimate_state_bytes(page + 1), 3 * per_page);
    }

    #[test]
    fn verify_batch_predictions_match_serial_step_token() {
        // The speculative verify wave feeds a whole chain per slot; its
        // per-position predictions must equal a serial step_token loop
        // bit-for-bit, for every kernel, at 1 and 4 threads — this is the
        // arithmetic identity the acceptance contract rests on.
        for kernel in ["zeta", "naive", "flash", "mamba"] {
            let model = NativeDecodeModel::new(NativeModelConfig {
                kernel: kernel.into(),
                ..Default::default()
            })
            .unwrap();
            let prompts: Vec<Vec<i32>> = vec![vec![3, 9, 1], vec![14; 10], vec![27, 2]];
            let chains: Vec<Vec<i32>> = vec![vec![5, 6, 7, 8], vec![1, 1], vec![30, 0, 12]];
            let (mut orow, mut logits) = (Vec::new(), Vec::new());
            let mut want: Vec<Vec<i32>> = Vec::new();
            for (p, c) in prompts.iter().zip(&chains) {
                let mut st = model.begin();
                for &t in p {
                    model.step_token(st.as_mut(), t, &mut orow, &mut logits);
                }
                let mut preds = Vec::new();
                for &t in c {
                    model.step_token(st.as_mut(), t, &mut orow, &mut logits);
                    preds.push(NativeDecodeModel::argmax(&logits));
                }
                want.push(preds);
            }
            for threads in [1usize, 4] {
                let pool = Pool::new(threads);
                let mut states: Vec<_> = prompts
                    .iter()
                    .map(|p| {
                        let mut st = model.begin();
                        for &t in p {
                            model.step_token(st.as_mut(), t, &mut orow, &mut logits);
                        }
                        st
                    })
                    .collect();
                let mut items: Vec<VerifyStep> = states
                    .iter_mut()
                    .zip(&chains)
                    .map(|(st, c)| VerifyStep {
                        state: st.as_mut(),
                        chain: c.as_slice(),
                        preds: Vec::new(),
                    })
                    .collect();
                model.verify_batch(&mut items, &pool);
                let got: Vec<Vec<i32>> = items.iter().map(|it| it.preds.clone()).collect();
                assert_eq!(got, want, "{kernel} threads={threads}");
            }
        }
    }

    #[test]
    fn drafter_catch_up_feeds_all_but_the_last_token_and_survives_shed() {
        let model = NativeDecodeModel::new(NativeModelConfig::default()).unwrap();
        let pool = Pool::serial();
        let mut dr = model.make_drafter(DraftSource::Mamba).expect("mamba drafter");
        assert!(model.make_drafter(DraftSource::Off).is_none());
        let tokens: Vec<i32> = (0..12).map(|i| (i * 5 + 2) % 32).collect();
        model.drafter_catch_up(&mut dr, &tokens, &pool);
        assert_eq!(dr.fed, 11, "catch-up stops one short of the committed stream");
        // Idempotent until the stream grows.
        model.drafter_catch_up(&mut dr, &tokens, &pool);
        assert_eq!(dr.fed, 11);
        let bytes = dr.state_bytes();
        assert!(bytes > 0, "mamba context pins arena bytes");
        // Draft a chain; proposals are deterministic for a fixed context.
        let (mut orow, mut logits) = (Vec::new(), Vec::new());
        let last = *tokens.last().unwrap();
        let mut fork = dr.begin(model.begin().as_ref()).expect("context forks");
        let a = model.draft_chain(fork.as_mut(), last, 4, &mut orow, &mut logits);
        assert_eq!(a.len(), 4);
        fork.release();
        // Shed, re-catch-up from zero: the rebuilt context drafts the
        // same chain (lazy catch-up is a pure function of the stream).
        dr.shed();
        assert_eq!(dr.fed, 0);
        assert_eq!(dr.state_bytes(), 0);
        model.drafter_catch_up(&mut dr, &tokens, &pool);
        let mut fork2 = dr.begin(model.begin().as_ref()).expect("re-grown context forks");
        let b = model.draft_chain(fork2.as_mut(), last, 4, &mut orow, &mut logits);
        fork2.release();
        assert_eq!(a, b, "shed + rebuild must not change proposals");
    }

    #[test]
    fn embeddings_are_deterministic_per_seed() {
        let a = NativeDecodeModel::new(NativeModelConfig::default()).unwrap();
        let b = NativeDecodeModel::new(NativeModelConfig::default()).unwrap();
        assert_eq!(a.qe, b.qe);
        let c = NativeDecodeModel::new(NativeModelConfig { seed: 1, ..Default::default() })
            .unwrap();
        assert_ne!(a.qe, c.qe);
    }
}
