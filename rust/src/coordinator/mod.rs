//! Serving coordinator — the L3 request path (vLLM-router-lite).
//!
//! Architecture (std threads; the offline build has no tokio):
//!
//! ```text
//!   clients ──mpsc──▶ [scheduler thread: Batcher + sessions + backend] ─▶ exe
//!      ▲                        │            │
//!      │     one-shot oneshot ◀─┘            │
//!      └───── per-token stream channel ◀─────┘
//! ```
//!
//! Two request kinds share one scheduler:
//!
//! * **one-shot `infer`** — aggregated by the [`batcher::Batcher`] up to the
//!   static batch B with a `max_delay` deadline, padded, executed, fanned
//!   back out (the prefill path).
//! * **streaming `generate`** — each request becomes a [`session::Session`]
//!   holding its per-request decode state. The scheduler runs *continuous
//!   batching*: every sweep advances every active session by one
//!   micro-batch (a prefill slice of the prompt, or one decode step that
//!   emits a token on the stream), interleaved with due infer batches, so
//!   long generations never block new arrivals.
//!
//! Backends:
//!
//! * **PJRT engine** (default): loads the preset's `forward` graph; decode
//!   sweeps are full-recompute forward batches over each session's token
//!   prefix (O(N log N)+ per token — the baseline `exp decode` measures).
//!   PJRT handles are `!Send` (Rc internals), so the scheduler thread
//!   constructs and owns its *own* [`Engine`]; the rest of the process only
//!   exchanges `Send` types with it over channels.
//! * **native decode engine** (`ServerConfig::native`): the in-process
//!   kernel-backed model ([`session::NativeDecodeModel`]) — no artifacts
//!   required, and decode steps run incrementally on the kernel's
//!   [`crate::attention::DecodeState`] (O(log N + k) per token for ZETA).
//!
//! Backpressure: beyond `queue_cap` in-flight requests (one-shot jobs and
//! live sessions both count), `infer` / `generate` fail fast with a Busy
//! error instead of growing the queue without bound. The admission counter
//! rolls back if the scheduler is gone, so a restarted client never eats
//! queue capacity permanently.

pub mod batcher;
pub mod metrics;
pub mod session;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::runtime::{Engine, HostTensor};
use crate::util::pool::{Pool, SharedSlice};
use batcher::{Batcher, Decision};
use metrics::Metrics;
pub use session::{GenStream, NativeModelConfig, StreamEvent};
use session::{NativeDecodeModel, Session};

/// Model output for one request.
#[derive(Debug, Clone)]
pub struct Response {
    /// LM: next-token logits at the last prefix position.
    /// cls: class logits.
    pub logits: Vec<f32>,
    pub latency: Duration,
}

struct Job {
    tokens: Vec<i32>,
    submitted: Instant,
    reply: mpsc::Sender<Result<Response>>,
}

struct GenJob {
    tokens: Vec<i32>,
    max_new: usize,
    submitted: Instant,
    reply: mpsc::Sender<Result<StreamEvent>>,
}

enum Request {
    Infer(Job),
    Generate(GenJob),
}

/// Static batch size of the native backend's one-shot path (the PJRT
/// backend takes its batch from the preset's compiled graph).
const NATIVE_MAX_BATCH: usize = 8;

/// Prompt tokens ingested per session per sweep while prefilling — the
/// micro-batch that keeps prefill from starving concurrent decodes.
const PREFILL_CHUNK: usize = 32;

#[derive(Clone)]
pub struct ServerConfig {
    pub artifacts_dir: String,
    pub preset: String,
    pub max_delay: Duration,
    pub queue_cap: usize,
    pub seed: i32,
    /// Worker-pool size for batch padding/fan-out on the scheduler thread
    /// (0 = the process-global pool, i.e. `ZETA_THREADS` / auto-detect).
    pub threads: usize,
    /// Serve with the in-process native decode engine instead of PJRT:
    /// runs without artifacts and decodes incrementally. `preset` /
    /// `artifacts_dir` are ignored when set.
    pub native: Option<NativeModelConfig>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            artifacts_dir: crate::ARTIFACTS_DIR.into(),
            preset: "serve_cls".into(),
            max_delay: Duration::from_millis(5),
            queue_cap: 256,
            seed: 0,
            threads: 0,
            native: None,
        }
    }
}

/// Handle for submitting requests; cheap to clone across client threads.
#[derive(Clone)]
pub struct ClientHandle {
    tx: mpsc::Sender<Request>,
    depth: Arc<AtomicUsize>,
    queue_cap: usize,
}

impl ClientHandle {
    /// Reserve one queue slot or fail fast. Reserve-then-check keeps the
    /// bound exact under concurrent clients (a load-then-add race would let
    /// a burst overshoot `queue_cap`).
    fn admit(&self) -> Result<()> {
        let prev = self.depth.fetch_add(1, Ordering::Relaxed);
        if prev >= self.queue_cap {
            self.depth.fetch_sub(1, Ordering::Relaxed);
            bail!("server busy: queue at capacity {}", self.queue_cap);
        }
        Ok(())
    }

    /// Send a request, rolling the admission back if the scheduler is gone
    /// (otherwise a stopped server would permanently leak queue capacity).
    fn send(&self, req: Request) -> Result<()> {
        if self.tx.send(req).is_err() {
            self.depth.fetch_sub(1, Ordering::Relaxed);
            bail!("server stopped");
        }
        Ok(())
    }

    /// Submit and wait for the response (blocking).
    pub fn infer(&self, tokens: Vec<i32>) -> Result<Response> {
        self.admit()?;
        let (rtx, rrx) = mpsc::channel();
        self.send(Request::Infer(Job { tokens, submitted: Instant::now(), reply: rtx }))?;
        rrx.recv().map_err(|_| anyhow!("server dropped request"))?
    }

    /// Submit a streaming generation: the returned [`GenStream`] yields
    /// `max_new` tokens (fewer if the context fills) followed by a `Done`
    /// event. Dropping the stream cancels the session.
    pub fn generate(&self, tokens: Vec<i32>, max_new: usize) -> Result<GenStream> {
        if tokens.is_empty() {
            bail!("generate requires a non-empty prompt");
        }
        self.admit()?;
        let (rtx, rrx) = mpsc::channel();
        self.send(Request::Generate(GenJob {
            tokens,
            max_new,
            submitted: Instant::now(),
            reply: rtx,
        }))?;
        Ok(GenStream { rx: rrx })
    }
}

/// The scheduler thread's execution backend (never crosses threads).
enum Backend {
    Native(NativeDecodeModel),
    Engine {
        exe: Arc<crate::runtime::Executable>,
        params: Vec<HostTensor>,
        seq_len: usize,
        is_lm: bool,
        vocab: usize,
    },
}

pub struct Server {
    handle: ClientHandle,
    stop: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<Result<()>>>,
    pub metrics: Arc<Mutex<Metrics>>,
}

impl Server {
    /// Start the scheduler thread. Model weights come from the preset's
    /// `init` graph with `cfg.seed`, unless `params` (e.g. loaded from a
    /// trainer checkpoint) are supplied. With `cfg.native` set, the server
    /// needs no artifacts at all.
    pub fn start(cfg: ServerConfig, params: Option<Vec<HostTensor>>) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Request>();
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let depth = Arc::new(AtomicUsize::new(0));
        // Report startup success/failure back before returning.
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let stop2 = stop.clone();
        let metrics2 = metrics.clone();
        let depth2 = depth.clone();
        let cfg2 = cfg.clone();

        let worker = std::thread::Builder::new()
            .name("zeta-scheduler".into())
            .spawn(move || -> Result<()> {
                // The engine lives on this thread (PJRT handles are !Send).
                let setup = (|| -> Result<(Option<Engine>, Backend, usize)> {
                    match &cfg2.native {
                        Some(ncfg) => {
                            let model = NativeDecodeModel::new(ncfg.clone())?;
                            Ok((None, Backend::Native(model), NATIVE_MAX_BATCH))
                        }
                        None => {
                            let engine = Engine::new(&cfg2.artifacts_dir)?;
                            let pspec = engine.manifest.preset(&cfg2.preset)?;
                            let info =
                                (pspec.batch, pspec.seq_len(), pspec.is_lm(), pspec.vocab());
                            let exe = engine.load(&cfg2.preset, "forward")?;
                            let params = match params {
                                Some(p) => p,
                                None => engine.init_params(&cfg2.preset, cfg2.seed)?,
                            };
                            let backend = Backend::Engine {
                                exe,
                                params,
                                seq_len: info.1,
                                is_lm: info.2,
                                vocab: info.3,
                            };
                            Ok((Some(engine), backend, info.0))
                        }
                    }
                })();
                let (_engine, backend, max_batch) = match setup {
                    Ok(v) => {
                        let _ = ready_tx.send(Ok(()));
                        v
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(anyhow!("{e:#}")));
                        return Err(e);
                    }
                };

                // Pool handle for padding/fan-out and native prefill.
                let pool =
                    if cfg2.threads == 0 { *Pool::global() } else { Pool::new(cfg2.threads) };
                let mut batcher: Batcher<Job> = Batcher::new(max_batch, cfg2.max_delay);
                let mut sessions: Vec<Session> = Vec::new();
                let mut orow: Vec<f32> = Vec::new();
                let mut logits_buf: Vec<f32> = Vec::new();
                // Engine decode sweeps rewrite only the token slab at
                // inputs[0]; the parameter tail is cloned once here, not
                // once per emitted token.
                let mut engine_inputs: Vec<HostTensor> = Vec::new();
                if let Backend::Engine { params, seq_len, .. } = &backend {
                    engine_inputs.push(HostTensor::I32(
                        vec![max_batch, *seq_len],
                        vec![0i32; max_batch * *seq_len],
                    ));
                    engine_inputs.extend(params.iter().cloned());
                }
                let mut disconnected = false;
                loop {
                    let mut stopping = stop2.load(Ordering::Relaxed) || disconnected;
                    // 1. Admit new work without blocking (new generations
                    // are rejected once stopping — their streams would
                    // only be truncated immediately below).
                    loop {
                        match rx.try_recv() {
                            Ok(req) => admit_request(
                                req,
                                &backend,
                                &mut batcher,
                                &mut sessions,
                                &depth2,
                                stopping,
                            ),
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                disconnected = true;
                                stopping = true;
                                break;
                            }
                        }
                    }

                    // Shutdown truncates live streams at a token boundary:
                    // each client gets a final Done with what was generated
                    // so far, so `shutdown()` cannot block on a slow (or
                    // absent) stream consumer.
                    if stopping && !sessions.is_empty() {
                        for s in sessions.drain(..) {
                            depth2.fetch_sub(1, Ordering::Relaxed);
                            let _ = s.reply.send(Ok(StreamEvent::Done {
                                generated: s.generated,
                                latency: s.submitted.elapsed(),
                            }));
                        }
                    }

                    // 2. Fire due one-shot batches (everything when stopping).
                    loop {
                        let fire = match batcher.poll(Instant::now()) {
                            Decision::Fire(k) => Some(k),
                            Decision::Wait(_) if stopping => Some(batcher.len().min(max_batch)),
                            _ => None,
                        };
                        let Some(k) = fire else { break };
                        if k == 0 {
                            break;
                        }
                        let jobs = batcher.take(k);
                        depth2.fetch_sub(jobs.len(), Ordering::Relaxed);
                        match &backend {
                            Backend::Engine { exe, params, seq_len, is_lm, vocab } => run_batch(
                                exe, params, jobs, max_batch, *seq_len, *is_lm, *vocab,
                                &metrics2, &pool,
                            ),
                            Backend::Native(model) => {
                                native_infer_batch(model, jobs, &metrics2, &pool)
                            }
                        }
                    }

                    // 3. Decode micro-batches: advance every active session.
                    if !sessions.is_empty() {
                        match &backend {
                            Backend::Native(model) => native_decode_sweep(
                                model,
                                &mut sessions,
                                &metrics2,
                                &depth2,
                                &mut orow,
                                &mut logits_buf,
                            ),
                            Backend::Engine { exe, seq_len, vocab, .. } => engine_decode_sweep(
                                exe,
                                &mut engine_inputs,
                                &mut sessions,
                                max_batch,
                                *seq_len,
                                *vocab,
                                &metrics2,
                                &depth2,
                            ),
                        }
                        continue; // stay hot while streams are live
                    }

                    // 4. Idle: exit or block briefly for new work.
                    if stopping && batcher.is_empty() {
                        break;
                    }
                    let wait = match batcher.poll(Instant::now()) {
                        Decision::Wait(d) => d,
                        _ => Duration::from_millis(2),
                    };
                    match rx.recv_timeout(wait) {
                        Ok(req) => admit_request(
                            req,
                            &backend,
                            &mut batcher,
                            &mut sessions,
                            &depth2,
                            stopping,
                        ),
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => disconnected = true,
                    }
                }
                Ok(())
            })
            .expect("spawn scheduler");

        ready_rx
            .recv()
            .map_err(|_| anyhow!("scheduler died during startup"))??;

        Ok(Server {
            handle: ClientHandle { tx, depth, queue_cap: cfg.queue_cap },
            stop,
            worker: Some(worker),
            metrics,
        })
    }

    pub fn client(&self) -> ClientHandle {
        self.handle.clone()
    }

    /// Stop the scheduler after draining queued work and live sessions.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Route one admitted request to the batcher or the session table.
fn admit_request(
    req: Request,
    backend: &Backend,
    batcher: &mut Batcher<Job>,
    sessions: &mut Vec<Session>,
    depth: &Arc<AtomicUsize>,
    stopping: bool,
) {
    match req {
        Request::Infer(job) => batcher.push(job),
        Request::Generate(g) => {
            if stopping {
                depth.fetch_sub(1, Ordering::Relaxed);
                let _ = g.reply.send(Err(anyhow!("server stopping")));
                return;
            }
            if g.max_new == 0 {
                depth.fetch_sub(1, Ordering::Relaxed);
                let _ = g.reply.send(Ok(StreamEvent::Done {
                    generated: 0,
                    latency: g.submitted.elapsed(),
                }));
                return;
            }
            match backend {
                Backend::Native(model) => {
                    let state = model.begin();
                    sessions.push(Session::new(
                        g.tokens,
                        g.max_new,
                        g.submitted,
                        g.reply,
                        Some(state),
                    ));
                }
                Backend::Engine { is_lm, seq_len, .. } => {
                    if !*is_lm {
                        depth.fetch_sub(1, Ordering::Relaxed);
                        let _ = g.reply.send(Err(anyhow!(
                            "preset is not an LM; streaming generate unsupported"
                        )));
                        return;
                    }
                    if g.tokens.len() >= *seq_len {
                        depth.fetch_sub(1, Ordering::Relaxed);
                        let _ = g.reply.send(Err(anyhow!(
                            "prompt length {} >= graph context {seq_len}",
                            g.tokens.len()
                        )));
                        return;
                    }
                    sessions.push(Session::new(g.tokens, g.max_new, g.submitted, g.reply, None));
                }
            }
        }
    }
}

/// One-shot inference on the native backend: prefill is exactly one full
/// forward per request (batched arrivals still amortize the scheduler trip).
fn native_infer_batch(
    model: &NativeDecodeModel,
    jobs: Vec<batcher::Pending<Job>>,
    metrics: &Arc<Mutex<Metrics>>,
    pool: &Pool,
) {
    metrics.lock().unwrap().record_batch(jobs.len());
    for p in jobs {
        let result = model.forward_logits(&p.payload.tokens, pool);
        let latency = p.payload.submitted.elapsed();
        match result {
            Ok(logits) => {
                metrics.lock().unwrap().record(latency);
                let _ = p.payload.reply.send(Ok(Response { logits, latency }));
            }
            Err(e) => {
                let _ = p.payload.reply.send(Err(e));
            }
        }
    }
}

/// Outcome of advancing one session by one micro-batch.
enum Advance {
    /// Still prefilling or more tokens to generate.
    Continue,
    /// `max_new` reached — retire with metrics + a `Done` event.
    Done,
    /// The client dropped the stream — retire silently (no metrics, the
    /// receiver is gone).
    Cancelled,
}

/// Advance one native session by one micro-batch.
fn native_advance(
    model: &NativeDecodeModel,
    s: &mut Session,
    orow: &mut Vec<f32>,
    logits: &mut Vec<f32>,
) -> Advance {
    let st = s.state.as_mut().expect("native session carries decode state");
    if s.fed < s.prompt_len {
        // Prefill micro-batch: a slice of prompt tokens per sweep.
        let e = (s.fed + PREFILL_CHUNK).min(s.prompt_len);
        for i in s.fed..e {
            model.step_token(st.as_mut(), s.tokens[i], orow, logits);
        }
        s.fed = e;
        if s.fed < s.prompt_len {
            return Advance::Continue; // still prefilling
        }
        // Prompt ingested: `logits` now predict the first new token.
    } else {
        // Decode step: feed the last emitted token.
        let last = *s.tokens.last().expect("prompt is non-empty");
        model.step_token(st.as_mut(), last, orow, logits);
        s.fed += 1;
    }
    let tok = NativeDecodeModel::argmax(logits);
    s.tokens.push(tok);
    s.generated += 1;
    let pos = s.generated - 1;
    if s.reply.send(Ok(StreamEvent::Token { token: tok, pos })).is_err() {
        return Advance::Cancelled;
    }
    if s.generated >= s.max_new {
        Advance::Done
    } else {
        Advance::Continue
    }
}

/// Continuous-batching sweep on the native backend: every live session
/// advances one micro-batch; finished sessions are retired. Cancelled
/// sessions free their queue slot but are not recorded as completions.
fn native_decode_sweep(
    model: &NativeDecodeModel,
    sessions: &mut Vec<Session>,
    metrics: &Arc<Mutex<Metrics>>,
    depth: &Arc<AtomicUsize>,
    orow: &mut Vec<f32>,
    logits: &mut Vec<f32>,
) {
    let sweep_t0 = Instant::now();
    let mut i = 0;
    let mut emitted = 0u64;
    while i < sessions.len() {
        let before = sessions[i].generated;
        let outcome = native_advance(model, &mut sessions[i], orow, logits);
        emitted += (sessions[i].generated - before) as u64;
        match outcome {
            Advance::Continue => i += 1,
            Advance::Cancelled => {
                sessions.swap_remove(i);
                depth.fetch_sub(1, Ordering::Relaxed);
            }
            Advance::Done => {
                let s = sessions.swap_remove(i);
                depth.fetch_sub(1, Ordering::Relaxed);
                let latency = s.submitted.elapsed();
                let mut m = metrics.lock().unwrap();
                m.record(latency);
                drop(m);
                let _ = s
                    .reply
                    .send(Ok(StreamEvent::Done { generated: s.generated, latency }));
            }
        }
    }
    if emitted > 0 {
        metrics.lock().unwrap().record_tokens(emitted, sweep_t0);
    }
}

/// Continuous-batching sweep on the PJRT backend: full-recompute decode —
/// each wave of up to `max_batch` sessions runs one forward over its token
/// prefixes and takes the logits at each last position. This is the
/// baseline the incremental engine replaces (and what `exp decode` prices).
#[allow(clippy::too_many_arguments)]
fn engine_decode_sweep(
    exe: &crate::runtime::Executable,
    inputs: &mut [HostTensor],
    sessions: &mut Vec<Session>,
    max_batch: usize,
    seq_len: usize,
    vocab: usize,
    metrics: &Arc<Mutex<Metrics>>,
    depth: &Arc<AtomicUsize>,
) {
    let sweep_t0 = Instant::now();
    let mut done = vec![false; sessions.len()];
    // Retire without metrics or a Done event: the request errored (client
    // already got the Err) or the client dropped the stream.
    let mut silent = vec![false; sessions.len()];
    let mut emitted = 0u64;
    let mut start = 0usize;
    while start < sessions.len() {
        let end = (start + max_batch).min(sessions.len());
        let mut last_pos = vec![0usize; end - start];
        {
            // Rewrite the token slab in place; the parameter tail of
            // `inputs` was cloned once at scheduler startup.
            let HostTensor::I32(_, slab) = &mut inputs[0] else {
                unreachable!("token slab is always I32");
            };
            slab.fill(0);
            for (r, s) in sessions[start..end].iter().enumerate() {
                let n = s.tokens.len().min(seq_len);
                slab[r * seq_len..r * seq_len + n].copy_from_slice(&s.tokens[..n]);
                last_pos[r] = n.saturating_sub(1);
            }
        }
        // A wave-wide failure (execution error, or a forward graph whose
        // output is not the expected (B, N, V) f32 logits) errors every
        // session in the wave instead of panicking the scheduler.
        let mut wave_err: Option<String> = None;
        match exe.run(inputs) {
            Ok(out) => {
                let logits = out[0].as_f32().unwrap_or(&[]);
                if logits.len() < max_batch * seq_len * vocab {
                    wave_err = Some(format!(
                        "decode batch returned malformed logits: {} elems, want {}",
                        logits.len(),
                        max_batch * seq_len * vocab
                    ));
                } else {
                    for (r, s) in sessions[start..end].iter_mut().enumerate() {
                        let base = (r * seq_len + last_pos[r]) * vocab;
                        let tok = NativeDecodeModel::argmax(&logits[base..base + vocab]);
                        s.tokens.push(tok);
                        s.generated += 1;
                        emitted += 1;
                        let pos = s.generated - 1;
                        let gone =
                            s.reply.send(Ok(StreamEvent::Token { token: tok, pos })).is_err();
                        if gone {
                            done[start + r] = true;
                            silent[start + r] = true;
                        } else if s.generated >= s.max_new || s.tokens.len() >= seq_len {
                            done[start + r] = true;
                        }
                    }
                }
            }
            Err(e) => wave_err = Some(format!("decode batch failed: {e}")),
        }
        if let Some(msg) = wave_err {
            for (r, s) in sessions[start..end].iter().enumerate() {
                let _ = s.reply.send(Err(anyhow!(msg.clone())));
                done[start + r] = true;
                silent[start + r] = true;
            }
        }
        start = end;
    }
    for i in (0..sessions.len()).rev() {
        if done[i] {
            let s = sessions.swap_remove(i);
            depth.fetch_sub(1, Ordering::Relaxed);
            if silent[i] {
                continue;
            }
            let latency = s.submitted.elapsed();
            let mut m = metrics.lock().unwrap();
            m.record(latency);
            drop(m);
            let _ = s
                .reply
                .send(Ok(StreamEvent::Done { generated: s.generated, latency }));
        }
    }
    if emitted > 0 {
        metrics.lock().unwrap().record_tokens(emitted, sweep_t0);
    }
}

/// Pad/fan-out threshold in total token elements: below this the scoped
/// thread spawn (tens of µs per worker; the pool has no persistent
/// threads) costs more than the memcpy it splits, so the fill stays on
/// the scheduler thread. 1M i32 elements = 4 MB of row copies, ~hundreds
/// of µs serially — the point where splitting starts to pay.
const PARALLEL_PAD_MIN_ELEMS: usize = 1 << 20;

#[allow(clippy::too_many_arguments)]
fn run_batch(
    exe: &crate::runtime::Executable,
    params: &[HostTensor],
    jobs: Vec<batcher::Pending<Job>>,
    max_batch: usize,
    seq_len: usize,
    is_lm: bool,
    vocab: usize,
    metrics: &Arc<Mutex<Metrics>>,
    pool: &Pool,
) {
    let mut x = vec![0i32; max_batch * seq_len];
    let mut last_pos = vec![0usize; jobs.len()];
    // Token refs only (the Job's reply channel stays on this thread).
    let toks: Vec<&[i32]> = jobs.iter().map(|p| p.payload.tokens.as_slice()).collect();
    for (r, t) in toks.iter().enumerate() {
        last_pos[r] = t.len().min(seq_len).saturating_sub(1);
    }
    if toks.len() * seq_len >= PARALLEL_PAD_MIN_ELEMS && toks.len() >= 2 && pool.threads() > 1 {
        // Row-parallel padding: each request row of x is disjoint.
        let xsh = SharedSlice::new(&mut x);
        pool.parallel_for(toks.len(), 1, |rows| {
            for r in rows {
                let t = toks[r];
                let n = t.len().min(seq_len);
                // Safety: row r claimed by exactly one chunk.
                let row = unsafe { xsh.range_mut(r * seq_len..(r + 1) * seq_len) };
                row[..n].copy_from_slice(&t[..n]);
            }
        });
    } else {
        for (r, t) in toks.iter().enumerate() {
            let n = t.len().min(seq_len);
            x[r * seq_len..r * seq_len + n].copy_from_slice(&t[..n]);
        }
    }
    let mut inputs = vec![HostTensor::I32(vec![max_batch, seq_len], x)];
    inputs.extend(params.iter().cloned());
    let result = exe.run(&inputs);
    metrics.lock().unwrap().record_batch(jobs.len());
    match result {
        Ok(out) => {
            let logits = out[0].as_f32().unwrap_or(&[]);
            for (r, p) in jobs.into_iter().enumerate() {
                let row = if is_lm {
                    let base = (r * seq_len + last_pos[r]) * vocab;
                    logits[base..base + vocab].to_vec()
                } else {
                    let ncls = logits.len() / max_batch;
                    logits[r * ncls..(r + 1) * ncls].to_vec()
                };
                let latency = p.payload.submitted.elapsed();
                metrics.lock().unwrap().record(latency);
                let _ = p.payload.reply.send(Ok(Response { logits: row, latency }));
            }
        }
        Err(e) => {
            let msg = format!("batch execution failed: {e}");
            for p in jobs {
                let _ = p.payload.reply.send(Err(anyhow!(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    //! Native-backend tests run everywhere; PJRT-backed tests skip when
    //! artifacts are absent.
    use super::*;

    fn have_artifacts() -> bool {
        let ok = std::path::Path::new(crate::ARTIFACTS_DIR).join("manifest.json").exists();
        if !ok {
            eprintln!("skipping coordinator test: artifacts/ missing");
        }
        ok
    }

    fn native_cfg(kernel: &str) -> ServerConfig {
        ServerConfig {
            native: Some(NativeModelConfig { kernel: kernel.into(), ..Default::default() }),
            max_delay: Duration::from_millis(1),
            ..Default::default()
        }
    }

    #[test]
    fn serves_single_request() {
        if !have_artifacts() {
            return;
        }
        let srv = Server::start(ServerConfig::default(), None).unwrap();
        let client = srv.client();
        let resp = client.infer(vec![5, 6, 7, 8]).unwrap();
        assert_eq!(resp.logits.len(), 2); // serve_cls has 2 classes
        assert!(resp.logits.iter().all(|v| v.is_finite()));
        srv.shutdown();
    }

    #[test]
    fn serves_concurrent_clients_and_batches() {
        if !have_artifacts() {
            return;
        }
        let cfg = ServerConfig { max_delay: Duration::from_millis(20), ..Default::default() };
        let srv = Server::start(cfg, None).unwrap();
        let mut handles = Vec::new();
        for i in 0..12 {
            let c = srv.client();
            handles.push(std::thread::spawn(move || {
                c.infer(vec![(i % 50) as i32 + 1; 16]).unwrap()
            }));
        }
        for h in handles {
            let r = h.join().unwrap();
            assert_eq!(r.logits.len(), 2);
        }
        let m = srv.metrics.lock().unwrap();
        assert_eq!(m.completed, 12);
        assert!(m.mean_batch_size() > 1.0, "no batching happened: {}", m.summary());
        drop(m);
        srv.shutdown();
    }

    #[test]
    fn identical_inputs_identical_outputs() {
        if !have_artifacts() {
            return;
        }
        let srv = Server::start(ServerConfig::default(), None).unwrap();
        let c = srv.client();
        let a = c.infer(vec![3; 32]).unwrap();
        let b = c.infer(vec![3; 32]).unwrap();
        assert_eq!(a.logits, b.logits);
        srv.shutdown();
    }

    #[test]
    fn bad_preset_fails_at_startup() {
        if !have_artifacts() {
            return;
        }
        let cfg = ServerConfig { preset: "nonexistent".into(), ..Default::default() };
        assert!(Server::start(cfg, None).is_err());
    }

    #[test]
    fn native_server_infers_without_artifacts() {
        let srv = Server::start(native_cfg("zeta"), None).unwrap();
        let c = srv.client();
        let r = c.infer(vec![3, 1, 4, 1, 5]).unwrap();
        assert_eq!(r.logits.len(), NativeModelConfig::default().vocab);
        assert!(r.logits.iter().all(|v| v.is_finite()));
        srv.shutdown();
    }

    #[test]
    fn native_generate_streams_exactly_max_new_tokens() {
        let srv = Server::start(native_cfg("zeta"), None).unwrap();
        let c = srv.client();
        let stream = c.generate(vec![3, 1, 4, 1, 5, 9, 2, 6], 12).unwrap();
        let toks = stream.collect_tokens().unwrap();
        assert_eq!(toks.len(), 12);
        let vocab = NativeModelConfig::default().vocab as i32;
        assert!(toks.iter().all(|&t| (0..vocab).contains(&t)), "{toks:?}");
        let m = srv.metrics.lock().unwrap();
        assert_eq!(m.tokens, 12);
        assert_eq!(m.completed, 1);
        drop(m);
        srv.shutdown();
    }

    #[test]
    fn native_generate_is_deterministic() {
        let srv = Server::start(native_cfg("zeta"), None).unwrap();
        let c = srv.client();
        let a = c.generate(vec![7, 7, 7], 8).unwrap().collect_tokens().unwrap();
        let b = c.generate(vec![7, 7, 7], 8).unwrap().collect_tokens().unwrap();
        assert_eq!(a, b);
        srv.shutdown();
    }

    #[test]
    fn incremental_sessions_match_full_recompute_reference() {
        // The session-level equivalence gate: streaming decode through the
        // server must reproduce the token stream of re-running a full
        // forward per emitted token.
        for kernel in ["zeta", "naive", "mamba"] {
            let srv = Server::start(native_cfg(kernel), None).unwrap();
            let prompt = vec![5, 9, 13, 2, 2, 7];
            let got =
                srv.client().generate(prompt.clone(), 10).unwrap().collect_tokens().unwrap();
            srv.shutdown();

            let model = NativeDecodeModel::new(NativeModelConfig {
                kernel: kernel.into(),
                ..Default::default()
            })
            .unwrap();
            let pool = Pool::serial();
            let mut toks = prompt;
            let mut want = Vec::new();
            for _ in 0..10 {
                let logits = model.forward_logits(&toks, &pool).unwrap();
                let t = NativeDecodeModel::argmax(&logits);
                want.push(t);
                toks.push(t);
            }
            assert_eq!(got, want, "kernel {kernel}");
        }
    }

    #[test]
    fn concurrent_generate_and_infer_interleave() {
        let srv = Server::start(native_cfg("zeta"), None).unwrap();
        let c = srv.client();
        let s1 = c.generate(vec![1, 2, 3], 6).unwrap();
        let s2 = c.generate(vec![9, 8, 7, 6], 4).unwrap();
        let r = c.infer(vec![4, 5, 6]).unwrap();
        assert_eq!(r.logits.len(), NativeModelConfig::default().vocab);
        assert_eq!(s1.collect_tokens().unwrap().len(), 6);
        assert_eq!(s2.collect_tokens().unwrap().len(), 4);
        let m = srv.metrics.lock().unwrap();
        assert_eq!(m.tokens, 10);
        drop(m);
        srv.shutdown();
    }

    #[test]
    fn stopped_server_rejects_without_leaking_queue_capacity() {
        // Regression for the depth-counter leak: every failed submit must
        // roll its admission back, so repeated retries against a stopped
        // server keep reporting "stopped" — never a phantom "busy".
        let cfg = ServerConfig { queue_cap: 2, ..native_cfg("zeta") };
        let srv = Server::start(cfg, None).unwrap();
        let c = srv.client();
        srv.shutdown();
        for i in 0..5 {
            let err = c.infer(vec![1, 2, 3]).unwrap_err().to_string();
            assert!(err.contains("server stopped"), "attempt {i}: {err}");
        }
        let err = c.generate(vec![1], 4).unwrap_err().to_string();
        assert!(err.contains("server stopped"), "{err}");
    }

    #[test]
    fn zero_max_new_completes_immediately() {
        let srv = Server::start(native_cfg("mamba"), None).unwrap();
        let toks = srv.client().generate(vec![1, 2], 0).unwrap().collect_tokens().unwrap();
        assert!(toks.is_empty());
        srv.shutdown();
    }

    #[test]
    fn dropping_stream_cancels_session() {
        let srv = Server::start(native_cfg("mamba"), None).unwrap();
        let c = srv.client();
        let stream = c.generate(vec![1, 2, 3], 1_000_000).unwrap();
        // read one token, then hang up
        let first = stream.recv().unwrap().unwrap();
        assert!(matches!(first, StreamEvent::Token { .. }));
        drop(stream);
        // the scheduler notices the dead channel and retires the session;
        // a subsequent one-shot request must still be served promptly.
        let r = c.infer(vec![2, 2, 2]).unwrap();
        assert!(r.logits.iter().all(|v| v.is_finite()));
        srv.shutdown();
    }
}
