//! Serving coordinator — the L3 request path (vLLM-router-lite).
//!
//! Architecture (std threads; the offline build has no tokio):
//!
//! ```text
//!   clients ──mpsc──▶ [scheduler thread: Batcher + own PJRT engine] ─▶ exe
//!      ▲                                                   │
//!      └──────────── per-request oneshot channel ◀─────────┘
//! ```
//!
//! * PJRT handles from the `xla` crate are `!Send` (Rc internals), so the
//!   scheduler thread constructs and owns its *own* [`Engine`]; the rest of
//!   the process only exchanges `Send` types (tokens, `HostTensor`s) with
//!   it over channels.
//! * Requests carry a token prefix; responses carry the model's next-token
//!   logits (LM presets) or class logits (cls presets).
//! * The scheduler aggregates up to the graph's static batch B with a
//!   `max_delay` deadline ([`batcher::Batcher`]), pads the tail, executes,
//!   and fans results back out.
//! * Backpressure: beyond `queue_cap` in-flight requests, `infer` fails
//!   fast with a Busy error instead of growing the queue without bound.
//! * The scheduler owns a worker-pool handle ([`crate::util::pool::Pool`],
//!   sized by `ServerConfig::threads` / `ZETA_THREADS`): padding and
//!   fan-out of large batches is split across the pool instead of running
//!   serially on the scheduler thread.

pub mod batcher;
pub mod metrics;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::runtime::{Engine, HostTensor};
use crate::util::pool::{Pool, SharedSlice};
use batcher::{Batcher, Decision};
use metrics::Metrics;

/// Model output for one request.
#[derive(Debug, Clone)]
pub struct Response {
    /// LM: next-token logits at the last prefix position.
    /// cls: class logits.
    pub logits: Vec<f32>,
    pub latency: Duration,
}

struct Job {
    tokens: Vec<i32>,
    submitted: Instant,
    reply: mpsc::Sender<Result<Response>>,
}

#[derive(Clone)]
pub struct ServerConfig {
    pub artifacts_dir: String,
    pub preset: String,
    pub max_delay: Duration,
    pub queue_cap: usize,
    pub seed: i32,
    /// Worker-pool size for batch padding/fan-out on the scheduler thread
    /// (0 = the process-global pool, i.e. `ZETA_THREADS` / auto-detect).
    pub threads: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            artifacts_dir: crate::ARTIFACTS_DIR.into(),
            preset: "serve_cls".into(),
            max_delay: Duration::from_millis(5),
            queue_cap: 256,
            seed: 0,
            threads: 0,
        }
    }
}

/// Handle for submitting requests; cheap to clone across client threads.
#[derive(Clone)]
pub struct ClientHandle {
    tx: mpsc::Sender<Job>,
    depth: Arc<AtomicUsize>,
    queue_cap: usize,
}

impl ClientHandle {
    /// Submit and wait for the response (blocking).
    pub fn infer(&self, tokens: Vec<i32>) -> Result<Response> {
        if self.depth.load(Ordering::Relaxed) >= self.queue_cap {
            bail!("server busy: queue at capacity {}", self.queue_cap);
        }
        self.depth.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Job { tokens, submitted: Instant::now(), reply: rtx })
            .map_err(|_| anyhow!("server stopped"))?;
        rrx.recv().map_err(|_| anyhow!("server dropped request"))?
    }
}

pub struct Server {
    handle: ClientHandle,
    stop: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<Result<()>>>,
    pub metrics: Arc<Mutex<Metrics>>,
}

impl Server {
    /// Start the scheduler thread. Model weights come from the preset's
    /// `init` graph with `cfg.seed`, unless `params` (e.g. loaded from a
    /// trainer checkpoint) are supplied.
    pub fn start(cfg: ServerConfig, params: Option<Vec<HostTensor>>) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Job>();
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let depth = Arc::new(AtomicUsize::new(0));
        // Report startup success/failure back before returning.
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let stop2 = stop.clone();
        let metrics2 = metrics.clone();
        let depth2 = depth.clone();
        let cfg2 = cfg.clone();

        let worker = std::thread::Builder::new()
            .name("zeta-scheduler".into())
            .spawn(move || -> Result<()> {
                // The engine lives on this thread (PJRT handles are !Send).
                let setup = (|| -> Result<_> {
                    let engine = Engine::new(&cfg2.artifacts_dir)?;
                    let pspec = engine.manifest.preset(&cfg2.preset)?;
                    let info = (pspec.batch, pspec.seq_len(), pspec.is_lm(), pspec.vocab());
                    let exe = engine.load(&cfg2.preset, "forward")?;
                    let params = match params {
                        Some(p) => p,
                        None => engine.init_params(&cfg2.preset, cfg2.seed)?,
                    };
                    Ok((engine, exe, params, info))
                })();
                let (_engine, exe, params, (max_batch, seq_len, is_lm, vocab)) = match setup {
                    Ok(v) => {
                        let _ = ready_tx.send(Ok(()));
                        v
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(anyhow!("{e:#}")));
                        return Err(e);
                    }
                };

                // Pool handle for padding/fan-out of large batches.
                let pool =
                    if cfg2.threads == 0 { *Pool::global() } else { Pool::new(cfg2.threads) };
                let mut batcher: Batcher<Job> = Batcher::new(max_batch, cfg2.max_delay);
                loop {
                    match batcher.poll(Instant::now()) {
                        Decision::Fire(k) => {
                            let jobs = batcher.take(k);
                            depth2.fetch_sub(jobs.len(), Ordering::Relaxed);
                            run_batch(
                                &exe, &params, jobs, max_batch, seq_len, is_lm, vocab,
                                &metrics2, &pool,
                            );
                            continue;
                        }
                        Decision::Wait(d) => match rx.recv_timeout(d) {
                            Ok(job) => {
                                batcher.push(job);
                                while batcher.len() < max_batch {
                                    match rx.try_recv() {
                                        Ok(j) => batcher.push(j),
                                        Err(_) => break,
                                    }
                                }
                            }
                            Err(mpsc::RecvTimeoutError::Timeout) => {}
                            Err(mpsc::RecvTimeoutError::Disconnected) => {}
                        },
                        Decision::Idle => {
                            match rx.recv_timeout(Duration::from_millis(2)) {
                                Ok(job) => batcher.push(job),
                                Err(mpsc::RecvTimeoutError::Timeout) => {}
                                Err(mpsc::RecvTimeoutError::Disconnected) => {
                                    if batcher.is_empty() {
                                        break;
                                    }
                                }
                            }
                            if stop2.load(Ordering::Relaxed) && batcher.is_empty() {
                                break;
                            }
                        }
                    }
                }
                Ok(())
            })
            .expect("spawn scheduler");

        ready_rx
            .recv()
            .map_err(|_| anyhow!("scheduler died during startup"))??;

        Ok(Server {
            handle: ClientHandle { tx, depth, queue_cap: cfg.queue_cap },
            stop,
            worker: Some(worker),
            metrics,
        })
    }

    pub fn client(&self) -> ClientHandle {
        self.handle.clone()
    }

    /// Stop the scheduler after draining queued work.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Pad/fan-out threshold in total token elements: below this the scoped
/// thread spawn (tens of µs per worker; the pool has no persistent
/// threads) costs more than the memcpy it splits, so the fill stays on
/// the scheduler thread. 1M i32 elements = 4 MB of row copies, ~hundreds
/// of µs serially — the point where splitting starts to pay.
const PARALLEL_PAD_MIN_ELEMS: usize = 1 << 20;

#[allow(clippy::too_many_arguments)]
fn run_batch(
    exe: &crate::runtime::Executable,
    params: &[HostTensor],
    jobs: Vec<batcher::Pending<Job>>,
    max_batch: usize,
    seq_len: usize,
    is_lm: bool,
    vocab: usize,
    metrics: &Arc<Mutex<Metrics>>,
    pool: &Pool,
) {
    let mut x = vec![0i32; max_batch * seq_len];
    let mut last_pos = vec![0usize; jobs.len()];
    // Token refs only (the Job's reply channel stays on this thread).
    let toks: Vec<&[i32]> = jobs.iter().map(|p| p.payload.tokens.as_slice()).collect();
    for (r, t) in toks.iter().enumerate() {
        last_pos[r] = t.len().min(seq_len).saturating_sub(1);
    }
    if toks.len() * seq_len >= PARALLEL_PAD_MIN_ELEMS && toks.len() >= 2 && pool.threads() > 1 {
        // Row-parallel padding: each request row of x is disjoint.
        let xsh = SharedSlice::new(&mut x);
        pool.parallel_for(toks.len(), 1, |rows| {
            for r in rows {
                let t = toks[r];
                let n = t.len().min(seq_len);
                // Safety: row r claimed by exactly one chunk.
                let row = unsafe { xsh.range_mut(r * seq_len..(r + 1) * seq_len) };
                row[..n].copy_from_slice(&t[..n]);
            }
        });
    } else {
        for (r, t) in toks.iter().enumerate() {
            let n = t.len().min(seq_len);
            x[r * seq_len..r * seq_len + n].copy_from_slice(&t[..n]);
        }
    }
    let mut inputs = vec![HostTensor::I32(vec![max_batch, seq_len], x)];
    inputs.extend(params.iter().cloned());
    let result = exe.run(&inputs);
    metrics.lock().unwrap().record_batch(jobs.len());
    match result {
        Ok(out) => {
            let logits = out[0].as_f32().unwrap_or(&[]);
            for (r, p) in jobs.into_iter().enumerate() {
                let row = if is_lm {
                    let base = (r * seq_len + last_pos[r]) * vocab;
                    logits[base..base + vocab].to_vec()
                } else {
                    let ncls = logits.len() / max_batch;
                    logits[r * ncls..(r + 1) * ncls].to_vec()
                };
                let latency = p.payload.submitted.elapsed();
                metrics.lock().unwrap().record(latency);
                let _ = p.payload.reply.send(Ok(Response { logits: row, latency }));
            }
        }
        Err(e) => {
            let msg = format!("batch execution failed: {e}");
            for p in jobs {
                let _ = p.payload.reply.send(Err(anyhow!(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    //! End-to-end serving tests over real artifacts (skip when absent).
    use super::*;

    fn have_artifacts() -> bool {
        let ok = std::path::Path::new(crate::ARTIFACTS_DIR).join("manifest.json").exists();
        if !ok {
            eprintln!("skipping coordinator test: artifacts/ missing");
        }
        ok
    }

    #[test]
    fn serves_single_request() {
        if !have_artifacts() {
            return;
        }
        let srv = Server::start(ServerConfig::default(), None).unwrap();
        let client = srv.client();
        let resp = client.infer(vec![5, 6, 7, 8]).unwrap();
        assert_eq!(resp.logits.len(), 2); // serve_cls has 2 classes
        assert!(resp.logits.iter().all(|v| v.is_finite()));
        srv.shutdown();
    }

    #[test]
    fn serves_concurrent_clients_and_batches() {
        if !have_artifacts() {
            return;
        }
        let cfg = ServerConfig { max_delay: Duration::from_millis(20), ..Default::default() };
        let srv = Server::start(cfg, None).unwrap();
        let mut handles = Vec::new();
        for i in 0..12 {
            let c = srv.client();
            handles.push(std::thread::spawn(move || {
                c.infer(vec![(i % 50) as i32 + 1; 16]).unwrap()
            }));
        }
        for h in handles {
            let r = h.join().unwrap();
            assert_eq!(r.logits.len(), 2);
        }
        let m = srv.metrics.lock().unwrap();
        assert_eq!(m.completed, 12);
        assert!(m.mean_batch_size() > 1.0, "no batching happened: {}", m.summary());
        drop(m);
        srv.shutdown();
    }

    #[test]
    fn identical_inputs_identical_outputs() {
        if !have_artifacts() {
            return;
        }
        let srv = Server::start(ServerConfig::default(), None).unwrap();
        let c = srv.client();
        let a = c.infer(vec![3; 32]).unwrap();
        let b = c.infer(vec![3; 32]).unwrap();
        assert_eq!(a.logits, b.logits);
        srv.shutdown();
    }

    #[test]
    fn bad_preset_fails_at_startup() {
        if !have_artifacts() {
            return;
        }
        let cfg = ServerConfig { preset: "nonexistent".into(), ..Default::default() };
        assert!(Server::start(cfg, None).is_err());
    }
}
