//! Serving coordinator — the L3 request path (vLLM-router-lite).
//!
//! Architecture (std threads; the offline build has no tokio):
//!
//! ```text
//!   clients ──mpsc──▶ [scheduler thread: Batcher + sessions + backend] ─▶ exe
//!      ▲                        │            │
//!      │     one-shot oneshot ◀─┘            │
//!      └───── per-token stream channel ◀─────┘
//! ```
//!
//! Two request kinds share one scheduler:
//!
//! * **one-shot `infer`** — aggregated by the [`batcher::Batcher`] up to the
//!   static batch B with a `max_delay` deadline, padded, executed, fanned
//!   back out (the prefill path).
//! * **streaming `generate`** — each request becomes a [`session::Session`]
//!   holding its per-request decode state. The scheduler runs *continuous
//!   batching*: every sweep partitions the live sessions into a prefill
//!   wave (bounded by a global per-sweep prefill-token budget, so a burst
//!   of long prompts cannot starve token cadence) and a *fused decode
//!   wave* — one pool-parallel [`crate::attention::AttentionImpl::step_batch`]
//!   kernel call across all ready sessions instead of N serial steps —
//!   interleaved with due infer batches, so long generations never block
//!   new arrivals.
//!
//! Backends:
//!
//! * **PJRT engine** (default): loads the preset's `forward` graph; decode
//!   sweeps are full-recompute forward batches over each session's token
//!   prefix (O(N log N)+ per token — the baseline `exp decode` measures).
//!   PJRT handles are `!Send` (Rc internals), so the scheduler thread
//!   constructs and owns its *own* [`Engine`]; the rest of the process only
//!   exchanges `Send` types with it over channels.
//! * **native decode engine** (`ServerConfig::native`): the in-process
//!   kernel-backed model ([`session::NativeDecodeModel`]) — no artifacts
//!   required, and decode steps run incrementally on the kernel's
//!   [`crate::attention::DecodeState`] (O(log N + k) per token for ZETA).
//!
//! Backpressure: beyond `queue_cap` in-flight requests (one-shot jobs and
//! live sessions both count), `infer` / `generate` fail fast with a Busy
//! error instead of growing the queue without bound. The admission counter
//! rolls back if the scheduler is gone, so a restarted client never eats
//! queue capacity permanently.

pub mod batcher;
pub mod metrics;
pub mod session;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::attention::speculate::DraftSource;
use crate::attention::DecodeState;
use crate::runtime::{Engine, HostTensor};
use crate::util::arena::{KvQuant, PageArena};
use crate::util::breakeven::{fan_out, PARALLEL_PAD_MIN_ELEMS};
use crate::util::pool::{Pool, SharedSlice};
use batcher::{Batcher, Decision};
use metrics::Metrics;
pub use session::{GenStream, NativeModelConfig, RecvTimeout, StreamEvent};
pub use session::{NativeDecodeModel, PrefixCache, Session};
use session::{PrefillStep, SessionStep, StepScratch, VerifyStep};

/// Model output for one request.
#[derive(Debug, Clone)]
pub struct Response {
    /// LM: next-token logits at the last prefix position.
    /// cls: class logits.
    pub logits: Vec<f32>,
    pub latency: Duration,
}

struct Job {
    tokens: Vec<i32>,
    submitted: Instant,
    reply: mpsc::Sender<Result<Response>>,
}

struct GenJob {
    tokens: Vec<i32>,
    max_new: usize,
    submitted: Instant,
    reply: mpsc::Sender<Result<StreamEvent>>,
    /// Shared with the client's [`GenStream`]; set when it is dropped.
    cancel: Arc<AtomicBool>,
}

enum Request {
    Infer(Job),
    Generate(GenJob),
}

/// Static batch size of the native backend's one-shot path (the PJRT
/// backend takes its batch from the preset's compiled graph).
const NATIVE_MAX_BATCH: usize = 8;

/// Default prefill chunk (`ServerConfig::prefill_chunk` / `--prefill-chunk`):
/// the round-robin grant size, in prompt tokens, of the per-sweep prefill
/// allocator. Chunks keep a burst of long prompts fair in arrival order;
/// once every prefilling session holds a chunk, leftover budget keeps
/// flowing, so a lone long prompt takes *many* chunks per sweep through the
/// pipelined kernel path instead of serializing one micro-batch per sweep.
const DEFAULT_PREFILL_CHUNK: usize = 32;

/// Default global per-sweep prefill-token budget (`ServerConfig::prefill_budget`).
const DEFAULT_PREFILL_BUDGET: usize = 256;

/// Entry cap of the prompt-prefix cache (LRU beyond it). Entries hold real
/// arena pages, so the cap bounds cache memory alongside the byte budget.
const PREFIX_CACHE_CAP: usize = 32;

/// Default speculative draft length (`--draft-len`): tokens proposed per
/// draft-then-verify wave when `--speculate` is on.
pub const DEFAULT_DRAFT_LEN: usize = 4;

#[derive(Clone)]
pub struct ServerConfig {
    pub artifacts_dir: String,
    pub preset: String,
    pub max_delay: Duration,
    pub queue_cap: usize,
    pub seed: i32,
    /// Worker-pool size for batch padding/fan-out on the scheduler thread
    /// (0 = the process-global pool, i.e. `ZETA_THREADS` / auto-detect).
    pub threads: usize,
    /// Global cap on prompt tokens ingested per scheduler sweep, summed
    /// across *all* prefilling sessions (native backend). The budget is
    /// dealt out round-robin in `prefill_chunk`-token grants in arrival
    /// order, so a burst of long prompts cannot starve the decode wave's
    /// token cadence — but when budget is left after every session holds a
    /// grant, sessions keep accumulating chunks (the pipelined long-prompt
    /// path). 0 = unlimited.
    pub prefill_budget: usize,
    /// Round-robin grant size of the per-sweep prefill allocator
    /// (`--prefill-chunk`), in prompt tokens. Must be >= 1 — rejected at
    /// startup otherwise. Default [`DEFAULT_PREFILL_CHUNK`].
    pub prefill_chunk: usize,
    /// Byte budget (`--kv-mem-budget`) over the native backend's page
    /// arena — the KV/code/state rows of every live session *and* the
    /// prompt-prefix cache. (Arena pages are the dominant share of decode
    /// memory; ZETA's refcounted sorted-run index adds ~8 B/token of
    /// plain heap the budget does not meter.) New sessions are admitted
    /// only when the budget has headroom;
    /// when live pages exceed it, the scheduler sheds prefix-cache entries
    /// first and then preempts the least-recently-stepped session (its
    /// pages drop, and it transparently re-prefills later with identical
    /// output tokens). 0 = unlimited. Must be at least one KV page.
    pub kv_mem_budget: usize,
    /// Speculative decoding draft source (`--speculate`): `"off"` (plain
    /// one-step decode), `"mamba"` (constant-state RNN drafter) or
    /// `"self"` (low-`k` self-speculation on kernels that offer a
    /// narrowed configuration — ZETA). Accepted token streams are
    /// bit-identical to `"off"` for every source, kernel and thread
    /// count (the `rust/tests/spec_decode.rs` gate); speculation only
    /// changes how many full-kernel waves those tokens cost. Native
    /// backend only.
    pub speculate: String,
    /// Tokens proposed per draft-then-verify wave (`--draft-len`, >= 1).
    /// Ignored when `speculate` is `"off"`.
    pub draft_len: usize,
    /// Serve with the in-process native decode engine instead of PJRT:
    /// runs without artifacts and decodes incrementally. `preset` /
    /// `artifacts_dir` are ignored when set.
    pub native: Option<NativeModelConfig>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            artifacts_dir: crate::ARTIFACTS_DIR.into(),
            preset: "serve_cls".into(),
            max_delay: Duration::from_millis(5),
            queue_cap: 256,
            seed: 0,
            threads: 0,
            prefill_budget: DEFAULT_PREFILL_BUDGET,
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
            kv_mem_budget: 0,
            speculate: "off".into(),
            draft_len: DEFAULT_DRAFT_LEN,
            native: None,
        }
    }
}

/// Handle for submitting requests; cheap to clone across client threads.
#[derive(Clone)]
pub struct ClientHandle {
    tx: mpsc::Sender<Request>,
    depth: Arc<AtomicUsize>,
    queue_cap: usize,
}

impl ClientHandle {
    /// Reserve one queue slot or fail fast. Reserve-then-check keeps the
    /// bound exact under concurrent clients (a load-then-add race would let
    /// a burst overshoot `queue_cap`).
    fn admit(&self) -> Result<()> {
        let prev = self.depth.fetch_add(1, Ordering::Relaxed);
        if prev >= self.queue_cap {
            self.depth.fetch_sub(1, Ordering::Relaxed);
            bail!("server busy: queue at capacity {}", self.queue_cap);
        }
        Ok(())
    }

    /// Send a request, rolling the admission back if the scheduler is gone
    /// (otherwise a stopped server would permanently leak queue capacity).
    fn send(&self, req: Request) -> Result<()> {
        if self.tx.send(req).is_err() {
            self.depth.fetch_sub(1, Ordering::Relaxed);
            bail!("server stopped");
        }
        Ok(())
    }

    /// Submit and wait for the response (blocking).
    pub fn infer(&self, tokens: Vec<i32>) -> Result<Response> {
        self.admit()?;
        let (rtx, rrx) = mpsc::channel();
        self.send(Request::Infer(Job { tokens, submitted: Instant::now(), reply: rtx }))?;
        rrx.recv().map_err(|_| anyhow!("server dropped request"))?
    }

    /// Submit a streaming generation: the returned [`GenStream`] yields
    /// `max_new` tokens (fewer if the context fills — the native backend's
    /// `NativeModelConfig::max_context`, the engine backend's graph
    /// `seq_len`) followed by a `Done` event. Dropping the stream cancels
    /// the session immediately, even mid-prefill.
    pub fn generate(&self, tokens: Vec<i32>, max_new: usize) -> Result<GenStream> {
        if tokens.is_empty() {
            bail!("generate requires a non-empty prompt");
        }
        self.admit()?;
        let (rtx, rrx) = mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(false));
        self.send(Request::Generate(GenJob {
            tokens,
            max_new,
            submitted: Instant::now(),
            reply: rtx,
            cancel: cancel.clone(),
        }))?;
        Ok(GenStream { rx: rrx, cancel })
    }
}

/// The scheduler thread's execution backend (never crosses threads).
enum Backend {
    Native(NativeServing),
    Engine {
        exe: Arc<crate::runtime::Executable>,
        params: Vec<HostTensor>,
        seq_len: usize,
        is_lm: bool,
        vocab: usize,
    },
}

pub struct Server {
    handle: ClientHandle,
    stop: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<Result<()>>>,
    pub metrics: Arc<Mutex<Metrics>>,
    /// Native backend's page arena (shared with the scheduler thread).
    /// `None` on the PJRT backend. After [`Server::shutdown`] the
    /// scheduler's serving state is dropped, so a drained server must
    /// report zero live pages here — the leak check the scenario gate's
    /// cancellation storms pin.
    kv_arena: Option<Arc<PageArena>>,
}

impl Server {
    /// Start the scheduler thread. Model weights come from the preset's
    /// `init` graph with `cfg.seed`, unless `params` (e.g. loaded from a
    /// trainer checkpoint) are supplied. With `cfg.native` set, the server
    /// needs no artifacts at all.
    pub fn start(cfg: ServerConfig, params: Option<Vec<HostTensor>>) -> Result<Server> {
        // Flag sanity up front: a zero grant size would make the prefill
        // allocator spin without ever feeding a session.
        if cfg.prefill_chunk == 0 {
            bail!("--prefill-chunk must be at least 1 token per grant");
        }
        // Speculation flags are validated even when speculation is off, so
        // a typo'd --speculate fails loudly instead of silently serving
        // without drafts.
        if DraftSource::parse(&cfg.speculate).is_none() {
            bail!(
                "unknown draft source {:?} for --speculate (want {})",
                cfg.speculate,
                DraftSource::ACCEPTED
            );
        }
        if cfg.draft_len == 0 {
            bail!("--draft-len must be at least 1 drafted token per wave");
        }
        // Budget sanity up front: a budget smaller than a single KV page
        // would admit sessions that can never allocate their first page.
        if let Some(ncfg) = &cfg.native {
            if ncfg.kv_page == 0 {
                bail!("--kv-page must be at least 1 token per page");
            }
            let quant = KvQuant::parse(&ncfg.kv_quant).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown KV codec {:?} for --kv-quant (want {})",
                    ncfg.kv_quant,
                    KvQuant::ACCEPTED
                )
            })?;
            if cfg.kv_mem_budget > 0 {
                // Page bytes at the selected codec's encoded row width.
                let words = quant.enc_row_elems(ncfg.d.max(ncfg.dv));
                let page_bytes = ncfg.kv_page * words * 4;
                if cfg.kv_mem_budget < page_bytes {
                    bail!(
                        "--kv-mem-budget {} B is smaller than one KV page \
                         ({page_bytes} B = {} tokens x {words} {} words x 4 B): no session \
                         could ever allocate its first page",
                        cfg.kv_mem_budget,
                        ncfg.kv_page,
                        quant.name()
                    );
                }
            }
        }
        let (tx, rx) = mpsc::channel::<Request>();
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let depth = Arc::new(AtomicUsize::new(0));
        // Report startup success/failure back before returning (plus the
        // native backend's arena handle for post-shutdown drain checks).
        let (ready_tx, ready_rx) = mpsc::channel::<Result<Option<Arc<PageArena>>>>();

        let stop2 = stop.clone();
        let metrics2 = metrics.clone();
        let depth2 = depth.clone();
        let cfg2 = cfg.clone();

        let worker = std::thread::Builder::new()
            .name("zeta-scheduler".into())
            .spawn(move || -> Result<()> {
                // The engine lives on this thread (PJRT handles are !Send).
                let setup = (|| -> Result<(Option<Engine>, Backend, usize)> {
                    match &cfg2.native {
                        Some(ncfg) => {
                            let model = NativeDecodeModel::new(ncfg.clone())?;
                            let mut serving = NativeServing::new(
                                model,
                                cfg2.kv_mem_budget,
                                cfg2.prefill_chunk,
                            );
                            let source = DraftSource::parse(&cfg2.speculate)
                                .expect("--speculate validated at startup");
                            serving.set_speculation(source, cfg2.draft_len);
                            Ok((None, Backend::Native(serving), NATIVE_MAX_BATCH))
                        }
                        None => {
                            let engine = Engine::new(&cfg2.artifacts_dir)?;
                            let pspec = engine.manifest.preset(&cfg2.preset)?;
                            let info =
                                (pspec.batch, pspec.seq_len(), pspec.is_lm(), pspec.vocab());
                            let exe = engine.load(&cfg2.preset, "forward")?;
                            let params = match params {
                                Some(p) => p,
                                None => engine.init_params(&cfg2.preset, cfg2.seed)?,
                            };
                            let backend = Backend::Engine {
                                exe,
                                params,
                                seq_len: info.1,
                                is_lm: info.2,
                                vocab: info.3,
                            };
                            Ok((Some(engine), backend, info.0))
                        }
                    }
                })();
                let (_engine, mut backend, max_batch) = match setup {
                    Ok(v) => {
                        let arena = match &v.1 {
                            Backend::Native(serving) => Some(serving.model().arena().clone()),
                            Backend::Engine { .. } => None,
                        };
                        let _ = ready_tx.send(Ok(arena));
                        v
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(anyhow!("{e:#}")));
                        return Err(e);
                    }
                };

                // Pool handle for padding/fan-out and native prefill.
                let pool =
                    if cfg2.threads == 0 { *Pool::global() } else { Pool::new(cfg2.threads) };
                let mut batcher: Batcher<Job> = Batcher::new(max_batch, cfg2.max_delay);
                let mut sessions: Vec<Session> = Vec::new();
                // Reusable fused-sweep buffers (per-slot orows/logits/tokens).
                let mut scratch = StepScratch::default();
                // Engine decode sweeps rewrite only the token slab at
                // inputs[0]; the parameter tail is cloned once here, not
                // once per emitted token.
                let mut engine_inputs: Vec<HostTensor> = Vec::new();
                if let Backend::Engine { params, seq_len, .. } = &backend {
                    engine_inputs.push(HostTensor::I32(
                        vec![max_batch, *seq_len],
                        vec![0i32; max_batch * *seq_len],
                    ));
                    engine_inputs.extend(params.iter().cloned());
                }
                let mut disconnected = false;
                loop {
                    let mut stopping = stop2.load(Ordering::Relaxed) || disconnected;
                    // 1. Admit new work without blocking (new generations
                    // are rejected once stopping — their streams would
                    // only be truncated immediately below).
                    loop {
                        match rx.try_recv() {
                            Ok(req) => admit_request(
                                req,
                                &backend,
                                &mut batcher,
                                &mut sessions,
                                &depth2,
                                stopping,
                            ),
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                disconnected = true;
                                stopping = true;
                                break;
                            }
                        }
                    }

                    // Shutdown truncates live streams at a token boundary:
                    // each client gets a final Done with what was generated
                    // so far, so `shutdown()` cannot block on a slow (or
                    // absent) stream consumer.
                    if stopping && !sessions.is_empty() {
                        for s in sessions.drain(..) {
                            depth2.fetch_sub(1, Ordering::Relaxed);
                            let _ = s.reply.send(Ok(StreamEvent::Done {
                                generated: s.generated,
                                latency: s.submitted.elapsed(),
                            }));
                        }
                    }

                    // 2. Fire due one-shot batches (everything when stopping).
                    loop {
                        let fire = match batcher.poll(Instant::now()) {
                            Decision::Fire(k) => Some(k),
                            Decision::Wait(_) if stopping => Some(batcher.len().min(max_batch)),
                            _ => None,
                        };
                        let Some(k) = fire else { break };
                        if k == 0 {
                            break;
                        }
                        let jobs = batcher.take(k);
                        depth2.fetch_sub(jobs.len(), Ordering::Relaxed);
                        match &backend {
                            Backend::Engine { exe, params, seq_len, is_lm, vocab } => run_batch(
                                exe, params, jobs, max_batch, *seq_len, *is_lm, *vocab,
                                &metrics2, &pool,
                            ),
                            Backend::Native(serving) => {
                                native_infer_batch(serving.model(), jobs, &metrics2, &pool)
                            }
                        }
                    }

                    // 3. Decode micro-batches: advance every active session.
                    if !sessions.is_empty() {
                        match &mut backend {
                            Backend::Native(serving) => serving.sweep(
                                &mut sessions,
                                &metrics2,
                                &depth2,
                                &mut scratch,
                                &pool,
                                cfg2.prefill_budget,
                            ),
                            Backend::Engine { exe, seq_len, vocab, .. } => engine_decode_sweep(
                                &*exe,
                                &mut engine_inputs,
                                &mut sessions,
                                max_batch,
                                *seq_len,
                                *vocab,
                                &metrics2,
                                &depth2,
                            ),
                        }
                        continue; // stay hot while streams are live
                    }

                    // 4. Idle: exit or block briefly for new work.
                    if stopping && batcher.is_empty() {
                        break;
                    }
                    let wait = match batcher.poll(Instant::now()) {
                        Decision::Wait(d) => d,
                        _ => Duration::from_millis(2),
                    };
                    match rx.recv_timeout(wait) {
                        Ok(req) => admit_request(
                            req,
                            &backend,
                            &mut batcher,
                            &mut sessions,
                            &depth2,
                            stopping,
                        ),
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => disconnected = true,
                    }
                }
                Ok(())
            })
            .expect("spawn scheduler");

        let kv_arena = ready_rx
            .recv()
            .map_err(|_| anyhow!("scheduler died during startup"))??;

        Ok(Server {
            handle: ClientHandle { tx, depth, queue_cap: cfg.queue_cap },
            stop,
            worker: Some(worker),
            metrics,
            kv_arena,
        })
    }

    pub fn client(&self) -> ClientHandle {
        self.handle.clone()
    }

    /// The native backend's KV page arena (`None` on the PJRT backend).
    /// Clone the `Arc` to inspect page counts after [`Server::shutdown`]:
    /// a drained server must have released every page.
    pub fn kv_arena(&self) -> Option<&Arc<PageArena>> {
        self.kv_arena.as_ref()
    }

    /// Stop the scheduler after draining queued work and live sessions.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Route one admitted request to the batcher or the session table.
fn admit_request(
    req: Request,
    backend: &Backend,
    batcher: &mut Batcher<Job>,
    sessions: &mut Vec<Session>,
    depth: &Arc<AtomicUsize>,
    stopping: bool,
) {
    match req {
        Request::Infer(job) => batcher.push(job),
        Request::Generate(g) => {
            if stopping {
                depth.fetch_sub(1, Ordering::Relaxed);
                let _ = g.reply.send(Err(anyhow!("server stopping")));
                return;
            }
            if g.max_new == 0 {
                depth.fetch_sub(1, Ordering::Relaxed);
                let _ = g.reply.send(Ok(StreamEvent::Done {
                    generated: 0,
                    latency: g.submitted.elapsed(),
                }));
                return;
            }
            match backend {
                Backend::Native(serving) => {
                    // The native context cap mirrors the engine backend's
                    // seq_len bound: a prompt that already fills the
                    // context could never emit a token.
                    let cap = serving.model().max_context();
                    if cap > 0 && g.tokens.len() >= cap {
                        depth.fetch_sub(1, Ordering::Relaxed);
                        let _ = g.reply.send(Err(anyhow!(
                            "prompt length {} >= native context cap {cap}",
                            g.tokens.len()
                        )));
                        return;
                    }
                    // Sessions start *parked* (no decode state): the next
                    // sweep's budget-aware admission gate activates them —
                    // possibly by forking a cached prompt prefix — once
                    // the arena has headroom.
                    sessions.push(Session::new(
                        g.tokens,
                        g.max_new,
                        g.submitted,
                        g.reply,
                        None,
                        g.cancel,
                    ));
                }
                Backend::Engine { is_lm, seq_len, .. } => {
                    if !*is_lm {
                        depth.fetch_sub(1, Ordering::Relaxed);
                        let _ = g.reply.send(Err(anyhow!(
                            "preset is not an LM; streaming generate unsupported"
                        )));
                        return;
                    }
                    if g.tokens.len() >= *seq_len {
                        depth.fetch_sub(1, Ordering::Relaxed);
                        let _ = g.reply.send(Err(anyhow!(
                            "prompt length {} >= graph context {seq_len}",
                            g.tokens.len()
                        )));
                        return;
                    }
                    sessions.push(Session::new(
                        g.tokens,
                        g.max_new,
                        g.submitted,
                        g.reply,
                        None,
                        g.cancel,
                    ));
                }
            }
        }
    }
}

/// One-shot inference on the native backend: prefill is exactly one full
/// forward per request (batched arrivals still amortize the scheduler trip).
fn native_infer_batch(
    model: &NativeDecodeModel,
    jobs: Vec<batcher::Pending<Job>>,
    metrics: &Arc<Mutex<Metrics>>,
    pool: &Pool,
) {
    metrics.lock().unwrap().record_batch(jobs.len());
    for p in jobs {
        let result = model.forward_logits(&p.payload.tokens, pool);
        let latency = p.payload.submitted.elapsed();
        match result {
            Ok(logits) => {
                metrics.lock().unwrap().record(latency);
                let _ = p.payload.reply.send(Ok(Response { logits, latency }));
            }
            Err(e) => {
                let _ = p.payload.reply.send(Err(e));
            }
        }
    }
}

/// Retire every session whose client dropped its stream — before any
/// compute is spent on it, including sessions still deep in prefill.
/// Cancelled sessions free their queue slot silently (no metrics, no Done:
/// the receiver is gone). Ordered removal (not `swap_remove`) keeps the
/// session table in arrival order — the prefill budget allocates down that
/// order, so reordering would let late arrivals capture the budget ahead
/// of older budget-starved sessions.
fn retire_cancelled(sessions: &mut Vec<Session>, depth: &Arc<AtomicUsize>) {
    sessions.retain(|s| {
        if s.cancelled() {
            depth.fetch_sub(1, Ordering::Relaxed);
            false
        } else {
            true
        }
    });
}

/// One sweep's token ledger: every token the backend produces is counted
/// `stepped`, then either `emitted` (send succeeded) or `dropped` (client
/// gone) — the conservation law `emitted + dropped == stepped` that
/// [`Metrics::token_accounting_balanced`] and the scenario gate pin.
/// First-token deliveries additionally log a TTFT sample.
#[derive(Default)]
struct SweepTally {
    emitted: u64,
    dropped: u64,
    stepped: u64,
    ttft: Vec<Duration>,
    retire_done: Vec<usize>,
    retire_silent: Vec<usize>,
}

impl SweepTally {
    /// Fold the sweep's counters into the shared metrics (one lock).
    fn publish(self, metrics: &Arc<Mutex<Metrics>>, sweep_t0: Instant) {
        if self.stepped == 0 && self.ttft.is_empty() {
            return;
        }
        let mut m = metrics.lock().unwrap();
        m.record_tokens(self.emitted, self.dropped, self.stepped, sweep_t0);
        for t in self.ttft {
            m.record_ttft(t);
        }
    }
}

/// Stream one generated token to a session's client and decide its fate.
/// Only a *delivered* token counts toward the tokens/sec metric — a failed
/// send means the client hung up between the sweep's cancel check and now,
/// and its token must not inflate throughput; the session retires silently.
fn emit_token(s: &mut Session, idx: usize, tok: i32, max_context: usize, tally: &mut SweepTally) {
    s.tokens.push(tok);
    s.generated += 1;
    tally.stepped += 1;
    let pos = s.generated - 1;
    if s.reply.send(Ok(StreamEvent::Token { token: tok, pos })).is_err() {
        tally.dropped += 1;
        tally.retire_silent.push(idx);
        return;
    }
    tally.emitted += 1;
    if pos == 0 {
        tally.ttft.push(s.submitted.elapsed());
    }
    if s.generated >= s.max_new || (max_context > 0 && s.tokens.len() >= max_context) {
        tally.retire_done.push(idx);
    }
}

/// Tokens a session must ingest via prefill before it joins the decode
/// wave: the full prompt on its first pass (the final position's logits
/// emit the first generated token), or — after a budget preemption —
/// everything but its latest token, which the decode wave then re-feeds
/// to continue the stream exactly where it left off. Decode == prefill
/// bit-equivalence makes the replay invisible to the client.
fn prefill_target(s: &Session) -> usize {
    if s.generated == 0 {
        s.prompt_len
    } else {
        s.tokens.len() - 1
    }
}

/// Native-backend serving state: the kernel-backed token model plus the
/// paged decode-state memory policy layered above it — the prompt-prefix
/// cache, the `--kv-mem-budget` admission gate, and LRU preemption of
/// live sessions back to the parked queue.
pub struct NativeServing {
    model: NativeDecodeModel,
    prefix: PrefixCache,
    /// Arena byte budget across every live decode state (0 = unlimited).
    budget: usize,
    /// Round-robin grant size of the per-sweep prefill allocator
    /// (`ServerConfig::prefill_chunk`), in prompt tokens (>= 1).
    prefill_chunk: usize,
    /// Monotonic sweep counter; stamps [`Session::last_step`] so the
    /// budget preemption can evict the least-recently-stepped session.
    sweep_no: u64,
    /// Speculative-decode draft source ([`ServerConfig::speculate`]);
    /// `Off` keeps the plain one-step fused decode wave.
    spec: DraftSource,
    /// Tokens proposed per draft-then-verify wave (>= 1).
    draft_len: usize,
}

impl NativeServing {
    pub fn new(model: NativeDecodeModel, budget: usize, prefill_chunk: usize) -> NativeServing {
        let prefix = PrefixCache::new(model.page_tokens(), PREFIX_CACHE_CAP);
        NativeServing {
            model,
            prefix,
            budget,
            prefill_chunk: prefill_chunk.max(1),
            sweep_no: 0,
            spec: DraftSource::Off,
            draft_len: DEFAULT_DRAFT_LEN,
        }
    }

    /// Turn speculative decoding on (`--speculate` / `--draft-len`). The
    /// decode wave then drafts up to `draft_len` tokens per active
    /// session and verifies them in one fused wave; accepted streams stay
    /// bit-identical to plain decode, so flipping this can change only
    /// throughput, never tokens.
    pub fn set_speculation(&mut self, source: DraftSource, draft_len: usize) {
        self.spec = source;
        self.draft_len = draft_len.max(1);
    }

    pub fn model(&self) -> &NativeDecodeModel {
        &self.model
    }

    pub fn prefix_cache(&self) -> &PrefixCache {
        &self.prefix
    }

    /// Test / benchmark harness: build one parked session per prompt,
    /// sweep until every session retires, and return the per-session
    /// token streams (asserting every stream ends in `Done`). Callers
    /// read eviction / arena counters from `metrics` and
    /// `self.model().arena().stats()` afterwards. Shared by the
    /// paged-state equivalence gate and `exp mem`.
    pub fn drive_to_completion(
        &mut self,
        prompts: &[Vec<i32>],
        max_new: usize,
        metrics: &Arc<Mutex<Metrics>>,
        pool: &Pool,
    ) -> Vec<Vec<i32>> {
        let depth = Arc::new(AtomicUsize::new(prompts.len()));
        let mut rxs = Vec::new();
        let mut sessions: Vec<Session> = Vec::new();
        for p in prompts {
            let (tx, rx) = mpsc::channel();
            rxs.push(rx);
            sessions.push(Session::new(
                p.clone(),
                max_new,
                Instant::now(),
                tx,
                None,
                Arc::new(AtomicBool::new(false)),
            ));
        }
        let mut scratch = StepScratch::default();
        let mut sweeps = 0u32;
        while !sessions.is_empty() {
            self.sweep(&mut sessions, metrics, &depth, &mut scratch, pool, 0);
            sweeps += 1;
            assert!(sweeps < 1_000_000, "session drive did not converge");
        }
        rxs.into_iter()
            .map(|rx| {
                let mut toks = Vec::new();
                let mut done = false;
                while let Ok(ev) = rx.try_recv() {
                    match ev.expect("no stream errors expected") {
                        StreamEvent::Token { token, .. } => toks.push(token),
                        StreamEvent::Done { .. } => done = true,
                    }
                }
                assert!(done, "stream must end with Done");
                toks
            })
            .collect()
    }

    /// While the arena's live bytes exceed the budget: shed prompt-prefix
    /// cache entries first (pure accelerators — dropping one can never
    /// change a stream), then preempt the least-recently-stepped *active*
    /// session: release its pages and park it. `activate` re-admits it
    /// when headroom returns and its re-prefill replays the exact context
    /// (identical tokens — the preemption gate in
    /// `rust/tests/paged_state.rs` pins this). At least one session stays
    /// active so the scheduler always makes progress, even when a single
    /// context alone exceeds the budget.
    fn enforce_budget(&mut self, sessions: &mut [Session], metrics: &Arc<Mutex<Metrics>>) {
        if self.budget == 0 {
            return;
        }
        // Drafter contexts go first: they are pure speed accelerators —
        // shedding one can never change a stream (the context re-grows
        // lazily from the committed tokens, or the session simply decodes
        // without drafts) — so they are cheaper to lose than the prefix
        // cache, let alone a live session's pages.
        if self.model.arena().stats().live_bytes > self.budget {
            let mut sheds = 0u64;
            for s in sessions.iter_mut() {
                if self.model.arena().stats().live_bytes <= self.budget {
                    break;
                }
                if let Some(dr) = s.drafter.as_mut() {
                    if dr.state_bytes() > 0 {
                        dr.shed();
                        sheds += 1;
                    }
                }
            }
            if sheds > 0 {
                metrics.lock().unwrap().draft_sheds += sheds;
            }
        }
        // Cache shedding stops the moment an eviction frees nothing: such
        // an entry's pages are pinned by live sessions (fork-shared), and
        // shedding more of them would wipe the hot cache without
        // reclaiming a byte — preemption is what actually frees pages.
        let mut shed_cache = true;
        while self.model.arena().stats().live_bytes > self.budget {
            if shed_cache {
                let before = self.model.arena().stats().live_bytes;
                if self.prefix.evict_lru() {
                    if self.model.arena().stats().live_bytes < before {
                        continue;
                    }
                    shed_cache = false;
                } else {
                    shed_cache = false;
                }
            }
            let mut victim: Option<(u64, usize)> = None;
            let mut actives = 0usize;
            for (i, s) in sessions.iter().enumerate() {
                if s.state.is_none() {
                    continue;
                }
                actives += 1;
                match victim {
                    Some((ls, _)) if ls <= s.last_step => {}
                    _ => victim = Some((s.last_step, i)),
                }
            }
            let Some((_, idx)) = victim else { return };
            if actives <= 1 {
                return;
            }
            let s = &mut sessions[idx];
            if let Some(mut st) = s.state.take() {
                st.release();
            }
            s.fed = 0;
            metrics.lock().unwrap().evictions += 1;
        }
    }

    /// Budget-aware admission: hand parked sessions (fresh arrivals and
    /// preempted ones) a decode state when the arena has headroom,
    /// strictly in table (arrival) order — when the oldest parked session
    /// does not fit, admission *stops* rather than skipping ahead, so a
    /// stream of small late arrivals can never starve a large session at
    /// the head of the queue. When nothing is active the oldest parked
    /// session activates unconditionally so the scheduler always makes
    /// progress. Activation consults the prompt-prefix cache — a hit
    /// forks the cached state (shared pages, shared Z-order runs) and the
    /// session skips prefill for the whole shared prefix.
    fn activate(&mut self, sessions: &mut [Session]) {
        let mut any_active = sessions.iter().any(|s| s.state.is_some());
        for s in sessions.iter_mut() {
            if s.state.is_some() {
                continue;
            }
            if self.budget > 0 && any_active {
                let live = self.model.arena().stats().live_bytes;
                let need = self.model.estimate_state_bytes(s.tokens.len());
                if live + need > self.budget {
                    break; // FIFO: nothing younger may jump this session
                }
            }
            let limit = prefill_target(s).min(s.tokens.len().saturating_sub(1));
            match self.prefix.lookup(&s.tokens[..limit]) {
                Some((l, st)) => {
                    debug_assert_eq!(st.pos(), l);
                    s.state = Some(st);
                    s.fed = l;
                    s.prefix_cached =
                        s.generated > 0 || l >= self.prefix.cacheable_len(s.prompt_len);
                }
                None => {
                    s.state = Some(self.model.begin());
                    s.fed = 0;
                }
            }
            s.last_step = self.sweep_no;
            any_active = true;
        }
    }

    /// Refresh the serving-memory gauges: aggregate per-session
    /// `state_bytes` (plus the prefix cache's share) and the arena's
    /// live / high-water counters — all in bytes, with the page count as a
    /// secondary gauge, so telemetry compares across `--kv-page` sizes and
    /// `--kv-quant` codecs.
    fn publish_memory_metrics(&self, sessions: &[Session], metrics: &Arc<Mutex<Metrics>>) {
        let stats = self.model.arena().stats();
        let active = sessions.iter().filter(|s| s.state.is_some()).count();
        let mut m = metrics.lock().unwrap();
        m.kv_state_bytes = sessions
            .iter()
            .filter_map(|s| s.state.as_ref())
            .map(|st| st.state_bytes())
            .sum::<usize>()
            + self.prefix.state_bytes();
        m.arena_live_bytes = stats.live_bytes;
        m.arena_high_water_bytes = stats.high_water_bytes;
        m.arena_live_pages = stats.live_pages;
        m.prefix_hits = self.prefix.hits;
        m.note_active_sessions(active);
    }

    /// Continuous-batching sweep on the native backend, fused across
    /// sessions:
    ///
    /// 1. Cancelled sessions (dropped streams) retire before any compute.
    /// 2. Memory policy runs: over-budget pages are reclaimed
    ///    (prefix-cache shedding, then LRU session preemption), and parked
    ///    sessions are activated while the budget has headroom — via a
    ///    prompt-prefix-cache fork when their prompt head is cached.
    /// 3. The active sessions partition into a *prefill wave* and a
    ///    *decode wave*. The global `prefill_budget` is dealt out
    ///    round-robin in `prefill_chunk`-token grants in arrival order, so
    ///    a burst of long prompts cannot starve decode cadence; leftover
    ///    budget keeps flowing once every session holds a grant, so a lone
    ///    long prompt ingests many chunks per sweep through the pipelined
    ///    prefill path instead of one micro-batch per sweep.
    /// 4. The prefill wave runs through
    ///    [`NativeDecodeModel::prefill_batch`] (across-session
    ///    pool-parallel; sessions whose prompt completes emit their first
    ///    token from the final prefill logits, and page-aligned prompt
    ///    prefixes are snapshotted into the prefix cache); the decode wave
    ///    runs through one fused [`NativeDecodeModel::step_batch`] kernel
    ///    call instead of N serial `step_token` calls.
    /// 5. Per-session arithmetic is identical to serial stepping, so fused
    ///    and serial sweeps produce identical token streams (the
    ///    fused-sweep equivalence gate in `rust/tests/fused_sweep.rs`).
    pub fn sweep(
        &mut self,
        sessions: &mut Vec<Session>,
        metrics: &Arc<Mutex<Metrics>>,
        depth: &Arc<AtomicUsize>,
        scratch: &mut StepScratch,
        pool: &Pool,
        prefill_budget: usize,
    ) {
        let sweep_t0 = Instant::now();
        self.sweep_no += 1;
        let mut tally = SweepTally::default();

        retire_cancelled(sessions, depth);
        if sessions.is_empty() {
            self.publish_memory_metrics(sessions, metrics);
            return;
        }

        self.enforce_budget(sessions, metrics);
        self.activate(sessions);

        // Partition the active sessions into the budgeted prefill wave and
        // the fused decode wave. Indices stay valid for the whole sweep:
        // retirement happens at the end.
        let mut decode: Vec<usize> = Vec::new();
        // (session idx, this sweep's cap, tokens allocated so far)
        let mut want: Vec<(usize, usize, usize)> = Vec::new();
        for (idx, s) in sessions.iter().enumerate() {
            if s.state.is_none() {
                continue; // parked under the memory budget
            }
            let target = prefill_target(s);
            if s.fed < target {
                let mut cap = target - s.fed;
                if s.generated == 0 && !s.prefix_cached {
                    // Stop exactly at the page-aligned cache boundary so
                    // the completed prefix can be snapshotted.
                    let cl = self.prefix.cacheable_len(s.prompt_len);
                    if s.fed < cl {
                        cap = cap.min(cl - s.fed);
                    }
                }
                want.push((idx, cap, 0));
            } else {
                decode.push(idx);
            }
        }
        // Deal the budget out in `prefill_chunk`-token grants, round-robin
        // in arrival order: the first round reproduces the classic
        // one-chunk-per-session fairness, further rounds let leftover
        // budget accumulate on still-hungry sessions (each session stays
        // one contiguous token run — a single `prefill_batch` slot feeding
        // the pipelined kernel path). A session granted nothing waits its
        // turn; arrival order keeps the wave fair across sweeps.
        let mut remaining = if prefill_budget == 0 { usize::MAX } else { prefill_budget };
        let mut granted = true;
        while remaining > 0 && granted {
            granted = false;
            for w in want.iter_mut() {
                let grant = self.prefill_chunk.min(w.1 - w.2).min(remaining);
                if grant > 0 {
                    w.2 += grant;
                    remaining -= grant;
                    granted = true;
                }
                if remaining == 0 {
                    break;
                }
            }
        }
        // (session idx, tokens granted this sweep)
        let prefill: Vec<(usize, usize)> =
            want.into_iter().filter(|w| w.2 > 0).map(|w| (w.0, w.2)).collect();

        let max_context = self.model.max_context();

        // Prefill wave: move each state out, run the batched prefill, put
        // the states back and stream first tokens for completed prompts.
        if !prefill.is_empty() {
            let mut staged: Vec<(usize, usize, Box<dyn DecodeState>)> =
                Vec::with_capacity(prefill.len());
            for &(idx, take) in &prefill {
                let st =
                    sessions[idx].state.take().expect("active session carries decode state");
                staged.push((idx, take, st));
            }
            {
                let mut items: Vec<PrefillStep> = staged
                    .iter_mut()
                    .map(|(idx, take, st)| {
                        let s = &sessions[*idx];
                        PrefillStep {
                            state: st.as_mut(),
                            tokens: &s.tokens[s.fed..s.fed + *take],
                            // Resumed (preempted) sessions never re-emit:
                            // their replayed positions already streamed.
                            emit: s.generated == 0 && s.fed + *take == s.prompt_len,
                        }
                    })
                    .collect();
                self.model.prefill_batch(&mut items, scratch, pool);
            }
            for ((idx, take, st), tok) in staged.into_iter().zip(scratch.next.iter().copied()) {
                let s = &mut sessions[idx];
                s.state = Some(st);
                s.fed += take;
                s.last_step = self.sweep_no;
                if s.generated == 0 && !s.prefix_cached {
                    let cl = self.prefix.cacheable_len(s.prompt_len);
                    if cl > 0 && s.fed == cl {
                        let snap = s.state.as_ref().expect("state put back above").fork();
                        self.prefix.insert(&s.tokens[..cl], snap);
                        s.prefix_cached = true;
                    } else if s.fed > cl {
                        s.prefix_cached = true; // crossed past the boundary
                    }
                }
                if s.fed < prefill_target(s) {
                    continue; // still prefilling next sweep
                }
                if s.generated > 0 {
                    continue; // resumed: the decode wave re-feeds the tail
                }
                emit_token(s, idx, tok, max_context, &mut tally);
            }
        }

        // Fused decode wave: one pool-parallel kernel call across all
        // ready sessions (each feeds its last emitted token). With
        // `--speculate` on and byte headroom for the transient draft /
        // snapshot forks, the wave instead drafts a chain per session and
        // verifies it fused — same per-token arithmetic, fewer waves.
        if !decode.is_empty() && self.speculation_headroom(decode.len()) {
            self.speculative_decode_wave(sessions, &decode, metrics, pool, max_context, &mut tally);
        } else if !decode.is_empty() {
            let mut staged: Vec<(usize, Box<dyn DecodeState>)> =
                Vec::with_capacity(decode.len());
            for &idx in &decode {
                let st =
                    sessions[idx].state.take().expect("active session carries decode state");
                staged.push((idx, st));
            }
            {
                let mut items: Vec<SessionStep> = staged
                    .iter_mut()
                    .map(|(idx, st)| SessionStep {
                        state: st.as_mut(),
                        tok: *sessions[*idx].tokens.last().expect("prompt is non-empty"),
                    })
                    .collect();
                self.model.step_batch(&mut items, scratch, pool);
            }
            for ((idx, st), tok) in staged.into_iter().zip(scratch.next.iter().copied()) {
                let s = &mut sessions[idx];
                s.state = Some(st);
                s.fed += 1;
                s.last_step = self.sweep_no;
                emit_token(s, idx, tok, max_context, &mut tally);
            }
        }

        // Retire in descending index order so removal never disturbs a
        // still-pending index; ordered `remove` keeps the survivors in
        // arrival order, which is what makes the prefill budget's "wait
        // your turn" fairness real across sweeps.
        let mut retire: Vec<(usize, bool)> = tally
            .retire_done
            .drain(..)
            .map(|i| (i, true))
            .chain(tally.retire_silent.drain(..).map(|i| (i, false)))
            .collect();
        retire.sort_unstable_by_key(|r| std::cmp::Reverse(r.0));
        for (idx, done) in retire {
            let s = sessions.remove(idx);
            depth.fetch_sub(1, Ordering::Relaxed);
            if !done {
                continue;
            }
            let latency = s.submitted.elapsed();
            let mut m = metrics.lock().unwrap();
            m.record(latency);
            drop(m);
            let _ = s
                .reply
                .send(Ok(StreamEvent::Done { generated: s.generated, latency }));
        }
        tally.publish(metrics, sweep_t0);
        self.publish_memory_metrics(sessions, metrics);
    }

    /// Whether this sweep's decode wave speculates: speculation must be
    /// on, and the byte budget must leave room for the wave's transient
    /// forks (one draft fork and one rollback snapshot per session —
    /// copy-on-write, so roughly one fresh tail-page pair each). The rule
    /// reads only deterministic state (live arena bytes), so the decision
    /// — and therefore the whole schedule — is identical across thread
    /// counts, which is what keeps lockstep replays bit-reproducible.
    /// Under sustained pressure drafting simply stays off and the wave
    /// takes the plain one-step path: streams are unchanged either way.
    fn speculation_headroom(&self, wave_sessions: usize) -> bool {
        if self.spec == DraftSource::Off {
            return false;
        }
        if self.budget == 0 {
            return true;
        }
        let transient = 2 * self.model.estimate_state_bytes(0) * wave_sessions;
        self.model.arena().stats().live_bytes + transient <= self.budget
    }

    /// Draft-then-verify decode wave. Per session: catch the drafter's
    /// context up to the committed stream, draft up to `draft_len` greedy
    /// proposals on a scratch fork, snapshot the real state (CoW fork),
    /// then feed `[last token, d_1..d_L]` through the real state in one
    /// fused [`NativeDecodeModel::verify_batch`] across sessions. The
    /// longest matched prefix plus the verify wave's bonus token at the
    /// first divergence commit through [`emit_token`]; on any rejection
    /// the advanced state is dropped and the snapshot restored — an O(1)
    /// page-drop rollback — leaving `fed` behind `tokens`, so the proven
    /// re-prefill machinery (bit-identical to stepping) absorbs the
    /// accepted tokens next sweep. `preds[0]` is by construction the
    /// token non-speculative decode would emit, and each later
    /// prediction follows a matched prefix, so committed streams are
    /// bit-identical to `--speculate off`.
    fn speculative_decode_wave(
        &mut self,
        sessions: &mut [Session],
        decode: &[usize],
        metrics: &Arc<Mutex<Metrics>>,
        pool: &Pool,
        max_context: usize,
        tally: &mut SweepTally,
    ) {
        let model = &self.model;
        // Draft phase: serial per session (chains are short and the
        // drafter is priced to make these steps negligible).
        let (mut orow, mut logits) = (Vec::new(), Vec::new());
        let mut chains: Vec<Vec<i32>> = Vec::with_capacity(decode.len());
        for &idx in decode {
            let s = &mut sessions[idx];
            if s.drafter.is_none() {
                s.drafter = model.make_drafter(self.spec);
            }
            let seed_tok = *s.tokens.last().expect("prompt is non-empty");
            // Cap the chain so the accepted prefix plus the bonus token
            // can never overrun max_new or the context cap: emission must
            // stop exactly where plain decode would.
            let remaining = s.max_new.saturating_sub(s.generated);
            let mut l_eff = self.draft_len.min(remaining.saturating_sub(1));
            if max_context > 0 {
                let room = max_context.saturating_sub(s.tokens.len());
                l_eff = l_eff.min(room.saturating_sub(1));
            }
            let mut chain = Vec::with_capacity(l_eff + 1);
            chain.push(seed_tok);
            if l_eff > 0 {
                if let Some(dr) = s.drafter.as_mut() {
                    model.drafter_catch_up(dr, &s.tokens, pool);
                    let target = s.state.as_deref().expect("active session carries decode state");
                    if let Some(mut draft) = dr.begin(target) {
                        let prop = model.draft_chain(
                            draft.as_mut(),
                            seed_tok,
                            l_eff,
                            &mut orow,
                            &mut logits,
                        );
                        chain.extend(prop);
                        draft.release();
                    }
                }
            }
            // An empty draft (no context yet, kernel offers none, L
            // capped to 0) degrades to a plain one-token verify step.
            chains.push(chain);
        }

        // Snapshot + fused verify: the snapshot fork is the rollback
        // point; CoW pages make it a tail-page copy, not a state copy.
        let mut staged: Vec<(usize, Box<dyn DecodeState>, Box<dyn DecodeState>)> =
            Vec::with_capacity(decode.len());
        for &idx in decode {
            let st = sessions[idx].state.take().expect("active session carries decode state");
            let snap = st.fork();
            staged.push((idx, st, snap));
        }
        let preds_all: Vec<Vec<i32>> = {
            let mut items: Vec<VerifyStep> = staged
                .iter_mut()
                .zip(&chains)
                .map(|((_, st, _), chain)| VerifyStep {
                    state: st.as_mut(),
                    chain,
                    preds: Vec::new(),
                })
                .collect();
            self.model.verify_batch(&mut items, pool);
            items.iter_mut().map(|it| std::mem::take(&mut it.preds)).collect()
        };

        // Acceptance: commit the longest matched prefix + bonus, roll
        // back on the first divergence.
        let (mut drafted, mut accepted) = (0u64, 0u64);
        for (((idx, mut st, mut snap), chain), preds) in
            staged.into_iter().zip(&chains).zip(preds_all)
        {
            let s = &mut sessions[idx];
            let l = chain.len() - 1;
            debug_assert_eq!(preds.len(), chain.len());
            let mut m = 0usize;
            while m < l && preds[m] == chain[m + 1] {
                m += 1;
            }
            drafted += l as u64;
            accepted += m as u64;
            if m == l {
                // Full acceptance (and the undrafted l == 0 step): the
                // advanced state is exactly where plain decode would be.
                snap.release();
                s.state = Some(st);
                s.fed += l + 1;
            } else {
                // Rollback: drop the advanced pages, restore the
                // snapshot. `fed` stays behind the committed tokens, so
                // the next sweep's prefill wave replays the accepted
                // tokens into the state (emit=false: they streamed here).
                st.release();
                s.state = Some(snap);
            }
            s.last_step = self.sweep_no;
            for &tok in preds.iter().take(m + 1) {
                let (done0, silent0) = (tally.retire_done.len(), tally.retire_silent.len());
                emit_token(s, idx, tok, max_context, tally);
                if tally.retire_done.len() > done0 || tally.retire_silent.len() > silent0 {
                    break; // retired (limits hit or client gone): stop emitting
                }
            }
        }
        if drafted > 0 {
            metrics.lock().unwrap().record_speculation(drafted, accepted);
        }
    }
}

/// Continuous-batching sweep on the PJRT backend: full-recompute decode —
/// each wave of up to `max_batch` sessions runs one forward over its token
/// prefixes and takes the logits at each last position. This is the
/// baseline the incremental engine replaces (and what `exp decode` prices).
#[allow(clippy::too_many_arguments)]
fn engine_decode_sweep(
    exe: &crate::runtime::Executable,
    inputs: &mut [HostTensor],
    sessions: &mut Vec<Session>,
    max_batch: usize,
    seq_len: usize,
    vocab: usize,
    metrics: &Arc<Mutex<Metrics>>,
    depth: &Arc<AtomicUsize>,
) {
    let sweep_t0 = Instant::now();
    retire_cancelled(sessions, depth);
    let mut done = vec![false; sessions.len()];
    // Retire without metrics or a Done event: the request errored (client
    // already got the Err) or the client dropped the stream.
    let mut silent = vec![false; sessions.len()];
    let mut emitted = 0u64;
    let mut dropped = 0u64;
    let mut stepped = 0u64;
    let mut ttft: Vec<Duration> = Vec::new();
    let mut start = 0usize;
    while start < sessions.len() {
        let end = (start + max_batch).min(sessions.len());
        let mut last_pos = vec![0usize; end - start];
        {
            // Rewrite the token slab in place; the parameter tail of
            // `inputs` was cloned once at scheduler startup.
            let HostTensor::I32(_, slab) = &mut inputs[0] else {
                unreachable!("token slab is always I32");
            };
            slab.fill(0);
            for (r, s) in sessions[start..end].iter().enumerate() {
                let n = s.tokens.len().min(seq_len);
                slab[r * seq_len..r * seq_len + n].copy_from_slice(&s.tokens[..n]);
                last_pos[r] = n.saturating_sub(1);
            }
        }
        // A wave-wide failure (execution error, or a forward graph whose
        // output is not the expected (B, N, V) f32 logits) errors every
        // session in the wave instead of panicking the scheduler.
        let mut wave_err: Option<String> = None;
        match exe.run(inputs) {
            Ok(out) => {
                let logits = out[0].as_f32().unwrap_or(&[]);
                if logits.len() < max_batch * seq_len * vocab {
                    wave_err = Some(format!(
                        "decode batch returned malformed logits: {} elems, want {}",
                        logits.len(),
                        max_batch * seq_len * vocab
                    ));
                } else {
                    for (r, s) in sessions[start..end].iter_mut().enumerate() {
                        let base = (r * seq_len + last_pos[r]) * vocab;
                        let tok = NativeDecodeModel::argmax(&logits[base..base + vocab]);
                        s.tokens.push(tok);
                        s.generated += 1;
                        stepped += 1;
                        let pos = s.generated - 1;
                        let gone =
                            s.reply.send(Ok(StreamEvent::Token { token: tok, pos })).is_err();
                        if gone {
                            // Never-delivered token: not counted toward
                            // tokens/sec (the receiver is gone).
                            dropped += 1;
                            done[start + r] = true;
                            silent[start + r] = true;
                        } else {
                            emitted += 1;
                            if pos == 0 {
                                ttft.push(s.submitted.elapsed());
                            }
                            if s.generated >= s.max_new || s.tokens.len() >= seq_len {
                                done[start + r] = true;
                            }
                        }
                    }
                }
            }
            Err(e) => wave_err = Some(format!("decode batch failed: {e}")),
        }
        if let Some(msg) = wave_err {
            for (r, s) in sessions[start..end].iter().enumerate() {
                let _ = s.reply.send(Err(anyhow!(msg.clone())));
                done[start + r] = true;
                silent[start + r] = true;
            }
        }
        start = end;
    }
    // Reverse order keeps pending indices valid; ordered `remove` keeps
    // the survivors in arrival order (see `retire_cancelled`).
    for i in (0..sessions.len()).rev() {
        if done[i] {
            let s = sessions.remove(i);
            depth.fetch_sub(1, Ordering::Relaxed);
            if silent[i] {
                continue;
            }
            let latency = s.submitted.elapsed();
            let mut m = metrics.lock().unwrap();
            m.record(latency);
            drop(m);
            let _ = s
                .reply
                .send(Ok(StreamEvent::Done { generated: s.generated, latency }));
        }
    }
    if stepped > 0 {
        let mut m = metrics.lock().unwrap();
        m.record_tokens(emitted, dropped, stepped, sweep_t0);
        for t in ttft {
            m.record_ttft(t);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_batch(
    exe: &crate::runtime::Executable,
    params: &[HostTensor],
    jobs: Vec<batcher::Pending<Job>>,
    max_batch: usize,
    seq_len: usize,
    is_lm: bool,
    vocab: usize,
    metrics: &Arc<Mutex<Metrics>>,
    pool: &Pool,
) {
    let mut x = vec![0i32; max_batch * seq_len];
    let mut last_pos = vec![0usize; jobs.len()];
    // Token refs only (the Job's reply channel stays on this thread).
    let toks: Vec<&[i32]> = jobs.iter().map(|p| p.payload.tokens.as_slice()).collect();
    for (r, t) in toks.iter().enumerate() {
        last_pos[r] = t.len().min(seq_len).saturating_sub(1);
    }
    if fan_out(toks.len(), toks.len() * seq_len, pool.threads(), PARALLEL_PAD_MIN_ELEMS) {
        // Row-parallel padding: each request row of x is disjoint.
        let xsh = SharedSlice::new(&mut x);
        pool.parallel_for(toks.len(), 1, |rows| {
            for r in rows {
                let t = toks[r];
                let n = t.len().min(seq_len);
                // Safety: row r claimed by exactly one chunk.
                let row = unsafe { xsh.range_mut(r * seq_len..(r + 1) * seq_len) };
                row[..n].copy_from_slice(&t[..n]);
            }
        });
    } else {
        for (r, t) in toks.iter().enumerate() {
            let n = t.len().min(seq_len);
            x[r * seq_len..r * seq_len + n].copy_from_slice(&t[..n]);
        }
    }
    let mut inputs = vec![HostTensor::I32(vec![max_batch, seq_len], x)];
    inputs.extend(params.iter().cloned());
    let result = exe.run(&inputs);
    metrics.lock().unwrap().record_batch(jobs.len());
    match result {
        Ok(out) => {
            let logits = out[0].as_f32().unwrap_or(&[]);
            for (r, p) in jobs.into_iter().enumerate() {
                let row = if is_lm {
                    let base = (r * seq_len + last_pos[r]) * vocab;
                    logits[base..base + vocab].to_vec()
                } else {
                    let ncls = logits.len() / max_batch;
                    logits[r * ncls..(r + 1) * ncls].to_vec()
                };
                let latency = p.payload.submitted.elapsed();
                metrics.lock().unwrap().record(latency);
                let _ = p.payload.reply.send(Ok(Response { logits: row, latency }));
            }
        }
        Err(e) => {
            let msg = format!("batch execution failed: {e}");
            for p in jobs {
                let _ = p.payload.reply.send(Err(anyhow!(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    //! Native-backend tests run everywhere; PJRT-backed tests skip when
    //! artifacts are absent.
    use super::*;

    fn have_artifacts() -> bool {
        let ok = std::path::Path::new(crate::ARTIFACTS_DIR).join("manifest.json").exists();
        if !ok {
            eprintln!("skipping coordinator test: artifacts/ missing");
        }
        ok
    }

    fn native_cfg(kernel: &str) -> ServerConfig {
        ServerConfig {
            native: Some(NativeModelConfig { kernel: kernel.into(), ..Default::default() }),
            max_delay: Duration::from_millis(1),
            ..Default::default()
        }
    }

    #[test]
    fn serves_single_request() {
        if !have_artifacts() {
            return;
        }
        let srv = Server::start(ServerConfig::default(), None).unwrap();
        let client = srv.client();
        let resp = client.infer(vec![5, 6, 7, 8]).unwrap();
        assert_eq!(resp.logits.len(), 2); // serve_cls has 2 classes
        assert!(resp.logits.iter().all(|v| v.is_finite()));
        srv.shutdown();
    }

    #[test]
    fn serves_concurrent_clients_and_batches() {
        if !have_artifacts() {
            return;
        }
        let cfg = ServerConfig { max_delay: Duration::from_millis(20), ..Default::default() };
        let srv = Server::start(cfg, None).unwrap();
        let mut handles = Vec::new();
        for i in 0..12 {
            let c = srv.client();
            handles.push(std::thread::spawn(move || {
                c.infer(vec![(i % 50) as i32 + 1; 16]).unwrap()
            }));
        }
        for h in handles {
            let r = h.join().unwrap();
            assert_eq!(r.logits.len(), 2);
        }
        let m = srv.metrics.lock().unwrap();
        assert_eq!(m.completed, 12);
        assert!(m.mean_batch_size() > 1.0, "no batching happened: {}", m.summary());
        drop(m);
        srv.shutdown();
    }

    #[test]
    fn identical_inputs_identical_outputs() {
        if !have_artifacts() {
            return;
        }
        let srv = Server::start(ServerConfig::default(), None).unwrap();
        let c = srv.client();
        let a = c.infer(vec![3; 32]).unwrap();
        let b = c.infer(vec![3; 32]).unwrap();
        assert_eq!(a.logits, b.logits);
        srv.shutdown();
    }

    #[test]
    fn bad_preset_fails_at_startup() {
        if !have_artifacts() {
            return;
        }
        let cfg = ServerConfig { preset: "nonexistent".into(), ..Default::default() };
        assert!(Server::start(cfg, None).is_err());
    }

    #[test]
    fn native_server_infers_without_artifacts() {
        let srv = Server::start(native_cfg("zeta"), None).unwrap();
        let c = srv.client();
        let r = c.infer(vec![3, 1, 4, 1, 5]).unwrap();
        assert_eq!(r.logits.len(), NativeModelConfig::default().vocab);
        assert!(r.logits.iter().all(|v| v.is_finite()));
        srv.shutdown();
    }

    #[test]
    fn native_generate_streams_exactly_max_new_tokens() {
        let srv = Server::start(native_cfg("zeta"), None).unwrap();
        let c = srv.client();
        let stream = c.generate(vec![3, 1, 4, 1, 5, 9, 2, 6], 12).unwrap();
        let toks = stream.collect_tokens().unwrap();
        assert_eq!(toks.len(), 12);
        let vocab = NativeModelConfig::default().vocab as i32;
        assert!(toks.iter().all(|&t| (0..vocab).contains(&t)), "{toks:?}");
        let m = srv.metrics.lock().unwrap();
        assert_eq!(m.tokens, 12);
        assert_eq!(m.completed, 1);
        drop(m);
        srv.shutdown();
    }

    #[test]
    fn native_generate_is_deterministic() {
        let srv = Server::start(native_cfg("zeta"), None).unwrap();
        let c = srv.client();
        let a = c.generate(vec![7, 7, 7], 8).unwrap().collect_tokens().unwrap();
        let b = c.generate(vec![7, 7, 7], 8).unwrap().collect_tokens().unwrap();
        assert_eq!(a, b);
        srv.shutdown();
    }

    #[test]
    fn incremental_sessions_match_full_recompute_reference() {
        // The session-level equivalence gate: streaming decode through the
        // server must reproduce the token stream of re-running a full
        // forward per emitted token.
        for kernel in ["zeta", "naive", "mamba"] {
            let srv = Server::start(native_cfg(kernel), None).unwrap();
            let prompt = vec![5, 9, 13, 2, 2, 7];
            let got =
                srv.client().generate(prompt.clone(), 10).unwrap().collect_tokens().unwrap();
            srv.shutdown();

            let model = NativeDecodeModel::new(NativeModelConfig {
                kernel: kernel.into(),
                ..Default::default()
            })
            .unwrap();
            let pool = Pool::serial();
            let mut toks = prompt;
            let mut want = Vec::new();
            for _ in 0..10 {
                let logits = model.forward_logits(&toks, &pool).unwrap();
                let t = NativeDecodeModel::argmax(&logits);
                want.push(t);
                toks.push(t);
            }
            assert_eq!(got, want, "kernel {kernel}");
        }
    }

    #[test]
    fn concurrent_generate_and_infer_interleave() {
        let srv = Server::start(native_cfg("zeta"), None).unwrap();
        let c = srv.client();
        let s1 = c.generate(vec![1, 2, 3], 6).unwrap();
        let s2 = c.generate(vec![9, 8, 7, 6], 4).unwrap();
        let r = c.infer(vec![4, 5, 6]).unwrap();
        assert_eq!(r.logits.len(), NativeModelConfig::default().vocab);
        assert_eq!(s1.collect_tokens().unwrap().len(), 6);
        assert_eq!(s2.collect_tokens().unwrap().len(), 4);
        let m = srv.metrics.lock().unwrap();
        assert_eq!(m.tokens, 10);
        drop(m);
        srv.shutdown();
    }

    #[test]
    fn stopped_server_rejects_without_leaking_queue_capacity() {
        // Regression for the depth-counter leak: every failed submit must
        // roll its admission back, so repeated retries against a stopped
        // server keep reporting "stopped" — never a phantom "busy".
        let cfg = ServerConfig { queue_cap: 2, ..native_cfg("zeta") };
        let srv = Server::start(cfg, None).unwrap();
        let c = srv.client();
        srv.shutdown();
        for i in 0..5 {
            let err = c.infer(vec![1, 2, 3]).unwrap_err().to_string();
            assert!(err.contains("server stopped"), "attempt {i}: {err}");
        }
        let err = c.generate(vec![1], 4).unwrap_err().to_string();
        assert!(err.contains("server stopped"), "{err}");
    }

    #[test]
    fn zero_max_new_completes_immediately() {
        let srv = Server::start(native_cfg("mamba"), None).unwrap();
        let toks = srv.client().generate(vec![1, 2], 0).unwrap().collect_tokens().unwrap();
        assert!(toks.is_empty());
        srv.shutdown();
    }

    #[test]
    fn undelivered_tokens_do_not_inflate_token_metrics() {
        // Regression: the sweep used to count a cancelled session's final
        // token into `emitted` even though the StreamEvent::Token send
        // failed — tokens/sec was inflated by never-delivered tokens. Keep
        // the cancel flag clear so the failure is observed at the send
        // itself, not at the sweep's cancel check.
        let model = NativeDecodeModel::new(NativeModelConfig::default()).unwrap();
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let depth = Arc::new(AtomicUsize::new(1));
        let (tx, rx) = mpsc::channel();
        drop(rx); // receiver gone, flag not set: the send must fail
        let cancel = Arc::new(AtomicBool::new(false));
        let mut sessions = vec![Session::new(
            vec![1, 2, 3],
            8,
            Instant::now(),
            tx,
            Some(model.begin()),
            cancel,
        )];
        let mut serving = NativeServing::new(model, 0, DEFAULT_PREFILL_CHUNK);
        let mut scratch = StepScratch::default();
        let pool = Pool::serial();
        serving.sweep(&mut sessions, &metrics, &depth, &mut scratch, &pool, 0);
        assert!(sessions.is_empty(), "send-failed session must retire");
        assert_eq!(depth.load(Ordering::Relaxed), 0);
        let m = metrics.lock().unwrap();
        assert_eq!(m.tokens, 0, "never-delivered tokens must not count");
        assert_eq!(m.dropped_tokens, 1);
        assert_eq!(m.completed, 0, "cancelled sessions are not completions");
    }

    #[test]
    fn cancelled_session_retires_before_prefill_compute() {
        // A dropped GenStream is detected at the top of the sweep — a
        // session still mid-prefill stops consuming kernel time instead of
        // burning its whole prompt for a vanished receiver.
        let model = NativeDecodeModel::new(NativeModelConfig::default()).unwrap();
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let depth = Arc::new(AtomicUsize::new(1));
        let (tx, rx) = mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(true)); // client hung up
        let mut sessions = vec![Session::new(
            vec![5; 500],
            4,
            Instant::now(),
            tx,
            Some(model.begin()),
            cancel,
        )];
        let mut serving = NativeServing::new(model, 0, DEFAULT_PREFILL_CHUNK);
        let mut scratch = StepScratch::default();
        let pool = Pool::serial();
        serving.sweep(&mut sessions, &metrics, &depth, &mut scratch, &pool, 0);
        assert!(sessions.is_empty(), "cancelled session must retire immediately");
        assert_eq!(depth.load(Ordering::Relaxed), 0);
        let m = metrics.lock().unwrap();
        assert_eq!(m.tokens + m.dropped_tokens, 0, "no prefill output was produced");
        drop(m);
        drop(rx); // receiver intentionally alive until here
    }

    #[test]
    fn prefill_budget_bounds_per_sweep_prompt_work() {
        // Three 100-token prompts under a 40-token global budget: the
        // round-robin allocator grants the first session a full
        // `DEFAULT_PREFILL_CHUNK` (32), the second the 8 remaining budget
        // tokens, and the third waits.
        let model = NativeDecodeModel::new(NativeModelConfig::default()).unwrap();
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let depth = Arc::new(AtomicUsize::new(3));
        let mut rxs = Vec::new();
        let mut sessions = Vec::new();
        for _ in 0..3 {
            let (tx, rx) = mpsc::channel();
            rxs.push(rx);
            sessions.push(Session::new(
                vec![7; 100],
                4,
                Instant::now(),
                tx,
                Some(model.begin()),
                Arc::new(AtomicBool::new(false)),
            ));
        }
        let mut serving = NativeServing::new(model, 0, DEFAULT_PREFILL_CHUNK);
        let mut scratch = StepScratch::default();
        let pool = Pool::serial();
        serving.sweep(&mut sessions, &metrics, &depth, &mut scratch, &pool, 40);
        let fed: Vec<usize> = sessions.iter().map(|s| s.fed).collect();
        assert_eq!(fed, vec![32, 8, 0]);
        // Unlimited budget (0): round-robin grants keep cycling until every
        // session hits its per-sweep cap — here the 64-token prefix-cache
        // boundary of the 100-token prompt — so a lone long prompt no longer
        // serializes one chunk per sweep.
        serving.sweep(&mut sessions, &metrics, &depth, &mut scratch, &pool, 0);
        let fed: Vec<usize> = sessions.iter().map(|s| s.fed).collect();
        assert_eq!(fed, vec![64, 64, 64]);
        // All three sessions crossed the 64-token page boundary with the
        // same prompt: one shared page-aligned prefix snapshot in the cache.
        assert_eq!(serving.prefix_cache().len(), 1);
    }

    #[test]
    fn native_context_cap_terminates_generation_early() {
        let mut cfg = native_cfg("zeta");
        if let Some(n) = cfg.native.as_mut() {
            n.max_context = 12;
        }
        let srv = Server::start(cfg, None).unwrap();
        let c = srv.client();
        // prompt 4 + cap 12 → at most 8 generated tokens despite max_new 50
        let toks = c.generate(vec![1, 2, 3, 4], 50).unwrap().collect_tokens().unwrap();
        assert_eq!(toks.len(), 8, "context cap must end generation early");
        // a prompt already at the cap is rejected up front
        let err = c.generate(vec![7; 12], 4).unwrap().collect_tokens().unwrap_err().to_string();
        assert!(err.contains("context cap"), "{err}");
        srv.shutdown();
    }

    #[test]
    fn kv_mem_budget_below_one_page_is_rejected_with_clear_error() {
        // Satellite fix: a budget smaller than one KV page could admit a
        // session that can never allocate — reject it at startup instead.
        let mut cfg = native_cfg("zeta");
        cfg.kv_mem_budget = 100; // default page: 64 tokens x 16 floats x 4 B = 4096 B
        let err = Server::start(cfg, None).unwrap_err().to_string();
        assert!(err.contains("kv-mem-budget"), "{err}");
        assert!(err.contains("one KV page"), "{err}");
        // Exactly one page is the smallest accepted budget.
        let mut cfg = native_cfg("zeta");
        cfg.kv_mem_budget = 64 * 16 * 4;
        let srv = Server::start(cfg, None).unwrap();
        srv.shutdown();
        // kv_page = 0 is rejected regardless of budget.
        let mut cfg = native_cfg("zeta");
        if let Some(n) = cfg.native.as_mut() {
            n.kv_page = 0;
        }
        let err = Server::start(cfg, None).unwrap_err().to_string();
        assert!(err.contains("kv-page"), "{err}");
    }

    #[test]
    fn invalid_kv_quant_is_rejected_with_codec_listing() {
        // Satellite: a typo'd codec must fail at startup with the accepted
        // spellings, mirroring the --kv-page/--kv-mem-budget rejections.
        let mut cfg = native_cfg("zeta");
        if let Some(n) = cfg.native.as_mut() {
            n.kv_quant = "fp16".into();
        }
        let err = Server::start(cfg, None).unwrap_err().to_string();
        assert!(err.contains("--kv-quant"), "{err}");
        assert!(err.contains(KvQuant::ACCEPTED), "must list accepted codecs: {err}");
        // Every accepted codec starts.
        for good in ["f32", "f16", "int8"] {
            let mut cfg = native_cfg("zeta");
            if let Some(n) = cfg.native.as_mut() {
                n.kv_quant = good.into();
            }
            let srv = Server::start(cfg, None).unwrap();
            srv.shutdown();
        }
        // The one-page minimum budget scales with the codec: 64 tokens x
        // 16-wide rows encode to 8 words under f16, so half the f32 floor
        // is accepted there but still rejected under f32.
        let mut cfg = native_cfg("zeta");
        cfg.kv_mem_budget = 64 * 8 * 4;
        let err = Server::start(cfg, None).unwrap_err().to_string();
        assert!(err.contains("one KV page"), "{err}");
        let mut cfg = native_cfg("zeta");
        cfg.kv_mem_budget = 64 * 8 * 4;
        if let Some(n) = cfg.native.as_mut() {
            n.kv_quant = "f16".into();
        }
        let srv = Server::start(cfg, None).unwrap();
        srv.shutdown();
    }

    #[test]
    fn prefill_chunk_of_zero_is_rejected_with_clear_error() {
        // A zero grant size would make the round-robin allocator spin
        // forever without feeding anyone — reject it at startup, like
        // --kv-mem-budget below one page.
        let mut cfg = native_cfg("zeta");
        cfg.prefill_chunk = 0;
        let err = Server::start(cfg, None).unwrap_err().to_string();
        assert!(err.contains("prefill-chunk"), "{err}");
        // The smallest useful grant (1 token) is accepted.
        let mut cfg = native_cfg("zeta");
        cfg.prefill_chunk = 1;
        let srv = Server::start(cfg, None).unwrap();
        srv.shutdown();
    }

    #[test]
    fn custom_prefill_chunk_drives_round_robin_grants() {
        // chunk = 16 under a 40-token budget: the allocator hands out
        // 16, 16, then the 8 leftover tokens — a smaller grant size
        // interleaves sessions more fairly than the default 32.
        let model = NativeDecodeModel::new(NativeModelConfig::default()).unwrap();
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let depth = Arc::new(AtomicUsize::new(3));
        let mut rxs = Vec::new();
        let mut sessions = Vec::new();
        for _ in 0..3 {
            let (tx, rx) = mpsc::channel();
            rxs.push(rx);
            sessions.push(Session::new(
                vec![7; 100],
                4,
                Instant::now(),
                tx,
                Some(model.begin()),
                Arc::new(AtomicBool::new(false)),
            ));
        }
        let mut serving = NativeServing::new(model, 0, 16);
        let mut scratch = StepScratch::default();
        let pool = Pool::serial();
        serving.sweep(&mut sessions, &metrics, &depth, &mut scratch, &pool, 40);
        let fed: Vec<usize> = sessions.iter().map(|s| s.fed).collect();
        assert_eq!(fed, vec![16, 16, 8]);
    }

    #[test]
    fn identical_prompts_hit_the_prefix_cache_with_identical_streams() {
        // Two sessions sharing a >= 1-page prompt: the second must fork
        // the cached prefix (prefix_hits > 0) and still stream exactly
        // the same tokens as the first (fork == fresh prefill).
        let srv = Server::start(native_cfg("zeta"), None).unwrap();
        let c = srv.client();
        let prompt: Vec<i32> = (0..100).map(|i| (i * 13 + 5) % 31).collect();
        let a = c.generate(prompt.clone(), 8).unwrap().collect_tokens().unwrap();
        let b = c.generate(prompt.clone(), 8).unwrap().collect_tokens().unwrap();
        assert_eq!(a, b);
        let m = srv.metrics.lock().unwrap();
        assert!(m.prefix_hits >= 1, "second session should hit the prefix cache");
        assert!(m.arena_high_water_bytes > 0);
        assert!(m.summary().contains("prefix_hits"), "{}", m.summary());
        drop(m);
        srv.shutdown();
    }

    #[test]
    fn invalid_speculate_flags_are_rejected_with_listings() {
        // A typo'd draft source fails at startup with the accepted
        // spellings, mirroring the --kv-quant rejection.
        let mut cfg = native_cfg("zeta");
        cfg.speculate = "medusa".into();
        let err = Server::start(cfg, None).unwrap_err().to_string();
        assert!(err.contains("--speculate"), "{err}");
        assert!(err.contains(DraftSource::ACCEPTED), "must list accepted sources: {err}");
        // A zero draft length could only ever verify nothing.
        let mut cfg = native_cfg("zeta");
        cfg.speculate = "mamba".into();
        cfg.draft_len = 0;
        let err = Server::start(cfg, None).unwrap_err().to_string();
        assert!(err.contains("--draft-len"), "{err}");
        // Every accepted source starts.
        for good in ["off", "mamba", "self"] {
            let mut cfg = native_cfg("zeta");
            cfg.speculate = good.into();
            let srv = Server::start(cfg, None).unwrap();
            srv.shutdown();
        }
    }

    #[test]
    fn speculative_streams_match_plain_decode_end_to_end() {
        // Serve-level smoke of the acceptance contract — the tier-1 gate
        // in rust/tests/spec_decode.rs covers the full source x kernel x
        // thread matrix; this pins the in-process server plumbing.
        let prompt: Vec<i32> = (0..20).map(|i| (i * 11 + 3) % 32).collect();
        let base = {
            let srv = Server::start(native_cfg("zeta"), None).unwrap();
            let t = srv.client().generate(prompt.clone(), 16).unwrap().collect_tokens().unwrap();
            srv.shutdown();
            t
        };
        assert_eq!(base.len(), 16);
        for source in ["mamba", "self"] {
            let mut cfg = native_cfg("zeta");
            cfg.speculate = source.into();
            cfg.draft_len = 4;
            let srv = Server::start(cfg, None).unwrap();
            let t = srv.client().generate(prompt.clone(), 16).unwrap().collect_tokens().unwrap();
            let m = srv.metrics.lock().unwrap();
            assert!(m.drafted_tokens > 0, "{source} must actually draft");
            assert!(m.speculation_balanced(), "{source}: {}", m.summary());
            assert!(m.token_accounting_balanced(), "{source}: {}", m.summary());
            drop(m);
            srv.shutdown();
            assert_eq!(t, base, "{source} streams must be bit-identical to off");
        }
    }

    #[test]
    fn dropping_stream_cancels_session() {
        let srv = Server::start(native_cfg("mamba"), None).unwrap();
        let c = srv.client();
        let stream = c.generate(vec![1, 2, 3], 1_000_000).unwrap();
        // read one token, then hang up
        let first = stream.recv().unwrap().unwrap();
        assert!(matches!(first, StreamEvent::Token { .. }));
        drop(stream);
        // the scheduler notices the dead channel and retires the session;
        // a subsequent one-shot request must still be served promptly.
        let r = c.infer(vec![2, 2, 2]).unwrap();
        assert!(r.logits.iter().all(|v| v.is_finite()));
        srv.shutdown();
    }
}
