//! Dynamic batcher: vLLM-router-style request aggregation.
//!
//! The compiled forward graphs have a *static* batch dimension B, so the
//! batcher's job is to fill as many of the B slots as possible without
//! letting any request wait longer than `max_delay`. Policy:
//!
//! * a batch closes as soon as B requests are queued, or
//! * when the oldest queued request has waited `max_delay`.
//!
//! Unfilled slots are padded (token 0 rows) and their outputs discarded —
//! the padding cost is the price of static shapes, measured by
//! `Metrics::mean_batch_size` and benchmarked in `benches/ablations.rs`.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// A queued request with its arrival time.
#[derive(Debug)]
pub struct Pending<T> {
    pub payload: T,
    pub arrived: Instant,
}

/// Decision returned by [`Batcher::poll`].
#[derive(Debug, PartialEq)]
pub enum Decision {
    /// Close a batch of this size now.
    Fire(usize),
    /// Wait at most this long before polling again.
    Wait(Duration),
    /// Queue empty.
    Idle,
}

/// Pure batching policy over an internal FIFO queue (transport-agnostic —
/// the server feeds it and executes the fired batches; tests drive it
/// directly with synthetic clocks).
pub struct Batcher<T> {
    pub max_batch: usize,
    pub max_delay: Duration,
    queue: VecDeque<Pending<T>>,
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize, max_delay: Duration) -> Self {
        assert!(max_batch > 0);
        Batcher { max_batch, max_delay, queue: VecDeque::new() }
    }

    pub fn push(&mut self, payload: T) {
        self.queue.push_back(Pending { payload, arrived: Instant::now() });
    }

    pub fn push_at(&mut self, payload: T, arrived: Instant) {
        self.queue.push_back(Pending { payload, arrived });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Batching decision at time `now`.
    pub fn poll(&self, now: Instant) -> Decision {
        let Some(oldest) = self.queue.front() else {
            return Decision::Idle;
        };
        if self.queue.len() >= self.max_batch {
            return Decision::Fire(self.max_batch);
        }
        let waited = now.saturating_duration_since(oldest.arrived);
        if waited >= self.max_delay {
            return Decision::Fire(self.queue.len());
        }
        Decision::Wait(self.max_delay - waited)
    }

    /// Remove and return the next `n` requests (FIFO).
    pub fn take(&mut self, n: usize) -> Vec<Pending<T>> {
        let n = n.min(self.queue.len());
        self.queue.drain(..n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_when_full() {
        let mut b = Batcher::new(4, Duration::from_millis(100));
        let now = Instant::now();
        for i in 0..4 {
            b.push_at(i, now);
        }
        assert_eq!(b.poll(now), Decision::Fire(4));
    }

    #[test]
    fn fires_partial_after_deadline() {
        let mut b = Batcher::new(8, Duration::from_millis(10));
        let t0 = Instant::now();
        b.push_at(1, t0);
        b.push_at(2, t0);
        match b.poll(t0) {
            Decision::Wait(d) => assert!(d <= Duration::from_millis(10)),
            other => panic!("{other:?}"),
        }
        let later = t0 + Duration::from_millis(11);
        assert_eq!(b.poll(later), Decision::Fire(2));
    }

    #[test]
    fn idle_when_empty() {
        let b: Batcher<u32> = Batcher::new(4, Duration::from_millis(5));
        assert_eq!(b.poll(Instant::now()), Decision::Idle);
    }

    #[test]
    fn take_is_fifo_and_never_exceeds() {
        let mut b = Batcher::new(3, Duration::from_millis(5));
        let now = Instant::now();
        for i in 0..5 {
            b.push_at(i, now);
        }
        assert_eq!(b.poll(now), Decision::Fire(3));
        let taken = b.take(3);
        assert_eq!(taken.iter().map(|p| p.payload).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(b.len(), 2);
        let t2 = b.take(10);
        assert_eq!(t2.len(), 2);
    }

    #[test]
    fn no_request_dropped_property() {
        use crate::util::prop;
        prop::check(50, 0xBA7C4, |rng| {
            let max_b = 1 + rng.usize_below(8);
            let mut b = Batcher::new(max_b, Duration::from_millis(1));
            let n = rng.usize_below(50);
            let now = Instant::now();
            for i in 0..n {
                b.push_at(i, now);
            }
            let mut got = Vec::new();
            let late = now + Duration::from_millis(2);
            loop {
                match b.poll(late) {
                    Decision::Fire(k) => {
                        assert!(k <= max_b);
                        got.extend(b.take(k).into_iter().map(|p| p.payload));
                    }
                    Decision::Idle => break,
                    Decision::Wait(_) => unreachable!("deadline passed"),
                }
            }
            prop::assert_eq_prop(&got, &(0..n).collect::<Vec<_>>())
        });
    }
}
