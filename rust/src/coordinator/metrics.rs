//! Serving metrics: latency percentiles, queue depth, throughput.

use std::time::{Duration, Instant};

/// Collects request latencies and computes robust summary statistics.
#[derive(Debug, Default)]
pub struct Metrics {
    latencies_us: Vec<u64>,
    pub completed: u64,
    pub batches: u64,
    pub batched_requests: u64,
    /// Tokens *delivered* to streaming generation clients (sends that
    /// succeeded). Never-delivered tokens — the client hung up before the
    /// send — are tracked separately in `dropped_tokens` so tokens/sec
    /// reflects real delivery, not work wasted on vanished receivers.
    pub tokens: u64,
    /// Tokens generated whose stream send failed (cancelled sessions).
    pub dropped_tokens: u64,
    /// Tokens *produced* by the backend (counted at the kernel output,
    /// before the delivery attempt). Every stepped token is either
    /// delivered (`tokens`) or dropped (`dropped_tokens`) — the invariant
    /// [`Metrics::token_accounting_balanced`] checks and the scenario gate
    /// pins, so a future scheduling path cannot silently miscount.
    pub stepped_tokens: u64,
    /// Time-to-first-token samples (µs), one per session whose first
    /// generated token was delivered — the latency the ROADMAP's serving
    /// scenarios score at p50/p99.
    ttft_us: Vec<u64>,
    /// Prompt-prefix cache hits: sessions that started by forking a cached
    /// page-aligned prompt prefix instead of re-prefilling it.
    pub prefix_hits: u64,
    /// Sessions preempted by the KV byte budget (pages dropped, re-prefilled
    /// later with identical output tokens).
    pub evictions: u64,
    /// Aggregate `DecodeState::state_bytes` across live sessions plus the
    /// prefix cache (gauge, refreshed each sweep; per-handle view, so pages
    /// shared by forks count per holder).
    pub kv_state_bytes: usize,
    /// Live bytes on the serving page arena (gauge; each page once).
    pub arena_live_bytes: usize,
    /// High-water mark of the serving page arena's live bytes.
    pub arena_high_water_bytes: usize,
    /// Pages currently live on the serving arena (secondary gauge — the
    /// byte gauges above are the primary telemetry, since page size varies
    /// with `--kv-page` and bytes/page with `--kv-quant`).
    pub arena_live_pages: usize,
    /// Most sessions ever simultaneously active (admitted, unparked) — how
    /// far the `--kv-mem-budget` admission gate actually stretched.
    pub peak_active_sessions: usize,
    /// Tokens proposed by speculative-decode drafters (`--speculate`).
    /// Every drafted token is either accepted (its verify-wave argmax
    /// matched the proposal) or rejected — the speculation conservation
    /// law [`Metrics::speculation_balanced`] checks. Bonus tokens the
    /// verify wave emits at a divergence are *not* drafted tokens; they
    /// flow through the ordinary `stepped`/`tokens` accounting only.
    pub drafted_tokens: u64,
    /// Drafted tokens whose full-kernel verification matched (committed).
    pub accepted_tokens: u64,
    /// Drafted tokens the verify wave refuted (state rolled back).
    pub rejected_tokens: u64,
    /// Drafter contexts shed by the KV byte budget (drafts go first,
    /// before the prefix cache and live-session preemption).
    pub draft_sheds: u64,
    started: Option<Instant>,
    finished: Option<Instant>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record(&mut self, latency: Duration) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
        self.finished = Some(Instant::now());
        self.latencies_us.push(latency.as_micros() as u64);
        self.completed += 1;
    }

    pub fn record_batch(&mut self, size: usize) {
        self.batches += 1;
        self.batched_requests += size as u64;
    }

    /// Count one decode sweep's tokens: `stepped` tokens the backend
    /// produced, of which `delivered` sends succeeded and `dropped` sends
    /// failed (client gone). Only delivered tokens feed tokens/sec.
    /// `sweep_started` is when the sweep began, so the observed span
    /// covers the work that produced the first tokens (a single-sweep
    /// generation still reports a non-zero span and therefore a real
    /// tok/s).
    pub fn record_tokens(
        &mut self,
        delivered: u64,
        dropped: u64,
        stepped: u64,
        sweep_started: Instant,
    ) {
        self.stepped_tokens += stepped;
        self.dropped_tokens += dropped;
        if delivered == 0 {
            // A drop-only sweep must not stretch the observed span — that
            // would deflate tokens/sec without any delivery happening.
            return;
        }
        match self.started {
            Some(s) if s <= sweep_started => {}
            _ => self.started = Some(sweep_started),
        }
        self.finished = Some(Instant::now());
        self.tokens += delivered;
    }

    /// Every produced token was either delivered or dropped — the
    /// conservation law of the token accounting.
    pub fn token_accounting_balanced(&self) -> bool {
        self.tokens + self.dropped_tokens == self.stepped_tokens
    }

    /// Count one speculative verify wave: `drafted` proposals of which
    /// `accepted` matched the target kernel's argmax. The remainder is
    /// rejected — callers never report rejections separately, so the
    /// speculation ledger balances by construction and a drifted caller
    /// shows up as a failed [`Metrics::speculation_balanced`] instead of
    /// silently skewing the accept rate.
    pub fn record_speculation(&mut self, drafted: u64, accepted: u64) {
        debug_assert!(accepted <= drafted);
        self.drafted_tokens += drafted;
        self.accepted_tokens += accepted;
        self.rejected_tokens += drafted - accepted;
    }

    /// Every drafted token was either accepted or rejected — the
    /// speculation side's conservation law. (Committed tokens, drafted or
    /// not, still flow through `record_tokens`, so
    /// [`Metrics::token_accounting_balanced`] is unaffected by drafting.)
    pub fn speculation_balanced(&self) -> bool {
        self.accepted_tokens + self.rejected_tokens == self.drafted_tokens
    }

    /// Fraction of drafted tokens the verify wave committed (0 when
    /// nothing was drafted).
    pub fn accept_rate(&self) -> f64 {
        if self.drafted_tokens == 0 {
            0.0
        } else {
            self.accepted_tokens as f64 / self.drafted_tokens as f64
        }
    }

    /// One session's time-to-first-token (first *delivered* token).
    pub fn record_ttft(&mut self, ttft: Duration) {
        self.ttft_us.push(ttft.as_micros() as u64);
    }

    /// TTFT percentile over the recorded per-session samples.
    pub fn ttft_percentile(&self, p: f64) -> Option<Duration> {
        percentile_us(&self.ttft_us, p)
    }

    pub fn ttft_samples(&self) -> usize {
        self.ttft_us.len()
    }

    /// Fold one sweep's active-session count into the peak gauge. The
    /// gauge is max-monotone within a run: it can only ratchet upward,
    /// never regress when the fleet drains.
    pub fn note_active_sessions(&mut self, active: usize) {
        self.peak_active_sessions = self.peak_active_sessions.max(active);
    }

    /// Generated tokens per second over the observed span.
    pub fn tokens_per_sec(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(s), Some(f)) if f > s => self.tokens as f64 / (f - s).as_secs_f64(),
            _ => 0.0,
        }
    }

    pub fn percentile(&self, p: f64) -> Option<Duration> {
        percentile_us(&self.latencies_us, p)
    }

    pub fn mean(&self) -> Option<Duration> {
        if self.latencies_us.is_empty() {
            return None;
        }
        let sum: u64 = self.latencies_us.iter().sum();
        Some(Duration::from_micros(sum / self.latencies_us.len() as u64))
    }

    /// Completed requests per second over the observed span.
    pub fn throughput(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(s), Some(f)) if f > s => self.completed as f64 / (f - s).as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Mean requests per executed batch (batching efficiency).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_requests as f64 / self.batches as f64
        }
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "n={} p50={:?} p99={:?} mean={:?} batch_avg={:.1} thpt={:.1}/s",
            self.completed,
            self.percentile(50.0).unwrap_or_default(),
            self.percentile(99.0).unwrap_or_default(),
            self.mean().unwrap_or_default(),
            self.mean_batch_size(),
            self.throughput(),
        );
        if self.tokens > 0 {
            s.push_str(&format!(" tokens={} tok/s={:.1}", self.tokens, self.tokens_per_sec()));
        }
        if self.dropped_tokens > 0 {
            s.push_str(&format!(" dropped_tokens={}", self.dropped_tokens));
        }
        if !self.ttft_us.is_empty() {
            s.push_str(&format!(
                " ttft_p50={:?} ttft_p99={:?}",
                self.ttft_percentile(50.0).unwrap_or_default(),
                self.ttft_percentile(99.0).unwrap_or_default()
            ));
        }
        if self.arena_high_water_bytes > 0 {
            s.push_str(&format!(
                " kv_state={}B arena_live={}B arena_hw={}B arena_pages={}",
                self.kv_state_bytes,
                self.arena_live_bytes,
                self.arena_high_water_bytes,
                self.arena_live_pages
            ));
        }
        if self.peak_active_sessions > 0 {
            s.push_str(&format!(" peak_active={}", self.peak_active_sessions));
        }
        if self.drafted_tokens > 0 {
            s.push_str(&format!(
                " drafted={} accepted={} rejected={} accept_rate={:.2}",
                self.drafted_tokens,
                self.accepted_tokens,
                self.rejected_tokens,
                self.accept_rate()
            ));
        }
        if self.draft_sheds > 0 {
            s.push_str(&format!(" draft_sheds={}", self.draft_sheds));
        }
        if self.prefix_hits > 0 {
            s.push_str(&format!(" prefix_hits={}", self.prefix_hits));
        }
        if self.evictions > 0 {
            s.push_str(&format!(" evictions={}", self.evictions));
        }
        s
    }
}

/// Nearest-rank percentile over raw µs samples.
fn percentile_us(samples: &[u64], p: f64) -> Option<Duration> {
    if samples.is_empty() {
        return None;
    }
    let mut v = samples.to_vec();
    v.sort_unstable();
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    Some(Duration::from_micros(v[idx.min(v.len() - 1)]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::new();
        for i in 1..=100u64 {
            m.record(Duration::from_micros(i * 10));
        }
        let p50 = m.percentile(50.0).unwrap();
        let p99 = m.percentile(99.0).unwrap();
        assert!(p50 < p99);
        assert_eq!(m.completed, 100);
        assert!(m.mean().unwrap() > Duration::from_micros(400));
    }

    #[test]
    fn empty_metrics_are_none() {
        let m = Metrics::new();
        assert!(m.percentile(50.0).is_none());
        assert_eq!(m.throughput(), 0.0);
    }

    #[test]
    fn batch_efficiency() {
        let mut m = Metrics::new();
        m.record_batch(8);
        m.record_batch(4);
        assert_eq!(m.mean_batch_size(), 6.0);
    }

    #[test]
    fn summary_reports_arena_bytes_with_page_count_secondary() {
        let mut m = Metrics::new();
        m.kv_state_bytes = 1024;
        m.arena_live_bytes = 2048;
        m.arena_high_water_bytes = 4096;
        m.arena_live_pages = 2;
        m.peak_active_sessions = 3;
        let s = m.summary();
        assert!(s.contains("arena_live=2048B"), "{s}");
        assert!(s.contains("arena_hw=4096B"), "{s}");
        assert!(s.contains("arena_pages=2"), "{s}");
        assert!(s.contains("peak_active=3"), "{s}");
    }

    #[test]
    fn dropped_tokens_do_not_feed_throughput() {
        let mut m = Metrics::new();
        let t0 = Instant::now();
        m.record_tokens(5, 3, 8, t0);
        assert_eq!(m.tokens, 5);
        assert_eq!(m.dropped_tokens, 3);
        // A drop-only sweep must neither count tokens nor stretch the
        // observed span (which would deflate tokens/sec).
        let tps = m.tokens_per_sec();
        std::thread::sleep(Duration::from_millis(2));
        m.record_tokens(0, 2, 2, t0);
        assert_eq!(m.tokens, 5);
        assert_eq!(m.dropped_tokens, 5);
        assert_eq!(m.tokens_per_sec(), tps);
        assert!(m.summary().contains("dropped_tokens=5"));
    }

    #[test]
    fn token_accounting_conservation_law() {
        let mut m = Metrics::new();
        assert!(m.token_accounting_balanced(), "empty metrics are balanced");
        let t0 = Instant::now();
        m.record_tokens(5, 3, 8, t0);
        m.record_tokens(0, 2, 2, t0);
        assert_eq!(m.stepped_tokens, 10);
        assert!(m.token_accounting_balanced());
        // A path that produced a token but neither delivered nor dropped
        // it breaks conservation — exactly what the gate must catch.
        m.record_tokens(0, 0, 1, t0);
        assert!(!m.token_accounting_balanced());
    }

    #[test]
    fn ttft_percentiles_ordered_and_reported() {
        let mut m = Metrics::new();
        assert!(m.ttft_percentile(50.0).is_none());
        for i in 1..=50u64 {
            m.record_ttft(Duration::from_micros(i * 100));
        }
        let p50 = m.ttft_percentile(50.0).unwrap();
        let p99 = m.ttft_percentile(99.0).unwrap();
        assert!(p50 < p99, "{p50:?} vs {p99:?}");
        assert_eq!(m.ttft_samples(), 50);
        let s = m.summary();
        assert!(s.contains("ttft_p50="), "{s}");
    }

    #[test]
    fn speculation_conservation_law() {
        let mut m = Metrics::new();
        assert!(m.speculation_balanced(), "empty metrics are balanced");
        assert_eq!(m.accept_rate(), 0.0);
        // Three verify waves: full acceptance, partial, total rejection.
        m.record_speculation(4, 4);
        m.record_speculation(4, 1);
        m.record_speculation(2, 0);
        assert_eq!(m.drafted_tokens, 10);
        assert_eq!(m.accepted_tokens, 5);
        assert_eq!(m.rejected_tokens, 5);
        assert!(m.speculation_balanced());
        assert_eq!(m.accept_rate(), 0.5);
        let s = m.summary();
        assert!(s.contains("drafted=10"), "{s}");
        assert!(s.contains("accept_rate=0.50"), "{s}");
        // A skewed ledger (e.g. a caller bumping the counters by hand)
        // must trip the invariant.
        m.rejected_tokens += 1;
        assert!(!m.speculation_balanced());
    }

    #[test]
    fn speculation_does_not_touch_token_accounting() {
        // Drafted/accepted counters are a parallel ledger: the delivered/
        // dropped/stepped conservation law must hold regardless of how
        // much speculation happened, because committed tokens (accepted
        // drafts and bonus tokens alike) all flow through record_tokens.
        let mut m = Metrics::new();
        let t0 = Instant::now();
        m.record_speculation(8, 5);
        m.record_tokens(6, 0, 6, t0); // 5 accepted + 1 bonus, all delivered
        assert!(m.token_accounting_balanced());
        assert!(m.speculation_balanced());
        assert_eq!(m.tokens, 6);
        assert_eq!(m.stepped_tokens, 6);
    }

    #[test]
    fn peak_active_sessions_is_monotone_within_a_run() {
        let mut m = Metrics::new();
        let mut prev = 0usize;
        // A fleet ramping up then draining: the gauge must never regress.
        for active in [1usize, 4, 9, 7, 2, 0, 5] {
            m.note_active_sessions(active);
            assert!(m.peak_active_sessions >= prev, "gauge regressed");
            assert!(m.peak_active_sessions >= active);
            prev = m.peak_active_sessions;
        }
        assert_eq!(m.peak_active_sessions, 9);
    }
}
