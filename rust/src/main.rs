//! `zeta` — leader binary: training, serving and the experiment harness.
//!
//! Usage:
//!   zeta list                              # presets in artifacts/manifest.json
//!   zeta info                              # runtime / platform info
//!   zeta train --preset P [--steps N] [--ckpt PATH] [--seed S]
//!   zeta serve --preset P [--requests N] [--clients C]
//!   zeta exp <fig2a|fig2b|fig2c|fig2d|fig3|table1|...|all> [--steps N] …
//!
//! Flags are std-only parsed (no clap offline); unknown flags error out.

use std::collections::HashMap;
use anyhow::{anyhow, bail, Result};

use zeta::coordinator::{NativeModelConfig, Server, ServerConfig};
use zeta::data::task_for_config;
use zeta::exp;
use zeta::runtime::Engine;
use zeta::trainer::Trainer;
use zeta::util::rng::Rng;

fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            map.insert(key.to_string(), val);
        } else {
            bail!("unexpected argument {a:?}");
        }
        i += 1;
    }
    Ok(map)
}

fn flag_usize(f: &HashMap<String, String>, key: &str, default: usize) -> Result<usize> {
    match f.get(key) {
        Some(v) => v.parse().map_err(|_| anyhow!("--{key} must be an integer, got {v:?}")),
        None => Ok(default),
    }
}

fn opts_from_flags(f: &HashMap<String, String>) -> Result<exp::Opts> {
    let mut o = exp::Opts::default();
    o.steps = flag_usize(f, "steps", o.steps)?;
    o.eval_batches = flag_usize(f, "eval-batches", o.eval_batches)?;
    o.seed = flag_usize(f, "seed", o.seed as usize)? as u64;
    o.max_len = flag_usize(f, "max-len", o.max_len)?;
    o.threads = flag_usize(f, "threads", o.threads)?;
    if let Some(out) = f.get("out") {
        o.out_dir = out.clone();
    }
    if let Some(q) = f.get("kv-quant") {
        o.kv_quant = q.clone();
    }
    o.kv_mem_budget = flag_usize(f, "kv-mem-budget", o.kv_mem_budget)?;
    o.verbose = f.contains_key("verbose");
    Ok(o)
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "list" => cmd_list(),
        "info" => cmd_info(),
        "train" => cmd_train(&parse_flags(&args[1..])?),
        "serve" => cmd_serve(&parse_flags(&args[1..])?),
        "bench" => cmd_bench(&args[1..]),
        "exp" => {
            let which = args.get(1).map(String::as_str).unwrap_or("all");
            let flags = parse_flags(&args[2..])?;
            cmd_exp(which, &flags)
        }
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}; see `zeta help`"),
    }
}

const HELP: &str = "\
zeta — Z-order top-k attention (ICLR 2025) reproduction

commands:
  list                         presets available in artifacts/
  info                         PJRT platform info
  train  --preset P [--steps N] [--seed S] [--ckpt PATH] [--eval-batches B]
  serve  --preset P [--requests N] [--clients C] [--max-delay-ms D]
         [--generate] [--max-new N] [--native] [--native-kernel K]
         [--prefill-budget T] [--prefill-chunk T] [--prompt-len N]
         [--max-context N] [--kv-page TOKENS] [--kv-mem-budget BYTES]
         [--kv-quant f32|f16|int8] [--speculate off|mamba|self]
         [--draft-len L]
  bench  diff OLD.json NEW.json [--fail-above PCT]
  exp    NAME [--steps N] [--seed S] [--max-len L] [--out DIR] [--threads T]
         [--kv-quant f32|f16|int8] [--kv-mem-budget BYTES] [--verbose]
         NAME ∈ {fig2a, fig2b, fig2c, fig2d, fig3, table1, table2,
                 table3, table4, table5, table6, kernels, decode,
                 decode_batch, prefill, pool, mem, scenarios, spec, all}

serving:
  `serve` runs one-shot batched inference by default. With --generate each
  request becomes a streaming generation session. On the native backend
  every scheduler sweep splits the live sessions into a prefill wave —
  prompt tokens are granted round-robin in --prefill-chunk slices
  (default 32, must be >= 1) under the global --prefill-budget cap per
  sweep (0 = unlimited), so bursts of long prompts cannot starve token
  cadence while a lone long prompt still prefills in one sweep through
  the pipelined sequence-parallel kernel path — and a *fused decode
  wave*: one pool-parallel step_batch kernel call across all ready
  sessions. --prompt-len N fixes every request's prompt length instead
  of sampling short prompts (long-context prefill smokes pair it with
  --max-context 0). (The PJRT backend decodes by full-recompute forward
  batches; --prefill-budget and --max-context apply to native serving.)
  --native (or missing artifacts) serves with the in-process native decode
  engine — per-request kernel decode state (ZETA: persistent Z-order
  index, O(log N + k) per token) instead of full-sequence recompute;
  --native-kernel picks zeta|naive|flash|mamba, and --max-context caps
  each session's total context (prompt + generated; sessions end with an
  early Done when it fills, 0 = unlimited).

serving memory (native backend):
  All per-session decode state lives on a shared arena of fixed-size KV
  pages. --kv-page sets the page size in tokens (default 64): caches
  grow, fork and release at page granularity, and identical page-aligned
  prompt prefixes are served from a prefix cache by copy-on-write fork
  (shared pages bump refcounts) instead of re-prefilling.
  --kv-mem-budget caps the arena's live bytes across all sessions + the
  prefix cache (0 = unlimited; must be at least one page): new sessions
  wait for headroom, and when live pages exceed the budget the scheduler
  sheds prefix-cache entries first and then preempts the
  least-recently-stepped session — its pages drop and it transparently
  re-prefills later with identical output tokens. --kv-quant picks the
  page element codec (default f32 = bit-exact): f16 halves page bytes,
  int8 (per-row scale) quarters the wide rows, so the same
  --kv-mem-budget admits 2-4x the sessions; quantized decode is
  tolerance-gated rather than bitwise (kernels score straight out of the
  packed pages through dequantizing SIMD lane ops). The serve summary
  line reports kv_state / arena_live / arena_hw bytes (plus a live page
  count), prefix_hits and evictions; `exp mem` benchmarks paged vs flat
  stepping, prefix-cache speedup, eviction thrash and the per-codec
  step-cost / bytes-per-token / admission-headroom matrix
  (BENCH_mem.json).

serving scenarios:
  `exp scenarios` is the seeded serving-workload suite: five generators
  — long-context needle retrieval, shared-system-prompt agent fleets
  (prefix-cache stress), bursty multi-turn chat (eviction/re-prefill
  stress under --kv-mem-budget), cancellation storms, and templated
  repetitive spec traffic (speculative-decoding acceptance) — each emit
  a JSONL trace (per-request arrival time, prompt, max-new, optional
  cancel point, and the reference output stream recorded at generation
  time) under --out. Every trace replays three ways: a deterministic
  lockstep replay run twice (same seed ⇒ bit-identical token streams
  and counters, at any --threads), the same lockstep under a tight
  --kv-mem-budget (eviction pressure must not change one output token),
  and a serve replay through the real coordinator (wall-clock tok/s and
  TTFT p50/p99). Scores land in BENCH_scenarios.json; the tier-1 gate
  rust/tests/scenario_gate.rs pins the deterministic properties across
  threads {1,4,8}.

speculative decoding:
  --speculate turns on speculative decoding for native generation
  sessions: a cheap drafter proposes --draft-len tokens (default 4) and
  the target kernel verifies all of them in ONE fused pool wave — the
  longest matching prefix (plus the bonus token computed at the first
  divergence) commits, and on a partial match the session's paged KV
  state rolls back to a copy-on-write snapshot (O(1) page drops, no
  recompute). Two draft sources: `mamba` steps a constant-state RNN
  drafter beside the session (O(1) state, serially cheap), `self`
  forks the session's own ZETA state copy-on-write and searches a k/8
  top-k window (self-speculation; exact-softmax kernels fall back to
  plain decode). Accepted streams are BIT-IDENTICAL to --speculate off
  for every kernel and thread count — speculation buys speed, never
  changes tokens (rust/tests/spec_decode.rs pins this, including under
  cancellation and tight --kv-mem-budget, where drafter state is shed
  first and drafts simply pause). Drafter state counts against
  --kv-mem-budget; the serve summary reports drafted/accepted/rejected
  and the accept rate. `exp spec` writes BENCH_spec.json: the accept
  rate × speedup matrix over draft source × draft length {2,4,8} ×
  threads {1,4,8} on the repetitive spec trace, and `zeta bench diff
  old.json new.json [--fail-above PCT]` compares any two BENCH_*.json
  trajectories (refusing mismatched threads/simd/kv-quant provenance).

parallelism:
  All attention kernels run on a shared worker pool sized by the
  ZETA_THREADS env var (unset or 0 = auto-detect hardware threads). The
  pool is a persistent resident team: workers park on a condvar between
  parallel regions and are woken per region, so entering a region costs
  µs, not a thread spawn. `exp table3` / `exp table4` report every row at
  threads=1 and at the pool size (`--threads T` overrides); `exp table3`
  writes the machine-readable BENCH_table3.json perf trajectory, `exp
  decode` writes BENCH_decode.json (incremental vs full-recompute
  per-token cost) plus BENCH_decode_batch.json (fused vs serial
  multi-session sweeps over a sessions × threads grid), `exp prefill`
  writes BENCH_prefill.json (long-prompt time-to-first-token: pipelined
  sequence-parallel prefill — index snapshots at every chunk boundary,
  all scoring fanned out in one region — vs the serial chunk loop, over
  a prompt-length × threads grid), and `exp pool` writes BENCH_pool.json
  (region launch latency: resident team vs scoped spawns, plus the
  fan-out break-even sweep).

simd:
  The f32 kernel inner loops (Cauchy scoring, softmax rows, the mamba
  recurrence, Morton interleave, dot/readout matvecs) dispatch once per
  process to the widest available vector unit — AVX2 (8 × f32) on
  x86_64, NEON (4 × f32) on aarch64 — with a bit-exact scalar fallback.
  Set ZETA_SIMD=scalar to force the seed-exact scalar loops (the mode
  every bitwise-determinism gate pins). `exp kernels` writes
  BENCH_kernels.json: per-loop ns/element, scalar arm vs the dispatched
  backend, at n ∈ {256, 4096, 65536}.

`make artifacts` builds the core presets; `make artifacts-full` builds the
experiment sweeps (required for fig2*/table1/2/5/6).";

fn cmd_list() -> Result<()> {
    let engine = Engine::new(zeta::ARTIFACTS_DIR)?;
    println!("{:<28}{:>10}  {:<8}{}", "preset", "params", "batch", "entries");
    for (name, p) in &engine.manifest.presets {
        let entries: Vec<&str> = p.entries.keys().map(String::as_str).collect();
        println!("{name:<28}{:>10}  {:<8}{}", p.param_count, p.batch, entries.join(","));
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    let engine = Engine::new(zeta::ARTIFACTS_DIR)?;
    println!("platform: {}", engine.platform());
    println!("presets: {}", engine.manifest.presets.len());
    println!("artifacts dir: {:?}", engine.manifest.dir);
    Ok(())
}

fn cmd_train(f: &HashMap<String, String>) -> Result<()> {
    let preset = f.get("preset").ok_or_else(|| anyhow!("--preset required"))?;
    let steps = flag_usize(f, "steps", 300)?;
    let seed = flag_usize(f, "seed", 0)? as u64;
    let eval_batches = flag_usize(f, "eval-batches", 8)?;
    let engine = Engine::new(zeta::ARTIFACTS_DIR)?;
    let pspec = engine.manifest.preset(preset)?;
    println!(
        "training {preset}: {} params, batch {}, seq {}",
        pspec.param_count, pspec.batch, pspec.seq_len()
    );
    let task = task_for_config(&pspec.config);
    let mut tr = Trainer::new(&engine, preset, seed as i32)?;
    let mut rng = Rng::new(seed);
    let t0 = std::time::Instant::now();
    let final_loss = tr.train_loop(&*task, steps, &mut rng, |s, l| {
        if s % 25 == 0 || s == 1 {
            println!("step {s:>5}  loss {l:.4}  ({:.1} s)", t0.elapsed().as_secs_f64());
        }
    })?;
    let mut erng = Rng::new(seed ^ 0xE7A1);
    let stats = tr.eval(&*task, eval_batches, &mut erng)?;
    println!(
        "done: final loss {final_loss:.4}, eval loss {:.4}, accuracy {:.4}, ppl {:.2}",
        stats.loss,
        stats.accuracy,
        stats.perplexity()
    );
    if let Some(ckpt) = f.get("ckpt") {
        tr.save(ckpt)?;
        println!("checkpoint written to {ckpt}");
    }
    Ok(())
}

/// `zeta bench diff <old.json> <new.json> [--fail-above PCT]` — compare
/// two `BENCH_*.json` perf trajectories by their provenance envelopes.
/// Exits 1 when the worst directional regression exceeds the threshold.
fn cmd_bench(args: &[String]) -> Result<()> {
    let sub = args.first().map(String::as_str).unwrap_or("");
    if sub != "diff" {
        bail!("unknown bench subcommand {sub:?}; usage: zeta bench diff OLD.json NEW.json");
    }
    let (old, new) = match (args.get(1), args.get(2)) {
        (Some(o), Some(n)) if !o.starts_with("--") && !n.starts_with("--") => {
            (o.clone(), n.clone())
        }
        _ => bail!("usage: zeta bench diff OLD.json NEW.json [--fail-above PCT]"),
    };
    let flags = parse_flags(&args[3..])?;
    let fail_above = match flags.get("fail-above") {
        Some(v) => Some(
            v.parse::<f64>()
                .map_err(|_| anyhow!("--fail-above must be a number, got {v:?}"))?,
        ),
        None => None,
    };
    if !exp::diff::bench_diff(&old, &new, fail_above)? {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_serve(f: &HashMap<String, String>) -> Result<()> {
    let preset = f.get("preset").cloned().unwrap_or_else(|| "serve_cls".into());
    let requests = flag_usize(f, "requests", 64)?;
    let clients = flag_usize(f, "clients", 4)?;
    let delay_ms = flag_usize(f, "max-delay-ms", 5)? as u64;
    let generate = f.contains_key("generate");
    let max_new = flag_usize(f, "max-new", 32)?;
    // Global per-sweep prefill-token budget across all prefilling sessions
    // (native backend; 0 = unlimited).
    let default_budget = ServerConfig::default().prefill_budget;
    let prefill_budget = flag_usize(f, "prefill-budget", default_budget)?;
    // Round-robin prompt-token grant size per prefilling session per sweep
    // (native backend; must be >= 1 — Server::start rejects 0).
    let default_chunk = ServerConfig::default().prefill_chunk;
    let prefill_chunk = flag_usize(f, "prefill-chunk", default_chunk)?;
    // Fixed prompt length for every request (0 = sample short prompts).
    // Long-context prefill smokes combine this with --max-context 0.
    let prompt_len = flag_usize(f, "prompt-len", 0)?;
    // Per-session context cap, prompt + generated (native backend;
    // 0 = unlimited).
    let default_ctx = NativeModelConfig::default().max_context;
    let max_context = flag_usize(f, "max-context", default_ctx)?;
    // KV page size in tokens and the arena byte budget across all live
    // decode states (native backend; budget 0 = unlimited).
    let kv_page = flag_usize(f, "kv-page", NativeModelConfig::default().kv_page)?;
    let kv_mem_budget = flag_usize(f, "kv-mem-budget", 0)?;
    // KV page element codec: f32 (bit-exact default) | f16 | int8.
    // Validated at Server::start, which lists the accepted codecs.
    let kv_quant = f.get("kv-quant").cloned().unwrap_or_else(|| "f32".into());
    // Speculative decoding (native backend): draft source and tokens
    // proposed per draft-then-verify wave. Validated at Server::start,
    // which lists the accepted sources.
    let speculate = f.get("speculate").cloned().unwrap_or_else(|| "off".into());
    let draft_len = flag_usize(f, "draft-len", ServerConfig::default().draft_len)?;
    // Native decode engine: forced with --native / --native-kernel, and the
    // fallback whenever the AOT artifacts are absent.
    let native_kernel = f.get("native-kernel").cloned();
    let have_artifacts =
        std::path::Path::new(zeta::ARTIFACTS_DIR).join("manifest.json").exists();
    let use_native = f.contains_key("native") || native_kernel.is_some() || !have_artifacts;
    let max_delay = std::time::Duration::from_millis(delay_ms);
    let (cfg, seq, backend_desc) = if use_native {
        let ncfg = NativeModelConfig {
            kernel: native_kernel.unwrap_or_else(|| "zeta".into()),
            max_context,
            kv_page,
            kv_quant,
            ..Default::default()
        };
        if !have_artifacts {
            eprintln!("artifacts/ missing — using the native decode engine");
        }
        let desc = format!("native decode engine ({} kernel)", ncfg.kernel);
        // Generation prompts must fit under the context cap (leave room
        // for at least one new token, as with the engine's seq_len).
        let seq = if max_context > 0 { max_context.min(128) } else { 128 };
        (
            ServerConfig {
                native: Some(ncfg),
                max_delay,
                prefill_budget,
                prefill_chunk,
                kv_mem_budget,
                speculate,
                draft_len,
                ..Default::default()
            },
            seq,
            desc,
        )
    } else {
        let seq = Engine::new(zeta::ARTIFACTS_DIR)?.manifest.preset(&preset)?.seq_len();
        let cfg = ServerConfig {
            preset: preset.clone(),
            max_delay,
            prefill_budget,
            prefill_chunk,
            ..Default::default()
        };
        (cfg, seq, format!("preset {preset}"))
    };
    let srv = Server::start(cfg, None)?;
    let clients = clients.max(1);
    // Distribute the remainder so exactly `requests` are served (65 reqs /
    // 4 clients = 17+16+16+16, not 4x16).
    let base = requests / clients;
    let extra = requests % clients;
    let mode = if generate {
        format!("generate (--max-new {max_new})")
    } else {
        "infer".into()
    };
    println!("serving {backend_desc}: {clients} clients, {requests} {mode} requests total");

    let mut joins = Vec::new();
    for c in 0..clients {
        let per_client = base + usize::from(c < extra);
        let client = srv.client();
        joins.push(std::thread::spawn(move || -> Result<u64> {
            let mut rng = Rng::new(c as u64);
            let mut streamed = 0u64;
            for _ in 0..per_client {
                // Sample a prompt length in [min(8, seq), seq), clamped so
                // presets with seq_len <= 8 cannot underflow the sampler.
                // Generation needs room for new tokens in the context, so
                // generate-mode prompts additionally stay below seq.
                let lo = seq.min(8).max(1);
                let mut len = if prompt_len > 0 {
                    prompt_len
                } else if seq > lo {
                    lo + rng.usize_below(seq - lo)
                } else {
                    lo
                };
                if generate && prompt_len == 0 {
                    len = len.min(seq.saturating_sub(1)).max(1);
                }
                let toks: Vec<i32> = (0..len).map(|_| 1 + rng.below(200) as i32).collect();
                if generate {
                    let stream = client.generate(toks, max_new)?;
                    streamed += stream.collect_tokens()?.len() as u64;
                } else {
                    client.infer(toks)?;
                }
            }
            Ok(streamed)
        }));
    }
    let mut streamed_total = 0u64;
    for j in joins {
        streamed_total += j.join().map_err(|_| anyhow!("client thread panicked"))??;
    }
    if generate {
        println!("streamed {streamed_total} generated tokens");
    }
    println!("metrics: {}", srv.metrics.lock().unwrap().summary());
    srv.shutdown();
    Ok(())
}

fn cmd_exp(which: &str, f: &HashMap<String, String>) -> Result<()> {
    let opts = opts_from_flags(f)?;
    // fig3 / table3 / table4 / kernels / decode / decode_batch / prefill /
    // pool / mem / scenarios / spec need no artifacts
    match which {
        "fig3" => return exp::fig3(&opts),
        "table3" => return exp::table3(&opts),
        "table4" => return exp::table4(&opts),
        "kernels" => return exp::kernels(&opts),
        "decode" => return exp::decode(&opts),
        "decode_batch" => return exp::decode_batch(&opts),
        "prefill" => return exp::prefill(&opts),
        "pool" => return exp::pool(&opts),
        "mem" => return exp::mem(&opts),
        "scenarios" => return exp::scenarios(&opts),
        "spec" => return exp::spec(&opts),
        _ => {}
    }
    let engine = Engine::new(zeta::ARTIFACTS_DIR)?;
    match which {
        "fig2a" => exp::fig2a(&engine, &opts),
        "fig2b" => exp::fig2b(&engine, &opts),
        "fig2c" => exp::fig2c(&engine, &opts),
        "fig2d" => exp::fig2d(&engine, &opts),
        "table1" => exp::table1(&engine, &opts),
        "table2" => exp::table2(&engine, &opts),
        "table5" => exp::table5(&engine, &opts),
        "table6" => exp::table6(&engine, &opts),
        "all" => exp::all(&engine, &opts),
        other => bail!("unknown experiment {other:?}; see `zeta help`"),
    }
}
