//! LRA-style synthetic long-range tasks (Tables 2 and 5).
//!
//! The real LONG RANGE ARENA datasets are external downloads; per the
//! substitution rule (DESIGN.md §5) each task here is a generator with the
//! same *structure* and an exactly-known ground truth:
//!
//! * **ListOps** — real nested MAX/MIN/MED/SM expressions over digits,
//!   evaluated exactly; 10 classes.
//! * **Text** — byte-stream "sentiment": sparse positive/negative evidence
//!   tokens planted in long Zipfian filler; 2 classes.
//! * **Retrieval** — two documents; class = does doc B contain doc A's
//!   signature 4-gram; 2 classes.
//! * **Image** — 16x16 grayscale renders of 10 parametric glyph classes,
//!   flattened to a 256-token sequence of intensity buckets.
//! * **Pathfinder** — random obstacle mazes on a 16x16 grid; class =
//!   BFS-connectivity of two marked cells.
//!
//! All tasks share the 256-token vocabulary of the `table2_*` presets.
//! Token 0 is reserved as padding everywhere (the classifier head
//! mean-pools over non-zero positions).

use super::{Batch, Task};
use crate::util::rng::Rng;

pub fn make_task(name: &str, seq_len: usize) -> Box<dyn Task> {
    match name {
        "listops" => Box::new(ListOps { seq_len }),
        "text" => Box::new(Text { seq_len }),
        "retrieval" => Box::new(Retrieval { seq_len }),
        "image" => Box::new(Image { seq_len }),
        "pathfinder" => Box::new(Pathfinder { seq_len }),
        _ => panic!("unknown LRA task {name:?}"),
    }
}

// ---------------------------------------------------------------------------
// ListOps
// ---------------------------------------------------------------------------

/// Tokens: digits 0-9 -> 1..=10, MAX=11 MIN=12 MED=13 SM=14, '['=15 ']'=16.
pub struct ListOps {
    pub seq_len: usize,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Op {
    Max,
    Min,
    Med,
    Sm,
}

impl Op {
    fn token(self) -> i32 {
        match self {
            Op::Max => 11,
            Op::Min => 12,
            Op::Med => 13,
            Op::Sm => 14,
        }
    }

    /// Evaluate the op over its argument values. `None` on an empty
    /// argument list — the generator never emits one (it draws 2..=4
    /// args), but the evaluator also runs over *parsed* token streams,
    /// where a malformed `[OP]` with no arguments must surface as a
    /// parse failure instead of a panic.
    fn apply(self, args: &[u8]) -> Option<u8> {
        if args.is_empty() {
            return None;
        }
        Some(match self {
            Op::Max => *args.iter().max()?,
            Op::Min => *args.iter().min()?,
            Op::Med => {
                let mut s = args.to_vec();
                s.sort_unstable();
                s[s.len() / 2]
            }
            Op::Sm => (args.iter().map(|&a| a as u32).sum::<u32>() % 10) as u8,
        })
    }
}

impl ListOps {
    /// Emit one expression into `out`, returning its value. Depth-bounded
    /// recursive generation; stops expanding when the budget runs low.
    fn gen_expr(&self, out: &mut Vec<i32>, budget: usize, depth: usize, rng: &mut Rng) -> u8 {
        if depth == 0 || budget < 8 || rng.f64() < 0.35 {
            let d = rng.below(10) as u8;
            out.push(d as i32 + 1);
            return d;
        }
        let op = match rng.below(4) {
            0 => Op::Max,
            1 => Op::Min,
            2 => Op::Med,
            _ => Op::Sm,
        };
        out.push(15); // '['
        out.push(op.token());
        let nargs = 2 + rng.usize_below(3);
        let mut vals = Vec::with_capacity(nargs);
        let per = budget.saturating_sub(3) / nargs;
        for _ in 0..nargs {
            vals.push(self.gen_expr(out, per, depth - 1, rng));
        }
        out.push(16); // ']'
        op.apply(&vals).expect("listops generator always emits >= 2 args")
    }
}

impl Task for ListOps {
    fn name(&self) -> &str {
        "listops"
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn sample(&self, batch: usize, rng: &mut Rng) -> Batch {
        let n = self.seq_len;
        let mut b = Batch::new_cls(batch, n);
        for r in 0..batch {
            let mut toks = Vec::with_capacity(n);
            let val = self.gen_expr(&mut toks, n - 1, 5, rng);
            toks.truncate(n);
            b.y[r] = val as i32;
            let row = b.x_row_mut(r);
            row[..toks.len()].copy_from_slice(&toks);
        }
        b
    }
}

/// Exact evaluator used by tests to confirm labels (independent impl).
pub fn eval_listops(tokens: &[i32]) -> Option<u8> {
    fn parse(t: &[i32], i: &mut usize) -> Option<u8> {
        match *t.get(*i)? {
            d @ 1..=10 => {
                *i += 1;
                Some((d - 1) as u8)
            }
            15 => {
                *i += 1;
                let op = match *t.get(*i)? {
                    11 => Op::Max,
                    12 => Op::Min,
                    13 => Op::Med,
                    14 => Op::Sm,
                    _ => return None,
                };
                *i += 1;
                let mut args = Vec::new();
                while *t.get(*i)? != 16 {
                    args.push(parse(t, i)?);
                }
                *i += 1;
                op.apply(&args)
            }
            _ => None,
        }
    }
    let mut i = 0;
    let end: usize = tokens.iter().position(|&t| t == 0).unwrap_or(tokens.len());
    parse(&tokens[..end], &mut i)
}

/// Locate the planted 4-gram signature (four consecutive tokens >= 230)
/// in a retrieval document. `None` when no signature is present — a
/// malformed or truncated row reports absence instead of panicking in
/// whoever indexes the match position.
pub fn find_signature(doc: &[i32]) -> Option<&[i32]> {
    let p = doc.windows(4).position(|w| w.iter().all(|&t| t >= 230))?;
    Some(&doc[p..p + 4])
}

// ---------------------------------------------------------------------------
// Text
// ---------------------------------------------------------------------------

/// Byte-level synthetic sentiment. Filler tokens 20..220 (Zipf), positive
/// evidence 221..225, negative evidence 226..230, planted sparsely.
pub struct Text {
    pub seq_len: usize,
}

impl Task for Text {
    fn name(&self) -> &str {
        "text"
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn sample(&self, batch: usize, rng: &mut Rng) -> Batch {
        let n = self.seq_len;
        let mut b = Batch::new_cls(batch, n);
        for r in 0..batch {
            let label = rng.below(2) as i32;
            // filler
            for t in 0..n {
                b.x[r * n + t] = 20 + rng.zipf(200, 1.1) as i32;
            }
            // evidence: majority class gets e_maj tokens, minority e_min.
            let e_maj = 3 + rng.usize_below(3);
            let e_min = rng.usize_below(e_maj); // strictly fewer
            let (maj_base, min_base) = if label == 1 { (221, 226) } else { (226, 221) };
            let spots = rng.sample_distinct(n, e_maj + e_min);
            for (i, &s) in spots.iter().enumerate() {
                let base = if i < e_maj { maj_base } else { min_base };
                b.x[r * n + s] = base + rng.below(5) as i32;
            }
            b.y[r] = label;
        }
        b
    }
}

// ---------------------------------------------------------------------------
// Retrieval
// ---------------------------------------------------------------------------

/// Doc A [sep] Doc B. Label 1 iff B contains A's signature 4-gram verbatim.
pub struct Retrieval {
    pub seq_len: usize,
}

const R_SEP: i32 = 17;

impl Task for Retrieval {
    fn name(&self) -> &str {
        "retrieval"
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn sample(&self, batch: usize, rng: &mut Rng) -> Batch {
        let n = self.seq_len;
        let half = n / 2;
        let mut b = Batch::new_cls(batch, n);
        for r in 0..batch {
            let label = rng.below(2) as i32;
            for t in 0..n {
                b.x[r * n + t] = 20 + rng.zipf(200, 1.1) as i32;
            }
            b.x[r * n + half] = R_SEP;
            // signature 4-gram in doc A
            let sig: Vec<i32> = (0..4).map(|_| 230 + rng.below(20) as i32).collect();
            let pa = rng.usize_below(half - 4);
            for (i, &s) in sig.iter().enumerate() {
                b.x[r * n + pa + i] = s;
            }
            if label == 1 {
                let pb = half + 1 + rng.usize_below(half - 5);
                for (i, &s) in sig.iter().enumerate() {
                    b.x[r * n + pb + i] = s;
                }
            } else {
                // decoy: a different 4-gram from the same signature alphabet
                let mut decoy = sig.clone();
                let flip = rng.usize_below(4);
                decoy[flip] = 230 + ((decoy[flip] - 230 + 1 + rng.below(19) as i32) % 20);
                let pb = half + 1 + rng.usize_below(half - 5);
                for (i, &s) in decoy.iter().enumerate() {
                    b.x[r * n + pb + i] = s;
                }
            }
            b.y[r] = label;
        }
        b
    }
}

// ---------------------------------------------------------------------------
// Image
// ---------------------------------------------------------------------------

/// 16x16 grayscale glyphs, 10 parametric classes, flattened row-major.
/// Pixel intensity buckets occupy tokens 1..=32 (0 stays padding).
pub struct Image {
    pub seq_len: usize,
}

impl Image {
    fn side(&self) -> usize {
        (self.seq_len as f64).sqrt() as usize
    }

    fn render(&self, class: usize, rng: &mut Rng) -> Vec<f32> {
        let s = self.side();
        let mut img = vec![0f32; s * s];
        let cx = s as f32 / 2.0 + rng.normal_f32() * 1.0;
        let cy = s as f32 / 2.0 + rng.normal_f32() * 1.0;
        let rad = s as f32 * (0.25 + 0.1 * rng.f32());
        let set = |img: &mut Vec<f32>, x: i32, y: i32, v: f32| {
            if x >= 0 && y >= 0 && (x as usize) < s && (y as usize) < s {
                img[y as usize * s + x as usize] = v.max(img[y as usize * s + x as usize]);
            }
        };
        match class {
            0 => {
                // horizontal bar
                let y = cy as i32;
                for x in 0..s as i32 {
                    set(&mut img, x, y, 1.0);
                    set(&mut img, x, y + 1, 0.6);
                }
            }
            1 => {
                // vertical bar
                let x = cx as i32;
                for y in 0..s as i32 {
                    set(&mut img, x, y, 1.0);
                    set(&mut img, x + 1, y, 0.6);
                }
            }
            2 => {
                // cross
                for t in 0..s as i32 {
                    set(&mut img, t, cy as i32, 1.0);
                    set(&mut img, cx as i32, t, 1.0);
                }
            }
            3 => {
                // diagonal
                for t in 0..s as i32 {
                    set(&mut img, t, t, 1.0);
                }
            }
            4 => {
                // anti-diagonal
                for t in 0..s as i32 {
                    set(&mut img, t, s as i32 - 1 - t, 1.0);
                }
            }
            5 => {
                // circle outline
                for a in 0..64 {
                    let th = a as f32 / 64.0 * std::f32::consts::TAU;
                    set(&mut img, (cx + rad * th.cos()) as i32, (cy + rad * th.sin()) as i32, 1.0);
                }
            }
            6 => {
                // filled disc
                for y in 0..s as i32 {
                    for x in 0..s as i32 {
                        let dx = x as f32 - cx;
                        let dy = y as f32 - cy;
                        if dx * dx + dy * dy < rad * rad {
                            set(&mut img, x, y, 0.9);
                        }
                    }
                }
            }
            7 => {
                // box outline
                let r = rad as i32;
                for t in -r..=r {
                    set(&mut img, cx as i32 + t, cy as i32 - r, 1.0);
                    set(&mut img, cx as i32 + t, cy as i32 + r, 1.0);
                    set(&mut img, cx as i32 - r, cy as i32 + t, 1.0);
                    set(&mut img, cx as i32 + r, cy as i32 + t, 1.0);
                }
            }
            8 => {
                // checkerboard
                for y in 0..s {
                    for x in 0..s {
                        if (x / 2 + y / 2) % 2 == 0 {
                            img[y * s + x] = 0.8;
                        }
                    }
                }
            }
            _ => {
                // two dots
                let r2 = (rad / 2.0) as i32;
                for dy in -1..=1 {
                    for dx in -1..=1 {
                        set(&mut img, cx as i32 - r2 + dx, cy as i32 + dy, 1.0);
                        set(&mut img, cx as i32 + r2 + dx, cy as i32 + dy, 1.0);
                    }
                }
            }
        }
        // noise
        for v in img.iter_mut() {
            *v = (*v + rng.f32() * 0.15).min(1.0);
        }
        img
    }
}

impl Task for Image {
    fn name(&self) -> &str {
        "image"
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn sample(&self, batch: usize, rng: &mut Rng) -> Batch {
        let n = self.seq_len;
        let mut b = Batch::new_cls(batch, n);
        for r in 0..batch {
            let class = rng.usize_below(10);
            let img = self.render(class, rng);
            for (t, &v) in img.iter().take(n).enumerate() {
                b.x[r * n + t] = 1 + (v * 31.0) as i32; // buckets 1..=32
            }
            b.y[r] = class as i32;
        }
        b
    }
}

// ---------------------------------------------------------------------------
// Pathfinder
// ---------------------------------------------------------------------------

/// Random-obstacle grid; tokens: 1 = free, 2 = wall, 3 = endpoint.
/// Label = endpoints BFS-connected. Rejection-balanced to ~50/50.
pub struct Pathfinder {
    pub seq_len: usize,
}

impl Pathfinder {
    fn side(&self) -> usize {
        (self.seq_len as f64).sqrt() as usize
    }

    fn gen_grid(&self, rng: &mut Rng) -> (Vec<bool>, usize, usize) {
        let s = self.side();
        let density = 0.32 + 0.12 * rng.f32();
        let mut wall = vec![false; s * s];
        for w in wall.iter_mut() {
            *w = rng.f64() < density as f64;
        }
        let a = rng.usize_below(s * s);
        let mut bpt = rng.usize_below(s * s);
        while bpt == a {
            bpt = rng.usize_below(s * s);
        }
        wall[a] = false;
        wall[bpt] = false;
        (wall, a, bpt)
    }
}

/// BFS connectivity on a side x side grid of walls.
pub fn connected(wall: &[bool], side: usize, a: usize, b: usize) -> bool {
    if a == b {
        return true;
    }
    let mut seen = vec![false; side * side];
    let mut queue = std::collections::VecDeque::new();
    seen[a] = true;
    queue.push_back(a);
    while let Some(p) = queue.pop_front() {
        let (x, y) = (p % side, p / side);
        let neigh = [
            (x.wrapping_sub(1), y),
            (x + 1, y),
            (x, y.wrapping_sub(1)),
            (x, y + 1),
        ];
        for (nx, ny) in neigh {
            if nx < side && ny < side {
                let q = ny * side + nx;
                if !seen[q] && !wall[q] {
                    if q == b {
                        return true;
                    }
                    seen[q] = true;
                    queue.push_back(q);
                }
            }
        }
    }
    false
}

impl Task for Pathfinder {
    fn name(&self) -> &str {
        "pathfinder"
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn sample(&self, batch: usize, rng: &mut Rng) -> Batch {
        let n = self.seq_len;
        let s = self.side();
        let mut b = Batch::new_cls(batch, n);
        for r in 0..batch {
            // rejection sampling for class balance
            let want = rng.below(2) == 1;
            let (wall, a, bp) = loop {
                let (wall, a, bp) = self.gen_grid(rng);
                if connected(&wall, s, a, bp) == want {
                    break (wall, a, bp);
                }
            };
            for (t, &w) in wall.iter().take(n).enumerate() {
                b.x[r * n + t] = if w { 2 } else { 1 };
            }
            b.x[r * n + a] = 3;
            b.x[r * n + bp] = 3;
            b.y[r] = want as i32;
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn listops_labels_match_independent_evaluator() {
        let task = ListOps { seq_len: 128 };
        let mut rng = Rng::new(0);
        let b = task.sample(16, &mut rng);
        for r in 0..16 {
            let toks = &b.x[r * 128..(r + 1) * 128];
            if let Some(v) = eval_listops(toks) {
                assert_eq!(v as i32, b.y[r], "row {r}");
            } // truncated expressions may not parse; label still well-defined
        }
    }

    #[test]
    fn listops_mostly_parseable() {
        let task = ListOps { seq_len: 256 };
        let b = task.sample(32, &mut Rng::new(1));
        let ok = (0..32)
            .filter(|&r| eval_listops(&b.x[r * 256..(r + 1) * 256]).is_some())
            .count();
        assert!(ok >= 28, "only {ok}/32 parse");
    }

    #[test]
    fn eval_listops_rejects_malformed_streams_without_panicking() {
        // An op with an empty argument list used to hit `.max().unwrap()` /
        // `s[s.len()/2]`; malformed data must parse to None instead.
        for op in [11, 12, 13, 14] {
            assert_eq!(eval_listops(&[15, op, 16]), None, "empty-args op token {op}");
        }
        // Unterminated expression (input ends before ']').
        assert_eq!(eval_listops(&[15, 11, 3, 4]), None);
        // '[' followed by a non-op token.
        assert_eq!(eval_listops(&[15, 16]), None);
        assert_eq!(eval_listops(&[15, 9, 3, 16]), None);
        // Empty / padding-only / stray-close streams.
        assert_eq!(eval_listops(&[]), None);
        assert_eq!(eval_listops(&[0, 0, 0]), None);
        assert_eq!(eval_listops(&[16]), None);
        // A malformed empty-args op nested inside a well-formed one
        // poisons the whole expression.
        assert_eq!(eval_listops(&[15, 11, 4, 15, 12, 16, 16]), None);
        // Well-formed input still evaluates: [SM 9 9] = (9+9) % 10.
        assert_eq!(eval_listops(&[15, 14, 10, 10, 16]), Some(8));
        // [MED 0 5 9] = 5 (token d encodes digit d-1).
        assert_eq!(eval_listops(&[15, 13, 1, 6, 10, 16]), Some(5));
    }

    #[test]
    fn retrieval_signature_helper_reports_absence() {
        // No 4-gram of signature-range tokens anywhere.
        assert!(find_signature(&[1, 2, 3, 4, 5]).is_none());
        // Shorter than a signature, including empty.
        assert!(find_signature(&[]).is_none());
        assert!(find_signature(&[230, 231, 232]).is_none());
        // Broken run: only 3 consecutive signature tokens.
        assert!(find_signature(&[230, 231, 232, 7, 233, 234]).is_none());
        let doc = [7, 230, 231, 232, 233, 9];
        assert_eq!(find_signature(&doc), Some(&doc[1..5]));
    }

    #[test]
    fn text_evidence_counts_decide_label() {
        let task = Text { seq_len: 256 };
        let b = task.sample(32, &mut Rng::new(2));
        for r in 0..32 {
            let row = &b.x[r * 256..(r + 1) * 256];
            let pos = row.iter().filter(|&&t| (221..226).contains(&t)).count();
            let neg = row.iter().filter(|&&t| (226..231).contains(&t)).count();
            if b.y[r] == 1 {
                assert!(pos > neg, "row {r}: pos {pos} neg {neg}");
            } else {
                assert!(neg > pos, "row {r}: pos {pos} neg {neg}");
            }
        }
    }

    #[test]
    fn retrieval_positive_contains_signature() {
        let task = Retrieval { seq_len: 128 };
        let b = task.sample(32, &mut Rng::new(3));
        for r in 0..32 {
            let row = &b.x[r * 128..(r + 1) * 128];
            let half = 64;
            // find signature = the 4-gram of tokens >= 230 in doc A
            let a = &row[..half];
            let sig = find_signature(a)
                .unwrap_or_else(|| panic!("row {r}: doc A carries no signature 4-gram"));
            let bdoc = &row[half + 1..];
            let found = bdoc.windows(4).any(|w| w == sig);
            assert_eq!(found, b.y[r] == 1, "row {r}");
        }
    }

    #[test]
    fn image_classes_distinguishable_by_pixels() {
        // Mean images of two different classes should differ substantially.
        let task = Image { seq_len: 256 };
        let mut rng = Rng::new(4);
        let mut mean = vec![[0f64; 256]; 10];
        let mut count = [0usize; 10];
        for _ in 0..20 {
            let b = task.sample(16, &mut rng);
            for r in 0..16 {
                let c = b.y[r] as usize;
                count[c] += 1;
                for t in 0..256 {
                    mean[c][t] += b.x[r * 256 + t] as f64;
                }
            }
        }
        let m0: Vec<f64> = mean[0].iter().map(|v| v / count[0].max(1) as f64).collect();
        let m1: Vec<f64> = mean[1].iter().map(|v| v / count[1].max(1) as f64).collect();
        let diff: f64 = m0.iter().zip(&m1).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 100.0, "diff {diff}");
    }

    #[test]
    fn pathfinder_labels_are_bfs_truth() {
        let task = Pathfinder { seq_len: 256 };
        let b = task.sample(16, &mut Rng::new(5));
        for r in 0..16 {
            let row = &b.x[r * 256..(r + 1) * 256];
            let wall: Vec<bool> = row.iter().map(|&t| t == 2).collect();
            let ends: Vec<usize> =
                row.iter().enumerate().filter(|(_, &t)| t == 3).map(|(i, _)| i).collect();
            assert_eq!(ends.len(), 2, "row {r}");
            assert_eq!(connected(&wall, 16, ends[0], ends[1]), b.y[r] == 1, "row {r}");
        }
    }

    #[test]
    fn pathfinder_classes_balanced() {
        let task = Pathfinder { seq_len: 256 };
        let b = task.sample(64, &mut Rng::new(6));
        let ones = b.y.iter().filter(|&&y| y == 1).count();
        assert!((20..=44).contains(&ones), "ones {ones}");
    }

    #[test]
    fn all_tasks_tokens_in_vocab_and_nonempty() {
        let mut rng = Rng::new(7);
        for name in ["listops", "text", "retrieval", "image", "pathfinder"] {
            let t = make_task(name, 256);
            let b = t.sample(4, &mut rng);
            assert!(b.x.iter().all(|&tok| (0..256).contains(&tok)), "{name}");
            assert!(b.x.iter().any(|&tok| tok != 0), "{name} all pad");
            assert_eq!(b.y.len(), 4);
        }
    }
}
