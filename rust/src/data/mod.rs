//! Dataset substrates: every workload the paper's evaluation needs,
//! generated deterministically in Rust (no Python at run time).
//!
//! * [`mqar`] — MULTI-QUERY ASSOCIATIVE RECALL (Arora et al., 2024), the
//!   synthetic recall task of Figure 2.
//! * [`lra`] — LRA-style synthetic long-range tasks (Table 2/5): ListOps,
//!   Text, Retrieval, Image, Pathfinder. See DESIGN.md §5 for how each
//!   preserves the structure of the original benchmark.
//! * [`corpus`] — a Zipf/Markov "wiki-like" token stream with planted
//!   long-range copy dependencies, the WikiText-103 stand-in (Table 1).

pub mod corpus;
pub mod lra;
pub mod mqar;

use crate::util::rng::Rng;

/// One training/eval batch in the layout the AOT graphs expect.
#[derive(Debug, Clone)]
pub struct Batch {
    pub batch: usize,
    pub seq_len: usize,
    /// Tokens, row-major (batch, seq_len).
    pub x: Vec<i32>,
    /// Targets: (batch, seq_len) for LM tasks, (batch,) for classification.
    pub y: Vec<i32>,
    /// Loss weights, same shape as y.
    pub w: Vec<f32>,
}

impl Batch {
    pub fn new_lm(batch: usize, seq_len: usize) -> Self {
        Batch {
            batch,
            seq_len,
            x: vec![0; batch * seq_len],
            y: vec![0; batch * seq_len],
            w: vec![0.0; batch * seq_len],
        }
    }

    pub fn new_cls(batch: usize, seq_len: usize) -> Self {
        Batch {
            batch,
            seq_len,
            x: vec![0; batch * seq_len],
            y: vec![0; batch],
            w: vec![1.0; batch],
        }
    }

    pub fn x_row_mut(&mut self, b: usize) -> &mut [i32] {
        &mut self.x[b * self.seq_len..(b + 1) * self.seq_len]
    }
}

/// A task that can emit train and eval batches of fixed geometry.
pub trait Task {
    /// Human name ("mqar", "listops", …).
    fn name(&self) -> &str;
    /// Fill a fresh batch; `rng` supplies all randomness.
    fn sample(&self, batch: usize, rng: &mut Rng) -> Batch;
    fn seq_len(&self) -> usize;
}

/// Construct the task matching an artifact preset's config (see
/// python/compile/presets.py: `lra_task` key for LRA presets, task=="lm"
/// with vocab 256 for corpus LM, vocab 64 for MQAR).
pub fn task_for_config(cfg: &crate::util::json::Json) -> Box<dyn Task> {
    let seq_len = cfg.get("seq_len").as_usize().expect("seq_len");
    if let Some(lra_name) = cfg.get("lra_task").as_str() {
        return lra::make_task(lra_name, seq_len);
    }
    match cfg.get("task").as_str() {
        Some("lm") if cfg.get("vocab").as_usize() == Some(64) => {
            Box::new(mqar::Mqar::new(seq_len))
        }
        Some("lm") => Box::new(corpus::CorpusLm::new(seq_len, 0xC0FFEE)),
        other => panic!("no task for config task={other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_layouts() {
        let lm = Batch::new_lm(2, 8);
        assert_eq!(lm.x.len(), 16);
        assert_eq!(lm.y.len(), 16);
        let cls = Batch::new_cls(3, 8);
        assert_eq!(cls.y.len(), 3);
        assert_eq!(cls.w, vec![1.0; 3]);
    }

    #[test]
    fn task_dispatch() {
        let cfg = crate::util::json::parse(
            r#"{"task":"lm","vocab":64,"seq_len":64}"#,
        )
        .unwrap();
        assert_eq!(task_for_config(&cfg).name(), "mqar");
        let cfg = crate::util::json::parse(
            r#"{"task":"cls","vocab":256,"seq_len":128,"lra_task":"listops"}"#,
        )
        .unwrap();
        assert_eq!(task_for_config(&cfg).name(), "listops");
    }
}
