//! Synthetic "wiki-like" corpus — the WikiText-103 stand-in (Table 1).
//!
//! A deterministic token stream over a 256-token vocabulary with the three
//! statistical properties the language-modeling comparison needs:
//!
//! 1. **Zipfian unigram distribution** — a few very frequent tokens, a long
//!    tail (like word/byte frequencies in Wikipedia).
//! 2. **Local structure** — a sparse 2nd-order Markov chain (each bigram
//!    context has a handful of plausible successors), so models that learn
//!    local syntax gain perplexity.
//! 3. **Long-range copy dependencies** — "entity mentions": a random entity
//!    id (from a small alphabet) is introduced with a marker token and the
//!    *same* id token recurs with its marker several hundred tokens later.
//!    Models that can look far back (attention, ZETA's top-k retrieval)
//!    predict the recurrence; local-only models cannot. This mirrors why
//!    WikiText-103 rewards long context.
//!
//! The stream is generated once per (seed, length) and windows are served
//! as LM batches; a held-out suffix provides the test split.

use super::{Batch, Task};
use crate::util::rng::Rng;

pub const VOCAB: usize = 256;
const ENTITY_MARKER: i32 = 250;
const ENTITY_BASE: i32 = 200;
const NUM_ENTITIES: i32 = 48;

/// The generated corpus: one long token stream + split index.
pub struct Corpus {
    pub tokens: Vec<i32>,
    pub train_end: usize,
}

impl Corpus {
    /// Generate `len` tokens deterministically from `seed`.
    pub fn generate(len: usize, seed: u64) -> Corpus {
        let mut rng = Rng::new(seed);
        // Sparse 2nd-order Markov table: for each context hash bucket, a
        // ranked successor list; successor ranks drawn Zipf at sample time.
        const BUCKETS: usize = 4096;
        const SUCC: usize = 8;
        let mut table = vec![0i32; BUCKETS * SUCC];
        for e in table.iter_mut() {
            // successors themselves Zipf-distributed over the filler range
            *e = 1 + rng.zipf(199, 1.15) as i32; // tokens 1..200
        }

        let mut tokens = Vec::with_capacity(len);
        tokens.push(1);
        tokens.push(2);
        // active entities: (id, next recurrence position)
        let mut pending: Vec<(i32, usize)> = Vec::new();
        while tokens.len() < len {
            let t = tokens.len();
            // entity recurrence due?
            if let Some(pos) = pending.iter().position(|&(_, at)| at <= t) {
                let (id, _) = pending.swap_remove(pos);
                tokens.push(ENTITY_MARKER);
                tokens.push(ENTITY_BASE + id);
                continue;
            }
            // introduce a new entity occasionally
            if rng.f64() < 0.004 && pending.len() < 8 {
                let id = rng.below(NUM_ENTITIES as u64) as i32;
                let dist = 64 + rng.usize_below(448); // recurs 64..512 later
                tokens.push(ENTITY_MARKER);
                tokens.push(ENTITY_BASE + id);
                pending.push((id, t + dist));
                continue;
            }
            // Markov step
            let a = tokens[tokens.len() - 2] as u64;
            let b = tokens[tokens.len() - 1] as u64;
            let ctx = ((a.wrapping_mul(0x9E37_79B9) ^ b.wrapping_mul(0x85EB_CA6B))
                % BUCKETS as u64) as usize;
            let succ = rng.zipf(SUCC, 1.3);
            tokens.push(table[ctx * SUCC + succ]);
        }
        tokens.truncate(len);
        let train_end = len * 9 / 10;
        Corpus { tokens, train_end }
    }

    /// Random training window of length n+1 -> (x, y) pair.
    fn window(&self, n: usize, rng: &mut Rng, test: bool) -> (Vec<i32>, Vec<i32>) {
        let (lo, hi) = if test {
            (self.train_end, self.tokens.len() - n - 1)
        } else {
            (0, self.train_end - n - 1)
        };
        let start = lo + rng.usize_below(hi - lo);
        let x = self.tokens[start..start + n].to_vec();
        let y = self.tokens[start + 1..start + n + 1].to_vec();
        (x, y)
    }
}

/// LM task over a lazily-generated shared corpus.
pub struct CorpusLm {
    pub seq_len: usize,
    corpus: Corpus,
    /// Serve test-split windows instead of train windows.
    pub test_split: bool,
}

impl CorpusLm {
    pub fn new(seq_len: usize, seed: u64) -> Self {
        // 512k tokens: enough that 2-layer models cannot memorize it.
        CorpusLm { seq_len, corpus: Corpus::generate(1 << 19, seed), test_split: false }
    }

    pub fn test_view(seq_len: usize, seed: u64) -> Self {
        CorpusLm { seq_len, corpus: Corpus::generate(1 << 19, seed), test_split: true }
    }
}

impl Task for CorpusLm {
    fn name(&self) -> &str {
        "corpus_lm"
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn sample(&self, batch: usize, rng: &mut Rng) -> Batch {
        let n = self.seq_len;
        let mut b = Batch::new_lm(batch, n);
        for r in 0..batch {
            let (x, y) = self.corpus.window(n, rng, self.test_split);
            b.x[r * n..(r + 1) * n].copy_from_slice(&x);
            b.y[r * n..(r + 1) * n].copy_from_slice(&y);
            for wv in &mut b.w[r * n..(r + 1) * n] {
                *wv = 1.0;
            }
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_vocab() {
        let c1 = Corpus::generate(10_000, 42);
        let c2 = Corpus::generate(10_000, 42);
        assert_eq!(c1.tokens, c2.tokens);
        assert!(c1.tokens.iter().all(|&t| (0..VOCAB as i32).contains(&t)));
    }

    #[test]
    fn zipfian_head_dominates() {
        let c = Corpus::generate(50_000, 1);
        let mut counts = [0usize; VOCAB];
        for &t in &c.tokens {
            counts[t as usize] += 1;
        }
        let mut sorted = counts;
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let head: usize = sorted[..10].iter().sum();
        assert!(head * 3 > c.tokens.len(), "head {head} of {}", c.tokens.len());
    }

    #[test]
    fn entities_recur() {
        let c = Corpus::generate(100_000, 2);
        // every entity mention after the first for an id should exist
        let mentions: Vec<(usize, i32)> = c
            .tokens
            .windows(2)
            .enumerate()
            .filter(|(_, w)| w[0] == ENTITY_MARKER)
            .map(|(i, w)| (i, w[1]))
            .collect();
        assert!(mentions.len() > 100, "{} mentions", mentions.len());
        // at least 40% of mentions are recurrences (same id seen before)
        let mut seen = std::collections::HashSet::new();
        let mut rec = 0;
        for &(_, id) in &mentions {
            if !seen.insert(id) {
                rec += 1;
            }
        }
        assert!(rec * 10 >= mentions.len() * 3, "{rec}/{}", mentions.len());
    }

    #[test]
    fn windows_are_shifted_pairs() {
        let lm = CorpusLm::new(32, 7);
        let mut rng = Rng::new(0);
        let b = lm.sample(4, &mut rng);
        for r in 0..4 {
            for t in 0..31 {
                assert_eq!(b.x[r * 32 + t + 1], b.y[r * 32 + t]);
            }
        }
    }

    #[test]
    fn test_split_disjoint_from_train() {
        let train = CorpusLm::new(64, 9);
        let test = CorpusLm::test_view(64, 9);
        assert_eq!(train.corpus.tokens, test.corpus.tokens);
        // train windows never reach past train_end; spot-check bounds logic
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let (lo, hi) = (train.corpus.train_end, train.corpus.tokens.len());
            let b = test.sample(1, &mut rng);
            // the first test window token must exist somewhere in the tail
            let probe = &b.x[..8];
            let tail = &test.corpus.tokens[lo..hi];
            let found = tail.windows(8).any(|w| w == probe);
            assert!(found);
        }
    }
}
