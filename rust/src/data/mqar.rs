//! MULTI-QUERY ASSOCIATIVE RECALL (MQAR) — the synthetic recall task of
//! Figure 2 (Arora et al., 2024, "Zoology").
//!
//! Layout of one sequence of length N with P key-value pairs:
//!
//!   [k1 v1 k2 v2 … kP vP | q_a ?_a q_b ?_b …]
//!
//! The first 2P positions present distinct key/value associations; the rest
//! of the sequence alternates (query-key, answer-value). Training loss and
//! accuracy are measured ONLY at positions whose next token is an answer
//! value (weight mask), matching the Zoology evaluation protocol.
//!
//! Vocabulary (64 tokens, matches the `vocab: 64` MQAR presets):
//!   0           pad
//!   1           separator between the KV prefix and the query section
//!   2 .. 32     key space (30 keys)
//!   33 .. 63    value space (31 values)

use super::{Batch, Task};
use crate::util::rng::Rng;

pub const VOCAB: usize = 64;
const KEY_BASE: i32 = 2;
const NUM_KEYS: i32 = 30;
const VAL_BASE: i32 = 33;
const NUM_VALS: i32 = 31;
const SEP: i32 = 1;

pub struct Mqar {
    pub seq_len: usize,
    pub pairs: usize,
}

impl Mqar {
    pub fn new(seq_len: usize) -> Self {
        // 8 pairs for N=64 (Zoology's default density scales with N).
        Mqar { seq_len, pairs: (seq_len / 8).clamp(4, 16) }
    }

    /// Fill one row; returns (keys, vals) used.
    fn fill_row(&self, x: &mut [i32], y: &mut [i32], w: &mut [f32], rng: &mut Rng) {
        let n = self.seq_len;
        let p = self.pairs;
        let keys: Vec<i32> = rng
            .sample_distinct(NUM_KEYS as usize, p)
            .into_iter()
            .map(|i| KEY_BASE + i as i32)
            .collect();
        let vals: Vec<i32> =
            (0..p).map(|_| VAL_BASE + rng.below(NUM_VALS as u64) as i32).collect();

        for i in 0..p {
            x[2 * i] = keys[i];
            x[2 * i + 1] = vals[i];
        }
        x[2 * p] = SEP;
        // Query section: alternate (query, answer) to the end.
        let mut t = 2 * p + 1;
        while t + 1 < n {
            let qi = rng.usize_below(p);
            x[t] = keys[qi];
            x[t + 1] = vals[qi];
            t += 2;
        }
        if t < n {
            x[t] = SEP; // odd tail
        }
        // LM targets: y[t] = x[t+1]; weight only where the *next* token is
        // an answer (odd offsets in the query section).
        for i in 0..n - 1 {
            y[i] = x[i + 1];
            let next_is_answer = i + 1 > 2 * p && (i + 1 - (2 * p + 1)) % 2 == 1;
            w[i] = if next_is_answer && x[i + 1] >= VAL_BASE { 1.0 } else { 0.0 };
        }
        y[n - 1] = 0;
        w[n - 1] = 0.0;
    }
}

impl Task for Mqar {
    fn name(&self) -> &str {
        "mqar"
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn sample(&self, batch: usize, rng: &mut Rng) -> Batch {
        let n = self.seq_len;
        let mut b = Batch::new_lm(batch, n);
        for r in 0..batch {
            let (xs, rest) = b.x[r * n..].split_at_mut(n);
            let _ = rest;
            let ys = &mut b.y[r * n..(r + 1) * n];
            let ws = &mut b.w[r * n..(r + 1) * n];
            self.fill_row(xs, ys, ws, rng);
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn structure_is_valid() {
        let task = Mqar::new(64);
        let mut rng = Rng::new(0);
        let b = task.sample(4, &mut rng);
        for r in 0..4 {
            let x = &b.x[r * 64..(r + 1) * 64];
            let p = task.pairs;
            // prefix: alternating key/value
            for i in 0..p {
                assert!((KEY_BASE..KEY_BASE + NUM_KEYS).contains(&x[2 * i]));
                assert!((VAL_BASE..VAL_BASE + NUM_VALS).contains(&x[2 * i + 1]));
            }
            assert_eq!(x[2 * p], SEP);
        }
    }

    #[test]
    fn answers_match_prefix_associations() {
        let task = Mqar::new(64);
        let mut rng = Rng::new(1);
        let b = task.sample(8, &mut rng);
        let p = task.pairs;
        for r in 0..8 {
            let x = &b.x[r * 64..(r + 1) * 64];
            let assoc: std::collections::HashMap<i32, i32> =
                (0..p).map(|i| (x[2 * i], x[2 * i + 1])).collect();
            let mut t = 2 * p + 1;
            while t + 1 < 64 {
                if x[t] >= KEY_BASE && x[t] < VAL_BASE {
                    assert_eq!(x[t + 1], assoc[&x[t]], "row {r} pos {t}");
                }
                t += 2;
            }
        }
    }

    #[test]
    fn weights_select_only_answer_positions() {
        let task = Mqar::new(64);
        let mut rng = Rng::new(2);
        let b = task.sample(4, &mut rng);
        let mut total = 0.0;
        for r in 0..4 {
            let x = &b.x[r * 64..(r + 1) * 64];
            let y = &b.y[r * 64..(r + 1) * 64];
            let w = &b.w[r * 64..(r + 1) * 64];
            for i in 0..64 {
                if w[i] > 0.0 {
                    // target must be a value token, and it must equal the
                    // association of the key at position i.
                    assert!(y[i] >= VAL_BASE, "row {r} pos {i}");
                    assert!(x[i] >= KEY_BASE && x[i] < VAL_BASE);
                    total += w[i];
                }
            }
        }
        assert!(total > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let task = Mqar::new(64);
        let b1 = task.sample(2, &mut Rng::new(7));
        let b2 = task.sample(2, &mut Rng::new(7));
        assert_eq!(b1.x, b2.x);
        assert_eq!(b1.y, b2.y);
    }

    #[test]
    fn tokens_within_vocab() {
        let task = Mqar::new(128);
        let b = task.sample(4, &mut Rng::new(3));
        assert!(b.x.iter().all(|&t| (0..VOCAB as i32).contains(&t)));
    }
}
