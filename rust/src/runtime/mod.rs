//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU client): HLO text from
//! `artifacts/` -> `HloModuleProto::from_text_file` -> `client.compile` ->
//! `execute`. One [`Engine`] per process owns the client and an executable
//! cache keyed by (preset, entry); loading is lazy and compiled modules are
//! shared across trainer / coordinator / experiment harness.
//!
//! Host tensors cross the boundary as [`HostTensor`] (shape + dtype-tagged
//! flat data); outputs come back as `HostTensor`s by decomposing the result
//! tuple (all our graphs are lowered with `return_tuple=True`).

pub mod manifest;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

pub use manifest::{DType, EntrySpec, Manifest, PresetSpec, TensorSpec};

/// A host-side tensor crossing the PJRT boundary.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
    U32(Vec<usize>, Vec<u32>),
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(s, _) | HostTensor::I32(s, _) | HostTensor::U32(s, _) => s,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(..) => DType::F32,
            HostTensor::I32(..) => DType::I32,
            HostTensor::U32(..) => DType::U32,
        }
    }

    pub fn elems(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::F32(vec![], vec![v])
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::I32(vec![], vec![v])
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(_, d) => Ok(d),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(_, d) => Ok(d),
            _ => bail!("tensor is not i32"),
        }
    }

    /// The single element of a scalar f32 tensor.
    pub fn item_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("expected scalar, got {} elems", d.len());
        }
        Ok(d[0])
    }

    /// Convert to an XLA literal (copies the host buffer once).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(_, d) => xla::Literal::vec1(d),
            HostTensor::I32(_, d) => xla::Literal::vec1(d),
            HostTensor::U32(_, d) => xla::Literal::vec1(d),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Read back from an XLA literal (copies once; shape from the manifest).
    pub fn from_literal(lit: &xla::Literal, shape: Vec<usize>) -> Result<HostTensor> {
        use xla::ElementType as ET;
        Ok(match lit.ty()? {
            ET::F32 => HostTensor::F32(shape, lit.to_vec::<f32>()?),
            ET::S32 => HostTensor::I32(shape, lit.to_vec::<i32>()?),
            ET::U32 => HostTensor::U32(shape, lit.to_vec::<u32>()?),
            other => bail!("unsupported output element type {other:?}"),
        })
    }
}

/// A compiled entry point, ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub spec: EntrySpec,
    pub key: String,
}

impl Executable {
    /// Execute with positional inputs; returns positional outputs.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.key,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            if t.elems() != s.elems() || t.dtype() != s.dtype {
                bail!(
                    "{}: input {i} ({}) mismatch: got {:?}/{:?}, want {:?}/{:?}",
                    self.key,
                    s.name,
                    t.shape(),
                    t.dtype(),
                    s.shape,
                    s.dtype
                );
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = tuple.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.key,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| HostTensor::from_literal(lit, spec.shape.clone()))
            .collect()
    }

    /// Zero-copy-in variant of [`Executable::run`] for hot loops: inputs are
    /// already XLA literals, outputs come back as literals (decomposed from
    /// the result tuple) without a host round-trip per tensor. The trainer
    /// keeps params/optimizer state in this form between steps — see
    /// EXPERIMENTS.md §Perf for the measured effect.
    pub fn run_literals<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        literals: &[L],
    ) -> Result<Vec<xla::Literal>> {
        if literals.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.key,
                self.spec.inputs.len(),
                literals.len()
            );
        }
        let result = self.exe.execute(literals)?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = tuple.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.key,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        Ok(parts)
    }
}

/// The process-wide runtime: PJRT CPU client + manifest + executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Engine {
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (and cache) the compiled executable for (preset, entry).
    pub fn load(&self, preset: &str, entry: &str) -> Result<std::sync::Arc<Executable>> {
        let key = format!("{preset}.{entry}");
        if let Some(e) = self.cache.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let pspec = self.manifest.preset(preset)?;
        let espec = pspec.entry(entry)?.clone();
        let path = espec
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {key}"))?;
        let handle = std::sync::Arc::new(Executable { exe, spec: espec, key: key.clone() });
        self.cache.lock().unwrap().insert(key, handle.clone());
        Ok(handle)
    }

    /// Initialize a preset's parameters by running its `init` graph.
    pub fn init_params(&self, preset: &str, seed: i32) -> Result<Vec<HostTensor>> {
        let init = self.load(preset, "init")?;
        init.run(&[HostTensor::scalar_i32(seed)])
    }
}

#[cfg(test)]
mod tests {
    //! These tests require `make artifacts` (the core set) to have run.
    use super::*;

    fn engine() -> Option<Engine> {
        if !std::path::Path::new(crate::ARTIFACTS_DIR).join("manifest.json").exists() {
            eprintln!("skipping runtime test: artifacts/ missing");
            return None;
        }
        Some(Engine::new(crate::ARTIFACTS_DIR).expect("engine"))
    }

    #[test]
    fn init_and_forward_quickstart() {
        let Some(eng) = engine() else { return };
        let params = eng.init_params("quickstart_zeta", 0).unwrap();
        let pspec = eng.manifest.preset("quickstart_zeta").unwrap();
        assert_eq!(params.len(), pspec.params.len());
        // compare a randomly-initialized tensor (biases are zeros for any seed)
        let embed_idx = pspec.params.iter().position(|p| p.name == "embed").unwrap();
        // deterministic init
        let params2 = eng.init_params("quickstart_zeta", 0).unwrap();
        assert_eq!(
            params[embed_idx].as_f32().unwrap(),
            params2[embed_idx].as_f32().unwrap()
        );
        // different seed -> different params
        let params3 = eng.init_params("quickstart_zeta", 1).unwrap();
        assert_ne!(
            params[embed_idx].as_f32().unwrap(),
            params3[embed_idx].as_f32().unwrap()
        );

        let fwd = eng.load("quickstart_zeta", "forward").unwrap();
        let b = pspec.batch;
        let n = pspec.seq_len();
        let mut inputs =
            vec![HostTensor::I32(vec![b, n], vec![1; b * n])];
        inputs.extend(params.clone());
        let out = fwd.run(&inputs).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[b, n, pspec.vocab()]);
        assert!(out[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn executable_cache_dedupes() {
        let Some(eng) = engine() else { return };
        let a = eng.load("quickstart_zeta", "init").unwrap();
        let b = eng.load("quickstart_zeta", "init").unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn input_validation_rejects_bad_shapes() {
        let Some(eng) = engine() else { return };
        let fwd = eng.load("quickstart_zeta", "forward").unwrap();
        assert!(fwd.run(&[]).is_err());
        let bad = vec![HostTensor::I32(vec![1], vec![0])];
        assert!(fwd.run(&bad).is_err());
    }
}
