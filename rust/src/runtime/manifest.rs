//! AOT manifest reader: `artifacts/manifest.json`.
//!
//! The manifest is written by `python/compile/aot.py` and is the contract
//! between the build-time Python world and the run-time Rust world: for
//! every preset it records the model config, the parameter-tree flattening
//! order and, per entry point, the exact positional input/output specs of
//! the lowered HLO module. The Rust side never guesses a shape.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "float32" => DType::F32,
            "int32" => DType::I32,
            "uint32" => DType::U32,
            _ => bail!("unsupported dtype {s:?}"),
        })
    }

    pub fn size(self) -> usize {
        4
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let name = j.get("name").as_str().unwrap_or("").to_string();
        let shape = j
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(j.get("dtype").as_str().ok_or_else(|| anyhow!("missing dtype"))?)?;
        Ok(TensorSpec { name, shape, dtype })
    }
}

#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone)]
pub struct PresetSpec {
    pub name: String,
    pub config: Json,
    pub batch: usize,
    pub lr: f64,
    pub param_count: usize,
    /// Flattening order of the parameter pytree.
    pub params: Vec<TensorSpec>,
    pub entries: BTreeMap<String, EntrySpec>,
}

impl PresetSpec {
    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("preset {} has no entry {name:?}", self.name))
    }

    pub fn seq_len(&self) -> usize {
        self.config.get("seq_len").as_usize().unwrap_or(0)
    }

    pub fn vocab(&self) -> usize {
        self.config.get("vocab").as_usize().unwrap_or(0)
    }

    pub fn is_lm(&self) -> bool {
        self.config.get("task").as_str() == Some("lm")
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub presets: BTreeMap<String, PresetSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = crate::util::json::parse(&text).map_err(|e| anyhow!("parse manifest: {e}"))?;
        let obj = root.as_obj().ok_or_else(|| anyhow!("manifest root must be an object"))?;
        let mut presets = BTreeMap::new();
        for (name, pj) in obj {
            let mut entries = BTreeMap::new();
            if let Some(eo) = pj.get("entries").as_obj() {
                for (ename, ej) in eo {
                    let file = dir.join(
                        ej.get("file").as_str().ok_or_else(|| anyhow!("entry missing file"))?,
                    );
                    let inputs = ej
                        .get("inputs")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<Vec<_>>>()?;
                    let outputs = ej
                        .get("outputs")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<Vec<_>>>()?;
                    entries.insert(ename.clone(), EntrySpec { file, inputs, outputs });
                }
            }
            let params = pj
                .get("params")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            presets.insert(
                name.clone(),
                PresetSpec {
                    name: name.clone(),
                    config: pj.get("config").clone(),
                    batch: pj.get("batch").as_usize().unwrap_or(0),
                    lr: pj.get("lr").as_f64().unwrap_or(0.0),
                    param_count: pj.get("param_count").as_usize().unwrap_or(0),
                    params,
                    entries,
                },
            );
        }
        Ok(Manifest { dir, presets })
    }

    pub fn preset(&self, name: &str) -> Result<&PresetSpec> {
        self.presets.get(name).ok_or_else(|| {
            anyhow!(
                "preset {name:?} not in manifest ({} presets available — \
                 experiment sweeps need `make artifacts-full`)",
                self.presets.len()
            )
        })
    }

    /// Preset names matching a prefix (used by sweep harnesses).
    pub fn matching(&self, prefix: &str) -> Vec<&PresetSpec> {
        self.presets
            .values()
            .filter(|p| p.name.starts_with(prefix))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest() -> String {
        r#"{
          "demo": {
            "config": {"task": "lm", "seq_len": 8, "vocab": 16},
            "batch": 2, "lr": 0.001, "param_count": 10,
            "params": [{"name": "embed", "shape": [16, 4], "dtype": "float32"}],
            "entries": {
              "forward": {
                "file": "demo.forward.hlo.txt",
                "inputs": [{"name": "x", "shape": [2, 8], "dtype": "int32"}],
                "outputs": [{"shape": [2, 8, 16], "dtype": "float32"}]
              }
            }
          }
        }"#
        .to_string()
    }

    #[test]
    fn parses_fake_manifest() {
        let dir = std::env::temp_dir().join(format!("zeta_mtest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), fake_manifest()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let p = m.preset("demo").unwrap();
        assert_eq!(p.batch, 2);
        assert_eq!(p.seq_len(), 8);
        assert!(p.is_lm());
        let e = p.entry("forward").unwrap();
        assert_eq!(e.inputs[0].dtype, DType::I32);
        assert_eq!(e.outputs[0].elems(), 2 * 8 * 16);
        assert!(p.entry("missing").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("float32").unwrap(), DType::F32);
        assert!(DType::parse("float64").is_err());
    }
}
