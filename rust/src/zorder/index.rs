//! Persistent sorted Z-order index — the L1 substrate of the incremental
//! decode engine.
//!
//! [`ZIndex`] maintains the Morton codes of all keys seen so far in sorted
//! order under *append-only* growth, so the per-token serving path never
//! re-sorts the whole key set:
//!
//! * `append(code)` — amortized O(log N): the index stores O(log N) sorted
//!   runs with binary-counter sizes (the classic logarithmic method /
//!   Bentley–Saxe transform). Each append creates a singleton run and
//!   merges equal-size runs; every element takes part in at most log2(N)
//!   merges over the index's lifetime.
//! * `window_with(code, w)` — O(w·log N·log w): the exact `w`-wide
//!   candidate window around `code`'s insertion rank in the *global* sorted
//!   order, assembled from per-run neighbourhoods.
//!
//! ## Exact equivalence with `argsort_codes`
//!
//! The global order is `(code, position)` lexicographic — identical to the
//! stable LSD radix sort in [`super::argsort_codes`], which orders equal
//! codes by insertion index. Every query helper here is defined against
//! that order, so a window taken from a `ZIndex` after `n` appends is
//! bit-for-bit the window a full rebuild + radix sort would produce on the
//! same prefix. The property tests below pin this at every prefix length,
//! and the ZETA kernel relies on it: batched prefill (`forward`) and
//! incremental decode (`decode_step`) share one selection routine over this
//! structure.

use std::sync::Arc;

/// One index entry: `(morton code, original append position)`.
pub type Entry = (u32, u32);

/// Append-only sorted index over Morton codes (sorted-runs design). Runs
/// are refcounted (`Arc`), so [`ZIndex::fork`] snapshots the whole index
/// in O(log N) pointer clones: a forked ZETA decode state shares its
/// sorted runs with the original up to the fork point instead of
/// re-sorting the prefix. Runs are immutable once built — appends only
/// ever *read* existing runs while merging into fresh ones — so sharing
/// never changes any query result.
#[derive(Debug, Default, Clone)]
pub struct ZIndex {
    /// Sorted runs, sizes forming a binary counter (largest first); each
    /// run is ascending in `(code, pos)`.
    runs: Vec<Arc<Vec<Entry>>>,
    len: usize,
}

/// Reusable scratch buffers for [`ZIndex::window_with`], so the per-token
/// hot path allocates nothing after warm-up.
#[derive(Debug, Default)]
pub struct WindowScratch {
    below: Vec<Entry>,
    above: Vec<Entry>,
}

impl WindowScratch {
    /// Bytes currently held by the scratch buffers (memory accounting).
    pub fn bytes(&self) -> usize {
        (self.below.capacity() + self.above.capacity()) * std::mem::size_of::<Entry>()
    }
}

fn merge_runs(a: &[Entry], b: &[Entry]) -> Vec<Entry> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        // Positions are unique, so `(code, pos)` is a strict total order.
        if a[i] <= b[j] {
            out.push(a[i]);
            i += 1;
        } else {
            out.push(b[j]);
            j += 1;
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
    out
}

impl ZIndex {
    pub fn new() -> ZIndex {
        ZIndex::default()
    }

    /// Build an index by appending every code in order.
    pub fn from_codes(codes: &[u32]) -> ZIndex {
        let mut ix = ZIndex::new();
        for &c in codes {
            ix.append(c);
        }
        ix
    }

    /// Number of entries appended so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of sorted runs currently held (≤ log2(len) + 1).
    pub fn run_count(&self) -> usize {
        self.runs.len()
    }

    /// Bytes held by the run storage (memory accounting).
    pub fn bytes(&self) -> usize {
        self.runs
            .iter()
            .map(|r| r.capacity() * std::mem::size_of::<Entry>())
            .sum()
    }

    /// Append the next key's Morton code; its position is the append index.
    /// Amortized O(log N): merges equal-size runs binary-counter style.
    /// Merging *reads* the popped runs and builds a fresh one, so runs
    /// shared with a fork are left untouched (the fork keeps its snapshot).
    pub fn append(&mut self, code: u32) {
        assert!(self.len < u32::MAX as usize, "ZIndex position overflow");
        let pos = self.len as u32;
        self.len += 1;
        let mut run = vec![(code, pos)];
        while let Some(top) = self.runs.last() {
            if top.len() > run.len() {
                break;
            }
            let top = self.runs.pop().expect("non-empty checked above");
            run = merge_runs(&top, &run);
        }
        self.runs.push(Arc::new(run));
    }

    /// O(log N) snapshot: the fork shares every run with the original;
    /// both sides append independently afterwards. Equivalent to a deep
    /// `clone()` in every observable way (runs are immutable), without
    /// copying the sorted prefix.
    pub fn fork(&self) -> ZIndex {
        self.clone()
    }

    /// Global insertion rank of `code`: the number of entries whose code is
    /// strictly smaller (equal codes sort *after* the probe, matching
    /// `partition_point(|c| c < code)` on the fully sorted array).
    pub fn rank(&self, code: u32) -> usize {
        self.runs
            .iter()
            .map(|run| run.partition_point(|&(c, _)| c < code))
            .sum()
    }

    /// The exact candidate window of the fully sorted array: with
    /// `ins = rank(code)` and `half = window / 2`, returns the entries at
    /// global sorted ranks `[ins - half, ins + half)` (clamped to the array
    /// bounds), in ascending `(code, pos)` order — byte-identical to
    /// slicing a full `argsort_codes` rebuild of the same code sequence.
    pub fn window_with(
        &self,
        code: u32,
        window: usize,
        scratch: &mut WindowScratch,
        out: &mut Vec<Entry>,
    ) {
        out.clear();
        if self.len == 0 || window == 0 {
            return;
        }
        let half = window / 2;
        scratch.below.clear();
        scratch.above.clear();
        let mut ins = 0usize;
        for run in &self.runs {
            let p = run.partition_point(|&(c, _)| c < code);
            ins += p;
            // Any global-window entry below the rank must be among its own
            // run's `half` entries nearest the partition point (fewer than
            // `half` entries separate it from the boundary globally, hence
            // within its run too). Same argument above the rank.
            scratch.below.extend_from_slice(&run[p.saturating_sub(half)..p]);
            scratch.above.extend_from_slice(&run[p..(p + half).min(run.len())]);
        }
        scratch.below.sort_unstable();
        scratch.above.sort_unstable();
        let take_below = half.min(ins);
        let take_above = half.min(self.len - ins);
        out.extend_from_slice(&scratch.below[scratch.below.len() - take_below..]);
        out.extend_from_slice(&scratch.above[..take_above]);
    }

    /// Allocating convenience wrapper around [`ZIndex::window_with`].
    pub fn window(&self, code: u32, window: usize) -> Vec<Entry> {
        let mut scratch = WindowScratch::default();
        let mut out = Vec::new();
        self.window_with(code, window, &mut scratch, &mut out);
        out
    }

    /// Materialize the full sorted view (k-way merge of the runs). O(N log N)
    /// worst case via repeated two-way merges — test/diagnostic use only;
    /// the hot paths never need it.
    pub fn sorted_entries(&self) -> Vec<Entry> {
        let mut acc: Vec<Entry> = Vec::new();
        for run in self.runs.iter().rev() {
            acc = merge_runs(&acc, run.as_slice());
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::zorder::argsort_codes;

    /// Reference: the fully sorted `(code, pos)` array via the stable radix
    /// argsort (the rebuild the index must be indistinguishable from).
    fn ref_sorted(codes: &[u32]) -> Vec<Entry> {
        argsort_codes(codes)
            .into_iter()
            .map(|p| (codes[p as usize], p))
            .collect()
    }

    /// Reference window on the fully sorted array — mirrors the ZETA
    /// kernel's `lo..hi` slice semantics exactly.
    fn ref_window(sorted: &[Entry], probe: u32, window: usize) -> Vec<Entry> {
        if window == 0 {
            return Vec::new();
        }
        let ins = sorted.partition_point(|&(c, _)| c < probe);
        let half = window / 2;
        let lo = ins.saturating_sub(half);
        let hi = (ins + half).min(sorted.len());
        sorted[lo..hi].to_vec()
    }

    #[test]
    fn empty_and_singleton() {
        let mut ix = ZIndex::new();
        assert!(ix.is_empty());
        assert_eq!(ix.window(5, 8), vec![]);
        ix.append(7);
        assert_eq!(ix.len(), 1);
        assert_eq!(ix.sorted_entries(), vec![(7, 0)]);
        assert_eq!(ix.window(7, 8), vec![(7, 0)]); // equal code sits above the rank
        assert_eq!(ix.window(9, 8), vec![(7, 0)]);
        assert_eq!(ix.rank(7), 0);
        assert_eq!(ix.rank(8), 1);
    }

    #[test]
    fn window_wider_than_index_returns_everything() {
        let codes = [9u32, 3, 7, 3, 1];
        let ix = ZIndex::from_codes(&codes);
        assert_eq!(ix.window(4, 100), ref_sorted(&codes));
    }

    #[test]
    fn duplicate_codes_keep_append_order() {
        // All-equal codes: sorted order must be pure position order (the
        // stability contract that matches the radix argsort).
        let codes = [5u32; 9];
        let ix = ZIndex::from_codes(&codes);
        let want: Vec<Entry> = (0..9).map(|p| (5, p as u32)).collect();
        assert_eq!(ix.sorted_entries(), want);
        assert_eq!(ix.rank(5), 0);
        assert_eq!(ix.rank(6), 9);
    }

    #[test]
    fn run_sizes_stay_logarithmic() {
        let mut ix = ZIndex::new();
        for i in 0..1000u32 {
            ix.append(i.wrapping_mul(2654435761) & 0x7FFF_FFFF);
            let n = ix.len();
            let cap = (usize::BITS - n.leading_zeros()) as usize; // floor(log2)+1
            assert!(ix.run_count() <= cap, "n={n}: {} runs", ix.run_count());
        }
    }

    #[test]
    fn sorted_entries_match_argsort_rebuild() {
        prop::check(40, 0x21DE1, |rng| {
            let n = 1 + rng.usize_below(400);
            // dup-heavy range so stability is actually exercised
            let codes: Vec<u32> = (0..n).map(|_| rng.next_u32() % 97).collect();
            let ix = ZIndex::from_codes(&codes);
            prop::assert_eq_prop(&ix.sorted_entries(), &ref_sorted(&codes))
        });
    }

    #[test]
    fn interleaved_appends_match_full_rebuild_at_every_prefix() {
        // The decode-engine contract: after every single append, candidate
        // windows from the persistent index are identical to windows over a
        // full argsort_codes rebuild of the same prefix.
        prop::check(15, 0x21DE2, |rng| {
            let n = 2 + rng.usize_below(160);
            let dup_heavy = rng.below(2) == 0;
            let codes: Vec<u32> = (0..n)
                .map(|_| {
                    if dup_heavy {
                        rng.next_u32() % 31
                    } else {
                        rng.next_u32() & 0x7FFF_FFFF
                    }
                })
                .collect();
            let mut ix = ZIndex::new();
            let mut scratch = WindowScratch::default();
            let mut got = Vec::new();
            for l in 1..=n {
                ix.append(codes[l - 1]);
                let sorted = ref_sorted(&codes[..l]);
                for w in [1usize, 2, 7, 16, 64] {
                    // probe an existing code, a neighbour, and a random one
                    let probes = [
                        codes[rng.usize_below(l)],
                        codes[rng.usize_below(l)].wrapping_add(1),
                        rng.next_u32() & 0x7FFF_FFFF,
                    ];
                    for probe in probes {
                        ix.window_with(probe, w, &mut scratch, &mut got);
                        let want = ref_window(&sorted, probe, w);
                        if got != want {
                            return Err(format!(
                                "prefix {l} w {w} probe {probe}: {got:?} != {want:?}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fork_shares_runs_and_diverges_independently() {
        prop::check(15, 0x21DE4, |rng| {
            let n = 8 + rng.usize_below(200);
            let split = 1 + rng.usize_below(n - 1);
            let codes: Vec<u32> = (0..n).map(|_| rng.next_u32() % 101).collect();
            let mut a = ZIndex::from_codes(&codes[..split]);
            let mut b = a.fork();
            // the snapshot is literal sharing: every run is the same
            // allocation (refcount bump, no copied prefix)
            if a.runs.len() != b.runs.len()
                || !a.runs.iter().zip(&b.runs).all(|(x, y)| Arc::ptr_eq(x, y))
            {
                return Err("fork did not share run storage".into());
            }
            // diverge: a continues with the real tail, b with a shifted one
            for &c in &codes[split..] {
                a.append(c);
            }
            let tail_b: Vec<u32> = codes[split..].iter().map(|c| c ^ 0x55).collect();
            for &c in &tail_b {
                b.append(c);
            }
            // each side is indistinguishable from a fresh rebuild of its
            // own full sequence
            prop::assert_eq_prop(&a.sorted_entries(), &ref_sorted(&codes))?;
            let seq_b: Vec<u32> =
                codes[..split].iter().copied().chain(tail_b.iter().copied()).collect();
            prop::assert_eq_prop(&b.sorted_entries(), &ref_sorted(&seq_b))?;
            // and windows still match the reference on the forked side
            let sorted_b = ref_sorted(&seq_b);
            let mut scratch = WindowScratch::default();
            let mut got = Vec::new();
            for probe in [codes[0], codes[split - 1].wrapping_add(1), 7] {
                b.window_with(probe, 16, &mut scratch, &mut got);
                prop::assert_eq_prop(&got, &ref_window(&sorted_b, probe, 16))?;
            }
            Ok(())
        });
    }

    #[test]
    fn boundary_snapshots_answer_windows_like_the_live_prefix() {
        // The pipelined prefill contract (PR 7): a `fork()` frozen at every
        // chunk edge must answer every window byte-identically to the live
        // index at the same prefix length — i.e. to a full rebuild of that
        // prefix — even as the original keeps appending far past the
        // snapshot.
        prop::check(12, 0x21DE5, |rng| {
            let chunk = [4usize, 8, 16, 32][rng.usize_below(4)];
            let n = chunk + 1 + rng.usize_below(300);
            let dup_heavy = rng.below(2) == 0;
            let codes: Vec<u32> = (0..n)
                .map(|_| {
                    if dup_heavy {
                        rng.next_u32() % 31
                    } else {
                        rng.next_u32() & 0x7FFF_FFFF
                    }
                })
                .collect();
            let mut live = ZIndex::new();
            let mut snaps: Vec<(usize, ZIndex)> = Vec::new();
            for (t, &c) in codes.iter().enumerate() {
                live.append(c);
                if (t + 1) % chunk == 0 {
                    snaps.push((t + 1, live.fork()));
                }
            }
            let mut scratch = WindowScratch::default();
            let mut got = Vec::new();
            for (prefix, snap) in &snaps {
                let sorted = ref_sorted(&codes[..*prefix]);
                prop::assert_eq_prop(&snap.sorted_entries(), &sorted)?;
                for w in [1usize, 2, 8, 64] {
                    let probes = [
                        codes[rng.usize_below(*prefix)],
                        codes[rng.usize_below(*prefix)].wrapping_add(1),
                        rng.next_u32() & 0x7FFF_FFFF,
                    ];
                    for probe in probes {
                        snap.window_with(probe, w, &mut scratch, &mut got);
                        let want = ref_window(&sorted, probe, w);
                        if got != want {
                            return Err(format!(
                                "chunk {chunk} prefix {prefix} w {w} probe {probe}: \
                                 {got:?} != {want:?}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn concurrent_draft_forks_share_runs_safely_under_append_storm() {
        use crate::util::rng::Rng;
        // Speculative decoding's aliasing pattern: the target session's
        // index keeps appending on one thread while many draft forks,
        // snapshotted from it, append divergent tails and answer windows
        // on other threads — every side reading (and merging out of) the
        // same Arc'd runs concurrently. Each side must stay bit-identical
        // to a fresh rebuild of its own sequence: immutable runs +
        // refcounts make this safe, and this test storms that claim.
        let mut rng = Rng::new(0x21DE6);
        let base_n = 300 + rng.usize_below(200);
        let base: Vec<u32> = (0..base_n).map(|_| rng.next_u32() % 257).collect();
        let target = ZIndex::from_codes(&base);
        std::thread::scope(|scope| {
            let mut joins = Vec::new();
            for t in 0..8u64 {
                let fork = target.fork();
                let base = &base;
                joins.push(scope.spawn(move || {
                    let mut rng = Rng::new(0x21DE6 ^ (t + 1));
                    let mut ix = fork;
                    let mut seq: Vec<u32> = base.clone();
                    let mut scratch = WindowScratch::default();
                    let mut got = Vec::new();
                    for _ in 0..400 {
                        // Per-thread disjoint code bands force deep merges
                        // against the shared sorted prefix runs.
                        let c = rng.next_u32() % 257 + (t as u32 + 1) * 1000;
                        ix.append(c);
                        seq.push(c);
                        // Interleave queries so reads alias the shared
                        // runs while sibling threads merge around them.
                        let probe = seq[rng.usize_below(seq.len())];
                        ix.window_with(probe, 16, &mut scratch, &mut got);
                        let want = ref_window(&ref_sorted(&seq), probe, 16);
                        assert_eq!(got, want, "thread {t}: window diverged mid-storm");
                    }
                    (ix.sorted_entries(), seq)
                }));
            }
            // The target keeps appending concurrently with all its forks.
            let mut target = target;
            let mut seq = base.clone();
            let mut trng = Rng::new(0x21DE6 ^ 0xFF);
            for _ in 0..400 {
                let c = trng.next_u32() % 257;
                target.append(c);
                seq.push(c);
            }
            assert_eq!(target.sorted_entries(), ref_sorted(&seq), "target perturbed by forks");
            for j in joins {
                let (entries, seq) = j.join().expect("fork thread panicked");
                assert_eq!(entries, ref_sorted(&seq), "fork diverged from its own rebuild");
            }
        });
    }

    #[test]
    fn rank_matches_partition_point() {
        prop::check(30, 0x21DE3, |rng| {
            let n = 1 + rng.usize_below(200);
            let codes: Vec<u32> = (0..n).map(|_| rng.next_u32() % 64).collect();
            let ix = ZIndex::from_codes(&codes);
            let sorted = ref_sorted(&codes);
            for probe in 0..65u32 {
                let want = sorted.partition_point(|&(c, _)| c < probe);
                if ix.rank(probe) != want {
                    return Err(format!("probe {probe}: {} != {want}", ix.rank(probe)));
                }
            }
            Ok(())
        });
    }
}
