//! Z-order (Morton) curve codec — Rust substrate.
//!
//! Mirror of python/compile/zorder.py, used on the Rust side by
//!   * the Fig-3 locality study (`exp fig3`, `benches/fig3_locality.rs`),
//!   * the Rust-native ZETA kernel (Table 3/4 benchmarks),
//!   * the persistent sorted index behind the incremental decode engine
//!     ([`index::ZIndex`]),
//!   * property tests that cross-check the JAX implementation's conventions
//!     (bit b of coordinate j lands at output position b*d + j).

pub mod index;
pub mod knn;

/// Bits per coordinate so the interleaved code fits in 31 bits (matches the
/// Python side, which must stay uint32-safe inside HLO).
pub fn bits_for_dim(d: usize) -> u32 {
    assert!(d >= 1, "dimension must be >= 1");
    (31 / d).clamp(1, 10) as u32
}

/// Quantize one float coordinate into `bits`-bit levels over [lo, hi].
#[inline]
pub fn quantize(x: f32, lo: f32, hi: f32, bits: u32) -> u32 {
    let levels = (1u32 << bits) - 1;
    let span = (hi - lo).max(1e-6);
    let u = (x - lo) / span * levels as f32;
    (u + 0.5).floor().clamp(0.0, levels as f32) as u32
}

/// Interleave the low `bits` bits of each coordinate (paper Eq. 4):
/// bit b of coordinate j lands at output position b*d + j.
///
/// Dispatched through [`crate::util::simd`]: scalar mode keeps the seed's
/// bit-by-bit loop, accelerated modes use branch-free magic-shift spreading
/// for d ≤ 3 — bit-identical on every input (integer math only, pinned by
/// property tests), so Morton codes never depend on the backend.
#[inline]
pub fn interleave(coords: &[u32], bits: u32) -> u32 {
    debug_assert!(bits as usize * coords.len() <= 31, "code exceeds 31 bits");
    crate::util::simd::interleave(coords, bits)
}

/// Inverse of `interleave`.
pub fn deinterleave(z: u32, d: usize, bits: u32) -> Vec<u32> {
    let mut coords = vec![0u32; d];
    for b in 0..bits {
        for (j, c) in coords.iter_mut().enumerate() {
            *c |= ((z >> (b as usize * d + j)) & 1) << b;
        }
    }
    coords
}

/// Morton-encode a batch of points (row-major `n x d`) over a fixed grid
/// [-range, range]^d. Returns one code per point.
pub fn encode_points(points: &[f32], d: usize, range: f32, bits: u32) -> Vec<u32> {
    encode_points_pool(points, d, range, bits, &crate::util::pool::Pool::serial())
}

/// [`encode_points`] split by point chunks over a worker pool — encoding is
/// embarrassingly parallel (one code per point, no shared state), which is
/// the first stage of the paper's "all queries searched simultaneously"
/// pipeline. `threads = 1` is exactly the serial encoder.
pub fn encode_points_pool(
    points: &[f32],
    d: usize,
    range: f32,
    bits: u32,
    pool: &crate::util::pool::Pool,
) -> Vec<u32> {
    use crate::util::pool::SharedSlice;
    assert_eq!(points.len() % d, 0);
    let n = points.len() / d;
    let mut out = vec![0u32; n];
    {
        let osh = SharedSlice::new(&mut out);
        pool.parallel_for(n, pool.grain(n, 512), |rows| {
            let mut scratch = vec![0u32; d];
            for i in rows {
                for (s, &x) in scratch.iter_mut().zip(&points[i * d..(i + 1) * d]) {
                    *s = quantize(x, -range, range, bits);
                }
                // Safety: index i claimed by exactly one chunk.
                unsafe { osh.write(i, interleave(&scratch, bits)) };
            }
        });
    }
    out
}

/// Morton-encode a single point over the fixed grid [-range, range]^d —
/// the per-token path of the decode engine. Exactly one row of
/// [`encode_points`], so incremental codes match batch-prefill codes
/// bit-for-bit.
pub fn encode_point(point: &[f32], range: f32, bits: u32) -> u32 {
    let d = point.len();
    assert!(d <= 16, "encode_point supports up to 16 dims");
    let mut coords = [0u32; 16];
    for (c, &x) in coords.iter_mut().zip(point) {
        *c = quantize(x, -range, range, bits);
    }
    interleave(&coords[..d], bits)
}

/// Morton-encode with a data-derived grid (per-dimension min/max), the
/// convention the Fig-3 locality study uses.
pub fn encode_points_fit(points: &[f32], d: usize, bits: u32) -> Vec<u32> {
    assert_eq!(points.len() % d, 0);
    let n = points.len() / d;
    let mut lo = vec![f32::INFINITY; d];
    let mut hi = vec![f32::NEG_INFINITY; d];
    for p in points.chunks_exact(d) {
        for j in 0..d {
            lo[j] = lo[j].min(p[j]);
            hi[j] = hi[j].max(p[j]);
        }
    }
    let mut out = Vec::with_capacity(n);
    let mut scratch = vec![0u32; d];
    for p in points.chunks_exact(d) {
        for j in 0..d {
            scratch[j] = quantize(p[j], lo[j], hi[j], bits);
        }
        out.push(interleave(&scratch, bits));
    }
    out
}

/// Argsort of Morton codes: permutation such that codes[perm] is ascending.
/// Radix-sorts the 32-bit codes (the O(N) sort the paper's appendix cites).
pub fn argsort_codes(codes: &[u32]) -> Vec<u32> {
    let n = codes.len();
    let mut perm: Vec<u32> = (0..n as u32).collect();
    let mut aux = vec![0u32; n];
    // 4 passes of 8-bit LSD radix sort — stable, O(N) per pass.
    for pass in 0..4 {
        let shift = pass * 8;
        let mut counts = [0usize; 256];
        for &i in perm.iter() {
            counts[((codes[i as usize] >> shift) & 0xFF) as usize] += 1;
        }
        let mut offsets = [0usize; 256];
        let mut acc = 0;
        for b in 0..256 {
            offsets[b] = acc;
            acc += counts[b];
        }
        for &i in perm.iter() {
            let b = ((codes[i as usize] >> shift) & 0xFF) as usize;
            aux[offsets[b]] = i;
            offsets[b] += 1;
        }
        std::mem::swap(&mut perm, &mut aux);
    }
    perm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn interleave_roundtrip() {
        prop::check(100, 0xA11CE, |rng| {
            let d = 1 + rng.usize_below(6);
            let bits = bits_for_dim(d);
            let coords: Vec<u32> =
                (0..d).map(|_| rng.next_u32() & ((1 << bits) - 1)).collect();
            let z = interleave(&coords, bits);
            prop::assert_eq_prop(&deinterleave(z, d, bits), &coords)
        });
    }

    #[test]
    fn interleave_matches_python_convention() {
        // bit b of coord j -> position b*d + j; cross-checked against the
        // jax implementation for (5, 3) at bits=3, d=2:
        // 5 = 101, 3 = 011 -> z = b0: 1,1 b1: 0,1 b2: 1,0 -> 0b011110 = 30... .
        let z = interleave(&[5, 3], 3);
        let mut want = 0u32;
        for b in 0..3 {
            want |= ((5 >> b) & 1) << (b * 2);
            want |= ((3 >> b) & 1) << (b * 2 + 1);
        }
        assert_eq!(z, want);
        // p0..p5 = (b0,j0)=1 (b0,j1)=1 (b1,j0)=0 (b1,j1)=1 (b2,j0)=1 (b2,j1)=0
        assert_eq!(z, 0b011011);
    }

    #[test]
    fn interleave_monotone_per_axis() {
        let bits = 5;
        for axis in 0..3 {
            let mut prev = None;
            for v in 0..(1 << bits) {
                let mut c = [7u32, 7, 7];
                c[axis] = v;
                let z = interleave(&c, bits);
                if let Some(p) = prev {
                    assert!(z > p, "axis {axis} v {v}");
                }
                prev = Some(z);
            }
        }
    }

    #[test]
    fn quantize_bounds() {
        assert_eq!(quantize(-10.0, -1.0, 1.0, 4), 0);
        assert_eq!(quantize(10.0, -1.0, 1.0, 4), 15);
        assert_eq!(quantize(0.0, -1.0, 1.0, 4), 8); // rounds up at midpoint
    }

    #[test]
    fn argsort_sorts() {
        prop::check(50, 0xB0B, |rng| {
            let n = 1 + rng.usize_below(500);
            let codes: Vec<u32> = (0..n).map(|_| rng.next_u32() & 0x7FFF_FFFF).collect();
            let perm = argsort_codes(&codes);
            // permutation property
            let mut seen = vec![false; n];
            for &p in &perm {
                assert!(!seen[p as usize]);
                seen[p as usize] = true;
            }
            for w in perm.windows(2) {
                assert!(codes[w[0] as usize] <= codes[w[1] as usize]);
            }
            Ok(())
        });
    }

    #[test]
    fn encode_point_matches_batch_rows() {
        let mut rng = Rng::new(0x0E0E);
        let d = 3;
        let mut pts = vec![0f32; 97 * d];
        rng.fill_normal(&mut pts, 1.5);
        let bits = bits_for_dim(d);
        let batch = encode_points(&pts, d, 4.0, bits);
        for (i, row) in pts.chunks_exact(d).enumerate() {
            assert_eq!(encode_point(row, 4.0, bits), batch[i], "row {i}");
        }
    }

    #[test]
    fn encode_points_pool_matches_serial() {
        let mut rng = Rng::new(0xE0C0);
        let d = 3;
        let mut pts = vec![0f32; 513 * d];
        rng.fill_normal(&mut pts, 1.0);
        let bits = bits_for_dim(d);
        let serial = encode_points(&pts, d, 4.0, bits);
        let par = encode_points_pool(&pts, d, 4.0, bits, &crate::util::pool::Pool::new(4));
        assert_eq!(serial, par);
    }

    #[test]
    fn argsort_is_stable() {
        let codes = vec![5u32, 1, 5, 1, 5];
        let perm = argsort_codes(&codes);
        assert_eq!(perm, vec![1, 3, 0, 2, 4]);
    }

    #[test]
    fn encode_points_locality() {
        // Near points share long code prefixes more often than far points.
        let mut rng = Rng::new(0);
        let n = 256;
        let d = 3;
        let mut pts = vec![0f32; n * d];
        rng.fill_normal(&mut pts, 1.0);
        let codes = encode_points(&pts, d, 4.0, bits_for_dim(d));
        // for each point, z-distance to its euclidean-nearest neighbour
        // should on average be far smaller than to a random point.
        let mut near_sum = 0f64;
        let mut rand_sum = 0f64;
        for i in 0..n {
            let pi = &pts[i * d..(i + 1) * d];
            let mut best = (f32::INFINITY, 0);
            for j in 0..n {
                if j == i {
                    continue;
                }
                let dd = crate::tensor::sqdist(pi, &pts[j * d..(j + 1) * d]);
                if dd < best.0 {
                    best = (dd, j);
                }
            }
            let r = (i + 97) % n;
            near_sum += (codes[i] as i64 - codes[best.1] as i64).unsigned_abs() as f64;
            rand_sum += (codes[i] as i64 - codes[r] as i64).unsigned_abs() as f64;
        }
        assert!(near_sum < 0.5 * rand_sum, "near {near_sum} rand {rand_sum}");
    }
}
