//! Exact kNN + Z-order locality metrics — substrate for the Fig-3 study.
//!
//! The paper's Figure 3 measures how well the Z-order projection preserves
//! locality: for each point, the overlap between its top-k Euclidean
//! neighbours (before projection) and its top-k neighbours along the
//! 1-D Morton code (after projection), as a function of d_K and N.

use crate::tensor::sqdist;

/// Indices of the k nearest neighbours of point `i` under Euclidean
/// distance (brute force, excludes `i` itself).
pub fn exact_knn(points: &[f32], d: usize, i: usize, k: usize) -> Vec<usize> {
    let n = points.len() / d;
    let pi = &points[i * d..(i + 1) * d];
    let mut dists: Vec<(f32, usize)> = (0..n)
        .filter(|&j| j != i)
        .map(|j| (sqdist(pi, &points[j * d..(j + 1) * d]), j))
        .collect();
    let k = k.min(dists.len());
    dists.select_nth_unstable_by(k - 1, |a, b| a.partial_cmp(b).unwrap());
    let mut out: Vec<usize> = dists[..k].iter().map(|&(_, j)| j).collect();
    out.sort_unstable();
    out
}

/// Indices of the k nearest neighbours of point `i` along the Morton codes
/// (|code_j - code_i|, excludes `i`).
pub fn zorder_knn(codes: &[u32], i: usize, k: usize) -> Vec<usize> {
    let ci = codes[i] as i64;
    let mut dists: Vec<(i64, usize)> = codes
        .iter()
        .enumerate()
        .filter(|&(j, _)| j != i)
        .map(|(j, &c)| ((c as i64 - ci).abs(), j))
        .collect();
    let k = k.min(dists.len());
    dists.select_nth_unstable_by(k - 1, |a, b| a.cmp(b));
    let mut out: Vec<usize> = dists[..k].iter().map(|&(_, j)| j).collect();
    out.sort_unstable();
    out
}

/// Mean top-k neighbour overlap over all points: |exact ∩ zorder| / k,
/// averaged. This is the y-axis of Figure 3.
pub fn mean_topk_overlap(points: &[f32], d: usize, codes: &[u32], k: usize) -> f64 {
    let n = points.len() / d;
    assert_eq!(codes.len(), n);
    let mut total = 0.0;
    for i in 0..n {
        let a = exact_knn(points, d, i, k);
        let b = zorder_knn(codes, i, k);
        // both sorted — linear intersection
        let (mut x, mut y, mut hits) = (0, 0, 0usize);
        while x < a.len() && y < b.len() {
            match a[x].cmp(&b[y]) {
                std::cmp::Ordering::Less => x += 1,
                std::cmp::Ordering::Greater => y += 1,
                std::cmp::Ordering::Equal => {
                    hits += 1;
                    x += 1;
                    y += 1;
                }
            }
        }
        total += hits as f64 / k as f64;
    }
    total / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::zorder;

    #[test]
    fn exact_knn_on_line() {
        // points at x = 0, 1, 2, 3, 4 (d = 1)
        let pts = [0.0f32, 1.0, 2.0, 3.0, 4.0];
        assert_eq!(exact_knn(&pts, 1, 2, 2), vec![1, 3]);
        assert_eq!(exact_knn(&pts, 1, 0, 2), vec![1, 2]);
    }

    #[test]
    fn zorder_knn_on_codes() {
        let codes = [10u32, 11, 12, 100, 101];
        assert_eq!(zorder_knn(&codes, 0, 2), vec![1, 2]);
        assert_eq!(zorder_knn(&codes, 4, 1), vec![3]);
    }

    #[test]
    fn overlap_is_one_in_1d() {
        // In d=1 the Morton code *is* the (quantized) coordinate, so with
        // well-separated points the overlap must be exactly 1.
        let pts: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let codes = zorder::encode_points_fit(&pts, 1, 10);
        let ov = mean_topk_overlap(&pts, 1, &codes, 3);
        assert!(ov > 0.99, "overlap {ov}");
    }

    #[test]
    fn overlap_decreases_with_dimension() {
        // The trend behind Fig. 3: higher d_K -> worse locality preservation.
        let mut rng = Rng::new(42);
        let n = 192;
        let mut prev = f64::INFINITY;
        for &d in &[2usize, 8, 16] {
            let mut pts = vec![0f32; n * d];
            rng.fill_normal(&mut pts, 1.0);
            let codes = zorder::encode_points_fit(&pts, d, zorder::bits_for_dim(d));
            let ov = mean_topk_overlap(&pts, d, &codes, 16);
            assert!(ov < prev + 0.05, "d={d}: {ov} !< {prev}");
            prev = ov;
        }
    }

    #[test]
    fn overlap_beats_random_at_low_dim() {
        let mut rng = Rng::new(7);
        let n = 128;
        let d = 3;
        let mut pts = vec![0f32; n * d];
        rng.fill_normal(&mut pts, 1.0);
        let codes = zorder::encode_points_fit(&pts, d, 10);
        let ov = mean_topk_overlap(&pts, d, &codes, 8);
        // random baseline would be k/(n-1) ≈ 0.06
        assert!(ov > 0.2, "overlap {ov}");
    }
}
