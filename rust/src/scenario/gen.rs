//! The five seeded trace generators.
//!
//! All prompts draw from the native model's default 32-token vocabulary:
//! filler tokens occupy `1..=23`, needle/signature alphabets `24..=30`,
//! and `31` is the query marker — so a planted needle is structurally
//! distinct from filler, exactly like the S-NIAH signature 4-grams.
//! Every generator records the reference answer stream (serial decode on
//! the trace's model) for requests that run to completion, which is what
//! lets replays score correctness, not just throughput.

use anyhow::Result;

use super::{reference_stream, GenCfg, Scenario, Trace, TraceRequest};
use crate::coordinator::{NativeDecodeModel, NativeModelConfig};
use crate::util::rng::Rng;

/// Highest filler token (filler = `1..=FILLER_TOP`).
const FILLER_TOP: u64 = 23;
/// Needle/signature alphabet: `NEEDLE_BASE..NEEDLE_BASE+NEEDLE_SPAN`.
const NEEDLE_BASE: u64 = 24;
const NEEDLE_SPAN: u64 = 7;
/// Query marker separating context from the re-stated needle.
const QUERY_MARK: i32 = 31;

fn filler(rng: &mut Rng, n: usize) -> Vec<i32> {
    (0..n).map(|_| 1 + rng.below(FILLER_TOP) as i32).collect()
}

fn needle_gram(rng: &mut Rng, n: usize) -> Vec<i32> {
    (0..n).map(|_| (NEEDLE_BASE + rng.below(NEEDLE_SPAN)) as i32).collect()
}

/// The model reference streams are recorded against: the same defaults
/// the replay drivers use (`kv_quant` stays f32 — quantized replays are
/// tolerance-gated elsewhere, not stream-pinned here).
fn trace_model(kernel: &str) -> Result<NativeDecodeModel> {
    NativeDecodeModel::new(NativeModelConfig { kernel: kernel.into(), ..Default::default() })
}

/// Fill in the reference streams for every request without a cancel
/// point, in id order (generation-time record half of record/replay).
fn record_expect(trace: &mut Trace) -> Result<()> {
    let model = trace_model(&trace.kernel)?;
    for r in trace.requests.iter_mut() {
        if r.cancel_at_us.is_none() && r.cancel_after_tokens.is_none() {
            r.expect = Some(reference_stream(&model, &r.prompt, r.max_new));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// needle — long-context retrieval (S-NIAH format)
// ---------------------------------------------------------------------------

pub struct Needle;

impl Scenario for Needle {
    fn name(&self) -> &'static str {
        "needle"
    }

    fn description(&self) -> &'static str {
        "long-context needle retrieval: a signature 4-gram planted at a \
         seeded depth in filler, re-stated as the query suffix"
    }

    fn expected_requests(&self, cfg: &GenCfg) -> usize {
        cfg.requests
    }

    fn generate(&self, cfg: &GenCfg) -> Result<Trace> {
        let mut rng = Rng::new(cfg.seed ^ 0x5EED_0001);
        let mut requests = Vec::with_capacity(cfg.requests);
        let mut arrival = 0u64;
        for i in 0..cfg.requests {
            // Context lengths spread over [ctx/2, ctx] so replays exercise
            // staggered prefill completion, not one synchronized wave.
            let len = (cfg.ctx / 2).max(16) + rng.usize_below(cfg.ctx / 2 + 1);
            let sig = needle_gram(&mut rng, 4);
            let mut prompt = filler(&mut rng, len);
            let depth = rng.usize_below(len.saturating_sub(4).max(1));
            prompt[depth..depth + 4].copy_from_slice(&sig);
            prompt.push(QUERY_MARK);
            prompt.extend_from_slice(&sig);
            arrival += 300 + rng.below(1200);
            requests.push(TraceRequest {
                id: format!("needle-{i:03}"),
                arrival_us: arrival,
                prompt,
                max_new: 8,
                cancel_at_us: None,
                cancel_after_tokens: None,
                needle: Some(sig),
                expect: None,
            });
        }
        let mut trace =
            Trace { name: "needle".into(), seed: cfg.seed, kernel: cfg.kernel.clone(), requests };
        record_expect(&mut trace)?;
        Ok(trace)
    }
}

// ---------------------------------------------------------------------------
// fleet — shared-system-prompt agent fleet (prefix-cache stress)
// ---------------------------------------------------------------------------

pub struct Fleet;

/// Agents per arrival wave.
const FLEET_WAVE: usize = 4;

impl Scenario for Fleet {
    fn name(&self) -> &'static str {
        "fleet"
    }

    fn description(&self) -> &'static str {
        "agent fleet sharing one long system prompt, arriving in waves: \
         later waves must fork the cached prompt prefix, not re-prefill it"
    }

    fn expected_requests(&self, cfg: &GenCfg) -> usize {
        cfg.requests
    }

    fn generate(&self, cfg: &GenCfg) -> Result<Trace> {
        let mut rng = Rng::new(cfg.seed ^ 0x5EED_0002);
        // Page-aligned system prompt (the prefix cache snapshots at whole
        // pages), at least two pages so a hit skips real work.
        let page = NativeModelConfig::default().kv_page;
        let sys_len = (cfg.ctx.max(2 * page) / page) * page;
        let sys = filler(&mut rng, sys_len);
        let mut requests = Vec::with_capacity(cfg.requests);
        for i in 0..cfg.requests {
            let wave = i / FLEET_WAVE;
            let mut prompt = sys.clone();
            prompt.push(QUERY_MARK);
            prompt.extend(filler(&mut rng, 8 + rng.usize_below(24)));
            requests.push(TraceRequest {
                id: format!("fleet-{i:03}"),
                arrival_us: wave as u64 * 4_000,
                prompt,
                max_new: 8,
                cancel_at_us: None,
                cancel_after_tokens: None,
                needle: None,
                expect: None,
            });
        }
        let mut trace =
            Trace { name: "fleet".into(), seed: cfg.seed, kernel: cfg.kernel.clone(), requests };
        record_expect(&mut trace)?;
        Ok(trace)
    }
}

// ---------------------------------------------------------------------------
// chat — bursty multi-turn conversations (eviction / re-prefill stress)
// ---------------------------------------------------------------------------

pub struct Chat;

const CHAT_TURNS: usize = 3;

impl Scenario for Chat {
    fn name(&self) -> &'static str {
        "chat"
    }

    fn description(&self) -> &'static str {
        "bursty multi-turn chat: each follow-up prompt extends the prior \
         turn's full context (prompt + recorded answer), so growing \
         sessions contend for KV memory and re-prefill after eviction"
    }

    fn expected_requests(&self, cfg: &GenCfg) -> usize {
        (cfg.requests / CHAT_TURNS).max(2) * CHAT_TURNS
    }

    fn generate(&self, cfg: &GenCfg) -> Result<Trace> {
        let mut rng = Rng::new(cfg.seed ^ 0x5EED_0003);
        let model = trace_model(&cfg.kernel)?;
        let convs = (cfg.requests / CHAT_TURNS).max(2);
        let max_new = 12;
        let mut requests = Vec::with_capacity(convs * CHAT_TURNS);
        // Conversation contexts: turn t+1's prompt = turn t's prompt + the
        // recorded answer + fresh user tokens. Turns arrive in per-turn
        // bursts (all conversations "reply at once"), with think-time gaps
        // between turns — the bursty arrival pattern eviction hates.
        let mut contexts: Vec<Vec<i32>> = (0..convs)
            .map(|_| filler(&mut rng, cfg.ctx / 4 + rng.usize_below(cfg.ctx / 4 + 1)))
            .collect();
        for turn in 0..CHAT_TURNS {
            let turn_t0 = turn as u64 * 25_000;
            for (c, ctx) in contexts.iter_mut().enumerate() {
                if turn > 0 {
                    // The user's follow-up, appended to the prior full
                    // context (which already ends with the model's answer).
                    ctx.push(QUERY_MARK);
                    ctx.extend((0..8 + rng.usize_below(8)).map(|_| 1 + rng.below(FILLER_TOP) as i32));
                }
                let prompt = ctx.clone();
                let answer = reference_stream(&model, &prompt, max_new);
                ctx.extend_from_slice(&answer);
                // All conversations reply at once (no sub-sweep jitter):
                // the whole turn burst parks before one admission pass, so
                // a tight budget sees concurrent growth, not a serialized
                // trickle it can admit one session at a time.
                requests.push(TraceRequest {
                    id: format!("chat-{c:02}-t{turn}"),
                    arrival_us: turn_t0,
                    prompt,
                    max_new,
                    cancel_at_us: None,
                    cancel_after_tokens: None,
                    needle: None,
                    expect: Some(answer),
                });
            }
        }
        requests.sort_by(|a, b| a.arrival_us.cmp(&b.arrival_us).then(a.id.cmp(&b.id)));
        Ok(Trace { name: "chat".into(), seed: cfg.seed, kernel: cfg.kernel.clone(), requests })
    }
}

// ---------------------------------------------------------------------------
// storm — cancellation storms (mid-prefill + mid-decode drops)
// ---------------------------------------------------------------------------

pub struct Storm;

/// Requests per arrival burst.
const STORM_BURST: usize = 32;
/// Request-count multiplier over the base `GenCfg::requests`.
const STORM_SCALE: usize = 4;

impl Scenario for Storm {
    fn name(&self) -> &'static str {
        "storm"
    }

    fn description(&self) -> &'static str {
        "cancellation storm: tight request bursts where a third cancels \
         mid-prefill (virtual-time drops), a third mid-decode (token-count \
         drops), and a third runs to completion"
    }

    fn expected_requests(&self, cfg: &GenCfg) -> usize {
        cfg.requests * STORM_SCALE
    }

    fn generate(&self, cfg: &GenCfg) -> Result<Trace> {
        let mut rng = Rng::new(cfg.seed ^ 0x5EED_0004);
        let total = cfg.requests * STORM_SCALE;
        let max_new = 6;
        let mut requests = Vec::with_capacity(total);
        for i in 0..total {
            let burst = (i / STORM_BURST) as u64;
            let arrival = burst * 2_000;
            let len = (cfg.ctx / 2).max(8) + rng.usize_below(cfg.ctx / 2 + 1);
            let prompt = filler(&mut rng, len);
            // rng draws happen for every branch so the request shapes stay
            // stable if the kind split ever changes.
            let prefill_delay = 1 + rng.below(3);
            let decode_point = 1 + rng.usize_below(max_new - 1);
            let (cancel_at_us, cancel_after_tokens) = match i % 3 {
                0 => (Some(arrival + prefill_delay * 1_000), None),
                1 => (None, Some(decode_point)),
                _ => (None, None),
            };
            requests.push(TraceRequest {
                id: format!("storm-{i:04}"),
                arrival_us: arrival,
                prompt,
                max_new,
                cancel_at_us,
                cancel_after_tokens,
                needle: None,
                expect: None,
            });
        }
        let mut trace =
            Trace { name: "storm".into(), seed: cfg.seed, kernel: cfg.kernel.clone(), requests };
        record_expect(&mut trace)?;
        Ok(trace)
    }
}

// ---------------------------------------------------------------------------
// spec — templated repetitive traffic (speculative-decode acceptance)
// ---------------------------------------------------------------------------

pub struct Spec;

/// Tiling period of every spec prompt, in tokens.
pub const SPEC_PERIOD: usize = 8;

impl Scenario for Spec {
    fn name(&self) -> &'static str {
        "spec"
    }

    fn description(&self) -> &'static str {
        "templated repetitive traffic: each prompt tiles one seeded \
         8-token template, so greedy continuations are locally predictable \
         and speculative drafters see high acceptance"
    }

    fn expected_requests(&self, cfg: &GenCfg) -> usize {
        cfg.requests
    }

    fn generate(&self, cfg: &GenCfg) -> Result<Trace> {
        let mut rng = Rng::new(cfg.seed ^ 0x5EED_0005);
        let max_new = 16;
        let mut requests = Vec::with_capacity(cfg.requests);
        let mut arrival = 0u64;
        for i in 0..cfg.requests {
            // One template per request: repetition *inside* a prompt is
            // what makes its continuation predictable; across requests the
            // templates differ so a drafter cannot overfit one stream.
            let template = filler(&mut rng, SPEC_PERIOD);
            let len = (cfg.ctx / 2).max(2 * SPEC_PERIOD) + rng.usize_below(cfg.ctx / 2 + 1);
            let prompt: Vec<i32> = template.iter().copied().cycle().take(len).collect();
            // Tight stagger: the fleet reaches steady-state decode quickly,
            // which is the regime the speculative verify waves batch over.
            arrival += 200 + rng.below(400);
            requests.push(TraceRequest {
                id: format!("spec-{i:03}"),
                arrival_us: arrival,
                prompt,
                max_new,
                cancel_at_us: None,
                cancel_after_tokens: None,
                needle: None,
                expect: None,
            });
        }
        let mut trace =
            Trace { name: "spec".into(), seed: cfg.seed, kernel: cfg.kernel.clone(), requests };
        record_expect(&mut trace)?;
        Ok(trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{by_name, contains_subseq, scenarios};

    fn small() -> GenCfg {
        GenCfg { seed: 7, kernel: "zeta".into(), requests: 6, ctx: 64 }
    }

    #[test]
    fn generators_are_seed_deterministic_and_sized() {
        let cfg = small();
        for s in scenarios() {
            let a = s.generate(&cfg).unwrap();
            let b = s.generate(&cfg).unwrap();
            assert_eq!(a.to_jsonl(), b.to_jsonl(), "{} not reproducible", s.name());
            assert_eq!(a.requests.len(), s.expected_requests(&cfg), "{}", s.name());
            let other = s.generate(&GenCfg { seed: 8, ..cfg.clone() }).unwrap();
            assert_ne!(a.to_jsonl(), other.to_jsonl(), "{} ignores the seed", s.name());
            // Arrival order is the replay admission order.
            assert!(
                a.requests.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us),
                "{} arrivals unsorted",
                s.name()
            );
        }
    }

    #[test]
    fn needle_prompts_plant_and_restate_the_signature() {
        let t = Needle.generate(&small()).unwrap();
        for r in &t.requests {
            let sig = r.needle.as_ref().unwrap();
            let body = &r.prompt[..r.prompt.len() - 5];
            assert!(contains_subseq(body, sig), "{}: needle not planted", r.id);
            assert_eq!(&r.prompt[r.prompt.len() - 4..], &sig[..], "{}: query missing", r.id);
            assert!(r.expect.as_ref().is_some_and(|e| e.len() == r.max_new), "{}", r.id);
        }
    }

    #[test]
    fn fleet_shares_a_page_aligned_system_prompt() {
        let t = Fleet.generate(&small()).unwrap();
        let page = NativeModelConfig::default().kv_page;
        let sys_len = t.requests[0].prompt.iter().position(|&x| x == QUERY_MARK).unwrap();
        assert_eq!(sys_len % page, 0, "system prompt must be page-aligned");
        let sys = &t.requests[0].prompt[..sys_len];
        for r in &t.requests {
            assert_eq!(&r.prompt[..sys_len], sys, "{}: system prompt differs", r.id);
        }
    }

    #[test]
    fn chat_follow_ups_extend_the_prior_turn_context() {
        let t = Chat.generate(&small()).unwrap();
        let find = |id: &str| t.requests.iter().find(|r| r.id == id).unwrap();
        let t0 = find("chat-00-t0");
        let t1 = find("chat-00-t1");
        let prior = [t0.prompt.clone(), t0.expect.clone().unwrap()].concat();
        assert_eq!(&t1.prompt[..prior.len()], &prior[..], "turn 1 must extend turn 0 + answer");
        assert!(t1.prompt.len() > prior.len(), "turn 1 adds user tokens");
    }

    #[test]
    fn spec_prompts_tile_one_template_per_request() {
        let t = Spec.generate(&small()).unwrap();
        for r in &t.requests {
            assert!(r.prompt.len() >= 2 * SPEC_PERIOD, "{}: too short to repeat", r.id);
            for (i, &tok) in r.prompt.iter().enumerate().skip(SPEC_PERIOD) {
                assert_eq!(tok, r.prompt[i - SPEC_PERIOD], "{}: tiling broken at {i}", r.id);
            }
            assert!(
                r.cancel_at_us.is_none() && r.cancel_after_tokens.is_none(),
                "{}: spec traffic never cancels",
                r.id
            );
            assert!(r.expect.as_ref().is_some_and(|e| e.len() == r.max_new), "{}", r.id);
        }
    }

    #[test]
    fn storm_mixes_prefill_decode_and_clean_requests() {
        let t = Storm.generate(&small()).unwrap();
        let prefill = t.requests.iter().filter(|r| r.cancel_at_us.is_some()).count();
        let decode = t.requests.iter().filter(|r| r.cancel_after_tokens.is_some()).count();
        let clean = t.requests.iter().filter(|r| r.expect.is_some()).count();
        assert!(prefill > 0 && decode > 0 && clean > 0, "{prefill}/{decode}/{clean}");
        assert_eq!(prefill + decode + clean, t.requests.len());
    }
}
