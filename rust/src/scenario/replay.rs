//! Trace replay drivers + scoring.
//!
//! Two drivers share one outcome shape:
//!
//! * [`lockstep`] — the deterministic replay. It owns a
//!   [`NativeServing`] directly and advances a *virtual clock*: sweep
//!   `t` is virtual time `t * sweep_us`, arrivals admit when the clock
//!   passes them, timed cancellations flip their session's cancel flag
//!   between sweeps, and token-count cancellations fire after the sweep
//!   that delivered the k-th token. Every scheduling decision is a pure
//!   function of the trace, so token streams *and* counters
//!   (prefix hits, evictions, peak active, token accounting) are
//!   bit-identical for a fixed trace at any thread count — the property
//!   `rust/tests/scenario_gate.rs` pins across threads {1,4,8}.
//! * [`serve`] — the end-to-end replay through the real [`Server`]:
//!   requests are submitted via `ClientHandle::generate` at their
//!   (wall-clock) arrival offsets, one collector thread per stream, and
//!   cancellations *drop the `GenStream`* exactly like a vanished client.
//!   This is where tokens/s and TTFT p50/p99 are real; cancellation
//!   outcomes are racy by nature, so only invariants (all sessions
//!   retire, token accounting balances, the arena drains after
//!   shutdown) are gated, not exact streams.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::{contains_subseq, Trace};
use crate::attention::speculate::DraftSource;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::session::StepScratch;
use crate::coordinator::{
    NativeDecodeModel, NativeModelConfig, NativeServing, RecvTimeout, Server, ServerConfig,
    Session, StreamEvent,
};
use crate::util::json::Json;
use crate::util::pool::Pool;

/// Replay knobs (the serving configuration a trace runs against).
#[derive(Debug, Clone)]
pub struct ReplayCfg {
    /// Worker-pool size (0 = the process-global pool).
    pub threads: usize,
    /// `--kv-mem-budget` byte cap over the page arena (0 = unlimited).
    pub kv_mem_budget: usize,
    /// Global per-sweep prefill-token budget (0 = unlimited).
    pub prefill_budget: usize,
    /// Round-robin prefill grant size, in prompt tokens.
    pub prefill_chunk: usize,
    /// KV page codec (`f32` keeps replays stream-pinned to the trace).
    pub kv_quant: String,
    /// Virtual microseconds one lockstep sweep represents (arrival and
    /// cancel times quantize to this).
    pub sweep_us: u64,
    /// Speculative-decode draft source (`--speculate`: off | mamba |
    /// self). Accepted streams are bit-identical to `"off"`, so a trace's
    /// recorded `expect` streams stay valid under any source.
    pub speculate: String,
    /// Tokens proposed per draft-then-verify wave (`--draft-len`, >= 1).
    pub draft_len: usize,
}

impl Default for ReplayCfg {
    fn default() -> Self {
        let s = ServerConfig::default();
        ReplayCfg {
            threads: 0,
            kv_mem_budget: 0,
            prefill_budget: s.prefill_budget,
            prefill_chunk: s.prefill_chunk,
            kv_quant: "f32".into(),
            sweep_us: 1_000,
            speculate: s.speculate,
            draft_len: s.draft_len,
        }
    }
}

/// One request's replayed stream, in trace order.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamOutcome {
    pub id: String,
    pub tokens: Vec<i32>,
    /// Stream ended with a `Done` event.
    pub done: bool,
    /// The replay cancelled this request (dropped its stream).
    pub cancelled: bool,
}

/// The deterministic counter tuple a lockstep replay must reproduce
/// exactly across thread counts.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Counters {
    pub completed: u64,
    pub delivered: u64,
    pub dropped: u64,
    pub stepped: u64,
    pub prefix_hits: u64,
    pub evictions: u64,
    pub peak_active: usize,
    /// Tokens proposed by the draft source (0 when `--speculate off`).
    pub drafted: u64,
    /// Drafted tokens the verify wave accepted.
    pub accepted: u64,
    /// Persistent drafter contexts dropped by budget pressure.
    pub draft_sheds: u64,
}

impl Counters {
    pub fn from_metrics(m: &Metrics) -> Counters {
        Counters {
            completed: m.completed,
            delivered: m.tokens,
            dropped: m.dropped_tokens,
            stepped: m.stepped_tokens,
            prefix_hits: m.prefix_hits,
            evictions: m.evictions,
            peak_active: m.peak_active_sessions,
            drafted: m.drafted_tokens,
            accepted: m.accepted_tokens,
            draft_sheds: m.draft_sheds,
        }
    }

    /// `emitted + dropped == stepped` — no token un-accounted for.
    pub fn balanced(&self) -> bool {
        self.delivered + self.dropped == self.stepped
    }
}

/// Full result of one replay (either driver).
pub struct ReplayOutcome {
    pub mode: &'static str,
    pub threads: usize,
    /// Per-request streams, in trace request order.
    pub streams: Vec<StreamOutcome>,
    pub counters: Counters,
    /// Lockstep sweeps executed (0 for `serve`).
    pub sweeps: u64,
    /// Arena pages live at end of replay, serving state still up (the
    /// prefix cache legitimately holds pages here).
    pub live_pages_end: usize,
    /// Arena pages live after the serving state is torn down — must be 0
    /// or pages leaked.
    pub live_pages_after_teardown: usize,
    pub ttft_p50: Option<Duration>,
    pub ttft_p99: Option<Duration>,
    pub tok_per_sec: f64,
    pub wall: Duration,
}

impl ReplayOutcome {
    /// FNV-1a digest over the non-cancelled streams (id + tokens, trace
    /// order) — one u64 that pins every delivered token of a replay.
    pub fn stream_digest(&self) -> u64 {
        stream_digest(&self.streams)
    }
}

pub fn stream_digest(streams: &[StreamOutcome]) -> u64 {
    fn eat(h: &mut u64, b: u8) {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for s in streams.iter().filter(|s| !s.cancelled) {
        for &b in s.id.as_bytes() {
            eat(&mut h, b);
        }
        eat(&mut h, 0xff);
        for &t in &s.tokens {
            for b in t.to_le_bytes() {
                eat(&mut h, b);
            }
        }
        eat(&mut h, 0xfe);
    }
    h
}

fn native_cfg(trace: &Trace, cfg: &ReplayCfg) -> NativeModelConfig {
    NativeModelConfig {
        kernel: trace.kernel.clone(),
        kv_quant: cfg.kv_quant.clone(),
        ..Default::default()
    }
}

/// Per-request receive state shared by the lockstep drain loop.
struct Slot {
    rx: mpsc::Receiver<Result<StreamEvent>>,
    cancel: Arc<AtomicBool>,
    tokens: Vec<i32>,
    done: bool,
    cancelled: bool,
}

/// Deterministic virtual-clock replay against [`NativeServing`] sweeps.
pub fn lockstep(trace: &Trace, cfg: &ReplayCfg) -> Result<ReplayOutcome> {
    let model = NativeDecodeModel::new(native_cfg(trace, cfg))?;
    let arena = model.arena().clone();
    let mut serving = NativeServing::new(model, cfg.kv_mem_budget, cfg.prefill_chunk.max(1));
    let Some(source) = DraftSource::parse(&cfg.speculate) else {
        bail!("unknown draft source {:?} (want {})", cfg.speculate, DraftSource::ACCEPTED);
    };
    serving.set_speculation(source, cfg.draft_len.max(1));
    let pool = if cfg.threads == 0 { *Pool::global() } else { Pool::new(cfg.threads) };
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let depth = Arc::new(AtomicUsize::new(0));
    let mut scratch = StepScratch::default();
    let wall_t0 = Instant::now();
    let sweep_us = cfg.sweep_us.max(1);

    // Admission order: by arrival, ties by trace position (generators
    // already emit sorted traces; replays must not depend on it).
    let mut order: Vec<usize> = (0..trace.requests.len()).collect();
    order.sort_by_key(|&i| (trace.requests[i].arrival_us, i));

    let mut slots: Vec<Option<Slot>> = (0..trace.requests.len()).map(|_| None).collect();
    let mut sessions: Vec<Session> = Vec::new();
    let mut next = 0usize;
    let mut tick: u64 = 0;
    let mut sweeps: u64 = 0;
    loop {
        let now_us = tick.saturating_mul(sweep_us);
        // Admit everything whose arrival the virtual clock has passed.
        while next < order.len() {
            let ri = order[next];
            let r = &trace.requests[ri];
            if r.arrival_us > now_us {
                break;
            }
            let (tx, rx) = mpsc::channel();
            let cancel = Arc::new(AtomicBool::new(false));
            depth.fetch_add(1, Ordering::Relaxed);
            sessions.push(Session::new(
                r.prompt.clone(),
                r.max_new,
                Instant::now(),
                tx,
                None,
                cancel.clone(),
            ));
            slots[ri] =
                Some(Slot { rx, cancel, tokens: Vec::new(), done: false, cancelled: false });
            next += 1;
        }
        // Timed cancellations flip deterministically between sweeps, so
        // the next sweep's `retire_cancelled` pass sees them first.
        for (ri, r) in trace.requests.iter().enumerate() {
            if let (Some(at), Some(slot)) = (r.cancel_at_us, slots[ri].as_mut()) {
                if !slot.cancelled && !slot.done && now_us >= at {
                    slot.cancel.store(true, Ordering::Relaxed);
                    slot.cancelled = true;
                }
            }
        }
        if !sessions.is_empty() {
            serving.sweep(&mut sessions, &metrics, &depth, &mut scratch, &pool, cfg.prefill_budget);
            sweeps += 1;
            if sweeps > 10_000_000 {
                bail!("lockstep replay of {:?} did not converge", trace.name);
            }
        }
        // Drain streams; token-count cancellations fire after the sweep
        // that delivered the k-th token (deterministic: one decode token
        // per session per sweep).
        for (ri, r) in trace.requests.iter().enumerate() {
            let Some(slot) = slots[ri].as_mut() else { continue };
            while let Ok(ev) = slot.rx.try_recv() {
                match ev {
                    Ok(StreamEvent::Token { token, .. }) => {
                        slot.tokens.push(token);
                        if let Some(k) = r.cancel_after_tokens {
                            if !slot.cancelled && slot.tokens.len() >= k {
                                slot.cancel.store(true, Ordering::Relaxed);
                                slot.cancelled = true;
                            }
                        }
                    }
                    Ok(StreamEvent::Done { .. }) => slot.done = true,
                    Err(e) => bail!("request {:?} errored during lockstep replay: {e:#}", r.id),
                }
            }
        }
        if sessions.is_empty() {
            if next >= order.len() {
                break;
            }
            // Idle gap before the next arrival: fast-forward the clock
            // instead of spinning empty sweeps (deterministic either way).
            let na = trace.requests[order[next]].arrival_us;
            tick = tick.max(na.div_ceil(sweep_us));
            continue;
        }
        tick += 1;
    }

    let mut streams = Vec::with_capacity(trace.requests.len());
    for (ri, r) in trace.requests.iter().enumerate() {
        let slot = slots[ri]
            .take()
            .unwrap_or_else(|| panic!("request {:?} was never admitted", r.id));
        if !slot.done && !slot.cancelled {
            bail!("request {:?} finished neither Done nor cancelled", r.id);
        }
        streams.push(StreamOutcome {
            id: r.id.clone(),
            tokens: slot.tokens,
            done: slot.done,
            cancelled: slot.cancelled,
        });
    }
    let (counters, ttft_p50, ttft_p99, tok_per_sec) = {
        let m = metrics.lock().unwrap();
        (
            Counters::from_metrics(&m),
            m.ttft_percentile(50.0),
            m.ttft_percentile(99.0),
            m.tokens_per_sec(),
        )
    };
    let live_pages_end = arena.stats().live_pages;
    drop(serving); // tears down the prefix cache + model state
    let live_pages_after_teardown = arena.stats().live_pages;
    Ok(ReplayOutcome {
        mode: "lockstep",
        threads: cfg.threads,
        streams,
        counters,
        sweeps,
        live_pages_end,
        live_pages_after_teardown,
        ttft_p50,
        ttft_p99,
        tok_per_sec,
        wall: wall_t0.elapsed(),
    })
}

/// End-to-end replay through the real coordinator: arrivals are
/// wall-clock offsets, cancellations drop the client's [`GenStream`].
pub fn serve(trace: &Trace, cfg: &ReplayCfg) -> Result<ReplayOutcome> {
    let scfg = ServerConfig {
        native: Some(native_cfg(trace, cfg)),
        max_delay: Duration::from_millis(1),
        queue_cap: trace.requests.len() + 8,
        threads: cfg.threads,
        prefill_budget: cfg.prefill_budget,
        prefill_chunk: cfg.prefill_chunk.max(1),
        kv_mem_budget: cfg.kv_mem_budget,
        speculate: cfg.speculate.clone(),
        draft_len: cfg.draft_len.max(1),
        ..Default::default()
    };
    let srv = Server::start(scfg, None)?;
    let metrics = srv.metrics.clone();
    let arena = srv
        .kv_arena()
        .cloned()
        .expect("native server always exposes its KV arena");
    let client = srv.client();
    let wall_t0 = Instant::now();

    let mut order: Vec<usize> = (0..trace.requests.len()).collect();
    order.sort_by_key(|&i| (trace.requests[i].arrival_us, i));

    struct Collected {
        tokens: Vec<i32>,
        done: bool,
        cancelled: bool,
        err: Option<String>,
    }
    let mut joins: Vec<(usize, std::thread::JoinHandle<Collected>)> = Vec::new();
    for &ri in &order {
        let r = &trace.requests[ri];
        let due = Duration::from_micros(r.arrival_us);
        let elapsed = wall_t0.elapsed();
        if due > elapsed {
            std::thread::sleep(due - elapsed);
        }
        let stream = client.generate(r.prompt.clone(), r.max_new)?;
        let deadline = r.cancel_at_us.map(|at| wall_t0 + Duration::from_micros(at));
        let cancel_tokens = r.cancel_after_tokens;
        joins.push((
            ri,
            std::thread::spawn(move || {
                let mut c =
                    Collected { tokens: Vec::new(), done: false, cancelled: false, err: None };
                loop {
                    let ev = match deadline {
                        Some(dl) => {
                            let now = Instant::now();
                            if now >= dl {
                                c.cancelled = true;
                                break;
                            }
                            match stream.recv_timeout(dl - now) {
                                RecvTimeout::Event(ev) => ev,
                                RecvTimeout::TimedOut => {
                                    c.cancelled = true;
                                    break;
                                }
                                RecvTimeout::Closed => break,
                            }
                        }
                        None => match stream.recv() {
                            Some(ev) => ev,
                            None => break,
                        },
                    };
                    match ev {
                        Ok(StreamEvent::Token { token, .. }) => {
                            c.tokens.push(token);
                            if let Some(k) = cancel_tokens {
                                if c.tokens.len() >= k {
                                    c.cancelled = true;
                                    break;
                                }
                            }
                        }
                        Ok(StreamEvent::Done { .. }) => {
                            c.done = true;
                            break;
                        }
                        Err(e) => {
                            c.err = Some(format!("{e:#}"));
                            break;
                        }
                    }
                }
                // Dropping the stream is the cancellation (and the normal
                // teardown): the scheduler's next sweep retires the session.
                drop(stream);
                c
            }),
        ));
    }

    let mut streams: Vec<Option<StreamOutcome>> = (0..trace.requests.len()).map(|_| None).collect();
    for (ri, j) in joins {
        let c = j.join().map_err(|_| anyhow::anyhow!("collector thread panicked"))?;
        let r = &trace.requests[ri];
        if let Some(e) = c.err {
            bail!("request {:?} errored during serve replay: {e}", r.id);
        }
        streams[ri] = Some(StreamOutcome {
            id: r.id.clone(),
            tokens: c.tokens,
            done: c.done,
            cancelled: c.cancelled,
        });
    }
    let streams: Vec<StreamOutcome> =
        streams.into_iter().map(|s| s.expect("every request collected")).collect();
    let wall = wall_t0.elapsed();
    let live_pages_end = arena.stats().live_pages;
    srv.shutdown();
    let live_pages_after_teardown = arena.stats().live_pages;
    let (counters, ttft_p50, ttft_p99, tok_per_sec) = {
        let m = metrics.lock().unwrap();
        (
            Counters::from_metrics(&m),
            m.ttft_percentile(50.0),
            m.ttft_percentile(99.0),
            m.tokens_per_sec(),
        )
    };
    Ok(ReplayOutcome {
        mode: "serve",
        threads: cfg.threads,
        streams,
        counters,
        sweeps: 0,
        live_pages_end,
        live_pages_after_teardown,
        ttft_p50,
        ttft_p99,
        tok_per_sec,
        wall,
    })
}

/// Scenario score: the deterministic quality/counter fields plus the
/// timing fields (`tok_per_sec`, TTFT, wall) that only `serve` replays
/// report meaningfully.
#[derive(Debug, Clone)]
pub struct Score {
    pub scenario: String,
    pub mode: &'static str,
    pub seed: u64,
    pub threads: usize,
    pub requests: usize,
    pub completed: u64,
    pub cancelled: usize,
    pub counters: Counters,
    /// Non-cancelled requests whose stream contains the planted needle.
    pub needle_hits: usize,
    pub needle_total: usize,
    /// Non-cancelled requests whose stream equals the recorded reference
    /// (`expect`); cancelled requests must match a prefix of it.
    pub expect_ok: usize,
    pub expect_total: usize,
    pub stream_digest: u64,
    pub tok_per_sec: f64,
    pub ttft_p50_us: u64,
    pub ttft_p99_us: u64,
    pub wall_ms: f64,
}

/// Score one replay outcome against its trace.
pub fn score(trace: &Trace, out: &ReplayOutcome) -> Score {
    let mut needle_hits = 0;
    let mut needle_total = 0;
    let mut expect_ok = 0;
    let mut expect_total = 0;
    for (r, s) in trace.requests.iter().zip(&out.streams) {
        if let Some(n) = &r.needle {
            if !s.cancelled {
                needle_total += 1;
                if contains_subseq(&s.tokens, n) {
                    needle_hits += 1;
                }
            }
        }
        if let Some(e) = &r.expect {
            expect_total += 1;
            let ok = if s.cancelled {
                s.tokens.len() <= e.len() && s.tokens[..] == e[..s.tokens.len()]
            } else {
                s.tokens[..] == e[..]
            };
            if ok {
                expect_ok += 1;
            }
        }
    }
    Score {
        scenario: trace.name.clone(),
        mode: out.mode,
        seed: trace.seed,
        threads: out.threads,
        requests: trace.requests.len(),
        completed: out.counters.completed,
        cancelled: out.streams.iter().filter(|s| s.cancelled).count(),
        counters: out.counters.clone(),
        needle_hits,
        needle_total,
        expect_ok,
        expect_total,
        stream_digest: out.stream_digest(),
        tok_per_sec: out.tok_per_sec,
        ttft_p50_us: out.ttft_p50.map(|d| d.as_micros() as u64).unwrap_or(0),
        ttft_p99_us: out.ttft_p99.map(|d| d.as_micros() as u64).unwrap_or(0),
        wall_ms: out.wall.as_secs_f64() * 1e3,
    }
}

impl Score {
    /// One `BENCH_scenarios.json` row.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scenario", Json::str(self.scenario.clone())),
            ("mode", Json::str(self.mode)),
            ("seed", Json::num(self.seed as f64)),
            ("threads", Json::num(self.threads as f64)),
            ("requests", Json::num(self.requests as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("cancelled", Json::num(self.cancelled as f64)),
            ("delivered_tokens", Json::num(self.counters.delivered as f64)),
            ("dropped_tokens", Json::num(self.counters.dropped as f64)),
            ("stepped_tokens", Json::num(self.counters.stepped as f64)),
            ("prefix_hits", Json::num(self.counters.prefix_hits as f64)),
            ("evictions", Json::num(self.counters.evictions as f64)),
            ("peak_active", Json::num(self.counters.peak_active as f64)),
            ("drafted_tokens", Json::num(self.counters.drafted as f64)),
            ("accepted_tokens", Json::num(self.counters.accepted as f64)),
            ("needle_hits", Json::num(self.needle_hits as f64)),
            ("needle_total", Json::num(self.needle_total as f64)),
            ("expect_ok", Json::num(self.expect_ok as f64)),
            ("expect_total", Json::num(self.expect_total as f64)),
            ("stream_digest", Json::str(format!("{:016x}", self.stream_digest))),
            ("tok_per_sec", Json::num(self.tok_per_sec)),
            ("ttft_p50_us", Json::num(self.ttft_p50_us as f64)),
            ("ttft_p99_us", Json::num(self.ttft_p99_us as f64)),
            ("wall_ms", Json::num(self.wall_ms)),
        ])
    }

    /// Human summary line for the experiment log.
    pub fn line(&self) -> String {
        format!(
            "{:<7} {:<9} req={:<4} done={:<4} cancel={:<4} expect={}/{} needle={}/{} \
             hits={} evict={} digest={:016x} tok/s={:.0} ttft_p50={}us",
            self.scenario,
            self.mode,
            self.requests,
            self.completed,
            self.cancelled,
            self.expect_ok,
            self.expect_total,
            self.needle_hits,
            self.needle_total,
            self.counters.prefix_hits,
            self.counters.evictions,
            self.stream_digest,
            self.tok_per_sec,
            self.ttft_p50_us,
        )
    }
}
