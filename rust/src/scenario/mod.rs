//! Serving scenario suite: seeded JSONL traces + record/replay scoring.
//!
//! Training has MQAR/LRA/WikiText; this module gives *serving* the same
//! footing. A [`Trace`] is a seeded, fully static description of one
//! serving workload — per-request arrival time (virtual microseconds),
//! prompt tokens, `max_new`, optional cancellation point (wall-clock or
//! token-count), and, where applicable, the planted needle and the
//! reference answer stream recorded at generation time. Traces serialize
//! to JSONL (one header line + one line per request, keys sorted), so a
//! fixed seed always produces a byte-identical trace file.
//!
//! Five generators ([`gen`]) cover the regimes the ROADMAP north star
//! names, following the `Dataset`-trait idiom of the S-NIAH needle suite:
//!
//! * **needle** — long-context retrieval: a signature 4-gram planted in
//!   Zipf-ish filler, re-stated as the query suffix (S-NIAH format).
//! * **fleet** — shared-system-prompt agent fleets arriving in waves
//!   (stresses the prompt-prefix cache).
//! * **chat** — bursty multi-turn conversations whose follow-up prompts
//!   extend the previous turn's full context (stresses `--kv-mem-budget`
//!   eviction and bit-identical re-prefill).
//! * **storm** — cancellation storms: bursts of requests dropped
//!   mid-prefill (virtual-time cancels) and mid-decode (token-count
//!   cancels).
//! * **spec** — templated repetitive traffic whose greedy continuations
//!   are locally predictable (the regime speculative decoding profits
//!   from; see `--speculate` and `zeta exp spec`).
//!
//! The [`replay`] module drives a trace through the serving stack two
//! ways: **lockstep** (the scheduler's [`crate::coordinator::NativeServing`]
//! sweeps under a virtual clock — token streams *and* counters are
//! bit-reproducible for a fixed seed at any thread count) and **serve**
//! (the real [`crate::coordinator::Server`] via `ClientHandle::generate`,
//! scoring wall-clock tokens/s and client-side TTFT). The tier-1 gate
//! `rust/tests/scenario_gate.rs` pins the stream-equivalence invariants;
//! `zeta exp scenarios` writes the scored trajectory to
//! `BENCH_scenarios.json`.

pub mod gen;
pub mod replay;

use anyhow::{bail, Context, Result};

use crate::coordinator::NativeDecodeModel;
use crate::util::json::{self, Json};

/// Trace schema version stamped into every header line.
pub const TRACE_VERSION: u64 = 1;

/// One request of a serving trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    /// Stable id (`needle-003`, `chat-2-t1`, …) — the unit scores key on.
    pub id: String,
    /// Arrival time in virtual microseconds from trace start.
    pub arrival_us: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// Cancel (drop the stream) once the virtual clock reaches this —
    /// arrivals deep in a burst cancel mid-prefill.
    pub cancel_at_us: Option<u64>,
    /// Cancel after this many received tokens (mid-decode cancellation).
    pub cancel_after_tokens: Option<usize>,
    /// Planted needle subsequence the answer should retrieve (S-NIAH).
    pub needle: Option<Vec<i32>>,
    /// Reference answer stream recorded at generation time by serial
    /// decode on the trace's model — any correct replay must reproduce it
    /// exactly (a prefix of it, for cancelled requests).
    pub expect: Option<Vec<i32>>,
}

impl TraceRequest {
    fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("id", Json::str(self.id.clone())),
            ("arrival_us", Json::num(self.arrival_us as f64)),
            ("prompt", tokens_json(&self.prompt)),
            ("max_new", Json::num(self.max_new as f64)),
        ];
        if let Some(t) = self.cancel_at_us {
            pairs.push(("cancel_at_us", Json::num(t as f64)));
        }
        if let Some(k) = self.cancel_after_tokens {
            pairs.push(("cancel_after_tokens", Json::num(k as f64)));
        }
        if let Some(n) = &self.needle {
            pairs.push(("needle", tokens_json(n)));
        }
        if let Some(e) = &self.expect {
            pairs.push(("expect", tokens_json(e)));
        }
        Json::obj(pairs)
    }

    fn from_json(j: &Json) -> Result<TraceRequest> {
        let id = j
            .get("id")
            .as_str()
            .context("trace request missing string \"id\"")?
            .to_string();
        let arrival_us = j
            .get("arrival_us")
            .as_usize()
            .with_context(|| format!("request {id:?}: missing \"arrival_us\""))?
            as u64;
        let prompt = tokens_from_json(j.get("prompt"))
            .with_context(|| format!("request {id:?}: bad \"prompt\""))?;
        if prompt.is_empty() {
            bail!("request {id:?}: empty prompt");
        }
        let max_new = j
            .get("max_new")
            .as_usize()
            .with_context(|| format!("request {id:?}: missing \"max_new\""))?;
        let cancel_at_us = j.get("cancel_at_us").as_usize().map(|v| v as u64);
        let cancel_after_tokens = j.get("cancel_after_tokens").as_usize();
        let needle = match j.get("needle") {
            Json::Null => None,
            v => Some(tokens_from_json(v).with_context(|| format!("request {id:?}: bad needle"))?),
        };
        let expect = match j.get("expect") {
            Json::Null => None,
            v => Some(tokens_from_json(v).with_context(|| format!("request {id:?}: bad expect"))?),
        };
        Ok(TraceRequest {
            id,
            arrival_us,
            prompt,
            max_new,
            cancel_at_us,
            cancel_after_tokens,
            needle,
            expect,
        })
    }
}

fn tokens_json(toks: &[i32]) -> Json {
    Json::Arr(toks.iter().map(|&t| Json::num(t as f64)).collect())
}

fn tokens_from_json(j: &Json) -> Result<Vec<i32>> {
    let arr = j.as_arr().context("expected a token array")?;
    arr.iter()
        .map(|v| {
            v.as_i64()
                .and_then(|n| i32::try_from(n).ok())
                .context("token must be an i32")
        })
        .collect()
}

/// A seeded serving workload: header metadata + requests sorted by arrival.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    /// Scenario name (`needle` | `fleet` | `chat` | `storm` | `spec`).
    pub name: String,
    /// Seed the generator ran with (provenance; replays re-derive nothing).
    pub seed: u64,
    /// Native kernel the reference `expect` streams were recorded against.
    pub kernel: String,
    pub requests: Vec<TraceRequest>,
}

impl Trace {
    /// Serialize to JSONL: a header object line, then one request per
    /// line. Objects serialize with sorted keys, so the same trace always
    /// produces byte-identical text (the record half of record/replay).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        let header = Json::obj(vec![
            ("trace", Json::str(self.name.clone())),
            ("seed", Json::num(self.seed as f64)),
            ("kernel", Json::str(self.kernel.clone())),
            ("version", Json::num(TRACE_VERSION as f64)),
            ("requests", Json::num(self.requests.len() as f64)),
        ]);
        out.push_str(&header.to_string());
        out.push('\n');
        for r in &self.requests {
            out.push_str(&r.to_json().to_string());
            out.push('\n');
        }
        out
    }

    pub fn from_jsonl(text: &str) -> Result<Trace> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header_line = lines.next().context("empty trace: no header line")?;
        let header = json::parse(header_line)
            .map_err(|e| anyhow::anyhow!("bad trace header: {e}"))?;
        let name = header
            .get("trace")
            .as_str()
            .context("trace header missing \"trace\" name")?
            .to_string();
        let version = header.get("version").as_usize().unwrap_or(0) as u64;
        if version != TRACE_VERSION {
            bail!("trace {name:?}: unsupported version {version} (want {TRACE_VERSION})");
        }
        let seed = header.get("seed").as_usize().context("trace header missing seed")? as u64;
        let kernel =
            header.get("kernel").as_str().context("trace header missing kernel")?.to_string();
        let mut requests = Vec::new();
        for (i, line) in lines.enumerate() {
            let j = json::parse(line)
                .map_err(|e| anyhow::anyhow!("trace line {}: {e}", i + 2))?;
            requests.push(TraceRequest::from_json(&j)?);
        }
        if let Some(n) = header.get("requests").as_usize() {
            if n != requests.len() {
                bail!("trace {name:?}: header says {n} requests, file holds {}", requests.len());
            }
        }
        Ok(Trace { name, seed, kernel, requests })
    }

    pub fn write(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_jsonl())
            .with_context(|| format!("writing trace to {path}"))
    }

    pub fn read(path: &str) -> Result<Trace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace from {path}"))?;
        Trace::from_jsonl(&text)
    }

    /// Total virtual span of the trace (last arrival / cancel time).
    pub fn span_us(&self) -> u64 {
        self.requests
            .iter()
            .map(|r| r.arrival_us.max(r.cancel_at_us.unwrap_or(0)))
            .max()
            .unwrap_or(0)
    }
}

/// Generator knobs shared by every scenario. `requests` and `ctx` are
/// *base* scales each scenario interprets (the storm multiplies the
/// request count; chat divides it into conversations).
#[derive(Debug, Clone)]
pub struct GenCfg {
    pub seed: u64,
    /// Native kernel reference streams are recorded against.
    pub kernel: String,
    /// Base request count.
    pub requests: usize,
    /// Base context length in tokens.
    pub ctx: usize,
}

impl Default for GenCfg {
    fn default() -> Self {
        GenCfg { seed: 0, kernel: "zeta".into(), requests: 16, ctx: 256 }
    }
}

/// One serving scenario: a named, described, seeded trace generator (the
/// `Dataset`-trait idiom of the S-NIAH suite applied to serving traffic).
pub trait Scenario {
    fn name(&self) -> &'static str;
    fn description(&self) -> &'static str;
    /// Requests the trace will contain at this config (pre-generation).
    fn expected_requests(&self, cfg: &GenCfg) -> usize;
    fn generate(&self, cfg: &GenCfg) -> Result<Trace>;
}

/// All scenarios, in canonical order.
pub fn scenarios() -> Vec<Box<dyn Scenario>> {
    vec![
        Box::new(gen::Needle),
        Box::new(gen::Fleet),
        Box::new(gen::Chat),
        Box::new(gen::Storm),
        Box::new(gen::Spec),
    ]
}

pub fn by_name(name: &str) -> Option<Box<dyn Scenario>> {
    scenarios().into_iter().find(|s| s.name() == name)
}

/// Serial reference decode: prompt through `step_token`, then greedy
/// continuation — the stream any correct serving schedule must reproduce
/// (honoring the model's context cap exactly like the coordinator does).
pub fn reference_stream(model: &NativeDecodeModel, prompt: &[i32], max_new: usize) -> Vec<i32> {
    let cap = model.max_context();
    let mut st = model.begin();
    let (mut orow, mut logits) = (Vec::new(), Vec::new());
    for &t in prompt {
        model.step_token(st.as_mut(), t, &mut orow, &mut logits);
    }
    let mut context = prompt.len();
    let mut out = Vec::new();
    for _ in 0..max_new {
        let t = NativeDecodeModel::argmax(&logits);
        out.push(t);
        context += 1;
        if cap > 0 && context >= cap {
            break; // the server retires the session with an early Done
        }
        if out.len() < max_new {
            model.step_token(st.as_mut(), t, &mut orow, &mut logits);
        }
    }
    out
}

/// Contiguous-subsequence search (needle scoring).
pub fn contains_subseq(hay: &[i32], needle: &[i32]) -> bool {
    !needle.is_empty() && hay.windows(needle.len()).any(|w| w == needle)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: &str) -> TraceRequest {
        TraceRequest {
            id: id.into(),
            arrival_us: 42,
            prompt: vec![1, 2, 3],
            max_new: 4,
            cancel_at_us: None,
            cancel_after_tokens: Some(2),
            needle: Some(vec![7, 8]),
            expect: None,
        }
    }

    #[test]
    fn trace_jsonl_roundtrips_and_is_deterministic() {
        let t = Trace {
            name: "needle".into(),
            seed: 9,
            kernel: "zeta".into(),
            requests: vec![req("a"), req("b")],
        };
        let text = t.to_jsonl();
        assert_eq!(text, t.to_jsonl(), "serialization must be deterministic");
        let back = Trace::from_jsonl(&text).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.to_jsonl(), text, "roundtrip must be byte-identical");
    }

    #[test]
    fn malformed_traces_error_out() {
        assert!(Trace::from_jsonl("").is_err(), "empty");
        assert!(Trace::from_jsonl("{\"trace\":\"x\"}").is_err(), "no version");
        let t = Trace { name: "n".into(), seed: 0, kernel: "zeta".into(), requests: vec![req("a")] };
        let mut text = t.to_jsonl();
        text.push_str("{\"id\":\"bad\"}\n"); // request missing required fields
        assert!(Trace::from_jsonl(&text).is_err(), "bad request line + count mismatch");
    }

    #[test]
    fn subseq_search_finds_planted_needles() {
        assert!(contains_subseq(&[1, 2, 3, 4], &[2, 3]));
        assert!(!contains_subseq(&[1, 2, 3, 4], &[3, 2]));
        assert!(!contains_subseq(&[1, 2], &[]));
    }
}
