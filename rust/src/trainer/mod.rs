//! Training orchestrator: drives the AOT `train`/`eval` graphs from Rust.
//!
//! Python never runs here — the full fwd+bwd+Adam update is one compiled
//! HLO module per preset ("train" entry, see python/compile/aot.py). The
//! trainer feeds batches from a [`crate::data::Task`] and tracks metrics.
//!
//! Hot-path note (§Perf): parameters and optimizer state stay in
//! `xla::Literal` form between steps. A step converts only the batch
//! (x, y, w) and the step counter to literals; the previous step's output
//! literals are fed straight back in. Converting the whole state to host
//! vectors and back (the obvious implementation) costs two extra copies of
//! ~3x params per step — measured in EXPERIMENTS.md §Perf.
//!
//! Also provides a tiny binary checkpoint format (`save` / `load`) so long
//! runs can resume and the serving coordinator can load trained weights.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::{Batch, Task};
use crate::runtime::{DType, Engine, HostTensor, TensorSpec};
use crate::util::rng::Rng;

pub struct Trainer<'e> {
    pub engine: &'e Engine,
    pub preset: String,
    /// Parameter / Adam-state literals, in manifest flattening order.
    params: Vec<xla::Literal>,
    m: Vec<xla::Literal>,
    v: Vec<xla::Literal>,
    shapes: Vec<TensorSpec>,
    pub step: i32,
    pub losses: Vec<(i32, f32)>,
}

#[derive(Debug, Clone, Copy)]
pub struct EvalStats {
    pub loss: f64,
    pub accuracy: f64,
    pub weight: f64,
}

impl EvalStats {
    pub fn perplexity(&self) -> f64 {
        self.loss.exp()
    }
}

fn zero_literals(shapes: &[TensorSpec]) -> Result<Vec<xla::Literal>> {
    shapes
        .iter()
        .map(|s| {
            let t = match s.dtype {
                DType::F32 => HostTensor::F32(s.shape.clone(), vec![0.0; s.elems()]),
                DType::I32 => HostTensor::I32(s.shape.clone(), vec![0; s.elems()]),
                DType::U32 => HostTensor::U32(s.shape.clone(), vec![0; s.elems()]),
            };
            t.to_literal()
        })
        .collect()
}

impl<'e> Trainer<'e> {
    /// Initialize from the preset's `init` graph.
    pub fn new(engine: &'e Engine, preset: &str, seed: i32) -> Result<Trainer<'e>> {
        let shapes = engine.manifest.preset(preset)?.params.clone();
        let init = engine.load(preset, "init")?;
        let seed_lit = HostTensor::scalar_i32(seed).to_literal()?;
        let params = init
            .run_literals(&[seed_lit])
            .with_context(|| format!("init {preset}"))?;
        let m = zero_literals(&shapes)?;
        let v = zero_literals(&shapes)?;
        Ok(Trainer {
            engine,
            preset: preset.to_string(),
            params,
            m,
            v,
            shapes,
            step: 0,
            losses: vec![],
        })
    }

    /// Current parameters as host tensors (copies; for checkpoints/serving).
    pub fn params_host(&self) -> Result<Vec<HostTensor>> {
        self.params
            .iter()
            .zip(&self.shapes)
            .map(|(l, s)| HostTensor::from_literal(l, s.shape.clone()))
            .collect()
    }

    fn batch_literals(&self, b: &Batch, lm: bool) -> Result<Vec<xla::Literal>> {
        let x = HostTensor::I32(vec![b.batch, b.seq_len], b.x.clone());
        let (y, w) = if lm {
            (
                HostTensor::I32(vec![b.batch, b.seq_len], b.y.clone()),
                HostTensor::F32(vec![b.batch, b.seq_len], b.w.clone()),
            )
        } else {
            (
                HostTensor::I32(vec![b.batch], b.y.clone()),
                HostTensor::F32(vec![b.batch], b.w.clone()),
            )
        };
        Ok(vec![x.to_literal()?, y.to_literal()?, w.to_literal()?])
    }

    /// One optimizer step; returns the batch loss.
    pub fn train_step(&mut self, batch: &Batch) -> Result<f32> {
        let exe = self.engine.load(&self.preset, "train")?;
        let lm = self.engine.manifest.preset(&self.preset)?.is_lm();
        self.step += 1;
        let n = self.params.len();

        let step_lit = HostTensor::scalar_i32(self.step).to_literal()?;
        let batch_lits = self.batch_literals(batch, lm)?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(4 + 3 * n);
        inputs.push(&step_lit);
        inputs.extend(batch_lits.iter());
        inputs.extend(self.params.iter());
        inputs.extend(self.m.iter());
        inputs.extend(self.v.iter());

        let mut out = exe.run_literals(&inputs)?;
        if out.len() != 1 + 3 * n {
            bail!("train returned {} outputs, want {}", out.len(), 1 + 3 * n);
        }
        let loss = out[0].to_vec::<f32>()?[0];
        if !loss.is_finite() {
            bail!("non-finite loss at step {}: {loss}", self.step);
        }
        // out layout: loss, params', m', v' — feed straight back next step.
        let v_new = out.split_off(1 + 2 * n);
        let m_new = out.split_off(1 + n);
        let p_new = out.split_off(1);
        self.params = p_new;
        self.m = m_new;
        self.v = v_new;
        self.losses.push((self.step, loss));
        Ok(loss)
    }

    /// Evaluate over `n_batches` sampled from `task`.
    pub fn eval(&self, task: &dyn Task, n_batches: usize, rng: &mut Rng) -> Result<EvalStats> {
        let exe = self.engine.load(&self.preset, "eval")?;
        let pspec = self.engine.manifest.preset(&self.preset)?;
        let lm = pspec.is_lm();
        let bsz = pspec.batch;
        let (mut loss_sum, mut correct, mut weight) = (0f64, 0f64, 0f64);
        for _ in 0..n_batches {
            let b = task.sample(bsz, rng);
            let batch_lits = self.batch_literals(&b, lm)?;
            let mut inputs: Vec<&xla::Literal> = batch_lits.iter().collect();
            inputs.extend(self.params.iter());
            let out = exe.run_literals(&inputs)?;
            loss_sum += out[0].to_vec::<f32>()?[0] as f64;
            correct += out[1].to_vec::<f32>()?[0] as f64;
            weight += out[2].to_vec::<f32>()?[0] as f64;
        }
        if weight == 0.0 {
            bail!("eval saw zero weight");
        }
        Ok(EvalStats { loss: loss_sum / weight, accuracy: correct / weight, weight })
    }

    /// Train for `steps` batches from `task`; returns the mean loss over
    /// the final 10% of steps.
    pub fn train_loop(
        &mut self,
        task: &dyn Task,
        steps: usize,
        rng: &mut Rng,
        mut log: impl FnMut(i32, f32),
    ) -> Result<f32> {
        let bsz = self.engine.manifest.preset(&self.preset)?.batch;
        for _ in 0..steps {
            let b = task.sample(bsz, rng);
            let loss = self.train_step(&b)?;
            log(self.step, loss);
        }
        let tail = (steps / 10).max(1);
        let recent: Vec<f32> =
            self.losses.iter().rev().take(tail).map(|&(_, l)| l).collect();
        Ok(recent.iter().sum::<f32>() / recent.len() as f32)
    }

    // -----------------------------------------------------------------
    // Checkpointing
    // -----------------------------------------------------------------

    /// Binary checkpoint: params + opt state + step.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"ZETACKPT")?;
        f.write_all(&(self.step as u32).to_le_bytes())?;
        for group in [&self.params, &self.m, &self.v] {
            f.write_all(&(group.len() as u32).to_le_bytes())?;
            for (lit, spec) in group.iter().zip(&self.shapes) {
                let t = HostTensor::from_literal(lit, spec.shape.clone())?;
                write_tensor(&mut f, &t)?;
            }
        }
        Ok(())
    }

    pub fn load(&mut self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != b"ZETACKPT" {
            bail!("bad checkpoint magic");
        }
        self.step = read_u32(&mut f)? as i32;
        let mut groups = Vec::new();
        for _ in 0..3 {
            let n = read_u32(&mut f)? as usize;
            if n != self.shapes.len() {
                bail!("checkpoint has {n} tensors, model has {}", self.shapes.len());
            }
            let mut g = Vec::with_capacity(n);
            for _ in 0..n {
                g.push(read_tensor(&mut f)?.to_literal()?);
            }
            groups.push(g);
        }
        self.v = groups.pop().unwrap();
        self.m = groups.pop().unwrap();
        self.params = groups.pop().unwrap();
        Ok(())
    }
}

fn write_tensor(f: &mut impl Write, t: &HostTensor) -> Result<()> {
    let tag: u8 = match t.dtype() {
        DType::F32 => 0,
        DType::I32 => 1,
        DType::U32 => 2,
    };
    f.write_all(&[tag])?;
    f.write_all(&(t.shape().len() as u32).to_le_bytes())?;
    for &d in t.shape() {
        f.write_all(&(d as u32).to_le_bytes())?;
    }
    match t {
        HostTensor::F32(_, d) => {
            for v in d {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        HostTensor::I32(_, d) => {
            for v in d {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        HostTensor::U32(_, d) => {
            for v in d {
                f.write_all(&v.to_le_bytes())?;
            }
        }
    }
    Ok(())
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_tensor(f: &mut impl Read) -> Result<HostTensor> {
    let mut tag = [0u8; 1];
    f.read_exact(&mut tag)?;
    let ndim = read_u32(f)? as usize;
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(read_u32(f)? as usize);
    }
    let n: usize = shape.iter().product();
    let mut raw = vec![0u8; n * 4];
    f.read_exact(&mut raw)?;
    Ok(match tag[0] {
        0 => HostTensor::F32(
            shape,
            raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        1 => HostTensor::I32(
            shape,
            raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        2 => HostTensor::U32(
            shape,
            raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect(),
        ),
        t => bail!("bad tensor tag {t}"),
    })
}

#[cfg(test)]
mod tests {
    //! Integration tests over real artifacts (skip when absent).
    use super::*;
    use crate::data::mqar::Mqar;

    fn engine() -> Option<Engine> {
        if !std::path::Path::new(crate::ARTIFACTS_DIR).join("manifest.json").exists() {
            eprintln!("skipping trainer test: artifacts/ missing");
            return None;
        }
        Some(Engine::new(crate::ARTIFACTS_DIR).expect("engine"))
    }

    fn batch_size(eng: &Engine, preset: &str) -> usize {
        eng.manifest.preset(preset).unwrap().batch
    }

    #[test]
    fn loss_decreases_on_mqar() {
        let Some(eng) = engine() else { return };
        let bsz = batch_size(&eng, "mqar_vanilla_d64");
        let mut tr = Trainer::new(&eng, "mqar_vanilla_d64", 0).unwrap();
        let task = Mqar::new(64);
        let mut rng = Rng::new(0);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..30 {
            let b = task.sample(bsz, &mut rng);
            last = tr.train_step(&b).unwrap();
            first.get_or_insert(last);
        }
        assert!(last < first.unwrap(), "no progress: {first:?} -> {last}");
    }

    #[test]
    fn eval_stats_sane() {
        let Some(eng) = engine() else { return };
        let tr = Trainer::new(&eng, "mqar_vanilla_d64", 1).unwrap();
        let task = Mqar::new(64);
        let mut rng = Rng::new(1);
        let st = tr.eval(&task, 2, &mut rng).unwrap();
        // untrained: accuracy near chance (1/31 values), loss near ln(64)
        assert!(st.accuracy < 0.3, "acc {}", st.accuracy);
        assert!(st.loss > 1.0 && st.loss < 10.0, "loss {}", st.loss);
        assert!(st.weight > 0.0);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let Some(eng) = engine() else { return };
        let bsz = batch_size(&eng, "mqar_vanilla_d64");
        let mut tr = Trainer::new(&eng, "mqar_vanilla_d64", 2).unwrap();
        let task = Mqar::new(64);
        let mut rng = Rng::new(2);
        let b = task.sample(bsz, &mut rng);
        tr.train_step(&b).unwrap();
        let dir = std::env::temp_dir().join(format!("zeta_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ckpt");
        tr.save(&path).unwrap();

        let mut tr2 = Trainer::new(&eng, "mqar_vanilla_d64", 99).unwrap();
        tr2.load(&path).unwrap();
        assert_eq!(tr2.step, tr.step);
        let p1 = tr.params_host().unwrap();
        let p2 = tr2.params_host().unwrap();
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.as_f32().ok(), b.as_f32().ok());
        }
        // both trainers continue identically
        let nb = task.sample(bsz, &mut rng);
        let l1 = tr.train_step(&nb).unwrap();
        let l2 = tr2.train_step(&nb).unwrap();
        assert!((l1 - l2).abs() < 1e-5, "{l1} vs {l2}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn params_host_shapes_match_manifest() {
        let Some(eng) = engine() else { return };
        let tr = Trainer::new(&eng, "mqar_vanilla_d64", 3).unwrap();
        let pspec = eng.manifest.preset("mqar_vanilla_d64").unwrap();
        let ps = tr.params_host().unwrap();
        assert_eq!(ps.len(), pspec.params.len());
        for (t, s) in ps.iter().zip(&pspec.params) {
            assert_eq!(t.shape(), &s.shape[..], "{}", s.name);
        }
    }
}
