//! # ZETA — Z-order curve top-k attention (ICLR 2025), full-stack reproduction
//!
//! Three-layer architecture:
//! * **Layer 1/2 (build time, Python)** — Pallas Cauchy top-k kernel + JAX
//!   model/training graphs, AOT-lowered to HLO text (`python/compile/`,
//!   `make artifacts`).
//! * **Layer 3 (this crate)** — the runtime coordinator: loads the HLO
//!   artifacts via PJRT ([`runtime`]), generates workloads ([`data`]),
//!   drives training ([`trainer`]), serves batched inference
//!   ([`coordinator`]) and regenerates every table/figure of the paper
//!   (`zeta exp …`, `rust/benches/`).
//!
//! ## Parallel execution core
//!
//! Every hot path runs on a shared **resident** worker pool
//! ([`util::pool::Pool`], sized by the `ZETA_THREADS` env var, auto-detected
//! when unset, serial at 1): worker threads park on a condvar between
//! parallel regions and are woken per region, so entering a region costs a
//! µs-scale handshake instead of a thread spawn — which is what lets the
//! small fused serving sweeps clear the [`util::breakeven`] fan-out
//! thresholds. The four native attention kernels
//! ([`attention`]) are row-parallel in the forward pass and chunk-parallel
//! in the backward pass (per-thread gradient accumulators merged after the
//! join); the ZETA pipeline additionally parallelizes Morton encoding and
//! the per-query window search ([`zorder`]); the serving coordinator's
//! scheduler uses the pool for batch padding/fan-out. [`attention`] also
//! carries a batched multi-head workload type
//! ([`attention::MultiWorkload`]) so one kernel call covers
//! `batch × heads` problems.
//!
//! ## Incremental decode engine
//!
//! Serving splits into prefill and decode. Prefill is the batched kernel
//! forward; decode runs on per-request [`attention::DecodeState`]s — for
//! ZETA a persistent sorted Z-order index ([`zorder::index::ZIndex`],
//! amortized O(log N) appends) plus windowed top-k and running
//! history-mean state, so each generated token costs O(log N + k) instead
//! of an O(N log N) re-sort. The coordinator turns `generate` requests
//! into [`coordinator::session::Session`]s and continuously batches them:
//! every sweep runs a prefill wave (round-robin `--prefill-chunk` token
//! grants across still-prefilling sessions under a *global* per-sweep
//! prefill-token budget) and a *fused decode wave* — one pool-parallel
//! [`attention::AttentionImpl::step_batch`]
//! kernel call across all ready sessions — interleaved with one-shot infer
//! batches. `rust/tests/decode_equivalence.rs` pins decode output to the
//! full-sequence forward row-for-row, `rust/tests/fused_sweep.rs` pins
//! fused sweeps to serial stepping; `zeta exp decode` prices incremental
//! vs full-recompute per token (`BENCH_decode.json`) and fused vs serial
//! multi-session sweeps (`BENCH_decode_batch.json`).
//!
//! ## Pipelined long-context prefill
//!
//! A long prompt's chunk phases used to serialize — each chunk's scoring
//! blocked the next chunk's index appends. The pipelined schedule splits
//! the true dependency: a serial front Morton-encodes and appends all
//! keys chunk-by-chunk, freezing an O(log N)-cost
//! [`zorder::index::ZIndex::fork`] snapshot at every chunk boundary, then
//! *all* (chunk, head, query) scoring fans out in one pool region, each
//! query searching its chunk's frozen snapshot. The same restructuring
//! drives [`attention::AttentionImpl::forward_with`] for ZETA and the
//! serving-side [`attention::DecodeState::prefill_run`] ingest; both are
//! bit-identical to the sequential schedule (tier-1 gate
//! `rust/tests/prefill_parallel.rs`) and gated on
//! [`util::breakeven::PARALLEL_PREFILL_SCORE_MIN_LOOKUPS`]. `zeta exp
//! prefill` prices TTFT at {4k, 16k, 64k} tokens × {1, 2, 4, 8} threads
//! (`BENCH_prefill.json`).
//!
//! ## Paged decode-state memory
//!
//! Every decode state's O(N) storage lives on a shared arena of
//! fixed-size, refcounted KV pages ([`util::arena::PageArena`],
//! `--kv-page` tokens per page): [`attention::DecodeState::fork`]
//! snapshots a stream copy-on-write (full pages and [`zorder::index::ZIndex`]
//! sorted runs shared by refcount bump, only the tail page copied), the
//! coordinator serves identical prompt prefixes from a page-aligned
//! prefix cache ([`coordinator::PrefixCache`]), and `--kv-mem-budget`
//! gates admission against the arena's live bytes with LRU preemption —
//! evicted sessions transparently re-prefill with identical output
//! tokens. `rust/tests/paged_state.rs` is the equivalence gate; `zeta
//! exp mem` prices paging overhead, prefix-cache speedup and eviction
//! thrash (`BENCH_mem.json`).
//!
//! ## Precision-polymorphic KV pages
//!
//! Pages carry a per-store element codec ([`util::arena::KvQuant`],
//! `--kv-quant f32|f16|int8`): `f32` is the bit-exact default, `f16`
//! packs two IEEE halfs per word, `int8` stores a per-row scale plus four
//! symmetric int8 lanes per word. Kernels score straight out of the
//! packed pages through the codec-aware [`util::arena::RowStore`] lane
//! ops (`dot`/`sqdist`/`axpy` `_dequant_*` in [`util::simd`]) — no
//! dequantized materialization — and byte accounting, copy-on-write
//! forking and the admission estimate all shrink with the codec, so a
//! fixed `--kv-mem-budget` admits 2–4× the sessions. Quantized decode is
//! tolerance-gated against f32 (`rust/tests/quant_state.rs`); the f32
//! path stays bitwise.
//!
//! ## SIMD kernel layer
//!
//! The f32 inner loops of every kernel — Cauchy top-k scoring, exact
//! softmax rows, flash tiled accumulation, the mamba recurrence, Morton
//! interleaving, and the dot/readout matvecs — funnel through a portable
//! lane-op layer ([`util::simd`]). One backend is picked per process at
//! first use: AVX2 (8 × f32) on x86_64, NEON (4 × f32) on aarch64, or the
//! seed-exact scalar loops (forced by `ZETA_SIMD=scalar`, the mode the
//! bitwise-determinism gates pin). Elementwise ops are bit-identical to
//! scalar on every backend; reductions block by element index with a fixed
//! lane tree, so results are alignment- and thread-count-independent and
//! stay within 1e-4 of scalar (`rust/tests/simd_equivalence.rs`). `zeta
//! exp kernels` prices each loop scalar-vs-SIMD (`BENCH_kernels.json`).
//!
//! ## Speculative decoding (session layer)
//!
//! Decode-sweep overhead is per *sweep*, not per token, so with
//! `--speculate` on each decode wave runs draft-then-verify per session:
//! a cheap [`attention::speculate::Drafter`] proposes up to `--draft-len`
//! greedy tokens — `mamba` drives a private constant-state RNN stream,
//! `self` narrows a copy-on-write [`attention::DecodeState::fork_draft`]
//! of the target's own ZETA state (`k` and window ÷ 8, shared pages and
//! index runs) — and one fused verify wave feeds `[last token, drafts…]`
//! through the real state with the exact per-token `step` arithmetic.
//! The longest matched prefix plus the wave's bonus prediction commit;
//! any rejection drops the advanced state and restores a pre-wave CoW
//! snapshot (O(1) page-drop rollback). Committed streams are therefore
//! **bit-identical to `--speculate off`** for every kernel and thread
//! count — tier-1 gate `rust/tests/spec_decode.rs`. Drafter contexts
//! live on the page arena, count against `--kv-mem-budget`, and are shed
//! first under pressure; `zeta exp spec` records the accept-rate ×
//! speedup matrix (`BENCH_spec.json`) and `zeta bench diff` compares two
//! provenance-stamped trajectories.
//!
//! ## Serving scenarios (record/replay)
//!
//! The [`scenario`] subsystem turns serving workloads into *seeded JSONL
//! traces* — per-request arrival time, prompt, `max_new`, optional
//! cancellation point, and the reference output stream recorded at
//! generation time — with five generators: long-context needle retrieval,
//! shared-system-prompt agent fleets (prefix-cache stress), bursty
//! multi-turn chat (eviction/re-prefill stress under `--kv-mem-budget`),
//! cancellation storms, and templated repetitive `spec` traffic (the
//! regime speculative drafters profit from). Two replay drivers share one
//! outcome shape: [`scenario::replay::lockstep`] advances a virtual clock
//! over direct [`coordinator::NativeServing`] sweeps, making token
//! streams *and* counters bit-reproducible across thread counts (pinned
//! by `rust/tests/scenario_gate.rs` at threads {1,4,8},
//! budget-constrained included), while [`scenario::replay::serve`]
//! replays through the real [`coordinator::Server`] for wall-clock
//! tokens/s and TTFT p50/p99. `zeta exp scenarios` scores all five into
//! `BENCH_scenarios.json`.
//!
//! Substrates implemented in-tree (offline std-only build): JSON, PRNG,
//! property tests, bench harness, worker pool ([`util`]), Morton codec +
//! persistent sorted index ([`zorder`]), native CPU attention kernels for
//! the efficiency study ([`attention`]).

pub mod attention;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod runtime;
pub mod scenario;
pub mod tensor;
pub mod trainer;
pub mod util;
pub mod zorder;

/// Default artifacts directory (relative to the repo root / CWD).
pub const ARTIFACTS_DIR: &str = "artifacts";
