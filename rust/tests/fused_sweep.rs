//! Fused-sweep equivalence gate (tier-1), the serving-level companion of
//! `decode_equivalence.rs` and `parallel_determinism.rs`:
//!
//! 1. Kernel level: `AttentionImpl::step_batch` over many live decode
//!    states — including states at *staggered* positions, as in a real
//!    mixed prefill/decode sweep — must be bit-identical to stepping each
//!    stream alone, for all four kernels across the thread matrix
//!    {1, 2, 4, 8}. Fused and serial sweeps are two schedules of one
//!    computation.
//! 2. Server level: token streams produced by the fused
//!    `native_decode_sweep` (budgeted prefill wave + one fused decode
//!    kernel call per sweep) must equal the serial full-recompute
//!    reference for every kernel, with mixed prompt lengths contending
//!    for a tight global prefill budget.
//! 3. Cancellation mid-generation (a dropped `GenStream`) must leave every
//!    other live stream's tokens exactly unchanged.

use zeta::coordinator::session::{NativeDecodeModel, NativeModelConfig};
use zeta::coordinator::{Server, ServerConfig, StreamEvent};
use zeta::util::pool::Pool;

fn native_cfg(kernel: &str, threads: usize, prefill_budget: usize) -> ServerConfig {
    ServerConfig {
        native: Some(NativeModelConfig { kernel: kernel.into(), ..Default::default() }),
        threads,
        prefill_budget,
        max_delay: std::time::Duration::from_millis(1),
        ..Default::default()
    }
}

/// Serial greedy reference: one isolated session stepped token-by-token
/// through `step_token` — exactly the pre-fusion scheduler's per-session
/// schedule, which the fused sweep must reproduce bit-for-bit. (The
/// decode-vs-forward gates in `decode_equivalence.rs` separately pin this
/// path to the full-sequence forward.)
fn reference_stream(kernel: &str, prompt: &[i32], max_new: usize) -> Vec<i32> {
    let model = NativeDecodeModel::new(NativeModelConfig {
        kernel: kernel.into(),
        ..Default::default()
    })
    .unwrap();
    let cap = NativeModelConfig::default().max_context;
    let mut st = model.begin();
    let (mut orow, mut logits) = (Vec::new(), Vec::new());
    for &t in prompt {
        model.step_token(st.as_mut(), t, &mut orow, &mut logits);
    }
    let mut context = prompt.len();
    let mut out = Vec::new();
    for _ in 0..max_new {
        let t = NativeDecodeModel::argmax(&logits);
        out.push(t);
        context += 1;
        if cap > 0 && context >= cap {
            break; // the server retires the session with an early Done
        }
        if out.len() < max_new {
            model.step_token(st.as_mut(), t, &mut orow, &mut logits);
        }
    }
    out
}

#[test]
fn kernel_step_batch_bitwise_matches_serial_at_staggered_positions() {
    use zeta::attention::{all_impls, DecodeStep, Workload};
    let (d, dv) = (16usize, 8usize);
    let n_streams = 5usize;
    for imp in all_impls() {
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            let ws: Vec<Workload> =
                (0..n_streams).map(|s| Workload::random(96, d, dv, 500 + s as u64)).collect();
            let mut fused: Vec<_> = (0..n_streams).map(|_| imp.begin_decode(d, dv)).collect();
            let mut serial: Vec<_> = (0..n_streams).map(|_| imp.begin_decode(d, dv)).collect();
            // Stagger the streams: stream s pre-ingests s*9 tokens, so the
            // fused sweep mixes early-prefill and deep-decode positions.
            let mut out = vec![0f32; dv];
            for (s, (fs, ss)) in fused.iter_mut().zip(serial.iter_mut()).enumerate() {
                for t in 0..s * 9 {
                    fs.step(ws[s].q.row(t), ws[s].k.row(t), ws[s].v.row(t), &mut out);
                    ss.step(ws[s].q.row(t), ws[s].k.row(t), ws[s].v.row(t), &mut out);
                }
            }
            let mut of = vec![0f32; n_streams * dv];
            let mut os = vec![0f32; n_streams * dv];
            for step in 0..40 {
                {
                    let mut batch: Vec<DecodeStep> = fused
                        .iter_mut()
                        .zip(of.chunks_mut(dv))
                        .enumerate()
                        .map(|(s, (st, orow))| {
                            let t = st.pos();
                            DecodeStep {
                                state: st.as_mut(),
                                q: ws[s].q.row(t),
                                k: ws[s].k.row(t),
                                v: ws[s].v.row(t),
                                out: orow,
                            }
                        })
                        .collect();
                    imp.step_batch(&mut batch, &pool);
                }
                for (s, st) in serial.iter_mut().enumerate() {
                    let t = st.pos();
                    st.step(
                        ws[s].q.row(t),
                        ws[s].k.row(t),
                        ws[s].v.row(t),
                        &mut os[s * dv..(s + 1) * dv],
                    );
                }
                assert_eq!(of, os, "{} threads={threads} step={step}", imp.name());
            }
        }
    }
}

#[test]
fn fused_paths_engage_pool_fanout_and_stay_exact() {
    // The break-evens keep toy-sized sweeps inline, so this test works at
    // serving scale (deep exact-KV contexts, vocab·dv readout) where the
    // kernel step, prefill, and readout phases all genuinely fan out to
    // the pool — and must still match per-session serial stepping exactly.
    use zeta::coordinator::session::{PrefillStep, SessionStep, StepScratch};
    let model = NativeDecodeModel::new(NativeModelConfig {
        kernel: "naive".into(),
        d: 64,
        dv: 64,
        vocab: 1024,
        seed: 0,
        max_context: 0,
        ..Default::default()
    })
    .unwrap();
    let pool = Pool::new(4);
    let n_streams = 6usize;
    let ctx = 300usize;
    let prompts: Vec<Vec<i32>> = (0..n_streams)
        .map(|s| (0..ctx).map(|t| ((t * 31 + s * 7 + 1) % 1024) as i32).collect())
        .collect();
    // Fused: parallel prefill wave, then fused decode steps.
    let mut scratch = StepScratch::default();
    let mut fused_states: Vec<_> = (0..n_streams).map(|_| model.begin()).collect();
    {
        let mut items: Vec<PrefillStep> = fused_states
            .iter_mut()
            .zip(&prompts)
            .map(|(st, p)| PrefillStep { state: st.as_mut(), tokens: p.as_slice(), emit: true })
            .collect();
        model.prefill_batch(&mut items, &mut scratch, &pool);
    }
    let mut fused_toks: Vec<Vec<i32>> = scratch.next.iter().map(|&t| vec![t]).collect();
    for _ in 0..8 {
        let mut items: Vec<SessionStep> = fused_states
            .iter_mut()
            .zip(&fused_toks)
            .map(|(st, toks)| SessionStep { state: st.as_mut(), tok: *toks.last().unwrap() })
            .collect();
        model.step_batch(&mut items, &mut scratch, &pool);
        drop(items);
        for (toks, &nx) in fused_toks.iter_mut().zip(&scratch.next) {
            toks.push(nx);
        }
    }
    // Serial reference: step_token loops per stream.
    let (mut orow, mut logits) = (Vec::new(), Vec::new());
    for (s, p) in prompts.iter().enumerate() {
        let mut st = model.begin();
        for &tok in p {
            model.step_token(st.as_mut(), tok, &mut orow, &mut logits);
        }
        let mut toks = vec![NativeDecodeModel::argmax(&logits)];
        for _ in 0..8 {
            let tok = *toks.last().unwrap();
            model.step_token(st.as_mut(), tok, &mut orow, &mut logits);
            toks.push(NativeDecodeModel::argmax(&logits));
        }
        assert_eq!(fused_toks[s], toks, "stream {s}");
    }
}

#[test]
fn fused_sweep_streams_match_serial_reference_per_kernel() {
    // Mixed prompt lengths: several prompts span multiple round-robin
    // prefill-chunk grants and, under a 48-token global prefill budget,
    // contend for the same sweep — so prefill and decode waves genuinely
    // mix while earlier sessions are already streaming tokens.
    let prompts: Vec<Vec<i32>> = vec![
        (0..70).map(|i| (i * 7 + 3) % 31).collect(),
        vec![5, 9, 13, 2, 2, 7],
        (0..45).map(|i| (i * 11 + 1) % 29).collect(),
        vec![1, 2, 3],
        (0..33).map(|i| (i * 5 + 2) % 23).collect(),
        vec![9; 12],
    ];
    let max_news = [10usize, 7, 12, 5, 9, 8];
    for kernel in ["zeta", "naive", "flash", "mamba"] {
        for threads in [1usize, 4] {
            let srv = Server::start(native_cfg(kernel, threads, 48), None).unwrap();
            let c = srv.client();
            let streams: Vec<_> = prompts
                .iter()
                .zip(&max_news)
                .map(|(p, &m)| c.generate(p.clone(), m).unwrap())
                .collect();
            let got: Vec<Vec<i32>> =
                streams.into_iter().map(|s| s.collect_tokens().unwrap()).collect();
            srv.shutdown();
            for (i, (p, &m)) in prompts.iter().zip(&max_news).enumerate() {
                let want = reference_stream(kernel, p, m);
                assert_eq!(got[i], want, "{kernel} threads={threads} session {i}");
            }
        }
    }
}

#[test]
fn mid_generation_cancellation_leaves_other_streams_exact() {
    for threads in [1usize, 4] {
        let srv = Server::start(native_cfg("zeta", threads, 0), None).unwrap();
        let c = srv.client();
        let a = c.generate(vec![3, 1, 4, 1, 5], 12).unwrap();
        let b = c.generate((0..50).map(|i| i % 17).collect(), 1_000_000).unwrap();
        let d = c.generate(vec![2, 7, 1, 8], 9).unwrap();
        // Read a couple of tokens from the doomed stream, then hang up
        // mid-generation; the scheduler retires it at the next sweep.
        let mut read = 0;
        while read < 2 {
            match b.recv() {
                Some(Ok(StreamEvent::Token { .. })) => read += 1,
                Some(Ok(StreamEvent::Done { .. })) | None => break,
                Some(Err(e)) => panic!("{e}"),
            }
        }
        drop(b);
        let got_a = a.collect_tokens().unwrap();
        let got_d = d.collect_tokens().unwrap();
        srv.shutdown();
        assert_eq!(got_a, reference_stream("zeta", &[3, 1, 4, 1, 5], 12), "threads={threads}");
        assert_eq!(got_d, reference_stream("zeta", &[2, 7, 1, 8], 9), "threads={threads}");
    }
}
