//! Scenario record/replay gate (tier-1): the serving-scenario suite's
//! deterministic contract.
//!
//! 1. Every seeded trace regenerates byte-identically, and a lockstep
//!    replay at pool sizes {1, 4, 8} produces *identical token streams
//!    and identical counters* (prefix hits, evictions, peak active,
//!    token accounting) — scheduling parallelism must be invisible.
//! 2. Replayed non-cancelled streams equal the reference streams recorded
//!    into the trace at generation time (`expect`), and cancelled streams
//!    are exact prefixes of theirs.
//! 3. A budget-constrained lockstep replay (tight `--kv-mem-budget`,
//!    real evictions) reproduces the unconstrained replay's streams
//!    bit-for-bit.
//! 4. Cancellation storm through the *real* coordinator at threads
//!    {2, 8}: hundreds of `GenStream`s dropped mid-prefill/mid-decode;
//!    every session retires, token accounting balances
//!    (emitted + dropped == stepped), and the page arena drains to zero
//!    after shutdown.

use zeta::scenario::replay::{lockstep, score, serve, ReplayCfg};
use zeta::scenario::{by_name, scenarios, GenCfg, Trace, TraceRequest};

fn small_cfg(kernel: &str, requests: usize, ctx: usize) -> GenCfg {
    GenCfg { seed: 7, kernel: kernel.into(), requests, ctx }
}

#[test]
fn traces_regenerate_byte_identically() {
    for sc in scenarios() {
        let cfg = small_cfg("zeta", 8, 96);
        let a = sc.generate(&cfg).unwrap().to_jsonl();
        let b = by_name(sc.name()).unwrap().generate(&cfg).unwrap().to_jsonl();
        assert_eq!(a, b, "{}: same seed must emit identical JSONL", sc.name());
        assert!(!a.is_empty());
    }
}

#[test]
fn lockstep_replay_is_thread_invariant_and_matches_recorded_streams() {
    for sc in scenarios() {
        let trace = sc.generate(&small_cfg("zeta", 8, 96)).unwrap();
        let base = lockstep(&trace, &ReplayCfg { threads: 1, ..ReplayCfg::default() }).unwrap();
        assert!(
            base.counters.balanced(),
            "{}: token accounting unbalanced: {:?}",
            trace.name,
            base.counters
        );
        assert_eq!(
            base.live_pages_after_teardown, 0,
            "{}: arena pages leaked after teardown",
            trace.name
        );
        let s = score(&trace, &base);
        assert_eq!(
            s.expect_ok, s.expect_total,
            "{}: replayed streams must match the recorded references ({}/{})",
            trace.name, s.expect_ok, s.expect_total
        );
        if trace.name == "needle" {
            assert!(s.needle_total > 0, "needle trace must carry needles");
            assert_eq!(
                s.needle_hits, s.needle_total,
                "needle retrieval must restate every planted signature"
            );
        }
        for threads in [4usize, 8] {
            let other =
                lockstep(&trace, &ReplayCfg { threads, ..ReplayCfg::default() }).unwrap();
            assert_eq!(
                base.streams, other.streams,
                "{}: token streams diverged between 1 and {threads} threads",
                trace.name
            );
            assert_eq!(
                base.counters, other.counters,
                "{}: counters diverged between 1 and {threads} threads",
                trace.name
            );
        }
    }
}

#[test]
fn budget_constrained_replay_reproduces_unconstrained_streams() {
    // The paged-state gate's proven eviction shape, as a trace: three
    // 100-token prompts on the exact-KV (naive) kernel arriving together
    // (all three are activated in one admission pass — live bytes lag
    // allocation) under a ~1.6-sessions byte budget, so their combined
    // growth is *guaranteed* to cross it mid-generation and force LRU
    // preemption — no seed luck involved.
    let trace = Trace {
        name: "evict".into(),
        seed: 0,
        kernel: "naive".into(),
        requests: (0..3)
            .map(|s| TraceRequest {
                id: format!("evict-{s}"),
                arrival_us: 0,
                prompt: (0..100).map(|i| ((i * 13 + s * 29 + 7) % 31) as i32).collect(),
                max_new: 20,
                cancel_at_us: None,
                cancel_after_tokens: None,
                needle: None,
                expect: None,
            })
            .collect(),
    };
    let free = lockstep(&trace, &ReplayCfg { threads: 2, ..ReplayCfg::default() }).unwrap();
    assert_eq!(free.counters.evictions, 0, "unlimited budget must never preempt");
    let tight = lockstep(
        &trace,
        &ReplayCfg { threads: 2, kv_mem_budget: 26_000, ..ReplayCfg::default() },
    )
    .unwrap();
    assert!(
        tight.counters.evictions > 0,
        "tight budget must actually preempt sessions (got {:?})",
        tight.counters
    );
    assert_eq!(
        free.streams, tight.streams,
        "preemption/re-prefill must be invisible in the token streams"
    );
    assert_eq!(free.stream_digest(), tight.stream_digest());
    assert!(tight.counters.balanced());
    assert_eq!(tight.live_pages_after_teardown, 0);
}

#[test]
fn fleet_lockstep_replay_hits_the_prefix_cache() {
    // Every fleet wave shares one page-aligned system prompt: all
    // followers must fork the cached prefix instead of re-prefilling it.
    let trace = by_name("fleet").unwrap().generate(&small_cfg("zeta", 12, 128)).unwrap();
    let out = lockstep(&trace, &ReplayCfg { threads: 2, ..ReplayCfg::default() }).unwrap();
    assert!(
        out.counters.prefix_hits > 0,
        "shared-system-prompt fleet must hit the prefix cache: {:?}",
        out.counters
    );
    let s = score(&trace, &out);
    assert_eq!(s.expect_ok, s.expect_total);
}

#[test]
fn serve_replay_of_needle_matches_recorded_streams() {
    // Through the real coordinator (threads = 2): scheduling is racy but
    // streams are pinned scheduling-invariant by the fused-sweep gates,
    // so every completed stream must equal its recorded reference.
    let trace = by_name("needle").unwrap().generate(&small_cfg("zeta", 6, 96)).unwrap();
    let out = serve(&trace, &ReplayCfg { threads: 2, ..ReplayCfg::default() }).unwrap();
    for s in &out.streams {
        assert!(s.done && !s.cancelled, "{}: did not complete", s.id);
    }
    let sc = score(&trace, &out);
    assert_eq!(
        sc.expect_ok, sc.expect_total,
        "serve replay must reproduce the recorded streams exactly"
    );
    assert!(out.counters.balanced());
    assert_eq!(out.live_pages_after_teardown, 0, "arena must drain after shutdown");
}

#[test]
fn cancellation_storm_drains_cleanly_at_threads_2_and_8() {
    // 60 x STORM_SCALE(4) = 240 requests, two thirds carrying a cancel
    // point: a storm of dropped GenStreams mid-prefill and mid-decode.
    let trace = by_name("storm").unwrap().generate(&small_cfg("zeta", 60, 96)).unwrap();
    assert!(trace.requests.len() >= 200, "storm must be hundreds of requests");
    for threads in [2usize, 8] {
        let out = serve(&trace, &ReplayCfg { threads, ..ReplayCfg::default() }).unwrap();
        assert_eq!(out.streams.len(), trace.requests.len());
        // Every request resolved: a finished stream or a dropped one.
        for (r, s) in trace.requests.iter().zip(&out.streams) {
            assert!(
                s.done || s.cancelled,
                "storm request {:?} neither finished nor cancelled at {threads} threads",
                r.id
            );
        }
        let cancelled = out.streams.iter().filter(|s| s.cancelled).count();
        assert!(cancelled > 0, "a storm replay must actually cancel streams");
        // The conservation law is the point of the storm: every stepped
        // token was either delivered or counted dropped, even with
        // hundreds of receivers vanishing mid-flight.
        assert!(
            out.counters.balanced(),
            "token accounting unbalanced at {threads} threads: {:?}",
            out.counters
        );
        assert_eq!(
            out.live_pages_after_teardown, 0,
            "storm leaked arena pages at {threads} threads"
        );
        // Cancelled streams must still be exact prefixes of their
        // references (score() checks prefix for cancelled-with-expect).
        let sc = score(&trace, &out);
        assert_eq!(
            sc.expect_ok, sc.expect_total,
            "storm streams (incl. cancelled prefixes) diverged at {threads} threads"
        );
    }
}

#[test]
fn lockstep_storm_is_deterministic_including_cancellations() {
    // In lockstep the virtual clock makes even the cancellation points
    // deterministic: two replays at different pool sizes must agree on
    // *which* requests were cancelled and on every delivered token.
    let trace = by_name("storm").unwrap().generate(&small_cfg("zeta", 12, 96)).unwrap();
    let a = lockstep(&trace, &ReplayCfg { threads: 1, ..ReplayCfg::default() }).unwrap();
    let b = lockstep(&trace, &ReplayCfg { threads: 8, ..ReplayCfg::default() }).unwrap();
    assert_eq!(a.streams, b.streams);
    assert_eq!(a.counters, b.counters);
    let cancelled = a.streams.iter().filter(|s| s.cancelled).count();
    let done = a.streams.iter().filter(|s| s.done).count();
    assert!(cancelled > 0 && done > 0, "storm must mix cancelled and completed requests");
    let s = score(&trace, &a);
    assert_eq!(s.expect_ok, s.expect_total);
}
