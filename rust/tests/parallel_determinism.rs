//! Determinism gate for the pool refactor (tier-1):
//!
//! 1. `flash` forward must match `naive` forward within 1e-4 on random
//!    workloads (exact-softmax cross-kernel agreement).
//! 2. Every kernel's parallel output must match its serial (threads=1)
//!    output within tolerance across the full thread matrix
//!    {2, 4, 8} — including oversubscribed sizes multiplexed over the
//!    resident team — forward and forward+backward.
//! 3. The batched multi-head path must agree with the per-head loop, and
//!    `MemReport` must stay measured (non-zero workspace) under the pool.
//! 4. The `shared_sort` ZETA serving path and the fused `step_batch` sweep
//!    are deterministic across the same thread matrix (`step_batch`
//!    bit-for-bit — slot arithmetic is slot-local, so pool size can never
//!    perturb a stream).

use zeta::attention::{all_impls, AttentionImpl, DecodeStep, MultiWorkload, Workload};
use zeta::util::pool::Pool;

const TOL: f32 = 1e-4;

/// The in-process pool sizes every matrix test sweeps (the `ZETA_THREADS`
/// values CI exercises process-wide, plus the serial reference).
const THREAD_MATRIX: [usize; 3] = [2, 4, 8];

#[test]
fn flash_forward_matches_naive_on_random_workloads() {
    use zeta::attention::{flash::Flash, naive::Naive};
    for (seed, &n) in [33usize, 96, 257].iter().enumerate() {
        let w = Workload::random(n, 24, 12, 100 + seed as u64);
        let (of, _) = Flash { block: 48 }.forward(&w);
        let (on, _) = Naive.forward(&w);
        assert!(
            of.max_abs_diff(&on) < TOL,
            "flash vs naive diverged at n={n}: {}",
            of.max_abs_diff(&on)
        );
    }
}

#[test]
fn every_kernel_parallel_forward_matches_serial() {
    let serial = Pool::serial();
    let w = Workload::random(384, 32, 16, 7);
    for imp in all_impls() {
        let (os, ms) = imp.forward_with(&w, &serial);
        assert!(ms.output_bytes > 0, "{}", imp.name());
        for threads in THREAD_MATRIX {
            let par = Pool::new(threads);
            let (op, mp) = imp.forward_with(&w, &par);
            assert!(
                os.max_abs_diff(&op) < TOL,
                "{} threads={threads}: parallel forward diverged: {}",
                imp.name(),
                os.max_abs_diff(&op)
            );
            // MemReport stays measured (not modeled) under the pool.
            assert!(mp.output_bytes > 0, "{}", imp.name());
            assert!(
                mp.workspace_bytes > 0,
                "{} threads={threads}: parallel run reported no measured workspace",
                imp.name()
            );
        }
    }
}

#[test]
fn every_kernel_parallel_backward_matches_serial() {
    let serial = Pool::serial();
    let w = Workload::random(256, 16, 8, 21);
    for imp in all_impls() {
        let (gs, _) = imp.forward_backward_with(&w, &serial);
        for threads in THREAD_MATRIX {
            let par = Pool::new(threads);
            let (gp, _) = imp.forward_backward_with(&w, &par);
            for (name, a, b) in [
                ("dq", &gs.dq, &gp.dq),
                ("dk", &gs.dk, &gp.dk),
                ("dv", &gs.dv, &gp.dv),
            ] {
                assert!(
                    a.max_abs_diff(b) < TOL,
                    "{} {name} threads={threads}: parallel backward diverged: {}",
                    imp.name(),
                    a.max_abs_diff(b)
                );
            }
        }
    }
}

#[test]
fn zeta_shared_sort_deterministic_across_thread_matrix() {
    use zeta::attention::zeta::ZetaNative;
    // The shared-sort serving path (one key sort serving every head of a
    // sequence) must be thread-count invariant like every other kernel
    // path: the sorted index is built sequentially per sequence, so only
    // the search/score fan-out varies with pool size.
    let z = ZetaNative { chunk: 16, shared_sort: true, ..ZetaNative::default() };
    let mw = MultiWorkload::random(2, 3, 96, 16, 8, 17);
    let (oref, _) = z.forward_batch(&mw, &Pool::serial());
    for threads in THREAD_MATRIX {
        let pool = Pool::new(threads);
        let (o, _) = z.forward_batch(&mw, &pool);
        assert!(
            oref.max_abs_diff(&o) < TOL,
            "shared_sort threads={threads}: diverged from serial by {}",
            oref.max_abs_diff(&o)
        );
    }
}

#[test]
fn step_batch_bitwise_identical_across_thread_matrix() {
    // Fused cross-stream sweeps advance each slot with slot-local serial
    // arithmetic, so every pool size — below and above the fan-out
    // break-even — must produce bit-identical streams. 24 streams push the
    // sweep's estimated work across PARALLEL_STEP_MIN_OPS partway through,
    // covering the inline path, the fan-out path and the boundary itself.
    let (d, dv) = (16usize, 8usize);
    let streams = 24usize;
    let steps = 64usize;
    for imp in all_impls() {
        let ws: Vec<Workload> =
            (0..streams).map(|s| Workload::random(steps, d, dv, 900 + s as u64)).collect();
        let mut reference: Vec<Vec<f32>> = Vec::new();
        for threads in [1usize, 2, 8] {
            let pool = Pool::new(threads);
            let mut states: Vec<_> = (0..streams).map(|_| imp.begin_decode(d, dv)).collect();
            let mut outs = vec![0f32; streams * dv];
            for step in 0..steps {
                {
                    let mut batch: Vec<DecodeStep> = states
                        .iter_mut()
                        .zip(outs.chunks_mut(dv))
                        .enumerate()
                        .map(|(s, (st, orow))| DecodeStep {
                            state: st.as_mut(),
                            q: ws[s].q.row(step),
                            k: ws[s].k.row(step),
                            v: ws[s].v.row(step),
                            out: orow,
                        })
                        .collect();
                    imp.step_batch(&mut batch, &pool);
                }
                if threads == 1 {
                    reference.push(outs.clone());
                } else {
                    assert_eq!(
                        outs,
                        reference[step],
                        "{} threads={threads} step {step}: fused sweep not bit-equal",
                        imp.name()
                    );
                }
            }
        }
    }
}

#[test]
fn batched_multihead_matches_per_head_loop() {
    let pool = Pool::new(4);
    let mw = MultiWorkload::random(2, 3, 64, 16, 8, 5);
    let n = mw.seq_len();
    let dv = mw.v.shape[1];
    for imp in all_impls() {
        let (o, mem) = imp.forward_batch(&mw, &pool);
        assert_eq!(o.shape, vec![mw.num_problems() * n, dv], "{}", imp.name());
        assert!(mem.workspace_bytes > 0, "{}", imp.name());
        for idx in 0..mw.num_problems() {
            let (oh, _) = imp.forward_with(&mw.problem(idx), &pool);
            let got = &o.data[idx * n * dv..(idx + 1) * n * dv];
            let maxdiff = got
                .iter()
                .zip(&oh.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(maxdiff < TOL, "{} head {idx}: {maxdiff}", imp.name());
        }
    }
}

#[test]
fn batched_multihead_backward_shapes() {
    let pool = Pool::new(2);
    let mw = MultiWorkload::random(1, 4, 32, 8, 8, 9);
    for imp in all_impls() {
        let (g, mem) = imp.forward_backward_batch(&mw, &pool);
        assert_eq!(g.dq.shape, vec![4 * 32, 8], "{}", imp.name());
        assert_eq!(g.dk.shape, vec![4 * 32, 8], "{}", imp.name());
        assert_eq!(g.dv.shape, vec![4 * 32, 8], "{}", imp.name());
        assert!(g.dv.data.iter().all(|v| v.is_finite()), "{}", imp.name());
        assert!(mem.output_bytes > 0, "{}", imp.name());
    }
}
