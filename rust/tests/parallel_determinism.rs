//! Determinism gate for the pool refactor (tier-1):
//!
//! 1. `flash` forward must match `naive` forward within 1e-4 on random
//!    workloads (exact-softmax cross-kernel agreement).
//! 2. Every kernel's parallel (threads=4) output must match its serial
//!    (threads=1) output within tolerance, forward and forward+backward.
//! 3. The batched multi-head path must agree with the per-head loop, and
//!    `MemReport` must stay measured (non-zero workspace) under the pool.

use zeta::attention::{all_impls, AttentionImpl, MultiWorkload, Workload};
use zeta::util::pool::Pool;

const TOL: f32 = 1e-4;

#[test]
fn flash_forward_matches_naive_on_random_workloads() {
    use zeta::attention::{flash::Flash, naive::Naive};
    for (seed, &n) in [33usize, 96, 257].iter().enumerate() {
        let w = Workload::random(n, 24, 12, 100 + seed as u64);
        let (of, _) = Flash { block: 48 }.forward(&w);
        let (on, _) = Naive.forward(&w);
        assert!(
            of.max_abs_diff(&on) < TOL,
            "flash vs naive diverged at n={n}: {}",
            of.max_abs_diff(&on)
        );
    }
}

#[test]
fn every_kernel_parallel_forward_matches_serial() {
    let serial = Pool::serial();
    let par = Pool::new(4);
    let w = Workload::random(384, 32, 16, 7);
    for imp in all_impls() {
        let (os, ms) = imp.forward_with(&w, &serial);
        let (op, mp) = imp.forward_with(&w, &par);
        assert!(
            os.max_abs_diff(&op) < TOL,
            "{}: parallel forward diverged: {}",
            imp.name(),
            os.max_abs_diff(&op)
        );
        // MemReport stays measured (not modeled) under the pool.
        assert!(ms.output_bytes > 0 && mp.output_bytes > 0, "{}", imp.name());
        assert!(
            mp.workspace_bytes > 0,
            "{}: parallel run reported no measured workspace",
            imp.name()
        );
    }
}

#[test]
fn every_kernel_parallel_backward_matches_serial() {
    let serial = Pool::serial();
    let par = Pool::new(4);
    let w = Workload::random(256, 16, 8, 21);
    for imp in all_impls() {
        let (gs, _) = imp.forward_backward_with(&w, &serial);
        let (gp, _) = imp.forward_backward_with(&w, &par);
        for (name, a, b) in [
            ("dq", &gs.dq, &gp.dq),
            ("dk", &gs.dk, &gp.dk),
            ("dv", &gs.dv, &gp.dv),
        ] {
            assert!(
                a.max_abs_diff(b) < TOL,
                "{} {name}: parallel backward diverged: {}",
                imp.name(),
                a.max_abs_diff(b)
            );
        }
    }
}

#[test]
fn batched_multihead_matches_per_head_loop() {
    let pool = Pool::new(4);
    let mw = MultiWorkload::random(2, 3, 64, 16, 8, 5);
    let n = mw.seq_len();
    let dv = mw.v.shape[1];
    for imp in all_impls() {
        let (o, mem) = imp.forward_batch(&mw, &pool);
        assert_eq!(o.shape, vec![mw.num_problems() * n, dv], "{}", imp.name());
        assert!(mem.workspace_bytes > 0, "{}", imp.name());
        for idx in 0..mw.num_problems() {
            let (oh, _) = imp.forward_with(&mw.problem(idx), &pool);
            let got = &o.data[idx * n * dv..(idx + 1) * n * dv];
            let maxdiff = got
                .iter()
                .zip(&oh.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(maxdiff < TOL, "{} head {idx}: {maxdiff}", imp.name());
        }
    }
}

#[test]
fn batched_multihead_backward_shapes() {
    let pool = Pool::new(2);
    let mw = MultiWorkload::random(1, 4, 32, 8, 8, 9);
    for imp in all_impls() {
        let (g, mem) = imp.forward_backward_batch(&mw, &pool);
        assert_eq!(g.dq.shape, vec![4 * 32, 8], "{}", imp.name());
        assert_eq!(g.dk.shape, vec![4 * 32, 8], "{}", imp.name());
        assert_eq!(g.dv.shape, vec![4 * 32, 8], "{}", imp.name());
        assert!(g.dv.data.iter().all(|v| v.is_finite()), "{}", imp.name());
        assert!(mem.output_bytes > 0, "{}", imp.name());
    }
}
