//! Concurrency stress gate for the resident parked worker pool (tier-1),
//! the substrate-level companion of `parallel_determinism.rs`:
//!
//! 1. Concurrent regions submitted from many OS threads at once — the
//!    serving shape: scheduler sweeps, client threads and test harness
//!    threads all racing regions through one shared team — must each see
//!    exactly-once chunk coverage.
//! 2. Nested region submission (a worker submitting from inside a region)
//!    must run inline on the submitting worker's thread, never deadlock
//!    the submission gate.
//! 3. Degenerate regions — zero work, a single chunk, grain ≫ n — take the
//!    inline path and still cover every index.
//! 4. Oversubscription (`threads ≫ cores`): logical worker ids multiplex
//!    over the capped resident team; coverage and worker-id order hold.
//! 5. A worker panic propagates to the submitting thread with its original
//!    payload, without deadlocking concurrent submitters or poisoning the
//!    team for the next region.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use zeta::util::pool::{ChunkQueue, Pool};

#[test]
fn concurrent_regions_from_many_os_threads() {
    let submitters = 8usize;
    let regions = 32usize;
    let n = 501usize;
    let total = Arc::new(AtomicUsize::new(0));
    let mut joins = Vec::new();
    for s in 0..submitters {
        let total = Arc::clone(&total);
        joins.push(std::thread::spawn(move || {
            // Mixed pool sizes: policies differ, the resident team is one.
            let pool = Pool::new(2 + (s % 3));
            for _ in 0..regions {
                let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                pool.parallel_for(n, 16, |r| {
                    for i in r {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
                assert!(
                    hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                    "submitter {s}: some index not covered exactly once"
                );
                total.fetch_add(n, Ordering::Relaxed);
            }
        }));
    }
    for j in joins {
        j.join().expect("submitter thread panicked");
    }
    assert_eq!(total.load(Ordering::Relaxed), submitters * regions * n);
}

#[test]
fn nested_region_submission_does_not_deadlock() {
    let pool = Pool::new(4);
    // Outer region fans out; every worker submits inner regions, which run
    // inline on that worker (the gate is never re-entered).
    let results = pool.run_workers(4, |w| {
        let me = std::thread::current().id();
        let inner_ids = pool.run_workers(3, |i| (i + w, std::thread::current().id()));
        assert!(
            inner_ids.iter().all(|(_, tid)| *tid == me),
            "nested region escaped the submitting worker's thread"
        );
        let inner: usize = inner_ids.iter().map(|(v, _)| v).sum();
        // Two levels deeper, through the chunked path.
        let sums: Vec<usize> = pool.run_chunked(10, 3, |q| {
            let mut s = 0usize;
            while let Some(r) = q.next_chunk() {
                s += r.sum::<usize>();
            }
            s
        });
        inner + sums.iter().sum::<usize>()
    });
    // inner = (0+w) + (1+w) + (2+w) = 3w + 3; chunked sum = 0+..+9 = 45.
    assert_eq!(results, vec![48, 51, 54, 57]);
}

#[test]
fn zero_work_single_chunk_and_oversized_grain_regions() {
    let pool = Pool::new(4);
    // Zero work: the closure must never run.
    pool.parallel_for(0, 8, |_r| panic!("zero-work region ran its closure"));
    assert_eq!(pool.parallel_for_stats(0, 8, |_r, _st| {}), 0);
    // Single index with a giant grain: one chunk, inline.
    let hits = AtomicUsize::new(0);
    pool.parallel_for(1, 1024, |r| {
        hits.fetch_add(r.len(), Ordering::Relaxed);
    });
    assert_eq!(hits.load(Ordering::Relaxed), 1);
    // n smaller than one grain through the chunked path.
    let parts: Vec<usize> = pool.run_chunked(7, 100, |q| {
        let mut s = 0usize;
        while let Some(r) = q.next_chunk() {
            s += r.len();
        }
        s
    });
    assert_eq!(parts.iter().sum::<usize>(), 7);
}

#[test]
fn oversubscribed_pool_covers_every_index_exactly_once() {
    // threads ≫ cores: the resident team is capped, so logical worker ids
    // multiplex over fewer OS threads — coverage must be unaffected.
    let pool = Pool::new(256);
    let n = 10_000usize;
    let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
    pool.parallel_for(n, 7, |r| {
        for i in r {
            hits[i].fetch_add(1, Ordering::Relaxed);
        }
    });
    assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    // Results still arrive in worker-id order under multiplexing.
    let ids = pool.run_workers(200, |w| w);
    assert_eq!(ids, (0..200).collect::<Vec<_>>());
}

#[test]
fn worker_panic_propagates_and_pool_stays_usable() {
    let pool = Pool::new(4);
    for round in 0..3 {
        let err = catch_unwind(AssertUnwindSafe(|| {
            pool.run_workers(4, |w| {
                if w == 2 {
                    panic!("boom {round}");
                }
                w
            })
        }))
        .expect_err("worker panic must reach the submitting thread");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("boom"), "panic payload lost: {msg:?}");
        // The team is not poisoned: the next region runs clean.
        let hits = AtomicUsize::new(0);
        pool.parallel_for(100, 4, |r| {
            hits.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
    }
}

#[test]
fn panics_under_concurrent_submission_neither_deadlock_nor_leak() {
    let joins: Vec<_> = (0..4usize)
        .map(|s| {
            std::thread::spawn(move || {
                let pool = Pool::new(3);
                for i in 0..12 {
                    if (s + i) % 3 == 0 {
                        let r = catch_unwind(AssertUnwindSafe(|| {
                            pool.parallel_for(64, 4, |r| {
                                if r.start == 32 {
                                    panic!("chunk boom");
                                }
                            })
                        }));
                        assert!(r.is_err(), "panic in a chunk must propagate");
                    } else {
                        let hits = AtomicUsize::new(0);
                        pool.parallel_for(64, 4, |r| {
                            hits.fetch_add(r.len(), Ordering::Relaxed);
                        });
                        assert_eq!(hits.load(Ordering::Relaxed), 64);
                    }
                }
            })
        })
        .collect();
    for j in joins {
        j.join().expect("submitter thread panicked");
    }
}

#[test]
fn chunk_queue_repolling_with_huge_grain_never_reissues() {
    // The old `fetch_add` cursor wrapped `usize` under repeated post-
    // exhaustion polling with huge grains and re-issued claimed chunks.
    let q = ChunkQueue::new(3, usize::MAX / 2);
    assert_eq!(q.next_chunk(), Some(0..3));
    for _ in 0..100 {
        assert!(q.next_chunk().is_none(), "exhausted queue re-issued a chunk");
    }
    // Concurrent post-exhaustion polling stays exhausted too.
    let q = Arc::new(ChunkQueue::new(5, usize::MAX));
    assert_eq!(q.next_chunk(), Some(0..5));
    let joins: Vec<_> = (0..4)
        .map(|_| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || (0..1000).all(|_| q.next_chunk().is_none()))
        })
        .collect();
    for j in joins {
        assert!(j.join().unwrap());
    }
}

#[test]
fn results_and_stats_are_consistent_under_contention() {
    // parallel_for_stats must sum per-worker stats exactly even while other
    // threads churn regions through the same team.
    let stop = Arc::new(AtomicUsize::new(0));
    let bg = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let pool = Pool::new(2);
            while stop.load(Ordering::Relaxed) == 0 {
                pool.parallel_for(64, 8, |r| {
                    std::hint::black_box(r.len());
                });
            }
        })
    };
    let pool = Pool::new(4);
    for _ in 0..50 {
        let total = pool.parallel_for_stats(321, 10, |r, st| {
            st.workspace_bytes += r.len();
        });
        assert_eq!(total, 321);
    }
    stop.store(1, Ordering::Relaxed);
    bg.join().unwrap();
}
